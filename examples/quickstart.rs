//! Quickstart: the BLaST pipeline in one page.
//!
//! 1. prune a weight matrix with blocked prune-and-grow,
//! 2. multiply with the BSpMM kernel (vs the dense baseline),
//! 3. run a block-sparse model end to end through the native engine.
//!
//! Run: `cargo run --release --example quickstart`

use std::collections::BTreeMap;

use blast::kernels::bspmm::{bspmm, bspmm_flops};
use blast::kernels::gemm::{gemm, gemm_flops};
use blast::model::config::{ModelKind, NativeConfig};
use blast::model::engine::{Engine, MlpMode};
use blast::model::params::ParamStore;
use blast::sparse::Bcsc;
use blast::sparsify::prune::generate_mask;
use blast::sparsify::SparsitySchedule;
use blast::tensor::Tensor;
use blast::testkit::bench::{bench_quick, black_box, fmt_flops, fmt_time};
use blast::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // --- 1. blocked prune-and-grow on one weight matrix -------------------
    let (k, n, b) = (512, 2048, 64);
    let w = Tensor::randn(&[k, n], 0.02, &mut rng);
    let g = Tensor::randn(&[k, n], 0.01, &mut rng); // a gradient snapshot
    let schedule = SparsitySchedule::new(0.0, 0.9, 100, 0);
    let s_target = schedule.sparsity_at(80); // late in training
    let (mask, regrown, stats) = generate_mask(&w, &g, b, s_target);
    println!(
        "prune-and-grow: target s={s_target:.2} → kept {} blocks ({} regrown from gradients), realized s={:.2}",
        mask.nnzb(),
        regrown.nnzb(),
        stats.realized_sparsity
    );

    // --- 2. BSpMM vs dense GEMM -------------------------------------------
    let x = Tensor::randn(&[256, k], 1.0, &mut rng);
    let sparse_w = Bcsc::from_dense(&w, &mask, b);
    let m_dense = bench_quick("gemm", || {
        black_box(gemm(&x, &w));
    });
    let m_sparse = bench_quick("bspmm", || {
        black_box(bspmm(&x, &sparse_w));
    });
    println!(
        "dense GEMM : {} ({})",
        fmt_time(m_dense.secs()),
        fmt_flops(m_dense.flops(gemm_flops(256, k, n)))
    );
    println!(
        "BSpMM      : {} ({} effective) → {:.2}x speedup at {:.0}% sparsity",
        fmt_time(m_sparse.secs()),
        fmt_flops(m_sparse.flops(bspmm_flops(256, &sparse_w))),
        m_dense.secs() / m_sparse.secs(),
        sparse_w.sparsity() * 100.0
    );

    // --- 3. a block-sparse Llama-style model, end to end ------------------
    let cfg = NativeConfig {
        name: "quickstart".into(),
        kind: ModelKind::Llama,
        vocab: 256,
        emb: 128,
        ffn: 512,
        layers: 2,
        heads: 4,
        max_seq: 64,
        block: 32,
    };
    let params = ParamStore::init_native(&cfg, 7);
    let mut masks = BTreeMap::new();
    let mut mrng = Rng::new(8);
    for i in 0..cfg.layers {
        for (nm, r, c) in cfg.mlp_shapes() {
            masks.insert(
                format!("layer{i}.{nm}"),
                blast::sparse::BlockMask::random(r / cfg.block, c / cfg.block, 0.8, &mut mrng),
            );
        }
    }
    let dense_bytes: usize = cfg
        .mlp_shapes()
        .iter()
        .map(|(_, r, c)| r * c * 4)
        .sum::<usize>()
        * cfg.layers;
    let engine = Engine::new(cfg, &params, &masks, MlpMode::Sparse)?;
    let mut cache = engine.new_cache();
    let logits = engine.prefill(&[1, 2, 3, 4], &mut cache)?;
    let mut tok = Engine::argmax(&logits);
    print!("generated:");
    for _ in 0..12 {
        print!(" {tok}");
        let logits = engine.decode(tok, &mut cache)?;
        tok = Engine::argmax(&logits);
    }
    println!(
        "\nsparse MLP weights resident: {} KiB (dense would be {} KiB)",
        engine.mlp_weight_bytes() / 1024,
        dense_bytes / 1024,
    );
    println!("\nquickstart OK — see `blast exp` and the other examples for the full tour");
    Ok(())
}
