//! Vision example (paper §5.3.3, Table 3 / Fig. 9): train the ViT twin on
//! the synthetic CIFAR-like dataset while sparsifying its MLP blocks, and
//! report accuracy + the FLOP savings of the schedule.
//!
//! Run (artifacts required):
//!   cargo run --release --example vit_cifar -- [--steps 120] [--smax 0.9]

use anyhow::Result;

use blast::data::cifar::CifarSim;
use blast::model::config::{ModelKind, NativeConfig};
use blast::perf::flops;
use blast::runtime::Runtime;
use blast::sparsify::SparsitySchedule;
use blast::train::classify::{ClassifyTrainer, ClsBatch};
use blast::train::pretrain::PretrainOptions;
use blast::util::cli::Args;

fn main() -> Result<()> {
    blast::util::logging::init();
    let args = Args::parse();
    let steps = args.get_usize("steps", 120);
    let smax = args.get_f64("smax", 0.9);
    let noise = args.get_f64("noise", 1.2) as f32;
    let rt = Runtime::open_default()?;
    let cfg = rt.manifest().config("vit-sim")?.clone();

    let opts = PretrainOptions {
        total_iters: steps,
        s_max: smax,
        step_size: 5,
        seed: 0xC1FA,
        ..Default::default()
    };
    let mut t = ClassifyTrainer::new(&rt, "vit-sim", &opts)?;
    let mut gen = CifarSim::new(0xC1FA, noise);
    let eval: Vec<ClsBatch> = CifarSim::eval_set(0xC1FA, noise, 8, cfg.batch)
        .into_iter()
        .map(|b| ClsBatch {
            features: b.patches,
            labels: b.labels,
        })
        .collect();

    for i in 0..steps {
        let b = gen.batch(cfg.batch);
        t.train_iteration(
            i,
            &ClsBatch {
                features: b.patches,
                labels: b.labels,
            },
        )?;
        if i % (steps / 8).max(1) == 0 {
            let acc = t.eval(&eval)?.accuracy;
            println!(
                "iter {i:4}  loss {:.4}  sparsity {:.2}  eval acc {:.1}%",
                t.log.last().unwrap().loss,
                t.mean_sparsity(),
                acc * 100.0
            );
        }
    }
    let final_acc = t.eval(&eval)?.accuracy;

    // FLOP accounting (Fig. 9's x-axis)
    let native = NativeConfig {
        name: cfg.name.clone(),
        kind: ModelKind::Vit,
        vocab: cfg.num_classes,
        emb: cfg.emb,
        ffn: cfg.ffn,
        layers: cfg.layers,
        heads: cfg.heads,
        max_seq: cfg.seq,
        block: cfg.block,
    };
    let tokens_per_iter = (cfg.batch * cfg.seq) as f64;
    let sched = SparsitySchedule::new(0.0, smax, steps, 0);
    let dense_sched = SparsitySchedule::new(0.0, 0.0, steps, 0);
    let fl_blast = flops::cumulative_train_flops(&native, cfg.seq, tokens_per_iter, &sched, steps);
    let fl_dense =
        flops::cumulative_train_flops(&native, cfg.seq, tokens_per_iter, &dense_sched, steps);
    println!(
        "\nfinal accuracy {:.1}% at {:.0}% MLP sparsity",
        final_acc * 100.0,
        t.mean_sparsity() * 100.0
    );
    println!(
        "training FLOPs: {:.2} GFLOP (dense would be {:.2} GFLOP) → {:.1}% saved (Fig. 9's effect)",
        fl_blast / 1e9,
        fl_dense / 1e9,
        (1.0 - fl_blast / fl_dense) * 100.0
    );
    Ok(())
}
