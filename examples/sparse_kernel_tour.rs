//! Standalone sparse-linear-algebra tour (paper §3.3: "BSpMM ... can also
//! be used independently as a stand-alone SpMM kernel, paving the road for
//! fast, sparse linear algebra kernels across various domains").
//!
//! Walks through the three formats (dense, BCSC, CSR) on a non-ML workload:
//! a 2-D 5-point Poisson stencil operator (naturally block-banded) and a
//! random block-sparse matrix, measuring the crossovers.
//!
//! Run: cargo run --release --example sparse_kernel_tour

use blast::kernels::bspmm::bspmm;
use blast::kernels::csr_spmm::csr_spmm;
use blast::kernels::gemm::gemm;
use blast::sparse::{Bcsc, BlockMask, Csr};
use blast::tensor::Tensor;
use blast::testkit::bench::{bench_quick, black_box, fmt_time, Table};
use blast::util::rng::Rng;

/// Block-banded operator: a blocked analogue of a 5-point stencil — block
/// diagonal + off-diagonals populated. Realistic "structured science"
/// sparsity the paper's standalone-kernel pitch targets.
fn stencil_mask(nb: usize, bandwidth: usize) -> BlockMask {
    let mut m = BlockMask::zeros(nb, nb);
    for i in 0..nb {
        for j in 0..nb {
            if i.abs_diff(j) <= bandwidth {
                m.set(i, j, true);
            }
        }
    }
    m
}

fn main() {
    let mut rng = Rng::new(1);
    let b = 64;
    let nb = 16; // 1024x1024 operator
    let k = nb * b;
    let x = Tensor::randn(&[128, k], 1.0, &mut rng);
    let dense_op = Tensor::randn(&[k, k], 1.0, &mut rng);

    let mut table = Table::new(
        "standalone SpMM tour — 1024x1024 operator, 128 rhs",
        &["operator", "sparsity", "format", "time", "vs dense"],
    );
    let t_dense = bench_quick("dense", || {
        black_box(gemm(&x, &dense_op));
    })
    .secs();
    table.row(&[
        "random dense".into(),
        "0%".into(),
        "GEMM".into(),
        fmt_time(t_dense),
        "1.00x".into(),
    ]);

    // block-banded stencil at growing bandwidth
    for bandwidth in [1usize, 2, 4] {
        let mask = stencil_mask(nb, bandwidth);
        let op = Bcsc::from_dense(&dense_op, &mask, b);
        let t = bench_quick("bcsc", || {
            black_box(bspmm(&x, &op));
        })
        .secs();
        table.row(&[
            format!("stencil bw={bandwidth}"),
            format!("{:.0}%", op.sparsity() * 100.0),
            "BCSC".into(),
            fmt_time(t),
            format!("{:.2}x", t_dense / t),
        ]);
    }

    // random block sparsity vs unstructured CSR at the same densities
    for s in [0.8, 0.95] {
        let mask = BlockMask::random(nb, nb, s, &mut rng);
        let op = Bcsc::from_dense(&dense_op, &mask, b);
        let t_b = bench_quick("bcsc", || {
            black_box(bspmm(&x, &op));
        })
        .secs();
        table.row(&[
            "random blocks".into(),
            format!("{:.0}%", s * 100.0),
            "BCSC".into(),
            fmt_time(t_b),
            format!("{:.2}x", t_dense / t_b),
        ]);
        let csr = Csr::random(k, k, s, &mut rng);
        let t_c = bench_quick("csr", || {
            black_box(csr_spmm(&x, &csr));
        })
        .secs();
        table.row(&[
            "random elements".into(),
            format!("{:.0}%", s * 100.0),
            "CSR".into(),
            fmt_time(t_c),
            format!("{:.2}x", t_dense / t_c),
        ]);
    }
    table.print();
    println!(
        "\ntakeaway (paper §1/§3.3): the same FLOP savings convert to wall-clock\n\
         only with block structure — CSR needs far higher sparsity to break even."
    );
}
