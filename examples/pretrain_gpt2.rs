//! End-to-end flagship driver (DESIGN.md deliverable (b) / the mandated
//! end-to-end validation): pretrain a GPT-2-style Transformer twin with
//! blocked prune-and-grow sparsification live during training, logging the
//! loss curve, the sparsity schedule, and final held-out perplexity vs a
//! dense control run. By default the whole step — forward, backward, Adam
//! — runs on the **native** packed block-sparse kernel stack (no
//! artifacts needed); `--backend aot` drives the AOT HLO `train_step`
//! through PJRT instead (requires `make artifacts` + `--features pjrt`).
//!
//! Run:
//!   cargo run --release --example pretrain_gpt2 -- \
//!       [--config e2e-small] [--steps 300] [--smax 0.8] [--dense-control] \
//!       [--backend native|aot]
//!
//! `--config e2e-small` is a ~29M-parameter 8-layer model (seq 256).
//! Default uses `gpt2s-sim` (4.2M) so the example finishes in minutes on
//! 1 CPU.

use anyhow::Result;

use blast::train::pretrain::{PretrainOptions, Trainer};
use blast::util::cli::Args;

fn main() -> Result<()> {
    blast::util::logging::init();
    let args = Args::parse();
    let config = args.get_str("config", "gpt2s-sim");
    let steps = args.get_usize("steps", 300);
    let backend = args.get_str("backend", "native");
    let rt = blast::train::pretrain::open_backend_runtime(&backend)?;
    let opts = PretrainOptions {
        total_iters: steps,
        s_max: args.get_f64("smax", 0.8),
        step_size: args.get_usize("step-size", 10),
        decay: args.get_usize("decay", steps / 2),
        dense_right: args.get_usize("dense-right", 1),
        block_mult: args.get_usize("block-mult", 1),
        ..Default::default()
    };
    println!(
        "pretraining {config} for {steps} steps (backend={backend}, s_max={}, step_size={}, d={}, L={})",
        opts.s_max, opts.step_size, opts.decay, opts.dense_right
    );

    let mut trainer = Trainer::from_backend(rt.as_ref(), &config, opts.clone())?;
    let t0 = std::time::Instant::now();
    let mut next_report = 0usize;
    for i in 0..steps {
        let loss = trainer.train_iteration(i)?;
        if i >= next_report {
            println!(
                "iter {i:5}  loss {loss:7.4}  s(i) {:.3}  mask-s {:.3}  {:5.0} ms/iter",
                trainer.controller().target_sparsity(i),
                trainer.controller().mean_sparsity(),
                trainer.log.last().unwrap().secs * 1e3,
            );
            next_report = i + (steps / 20).max(1);
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    let ppl = trainer.eval_perplexity(8)?;
    println!(
        "\nBLaST run: {train_secs:.1}s, final sparsity {:.2}, held-out perplexity {ppl:.3}",
        trainer.controller().mean_sparsity()
    );
    // loss curve summary (first/mid/last) — the EXPERIMENTS.md record
    let losses: Vec<f32> = trainer.log.iter().map(|l| l.loss).collect();
    println!(
        "loss curve: start {:.3} → 25% {:.3} → 50% {:.3} → 75% {:.3} → end {:.3}",
        losses[0],
        losses[losses.len() / 4],
        losses[losses.len() / 2],
        losses[3 * losses.len() / 4],
        losses[losses.len() - 1]
    );

    if let Some(path) = args.get("save") {
        trainer.params().save(std::path::Path::new(path))?;
        println!("checkpoint saved to {path}");
    }

    if args.get_bool("dense-control") {
        println!("\n--- dense control run ---");
        let dense_opts = PretrainOptions {
            s_max: 0.0,
            ..opts
        };
        let mut dense = Trainer::from_backend(rt.as_ref(), &config, dense_opts)?;
        let t1 = std::time::Instant::now();
        dense.run(steps)?;
        let dppl = dense.eval_perplexity(8)?;
        println!(
            "dense run: {:.1}s, perplexity {dppl:.3}  (BLaST gap: {:+.3})",
            t1.elapsed().as_secs_f64(),
            ppl - dppl
        );
    }
    Ok(())
}
