//! Fine-tuning / post-training-compression example (paper §5.2, Table 1):
//! take a dense "pretrained" checkpoint, sparsify it with blocked
//! prune-and-grow while fine-tuning, and compare the recovered accuracy to
//! the dense baseline on one GLUE-sim task.
//!
//! Run (artifacts required):
//!   cargo run --release --example finetune_glue -- \
//!       [--task sst2] [--smax 0.9] [--block-mult 1] [--steps 60]

use anyhow::Result;

use blast::data::glue::{GlueGen, GlueTask};
use blast::runtime::Runtime;
use blast::train::classify::{ClassifyTrainer, ClsBatch};
use blast::train::pretrain::PretrainOptions;
use blast::util::cli::Args;

fn to_cls(b: blast::data::glue::GlueBatch) -> ClsBatch {
    ClsBatch {
        features: b.features,
        labels: b.labels,
    }
}

fn main() -> Result<()> {
    blast::util::logging::init();
    let args = Args::parse();
    let task = match args.get_str("task", "sst2").as_str() {
        "cola" => GlueTask::CoLA,
        "mrpc" => GlueTask::Mrpc,
        "rte" => GlueTask::Rte,
        "wnli" => GlueTask::Wnli,
        _ => GlueTask::Sst2,
    };
    let steps = args.get_usize("steps", 60);
    let smax = args.get_f64("smax", 0.9);
    let mult = args.get_usize("block-mult", 1);
    let rt = Runtime::open_default()?;
    let cfg = rt.manifest().config("glue-sim")?.clone();
    let (seq, feat, batch) = (cfg.seq - 1, cfg.patch_dim, cfg.batch);
    let seed = 0xF1DE;

    println!("task {} (metric: {})", task.name(), task.metric());

    // --- 1. dense pretraining → the checkpoint --------------------------
    let dense_opts = PretrainOptions {
        total_iters: steps,
        s_max: 0.0,
        seed,
        ..Default::default()
    };
    let mut dense = ClassifyTrainer::new(&rt, "glue-sim", &dense_opts)?;
    let mut gen = GlueGen::new(task, seq, feat, seed);
    for i in 0..steps {
        dense.train_iteration(i, &to_cls(gen.batch(batch)))?;
    }
    let eval: Vec<ClsBatch> = GlueGen::eval_set(task, seq, feat, seed, 8, batch)
        .into_iter()
        .map(to_cls)
        .collect();
    let dense_scores = dense.eval(&eval)?;
    println!(
        "dense baseline: acc {:.1}%  mcc {:.3}  f1 {:.3}",
        dense_scores.accuracy * 100.0,
        dense_scores.matthews,
        dense_scores.f1
    );
    let ckpt = dense.params().clone();

    // --- 2. BLaST fine-tune: sparsify + recover --------------------------
    let ft_opts = PretrainOptions {
        total_iters: steps,
        s_max: smax,
        step_size: 5,
        seed,
        block_mult: mult,
        ..Default::default()
    };
    let mut ft = ClassifyTrainer::with_params(&rt, "glue-sim", &ft_opts, ckpt)?;
    for i in 0..steps {
        ft.train_iteration(i, &to_cls(gen.batch(batch)))?;
        if i % (steps / 6).max(1) == 0 {
            println!(
                "  ft iter {i:4}  loss {:.4}  sparsity {:.2}",
                ft.log.last().unwrap().loss,
                ft.mean_sparsity()
            );
        }
    }
    let ft_scores = ft.eval(&eval)?;
    println!(
        "BLaST {:.0}%/{}x{}: acc {:.1}%  mcc {:.3}  f1 {:.3}  (Δacc {:+.1} pts at {:.0}% sparsity)",
        smax * 100.0,
        cfg.block * mult,
        cfg.block * mult,
        ft_scores.accuracy * 100.0,
        ft_scores.matthews,
        ft_scores.f1,
        (ft_scores.accuracy - dense_scores.accuracy) * 100.0,
        ft.mean_sparsity() * 100.0
    );
    println!("\nTable 1's claim: this gap stays small across (s, b) — run `blast exp tab1` for the grid.");
    Ok(())
}
