//! Serving example: load a trained (or synthetic) block-sparse model into
//! the native engine and serve a batched request load through the
//! continuous-batching coordinator, comparing dense vs sparse MLP modes —
//! the Fig. 6 claim at the *service* level (latency + throughput).
//!
//! Run: cargo run --release --example serve_inference -- \
//!          [--sparsity 0.9] [--block 128] [--requests 16] [--max-batch 4]
//!          [--batched false]                      # sequential A/B baseline
//!          [--kv-page 64] [--kv-pool-pages 0]     # KV paging (0 = unbounded)
//!          [--prefix-cache false]                 # disable CoW prefix sharing
//!          [--attn-threshold 8.0]                 # BLASST dynamic attention sparsity
//!          [--replicas 3]                         # replicated fleet tier
//!          [--ckpt path.bin --config llama-sim]   # serve trained weights
//!
//! Batched decode rounds (one `(B × d_model)` GEMM/BSpMM per projection via
//! `Engine::decode_batch`) are **on by default**; `--batched false` serves
//! the same load through per-session GEMV chains — greedy tokens are
//! bit-identical, only the throughput differs.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use blast::coordinator::{BatcherConfig, Coordinator, Fleet, FleetConfig, Request};
use blast::eval::kernel_exps::{fig6_config, fig6_params, random_masks};
use blast::model::config::NativeConfig;
use blast::model::engine::{AttnOptions, Engine, MlpMode};
use blast::model::kv::{KvOptions, DEFAULT_KV_PAGE};
use blast::model::params::ParamStore;
use blast::runtime::Runtime;
use blast::util::cli::Args;

fn main() -> Result<()> {
    blast::util::logging::init();
    let args = Args::parse();
    // `--no-simd` pins the scalar kernel arm (same as BLAST_SIMD=off)
    blast::kernels::simd::set_simd_enabled(!args.get_bool("no-simd"));
    println!("kernel isa: {}", blast::kernels::simd::dispatch().isa.name());
    let sparsity = args.get_f64("sparsity", 0.9);
    let block = args.get_usize("block", 128);
    let n_requests = args.get_usize("requests", 16);
    let max_new = args.get_usize("max-new", 12);
    let batched = args.get_bool_or("batched", true);
    let kv = KvOptions {
        page: args.get_usize("kv-page", DEFAULT_KV_PAGE),
        // 0 = unbounded pool (no admission gating on KV memory)
        pool_pages: match args.get_usize("kv-pool-pages", 0) {
            0 => None,
            n => Some(n),
        },
        // default on; off restores the unshared pool byte-for-byte
        prefix_cache: args.get_bool_or("prefix-cache", true),
    };
    // BLASST dynamic attention sparsity: omitted = exact attention
    // (bit-identical to previous releases); NaN/negative τ panics here
    // and the engine rejects it again at build time
    let attn = AttnOptions { threshold: args.get_threshold("attn-threshold") };
    if let Some(tau) = attn.threshold {
        println!("attn threshold: tau={tau} (skipped-tile counters appear in the summaries)");
    }

    // weights: either a checkpoint trained by examples/pretrain_gpt2 /
    // `blast train --save`, or a synthetic model
    let (cfg, params) = match args.get("ckpt") {
        Some(path) => {
            let rt = Runtime::open_default()?;
            let config = args.get_str("config", "llama-sim");
            let c = NativeConfig::from_manifest(rt.manifest().config(&config)?);
            (c, ParamStore::load(std::path::Path::new(path))?)
        }
        None => {
            let c = fig6_config(block);
            let p = fig6_params(&c, 42);
            (c, p)
        }
    };
    let masks = random_masks(&cfg, sparsity, 77);

    // `--replicas R` (R > 1) serves each mode through the replicated fleet
    // tier instead of a single coordinator — same tokens, plus placement
    // spread, supervision and zero-downtime restarts
    let replicas = args.get_usize("replicas", 1);
    for mode in [MlpMode::Dense, MlpMode::Sparse] {
        let engine =
            Arc::new(Engine::new_with_opts(cfg.clone(), &params, &masks, mode, kv, attn)?);
        println!(
            "\n=== mode {mode:?} ({}, kv-page {}, replicas {}) — MLP bytes resident {} KiB ===",
            if batched { "batched rounds" } else { "sequential rounds" },
            engine.kv_page(),
            replicas.max(1),
            engine.mlp_weight_bytes() / 1024
        );
        let bcfg = BatcherConfig {
            max_batch: args.get_usize("max-batch", 4),
            max_queue: 64,
            batched,
            ..BatcherConfig::default()
        };
        let mut coord = None;
        let mut fleet = None;
        if replicas > 1 {
            fleet = Some(Fleet::start(
                &engine,
                FleetConfig { replicas, batcher: bcfg, ..FleetConfig::default() },
            ));
        } else {
            coord = Some(Coordinator::start(engine, bcfg));
        }
        let submit = |req: Request| match (&coord, &fleet) {
            (Some(c), _) => c.submit(req),
            (_, Some(f)) => f.submit(req),
            _ => unreachable!(),
        };
        let t0 = std::time::Instant::now();
        for i in 0..n_requests {
            submit(Request {
                id: i as u64,
                prompt: (0..8 + i % 8)
                    .map(|j| ((i * 131 + j * 17) % cfg.vocab) as u32)
                    .collect(),
                max_new,
                eos: None,
                ..Default::default()
            })?;
        }
        for _ in 0..n_requests {
            let c = match (&coord, &fleet) {
                (Some(c), _) => c.next_completion(Duration::from_secs(300)),
                (_, Some(f)) => f.next_completion(Duration::from_secs(300)),
                _ => unreachable!(),
            }
            .ready()
            .expect("completion");
            if let Some(e) = c.error {
                println!("request {} error: {e}", c.id);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        match (&mut coord, &mut fleet) {
            (Some(c), _) => {
                println!("{}", c.metrics_summary());
                c.stop();
            }
            (_, Some(f)) => {
                println!("{}", f.metrics_summary());
                f.stop();
            }
            _ => unreachable!(),
        }
        println!(
            "wall {wall:.2}s → {:.1} generated tokens/s",
            (n_requests * max_new) as f64 / wall
        );
    }
    println!("\ncompare the two blocks above: the sparse engine serves the same greedy tokens faster.");
    Ok(())
}
