//! Bench: regenerate Fig. 5 (Llama-family fused sparse MLP speedup).
//! `cargo bench --bench fig5_mlp_llama [-- --quick]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::eval::kernel_exps::fig5(&args).unwrap();
}
