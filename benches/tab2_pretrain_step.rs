//! Bench: Table 2's wall-clock axis — time per training iteration at each
//! Table 2 configuration, without the full-run perplexity (use `blast exp
//! tab2` for the complete table). Runs the native train step by default;
//! `-- --backend aot` drives the AOT executables instead.
//! `cargo bench --bench tab2_pretrain_step [-- --steps 12 --backend native|aot]`
use blast::testkit::bench::Table;
use blast::train::pretrain::{PretrainOptions, Trainer};
use blast::util::cli::Args;
use blast::util::stats;

fn main() {
    let args = Args::parse();
    let steps = args.get_usize("steps", 12);
    let rt = blast::train::pretrain::open_backend_runtime(&args.get_str("backend", "native"))
        .expect("aot backend needs `make artifacts` + --features pjrt");
    println!("backend: {}", if rt.is_some() { "aot" } else { "native" });
    let mut table = Table::new(
        "Tab.2 (time axis) — per-iteration wall-clock",
        &["config", "variant", "median ms/iter", "mask-update ms"],
    );
    for config in ["gpt2s-sim", "llama-sim"] {
        for (smax, mult, tag) in [(0.0, 1usize, "dense"), (0.8, 4, "BLaST-80%/128")] {
            let opts = PretrainOptions {
                total_iters: steps,
                s_max: smax,
                step_size: 5,
                block_mult: mult,
                ..Default::default()
            };
            let mut t = Trainer::from_backend(rt.as_ref(), config, opts).unwrap();
            t.run(steps).unwrap();
            let plain: Vec<f64> = t.log.iter().filter(|l| !l.mask_update).map(|l| l.secs * 1e3).collect();
            let upd: Vec<f64> = t.log.iter().filter(|l| l.mask_update).map(|l| l.secs * 1e3).collect();
            table.row(&[
                config.into(),
                tag.into(),
                format!("{:.1}", stats::median(&plain)),
                format!("{:.1}", stats::median(&upd)),
            ]);
        }
    }
    table.print();
}
