//! Bench: Table 2's wall-clock axis — time per training iteration through
//! the AOT train_step at each Table 2 configuration, without the full-run
//! perplexity (use `blast exp tab2` for the complete table).
//! `cargo bench --bench tab2_pretrain_step [-- --steps 12]`
use blast::runtime::Runtime;
use blast::testkit::bench::Table;
use blast::train::pretrain::{PretrainOptions, Trainer};
use blast::util::cli::Args;
use blast::util::stats;

fn main() {
    let args = Args::parse();
    let steps = args.get_usize("steps", 12);
    let rt = Runtime::open_default().expect("run `make artifacts`");
    let mut table = Table::new(
        "Tab.2 (time axis) — per-iteration wall-clock",
        &["config", "variant", "median ms/iter", "mask-update ms"],
    );
    for config in ["gpt2s-sim", "llama-sim"] {
        for (smax, mult, tag) in [(0.0, 1usize, "dense"), (0.8, 4, "BLaST-80%/128")] {
            let opts = PretrainOptions {
                total_iters: steps,
                s_max: smax,
                step_size: 5,
                block_mult: mult,
                ..Default::default()
            };
            let mut t = Trainer::new(&rt, config, opts).unwrap();
            t.run(steps).unwrap();
            let plain: Vec<f64> = t.log.iter().filter(|l| !l.mask_update).map(|l| l.secs * 1e3).collect();
            let upd: Vec<f64> = t.log.iter().filter(|l| l.mask_update).map(|l| l.secs * 1e3).collect();
            table.row(&[
                config.into(),
                tag.into(),
                format!("{:.1}", stats::median(&plain)),
                format!("{:.1}", stats::median(&upd)),
            ]);
        }
    }
    table.print();
}
