//! Bench: regenerate Fig. 7 (GPU-count / memory footprint model).
//! `cargo bench --bench fig7_memory_footprint`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::eval::memory_exps::fig7(&args).unwrap();
}
