//! Bench: micro-ablations over the kernel design choices DESIGN.md calls
//! out — block size vs MXU-style tile efficiency, fused vs unfused MLP,
//! BCSC vs CSR at matched sparsity, and the blk_M (row-tile) sweep.
//! `cargo bench --bench ablations [-- --quick]`
use blast::kernels::bspmm::{bspmm, fused_mlp_sparse, gelu_mlp_sparse, FusedMlpWeights};
use blast::kernels::csr_spmm::csr_spmm;
use blast::kernels::gemm::gemm;
use blast::kernels::ops;
use blast::sparse::{Bcsc, BlockMask, Csr};
use blast::tensor::Tensor;
use blast::testkit::bench::{bench_quick, black_box, fmt_time, Table};
use blast::util::cli::Args;
use blast::util::rng::Rng;

fn main() {
    let args = Args::parse();
    let quick = args.get_bool("quick");
    let mut rng = Rng::new(7);
    let (m, k, n) = if quick { (128, 512, 1024) } else { (256, 1024, 4096) };
    let s = 0.9;

    // 1. block-size sweep at fixed sparsity
    let mut t1 = Table::new("ablation: block size @90% sparsity", &["b", "time", "vs b=128"]);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let wd = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut t128 = 0.0;
    for b in [128usize, 64, 32, 16] {
        let mask = BlockMask::random(k / b, n / b, s, &mut rng);
        let w = Bcsc::from_dense(&wd, &mask, b);
        let t = bench_quick("b", || {
            black_box(bspmm(&x, &w));
        })
        .secs();
        if b == 128 {
            t128 = t;
        }
        t1.row(&[b.to_string(), fmt_time(t), format!("{:.2}x", t128 / t)]);
    }
    t1.print();

    // 2. fused vs unfused sparse MLP
    let e = k;
    let f = n;
    let b = 64;
    let w1d = Tensor::randn(&[e, f], 0.02, &mut rng);
    let w2d = Tensor::randn(&[e, f], 0.02, &mut rng);
    let w3d = Tensor::randn(&[f, e], 0.02, &mut rng);
    let m1 = BlockMask::random(e / b, f / b, s, &mut rng);
    let m2 = BlockMask::random(e / b, f / b, s, &mut rng);
    let m3 = BlockMask::random(f / b, e / b, s, &mut rng);
    let w1 = Bcsc::from_dense(&w1d, &m1, b);
    let w2 = Bcsc::from_dense(&w2d, &m2, b);
    let w3 = Bcsc::from_dense(&w3d, &m3, b);
    let t_fused = bench_quick("fused", || {
        black_box(fused_mlp_sparse(&x, &FusedMlpWeights { w1: &w1, w2: &w2, w3: &w3 }));
    })
    .secs();
    let t_unfused = bench_quick("unfused", || {
        let h1 = bspmm(&x, &w1);
        let h2 = bspmm(&x, &w2);
        let mut h = h1.clone();
        for (a, (&p, &q)) in h.data_mut().iter_mut().zip(h1.data().iter().zip(h2.data())) {
            *a = ops::silu(p) * q;
        }
        black_box(bspmm(&h, &w3));
    })
    .secs();
    let mut t2 = Table::new("ablation: fused vs unfused sparse MLP (§3.3.3)", &["variant", "time", "speedup"]);
    t2.row(&["unfused".into(), fmt_time(t_unfused), "1.00x".into()]);
    t2.row(&["fused".into(), fmt_time(t_fused), format!("{:.2}x", t_unfused / t_fused)]);
    t2.print();

    // 3. BCSC vs CSR vs dense at matched sparsity
    let mut t3 = Table::new("ablation: format comparison @90%", &["format", "time", "vs dense"]);
    let t_dense = bench_quick("dense", || {
        black_box(gemm(&x, &wd));
    })
    .secs();
    let mask = BlockMask::random(k / 64, n / 64, s, &mut rng);
    let wb = Bcsc::from_dense(&wd, &mask, 64);
    let t_b = bench_quick("bcsc", || {
        black_box(bspmm(&x, &wb));
    })
    .secs();
    let wc = Csr::random(k, n, s, &mut rng);
    let t_c = bench_quick("csr", || {
        black_box(csr_spmm(&x, &wc));
    })
    .secs();
    t3.row(&["dense GEMM".into(), fmt_time(t_dense), "1.00x".into()]);
    t3.row(&["BCSC 64x64".into(), fmt_time(t_b), format!("{:.2}x", t_dense / t_b)]);
    t3.row(&["CSR".into(), fmt_time(t_c), format!("{:.2}x", t_dense / t_c)]);
    t3.print();

    // 4. gelu MLP variant sanity (GPT-2 path)
    let t_gelu = bench_quick("gelu-mlp", || {
        black_box(gelu_mlp_sparse(&x, &w1, &w3));
    })
    .secs();
    println!("\ngelu sparse MLP (GPT-2 path): {}", fmt_time(t_gelu));
}
