//! Bench: dense-vs-block-sparse native training step A/B; writes
//! BENCH_pretrain.json.
//! `cargo bench --bench pretrain_ab [-- --quick --config gpt2s-sim --sparsities 0.0,0.5,0.8,0.9 --out BENCH_pretrain.json]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::kernels::simd::set_simd_enabled(!args.get_bool("no-simd"));
    blast::eval::pretrain_exps::pretrain_ab(&args).unwrap();
}
