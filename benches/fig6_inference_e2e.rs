//! Bench: regenerate Fig. 6 (end-to-end inference speedup, dense vs sparse
//! native engine). `cargo bench --bench fig6_inference_e2e [-- --quick]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::eval::kernel_exps::fig6(&args).unwrap();
}
