//! Bench: batched-vs-sequential decode round A/B plus the shared-prefix
//! KV-cache arm (prefix cache on vs off, bitwise-identical streams);
//! writes BENCH_serve.json.
//! `cargo bench --bench serve_ab [-- --quick --batches 1,4,8 --out BENCH_serve.json]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::kernels::simd::set_simd_enabled(!args.get_bool("no-simd"));
    blast::eval::serve_exps::serve(&args).unwrap();
}
