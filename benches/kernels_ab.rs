//! Bench: seed-vs-packed kernel A/B; writes BENCH_kernels.json.
//! `cargo bench --bench kernels_ab [-- --quick --out BENCH_kernels.json]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::kernels::simd::set_simd_enabled(!args.get_bool("no-simd"));
    blast::eval::kernel_exps::kernels(&args).unwrap();
}
