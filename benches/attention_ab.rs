//! Bench: tiled/paged-vs-seed attention A/B + paged-KV memory check;
//! writes BENCH_attention.json.
//! `cargo bench --bench attention_ab [-- --quick --seqs 128,256,512 --kv-page 64 --out BENCH_attention.json]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::kernels::simd::set_simd_enabled(!args.get_bool("no-simd"));
    blast::eval::attention_exps::attention(&args).unwrap();
}
