//! Bench: regenerate Fig. 4 (BSpMM kernel speedup sweep).
//! `cargo bench --bench fig4_bspmm [-- --quick]`
use blast::util::cli::Args;

fn main() {
    let args = Args::parse();
    blast::eval::kernel_exps::fig4(&args).unwrap();
}
