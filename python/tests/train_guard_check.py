"""Pure-python transliteration of PR 10's self-healing training guard
(rust/src/train/guard.rs and the guarded trainer plumbing in
rust/src/train/pretrain.rs).

No Rust toolchain ships in this container, so the guard's deterministic
surfaces are pinned here against independent oracles:

  1. the RNG substrate: splitmix64 (published reference vector) seeding
     xoshiro256**, the Lemire `below(n)` sampler and the 53-bit `f64()`
     draw that fault-site probability checks consume;
  2. seed derivations: per-site `stream_seed` for the four training fault
     sites, the guard's `fork_rng("train_guard")` jitter stream (armed
     and disabled forms), and `forked_corpus_seed` (fork 0 = identity —
     the guards-off bit-identity guarantee);
  3. guard arithmetic, bit-for-bit: the accepted-loss EWMA recurrence
     (f32 loss widened to f64), the f32 clip scale `clip_norm/grad_norm`,
     `guard_backoff_ms` (base clamp, shift cap at 16x, jitter in
     [0, base)), the divergence decision `ewma > best*(1+div_tol)` with
     its best-update ordering, and the NaN-bits persist sentinel for an
     uninitialized EWMA;
  4. the mask-guardrail decision table: cooldown consumption, deferred
     accounting and the relaxed (half-climb) retry target;
  5. fault-stream simulation pinning the exact trajectories asserted in
     rust/tests/chaos_training.rs: `grad_nan:0.25:5` fires 9/24 (longest
     run 2 -> 9 skips, 15 accepted), `grad_explode:0.3:11` fires 7/16,
     `loss_spike_mul:0.3:7` fires 6/23 post-warmup, the everything-storm
     seed-4 streams, and the probability-1 skip-escalation ladder
     (max_skips 3 / max_rollbacks 2 -> 9 skips, 2 rollbacks, 2 data
     forks; the exp-driver variant 2/3 -> 8 skips, 3 rollbacks).

A mismatch in section 5 means the RNG or stream-seed derivation drifted
— fix that, do not re-pin the constants.

Run: python3 python/tests/train_guard_check.py   (prints ALL OK)
"""

import struct
import zlib

import numpy as np

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15

checks = []


def check(name, ok):
    checks.append((name, bool(ok)))
    print(("PASS" if ok else "FAIL"), name)
    assert ok, name


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", np.float32(x)))[0]


# ---------------------------------------------------------------------
# 1. RNG substrate (util/rng.rs)
# ---------------------------------------------------------------------

def splitmix64_next(state):
    state = (state + GOLDEN) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded through splitmix64 — util/rng.rs verbatim."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s, v = splitmix64_next(s)
            self.s.append(v)

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def below(self, n):
        assert n > 0
        return (self.next_u64() * n) >> 64

    def f64(self):
        # (next_u64 >> 11) * 2^-53 — exact in python floats
        return (self.next_u64() >> 11) * (2.0 ** -53)


_, first = splitmix64_next(0)
check("splitmix64 reference vector: next(0) == 0xE220A8397B1DCDAF",
      first == 0xE220A8397B1DCDAF)
r = Rng(7)
draws = [r.f64() for _ in range(500)]
check("f64(): every draw in [0, 1) with 53-bit granularity",
      all(0.0 <= d < 1.0 and f64_bits(d) == f64_bits((f64_bits(d) and d))
          for d in draws))


# ---------------------------------------------------------------------
# 2. Seed derivations (util/faults.rs, train/pretrain.rs)
# ---------------------------------------------------------------------

def crc32(s):
    return zlib.crc32(s.encode()) & 0xFFFFFFFF


def stream_seed(seed, site, salt=0):
    """SiteState::stream_seed — the per-site fault draw stream."""
    return (seed ^ crc32(site) ^ ((salt * GOLDEN) & MASK)) & MASK


def fork_rng_seed(spec, label, salt, armed):
    """Faults::fork_rng — the guard's backoff jitter stream."""
    l = crc32(label)
    if not armed:
        return (0xB0FF ^ l) & MASK
    return (((crc32(spec) << 32) ^ l ^ ((salt * GOLDEN) & MASK)) ^ 0xB0FF) & MASK


def forked_corpus_seed(seed, fork):
    """pretrain.rs: the data-order re-fork after a divergence rollback."""
    return (seed ^ ((fork * GOLDEN) & MASK)) & MASK


TRAIN_SITES = ["grad_nan", "grad_explode", "loss_spike_mul", "mask_corrupt"]

check("train sites: distinct per-site streams from one spec seed",
      len({stream_seed(5, s) for s in TRAIN_SITES}) == 4)
check("guard jitter: disabled form is 0xB0FF ^ crc32('train_guard')",
      fork_rng_seed("", "train_guard", 0, False) == 0xB0FF ^ crc32("train_guard"))
check("guard jitter: armed form folds the spec hash in",
      fork_rng_seed("grad_nan:0.25:5", "train_guard", 0, True)
      == ((crc32("grad_nan:0.25:5") << 32) ^ crc32("train_guard") ^ 0xB0FF))
check("forked_corpus_seed: fork 0 is the identity (guards-off bit-identity)",
      forked_corpus_seed(0xB1A57, 0) == 0xB1A57)
check("forked_corpus_seed: forks 1..8 all distinct from the root and each other",
      len({forked_corpus_seed(0xB1A57, f) for f in range(9)}) == 9)


# ---------------------------------------------------------------------
# 3. Guard arithmetic (train/guard.rs), bit-for-bit
# ---------------------------------------------------------------------

# EWMA recurrence: first accepted loss seeds it, then
# e = alpha*l + (1-alpha)*e, all in f64 on the f32 loss widened exactly.
def ewma_fold(losses_f32, alpha):
    e = None
    for l in losses_f32:
        l = float(np.float64(np.float32(l)))
        e = l if e is None else alpha * l + (1.0 - alpha) * e
    return e


e = ewma_fold([4.0, 3.5, 3.8, 3.2, 3.0], 0.3)
check("EWMA: pinned bits for the [4.0,3.5,3.8,3.2,3.0] @ alpha=0.3 fold "
      "(losses widened from f32, as the guard sees them)",
      f64_bits(e) == 0x400B9BF48863F140)
check("EWMA: first accepted loss seeds the baseline exactly",
      ewma_fold([3.7], 0.3) == float(np.float32(3.7)))

# Clip scale: (clip_norm / grad_norm) as f32 — the one f32 rounding in
# the guard. Pinned against independently computed IEEE bit patterns.
check("clip scale: f32(10/25) == 0x3ECCCCCD",
      f32_bits(10.0 / 25.0) == 0x3ECCCCCD)
check("clip scale: f32(10/1e6) == 0x3727C5AC",
      f32_bits(10.0 / 1e6) == 0x3727C5AC)
check("clip scale: f32(1/3) == 0x3EAAAAAB",
      f32_bits(1.0 / 3.0) == 0x3EAAAAAB)


def guard_backoff_ms(base_ms, streak, rng):
    """guard.rs::guard_backoff_ms verbatim."""
    base = max(base_ms, 1)
    return (base << min(max(streak - 1, 0), 4)) + rng.below(base)


jr = Rng(fork_rng_seed("grad_nan:1:1", "train_guard", 0, True))
backoffs = [guard_backoff_ms(5, k, jr) for k in range(1, 11)]
check("backoff: exponential with the shift capped at 16x base",
      all(5 * 2 ** min(k - 1, 4) <= b < 5 * 2 ** min(k - 1, 4) + 5
          for k, b in zip(range(1, 11), backoffs)))
jr2 = Rng(fork_rng_seed("grad_nan:1:1", "train_guard", 0, True))
check("backoff: replays bit-for-bit from the spec-derived jitter stream",
      backoffs == [guard_backoff_ms(5, k, jr2) for k in range(1, 11)])
check("backoff: base 0 clamps to 1 (never a zero-length sleep window)",
      guard_backoff_ms(0, 1, Rng(1)) >= 1)


# Divergence decision: streak advances when ewma > best*(1+tol), and the
# check runs BEFORE best absorbs the new ewma (a fresh minimum cannot
# also count as divergence).
def divergence_sim(losses_f32, alpha, tol, div_steps):
    e, best, streak = None, float("inf"), 0
    trigger = None
    for i, l in enumerate(losses_f32):
        l = float(np.float64(np.float32(l)))
        e = l if e is None else alpha * l + (1.0 - alpha) * e
        if e > best * (1.0 + tol):
            streak += 1
        else:
            streak = 0
        if e < best:
            best = e
        if streak >= div_steps and trigger is None:
            trigger = i
    return trigger


losses = [3.0, 2.9, 2.8] + [4.2] * 10
check("divergence: 20% tolerance, 5 steps — triggers once the EWMA has "
      "climbed and held",
      divergence_sim(losses, 0.3, 0.2, 5) == 8)
check("divergence: an improving run never triggers",
      divergence_sim([3.0 - 0.01 * i for i in range(50)], 0.3, 0.2, 5) is None)
check("divergence: INF tolerance (permissive guard) never triggers",
      divergence_sim(losses, 0.3, float("inf"), 5) is None)

# Persist sentinel: uninitialized EWMA round-trips through NaN bits.
nan_bits = f64_bits(float("nan"))
restored = struct.unpack("<d", struct.pack("<Q", nan_bits))[0]
check("persist: EWMA None <-> NaN-bits sentinel survives the round-trip",
      restored != restored)
check("persist: a real EWMA round-trips bit-exactly",
      struct.unpack("<d", struct.pack("<Q", f64_bits(e)))[0] == e)


# ---------------------------------------------------------------------
# 4. Mask-guardrail decision table (cooldown / deferred / relaxed)
# ---------------------------------------------------------------------

def mask_ladder(iters, step_size, cooldown_updates, revert_all):
    """Walk the trainer's update schedule with every probed update
    reverting (the mask_corrupt:1 + paranoid-budget storm)."""
    cooldown, reverts, deferred = 0, 0, 0
    for it in range(iters):
        if it % step_size != 0:
            continue
        if cooldown > 0:
            cooldown -= 1
            deferred += 1
            continue
        if revert_all:
            reverts += 1
            cooldown = cooldown_updates
    return reverts, deferred


check("mask ladder: 12 iters, step 5, cooldown 2 -> 1 revert, 2 deferred "
      "(chaos_training.rs pin)",
      mask_ladder(12, 5, 2, True) == (1, 2))
check("mask ladder: 24 iters -> 2 reverts, 3 deferred (exp-driver full run)",
      mask_ladder(24, 5, 2, True) == (2, 3))
check("mask ladder: 10 iters -> 1 revert, 1 deferred (exp-driver --quick)",
      mask_ladder(10, 5, 2, True) == (1, 1))

# Relaxed retry target: half the remaining climb, schedule otherwise.
def mask_target(relaxed, scheduled, current):
    if relaxed and scheduled > current:
        return current + (scheduled - current) * 0.5
    return scheduled


check("mask target: relaxed halves the climb",
      mask_target(True, 0.75, 0.25) == 0.5)
check("mask target: relaxed never raises a descending schedule",
      mask_target(True, 0.25, 0.5) == 0.25)
check("mask target: unrelaxed follows the schedule",
      mask_target(False, 0.75, 0.25) == 0.75)

# Paranoid probe budget: after <= before*(1 - 0.5) is impossible for
# positive losses, so every probed update reverts — the determinism the
# mask storm relies on.
check("paranoid budget: a positive probe loss can never pass budget -0.5",
      all(not (after <= before * 0.5)
          for before in [0.1, 2.0, 5.5] for after in [before, before * 0.99]))


# ---------------------------------------------------------------------
# 5. Fault-stream simulation — the constants chaos_training.rs asserts
# ---------------------------------------------------------------------

def fire_pattern(site, seed, prob, n):
    rng = Rng(stream_seed(seed, site))
    return [rng.f64() < prob for _ in range(n)]


def longest_run(fires):
    run = best = 0
    for f in fires:
        run = run + 1 if f else 0
        best = max(best, run)
    return best


nan24 = fire_pattern("grad_nan", 5, 0.25, 24)
check("grad_nan:0.25:5 over 24 checks: exactly 9 fires",
      sum(nan24) == 9)
check("grad_nan:0.25:5: longest fire run 2 (< max_skips 8, no escalation)",
      longest_run(nan24) == 2)
check("grad_nan:0.25:5: trajectory 9 skips / 15 accepted",
      (sum(nan24), 24 - sum(nan24)) == (9, 15))

exp16 = fire_pattern("grad_explode", 11, 0.3, 16)
check("grad_explode:0.3:11 over 16 checks: exactly 7 fires, longest run 3",
      (sum(exp16), longest_run(exp16)) == (7, 3))

spike23 = fire_pattern("loss_spike_mul", 7, 0.3, 23)
check("loss_spike_mul:0.3:7 over 23 post-warmup checks: exactly 6 fires",
      sum(spike23) == 6)
check("loss_spike_mul:0.3:7: longest run 2 — EWMA stays clean, every fire "
      "skipped",
      longest_run(spike23) == 2)

# everything-at-once storm, seed 4: grad_nan never fires; grad_explode's
# 4 fires and loss_spike's 1 guarantee the `skips >= 1` assertion.
all4 = {s: sum(fire_pattern(s, 4, p, 24))
        for s, p in [("grad_nan", 0.1), ("grad_explode", 0.1),
                     ("loss_spike_mul", 0.15)]}
check("everything storm seed 4: grad_nan 0, grad_explode 4, loss_spike 1 fires",
      (all4["grad_nan"], all4["grad_explode"], all4["loss_spike_mul"]) == (0, 4, 1))


def escalation_sim(max_skips, max_rollbacks):
    """The probability-1 grad_nan ladder: every step skips (no RNG draw at
    prob >= 1), max_skips consecutive skips escalate to an anchored
    rollback, and the (max_rollbacks+1)-th escalation aborts."""
    skips = rollbacks = forks = streak = 0
    while True:
        skips += 1
        streak += 1
        if streak >= max_skips:
            if rollbacks >= max_rollbacks:
                return skips, rollbacks, forks, "rollback budget exhausted"
            rollbacks += 1
            forks += 1
            streak = 0


check("escalation 3/2 (chaos_training.rs): 9 skips, 2 rollbacks, 2 forks, abort",
      escalation_sim(3, 2) == (9, 2, 2, "rollback budget exhausted"))
check("escalation 2/3 (exp driver): 8 skips, 3 rollbacks, 3 forks, abort",
      escalation_sim(2, 3) == (8, 3, 3, "rollback budget exhausted"))
check("probability-1 fires draw nothing: pattern independent of the seed",
      all(all(Rng(stream_seed(s, "grad_nan")).f64() is not None for _ in [0])
          for s in range(4)))  # prob>=1 short-circuits before the stream


# ---------------------------------------------------------------------

failed = [n for n, ok in checks if not ok]
assert not failed, failed
print(f"ALL OK ({len(checks)} checks)")
