"""Transliteration of the BLST1 checkpoint format (model/params.rs).

No Rust toolchain ships in this container, so the v2 byte layout and its
CRC32 are pinned here in pure python/numpy, independently of the Rust
writer. Mirrors:

  * util/crc.rs           — IEEE reflected CRC32 == zlib.crc32 (canonical
                            check value crc32(b"123456789") == 0xCBF43926)
  * ParamStore::save_with_meta — magic b"BLST1" + u64 LE header length +
                            JSON header {"version": 2, "meta": {...},
                            "tensors": [{name, shape, crc}, ...]} + raw
                            little-endian f32 payloads in header order
  * ParamStore::load_with_meta — magic/version/shape/CRC verification,
                            truncation + bit-flip rejection, legacy v1
                            bare-array headers (no meta, no CRCs)

Any change to the Rust format that breaks these checks is a format break
and needs a version bump, not a silent re-interpretation.
"""
import io
import json
import struct
import zlib

import numpy as np

f32 = np.float32
ok_count = 0

def check(name, cond):
    global ok_count
    assert cond, f"FAIL: {name}"
    ok_count += 1
    print(f"  ok: {name}")

# ---------------------------------------------------------------------------
# 1. CRC32: the Rust table-driven implementation is IEEE reflected
#    (poly 0xEDB88320), i.e. exactly zlib.crc32
# ---------------------------------------------------------------------------

def crc32_rust(data):
    """Literal transliteration of util/crc.rs (bitwise, no table)."""
    crc = 0xFFFF_FFFF
    for byte in data:
        crc ^= byte
        for _ in range(8):
            if crc & 1:
                crc = (crc >> 1) ^ 0xEDB8_8320
            else:
                crc >>= 1
    return crc ^ 0xFFFF_FFFF

check("crc32 canonical check value", crc32_rust(b"123456789") == 0xCBF43926)
check("crc32 empty", crc32_rust(b"") == 0 == zlib.crc32(b""))
rng = np.random.default_rng(0)
for n in [1, 7, 64, 1000]:
    buf = rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    check(f"crc32 == zlib.crc32 ({n} bytes)",
          crc32_rust(buf) == zlib.crc32(buf) & 0xFFFFFFFF)

# ---------------------------------------------------------------------------
# 2. v2 writer/reader — independent implementation of the byte layout
# ---------------------------------------------------------------------------
MAGIC = b"BLST1"
HLEN_CAP = 1 << 30

def save_v2(tensors, meta):
    """tensors: list of (name, np.ndarray f32). Mirrors save_with_meta
    (minus the tmp+rename dance, which is filesystem protocol, not
    byte layout)."""
    items = []
    payload = b""
    for name, arr in tensors:
        raw = np.ascontiguousarray(arr, f32).tobytes()  # little-endian f32
        items.append({"name": name,
                      "shape": list(arr.shape),
                      "crc": zlib.crc32(raw) & 0xFFFFFFFF})
        payload += raw
    header = json.dumps({"version": 2, "meta": meta, "tensors": items})
    return (MAGIC + struct.pack("<Q", len(header))
            + header.encode() + payload)

def load(blob):
    """Mirrors load_with_meta: v2 (verify CRCs) or legacy v1 bare array."""
    f = io.BytesIO(blob)
    if f.read(5) != MAGIC:
        raise ValueError("not a BLST1 checkpoint")
    (hlen,) = struct.unpack("<Q", f.read(8))
    if hlen > HLEN_CAP:
        raise ValueError(f"implausible header length {hlen}")
    hbuf = f.read(hlen)
    if len(hbuf) != hlen:
        raise ValueError("truncated header")
    header = json.loads(hbuf)
    if isinstance(header, list):
        meta, items = {}, header          # legacy v1: header IS the list
    else:
        if header["version"] != 2:
            raise ValueError(f"unsupported version {header['version']}")
        meta, items = header.get("meta", {}), header["tensors"]
    out = []
    for item in items:
        n = int(np.prod(item["shape"])) if item["shape"] else 1
        raw = f.read(n * 4)
        if len(raw) != n * 4:
            raise ValueError(f"tensor {item['name']}: torn write / truncated")
        if "crc" in item and zlib.crc32(raw) & 0xFFFFFFFF != item["crc"]:
            raise ValueError(f"CRC mismatch for tensor {item['name']}")
        out.append((item["name"],
                    np.frombuffer(raw, dtype="<f4").reshape(item["shape"])))
    return out, meta

tensors = [("tok_emb", rng.standard_normal((8, 4)).astype(f32)),
           ("layer0.ln1", np.ones(4, f32)),
           ("layer0.mlp.w1", rng.standard_normal((4, 8)).astype(f32)),
           ("layer0.mlp.w3", rng.standard_normal((8, 4)).astype(f32))]
meta = {"kind": "trainer", "iter": 42, "seed": "12345678901234567890"}
blob = save_v2(tensors, meta)

# layout invariants, byte for byte
check("magic is 5 bytes BLST1", blob[:5] == b"BLST1")
hlen = struct.unpack("<Q", blob[5:13])[0]
check("u64 LE header length", blob[13:13 + hlen].decode().startswith('{"version": 2'))
payload_off = 13 + hlen
first = tensors[0][1].tobytes()
check("payload starts at 13+hlen, first tensor LE f32",
      blob[payload_off:payload_off + len(first)] == first)
check("total size = 13 + hlen + 4*elements",
      len(blob) == 13 + hlen + 4 * sum(t.size for _, t in tensors))

back, m = load(blob)
check("meta roundtrips (u64 seed as string survives)", m == meta)
check("names + order roundtrip", [n for n, _ in back] == [n for n, _ in tensors])
check("payloads bit-identical",
      all(np.array_equal(a, b) for (_, a), (_, b) in zip(back, tensors)))

# ---------------------------------------------------------------------------
# 3. corruption rejection — the crash-safety contract
# ---------------------------------------------------------------------------

def rejects(name, blob, needle):
    try:
        load(blob)
    except ValueError as e:
        check(name, needle in str(e))
    else:
        check(name, False)

rejects("wrong magic rejected", b"XLST1" + blob[5:], "not a BLST1")
rejects("truncated payload rejected (torn write)", blob[:-7], "torn write")
rejects("half-written first tensor rejected",
        blob[:payload_off + len(first) // 2], "torn write")
flipped = bytearray(blob)
flipped[-2] ^= 0x40                       # inside the final tensor's payload
rejects("bit flip fails CRC", bytes(flipped), "CRC mismatch")
huge = bytearray(blob)
huge[5:13] = struct.pack("<Q", (1 << 30) + 1)
rejects("implausible header length rejected", bytes(huge), "implausible")
v3 = json.dumps({"version": 3, "meta": {}, "tensors": []}).encode()
rejects("unknown version rejected",
        MAGIC + struct.pack("<Q", len(v3)) + v3, "unsupported version")

# ---------------------------------------------------------------------------
# 4. legacy v1: bare-array header, no meta, no CRCs — still loads
# ---------------------------------------------------------------------------
w = np.array([[1.0, -2.5], [3.25, 0.0]], f32)
v1_header = json.dumps([{"name": "w", "shape": [2, 2]}]).encode()
v1 = MAGIC + struct.pack("<Q", len(v1_header)) + v1_header + w.tobytes()
back, m = load(v1)
check("v1 loads with empty meta", m == {})
check("v1 payload intact", np.array_equal(back[0][1], w))
# v1 has no checksums: a bit flip goes undetected (why v2 exists)
v1_flip = bytearray(v1)
v1_flip[-2] ^= 0x40
back, _ = load(bytes(v1_flip))
check("v1 silently accepts corruption (motivates v2 CRCs)",
      not np.array_equal(back[0][1], w))

# ---------------------------------------------------------------------------
# 5. crc as a JSON number is safe: every u32 is exact in f64 (the Rust
#    Json::num carrier) — no precision loss for any possible checksum
# ---------------------------------------------------------------------------
for v in [0, 1, 0xCBF43926, 0xFFFFFFFF]:
    check(f"u32 crc {v:#010x} exact through f64",
          int(float(v)) == v and json.loads(json.dumps({"crc": v}))["crc"] == v)

print(f"\nALL OK ({ok_count} checks)")
