"""AOT pipeline sanity: every planned entry lowers, the manifest is a
faithful ABI description, and a lowered train_step executes correctly when
fed flat positional inputs (the exact calling convention Rust uses)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_configs_block_divisibility():
    for cfg in aot.CONFIGS.values():
        for name, (kb, nb) in M.mask_spec(cfg):
            shapes = dict(M.param_spec(cfg))
            k, n = shapes[name]
            assert k == kb * cfg.block and n == nb * cfg.block


def test_entry_specs_cover_all_kinds():
    cfg = aot.CONFIGS["micro-llama"]
    for kind in ["train_step", "eval_loss", "eval_loss_pallas", "prefill", "decode_step"]:
        specs = aot.entry_specs(cfg, kind)
        outs = aot.output_names(cfg, kind)
        assert len(specs) > 0 and len(outs) > 0


def test_flat_abi_train_step_executes():
    """Call the flat-positional train_step exactly as Rust will."""
    cfg = aot.CONFIGS["micro"]
    fns = aot.make_entry_fns(cfg, aot.LEARNING_RATES[cfg.name])
    specs = aot.entry_specs(cfg, "train_step")
    rng = np.random.default_rng(0)

    params = M.init_params(cfg)
    pnames = [n for n, _ in M.param_spec(cfg)]
    args = [params[n] for n in pnames]
    args += [jnp.zeros_like(params[n]) for n in pnames]  # m
    args += [jnp.zeros_like(params[n]) for n in pnames]  # v
    args += [jnp.asarray(0, jnp.int32)]
    args += [jnp.ones(tuple(s), jnp.float32) for _, s in M.mask_spec(cfg)]
    args += [
        jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32),
        jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32),
    ]
    assert len(args) == len(specs)
    for a, (n, s) in zip(args, specs):
        assert a.shape == s.shape and a.dtype == s.dtype, (n, a.shape, s)

    out = jax.jit(fns["train_step"])(*args)
    names = aot.output_names(cfg, "train_step")
    assert len(out) == len(names)
    loss = out[names.index("loss")]
    assert np.isfinite(float(loss))
    step = out[names.index("step")]
    assert int(step) == 1


@pytest.mark.parametrize("entry", ["bspmm_pallas", "fused_mlp_pallas"])
def test_kernel_entries_lower(entry, tmp_path):
    for name, fn, specs, outs, meta in aot.kernel_entries():
        if name != entry:
            continue
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert meta["block"] >= 16


def test_manifest_roundtrip(tmp_path):
    """Lower the micro config end-to-end and validate the manifest schema."""
    out = str(tmp_path)
    e = aot.lower_entry(aot.CONFIGS["micro"], "eval_loss", out)
    assert os.path.exists(os.path.join(out, e["file"]))
    cm = aot.config_manifest(aot.CONFIGS["micro"])
    blob = json.dumps({"configs": {"micro": cm}, "entries": [e]})
    back = json.loads(blob)
    assert back["entries"][0]["kind"] == "eval_loss"
    assert back["configs"]["micro"]["param_count"] > 0
    assert [p["name"] for p in back["configs"]["micro"]["params"]] == [
        n for n, _ in M.param_spec(aot.CONFIGS["micro"])
    ]


def test_artifact_hlo_text_parses_as_hlo_module(tmp_path):
    e = aot.lower_entry(aot.CONFIGS["micro"], "eval_loss", str(tmp_path))
    text = open(os.path.join(str(tmp_path), e["file"])).read()
    assert text.startswith("HloModule")
    # return_tuple=True → a single tuple-shaped root
    assert "ROOT" in text
