"""L2 model correctness: shapes, masking semantics, training dynamics,
KV-cache decode vs full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import CONFIGS, LEARNING_RATES

jax.config.update("jax_platform_name", "cpu")

MICRO = CONFIGS["micro"]
MICRO_L = CONFIGS["micro-llama"]
VIT = CONFIGS["vit-sim"]


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)), jnp.int32)
    tgts = jnp.asarray(rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)), jnp.int32)
    return toks, tgts


@pytest.mark.parametrize("cfg", [MICRO, MICRO_L], ids=lambda c: c.name)
def test_lm_logits_shape(cfg):
    params = M.init_params(cfg)
    masks = M.full_masks(cfg)
    toks, _ = _batch(cfg)
    logits = M.lm_logits(cfg, params, masks, toks)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("cfg", [MICRO, MICRO_L], ids=lambda c: c.name)
def test_param_spec_matches_init(cfg):
    params = M.init_params(cfg)
    spec = M.param_spec(cfg)
    assert set(params) == {n for n, _ in spec}
    for n, s in spec:
        assert params[n].shape == tuple(s), n


def test_mask_zero_blocks_change_nothing_when_weights_zeroed():
    """Masking semantics: pruned blocks are dead in fwd AND bwd."""
    cfg = MICRO
    params = M.init_params(cfg)
    masks = M.full_masks(cfg)
    # prune one block of layer0 w1 and poison it
    name = "layer0.mlp.w1"
    m = np.asarray(masks[name]).copy()
    m[0, 0] = 0.0
    masks = dict(masks, **{name: jnp.asarray(m)})
    toks, tgts = _batch(cfg)

    poisoned = np.asarray(params[name]).copy()
    poisoned[: cfg.block, : cfg.block] = 1e6
    params2 = dict(params, **{name: jnp.asarray(poisoned)})

    l1 = M.lm_loss(cfg, params, masks, toks, tgts)
    l2 = M.lm_loss(cfg, params2, masks, toks, tgts)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

    # gradient wrt the pruned block must be exactly zero (no STE — §3.2)
    g = jax.grad(lambda p: M.lm_loss(cfg, p, masks, toks, tgts))(params)[name]
    assert float(jnp.abs(g[: cfg.block, : cfg.block]).max()) == 0.0
    assert float(jnp.abs(g).max()) > 0.0


@pytest.mark.parametrize("cfg", [MICRO, MICRO_L], ids=lambda c: c.name)
def test_train_step_decreases_loss(cfg):
    step_fn = M.make_train_step(cfg, LEARNING_RATES[cfg.name])
    params = M.init_params(cfg)
    masks = M.full_masks(cfg)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    step = jnp.asarray(0, jnp.int32)
    toks, tgts = _batch(cfg)
    jit_step = jax.jit(step_fn)
    losses = []
    for _ in range(8):
        params, m, v, step, loss, _g = jit_step(params, m, v, step, masks, toks, tgts)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert int(step) == 8


def test_train_step_returns_masked_mlp_grads():
    cfg = MICRO
    step_fn = M.make_train_step(cfg, 1e-3)
    params = M.init_params(cfg)
    masks = M.full_masks(cfg)
    name = "layer1.mlp.w3"
    mm = np.asarray(masks[name]).copy()
    mm[1, 0] = 0.0
    masks = dict(masks, **{name: jnp.asarray(mm)})
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    toks, tgts = _batch(cfg)
    *_, grads = step_fn(params, m, v, jnp.asarray(0, jnp.int32), masks, toks, tgts)
    g = grads[name]
    b = cfg.block
    assert float(jnp.abs(g[b : 2 * b, :b]).max()) == 0.0


def test_vit_logits_and_training():
    cfg = VIT
    rng = np.random.default_rng(0)
    params = M.init_params(cfg)
    masks = M.full_masks(cfg)
    patches = jnp.asarray(
        rng.normal(size=(cfg.batch, cfg.seq - 1, cfg.patch_dim)), jnp.float32
    )
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, size=(cfg.batch,)), jnp.int32)
    logits = M.vit_logits(cfg, params, masks, patches)
    assert logits.shape == (cfg.batch, cfg.num_classes)

    step_fn = jax.jit(M.make_train_step(cfg, 1e-3))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    step = jnp.asarray(0, jnp.int32)
    losses = []
    for _ in range(6):
        params, m, v, step, loss, _ = step_fn(params, m, v, step, masks, patches, labels)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_decode_matches_full_forward():
    """Prefill + repeated decode_step must reproduce full-sequence logits."""
    cfg = MICRO_L
    params = M.init_params(cfg, seed=3)
    masks = M.full_masks(cfg)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)), jnp.int32)

    prompt_len = cfg.seq // 2
    logits_full = M.lm_logits(cfg, params, masks, toks)

    last, kc, vc = M.prefill(cfg, params, masks, toks[:, :prompt_len])
    # left-pad comparison: prefill uses a fixed (batch, seq) shape in AOT, but
    # the python-side function accepts the true prompt length
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full[:, prompt_len - 1]), atol=2e-3
    )

    logits = last
    for t in range(prompt_len, cfg.seq):
        logits, kc, vc = M.decode_step(
            cfg, params, masks, kc, vc, toks[:, t], jnp.asarray(t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(logits_full[:, t]), atol=2e-2
        )


def test_decode_respects_block_sparsity():
    cfg = MICRO_L
    params = M.init_params(cfg, seed=4)
    masks = {
        n: jnp.asarray((np.random.default_rng(9).random(tuple(s)) > 0.5).astype(np.float32))
        for n, s in M.mask_spec(cfg)
    }
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)), jnp.int32)
    logits_full = M.lm_logits(cfg, params, masks, toks)
    last, kc, vc = M.prefill(cfg, params, masks, toks[:, : cfg.seq // 2])
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full[:, cfg.seq // 2 - 1]), atol=2e-3
    )


def test_pallas_path_matches_dense_path():
    """L1→L2 composition: the Pallas fused-MLP model path == masked-dense."""
    cfg = MICRO_L
    params = M.init_params(cfg, seed=8)
    masks = {
        n: jnp.asarray((np.random.default_rng(2).random(tuple(s)) > 0.3).astype(np.float32))
        for n, s in M.mask_spec(cfg)
    }
    toks, tgts = _batch(cfg, seed=9)
    l_dense = M.lm_loss(cfg, params, masks, toks, tgts, use_pallas=False)
    l_pallas = M.lm_loss(cfg, params, masks, toks, tgts, use_pallas=True)
    np.testing.assert_allclose(float(l_dense), float(l_pallas), rtol=1e-5)
