"""Numpy transliteration of PR 4's native training backend.

No Rust toolchain ships in this container, so every new kernel's index
math and every backward formula is transliterated to numpy (f32, same
loop/layout structure as the Rust) and checked against oracles and
central finite differences. Mirrors:

  * PackedB::pack_transposed + gemm_nt_into / gemm_tn_into (pack layouts
    + microkernel contract)
  * bspmm_dw_masked_into (block-row/col panel packs + per-block microkernel)
  * Bcsc::transpose / refresh_from_dense / refresh_from_dense_transposed
  * ops: gelu_grad / silu_grad / layernorm_bwd / rmsnorm_bwd / rope_bwd
  * attn_bwd_head (softmax/causal chain)
  * NativeBackend.forward/backward (gpt2 + llama) vs finite differences —
    calibrates the 1e-3 directional-gradient gate in f32
  * AdamW update vs the JAX reference formula
"""
import numpy as np

f32 = np.float32
ok_count = 0

def check(name, cond):
    global ok_count
    assert cond, f"FAIL: {name}"
    ok_count += 1
    print(f"  ok: {name}")

# ---------------------------------------------------------------------------
# 1. pack_transposed: panels of Bᵀ from row-major (n×k) B
# ---------------------------------------------------------------------------
NR = 16

def pack(b, k, n):
    """PackedB::pack — row-major (k×n) → NR-wide k-major panels."""
    panels = -(-n // NR)
    data = np.zeros(panels * k * NR, f32)
    for p in range(panels):
        j0 = p * NR
        cols = min(n - j0, NR)
        chunk = data[p * k * NR:(p + 1) * k * NR]
        for kk in range(k):
            chunk[kk * NR:kk * NR + cols] = b[kk * n + j0:kk * n + j0 + cols]
    return data, panels

def pack_transposed(b, n, k):
    """PackedB::pack_transposed — row-major (n×k) B, panels of Bᵀ (k×n)."""
    panels = -(-n // NR)
    data = np.zeros(panels * k * NR, f32)
    for p in range(panels):
        j0 = p * NR
        cols = min(n - j0, NR)
        chunk = data[p * k * NR:(p + 1) * k * NR]
        j = 0
        while j + 4 <= cols:
            s = [b[(j0 + j + t) * k:(j0 + j + t + 1) * k] for t in range(4)]
            for kk in range(k):
                for t in range(4):
                    chunk[kk * NR + j + t] = s[t][kk]
            j += 4
        for jj in range(j, cols):
            srow = b[(j0 + jj) * k:(j0 + jj + 1) * k]
            for kk in range(k):
                chunk[kk * NR + jj] = srow[kk]
    return data, panels

rng = np.random.default_rng(0)
for (n, k) in [(1, 1), (3, 5), (4, 7), (16, 3), (17, 8), (37, 11)]:
    B = rng.standard_normal((n, k)).astype(f32)
    via_t, p1 = pack(np.ascontiguousarray(B.T).ravel(), k, n)
    direct, p2 = pack_transposed(B.ravel(), n, k)
    check(f"pack_transposed n={n} k={k}", p1 == p2 and np.array_equal(via_t, direct))

# ---------------------------------------------------------------------------
# 2. microkernel contract + gemm_tn_into
# ---------------------------------------------------------------------------

def microkernel(ap, lda, rows, bp, ldb, cols, k, c, ldc):
    """C[rows×cols] += Aᵖ·Bᵖ with ap[kk*lda+i], bp[kk*ldb+j] (f32 fma order
    is irrelevant for correctness here; numpy matmul suffices)."""
    A = np.zeros((rows, k), f32)
    Bm = np.zeros((k, cols), f32)
    for kk in range(k):
        A[:, kk] = ap[kk * lda:kk * lda + rows]
        Bm[kk, :] = bp[kk * ldb:kk * ldb + cols]
    prod = (A.astype(np.float64) @ Bm.astype(np.float64)).astype(f32)
    for i in range(rows):
        c[i * ldc:i * ldc + cols] += prod[i]

def gemm_tn_into(a, b, c, m, k, n):
    """C(k×n) += Aᵀ·B; a (m×k) row-major, b (m×n) row-major."""
    MR = 16
    packed, panels = pack(b, m, n)
    for t in range(-(-k // MR)):
        i0 = t * MR
        i1 = min(i0 + MR, k)
        mr = i1 - i0
        ap = np.zeros(mr * m, f32)
        for d in range(m):
            ap[d * mr:(d + 1) * mr] = a[d * k + i0:d * k + i1]
        for p in range(panels):
            cols = min(n - p * NR, NR)
            ctile = c[i0 * n:]
            # microkernel writes into c[i0*n + p*NR ...] with ldc=n
            sub = np.zeros(mr * n, f32)
            sub[:] = c[i0 * n:i0 * n + mr * n]
            microkernel(ap, mr, mr, packed[p * m * NR:], NR, cols, m,
                        sub[p * NR:], n)
            c[i0 * n:i0 * n + mr * n] = sub

for (m, k, n) in [(1, 1, 1), (5, 3, 4), (12, 16, 20), (7, 17, 33), (24, 5, 40)]:
    A = rng.standard_normal((m, k)).astype(f32)
    Bm = rng.standard_normal((m, n)).astype(f32)
    C = np.zeros(k * n, f32)
    gemm_tn_into(A.ravel(), Bm.ravel(), C, m, k, n)
    want = (A.astype(np.float64).T @ Bm.astype(np.float64)).astype(f32)
    check(f"gemm_tn m={m} k={k} n={n}",
          np.max(np.abs(C.reshape(k, n) - want)) < 1e-3)

# gemm_nt = gemm_packed over pack_transposed panels: layout already proven
# by check 1 + the packed-GEMM machinery from PR 1; verify composition once
def gemm_nt(a, b, m, k, n):
    """C = A·Bᵀ via pack_transposed panels + microkernel."""
    packed, panels = pack_transposed(b, n, k)
    c = np.zeros(m * n, f32)
    # one row tile (m small in tests)
    ap = np.zeros(m * k, f32)
    for i in range(m):
        for kk in range(k):
            ap[kk * m + i] = a[i * k + kk]
    for p in range(panels):
        cols = min(n - p * NR, NR)
        microkernel(ap, m, m, packed[p * k * NR:], NR, cols, k, c[p * NR:], n)
    return c.reshape(m, n)

for (m, k, n) in [(4, 6, 9), (3, 16, 17), (8, 5, 32)]:
    A = rng.standard_normal((m, k)).astype(f32)
    Bm = rng.standard_normal((n, k)).astype(f32)
    got = gemm_nt(A.ravel(), Bm.ravel(), m, k, n)
    want = (A.astype(np.float64) @ Bm.astype(np.float64).T).astype(f32)
    check(f"gemm_nt m={m} k={k} n={n}", np.max(np.abs(got - want)) < 1e-3)

# ---------------------------------------------------------------------------
# 3. bspmm_dw_masked_into
# ---------------------------------------------------------------------------

def bspmm_dw_masked(x, dy, mask, b, m, k, n):
    """Literal transliteration: block-row panels of Xᵀ, block-col panels of
    dY, one b×b microkernel per resident block."""
    dw = np.zeros(k * n, f32)
    xp = np.zeros(m * k, f32)
    for br in range(k // b):
        chunk = xp[br * m * b:(br + 1) * m * b]
        for d in range(m):
            chunk[d * b:(d + 1) * b] = x[d * k + br * b:d * k + (br + 1) * b]
    dyp = np.zeros(m * n, f32)
    for bc in range(n // b):
        chunk = dyp[bc * m * b:(bc + 1) * m * b]
        for d in range(m):
            chunk[d * b:(d + 1) * b] = dy[d * n + bc * b:d * n + (bc + 1) * b]
    for br in range(k // b):
        for bc in range(n // b):
            if not mask[br, bc]:
                continue
            tile = np.zeros(b * b, f32)
            microkernel(xp[br * m * b:], b, b, dyp[bc * m * b:], b, b, m, tile, b)
            for i in range(b):
                dw[(br * b + i) * n + bc * b:(br * b + i) * n + (bc + 1) * b] += \
                    tile[i * b:(i + 1) * b]
    return dw.reshape(k, n)

for (b, rb, cb, m) in [(4, 2, 3, 7), (8, 3, 2, 16), (16, 2, 2, 5)]:
    k, n = rb * b, cb * b
    X = rng.standard_normal((m, k)).astype(f32)
    dY = rng.standard_normal((m, n)).astype(f32)
    mask = rng.random((rb, cb)) > 0.4
    got = bspmm_dw_masked(X.ravel(), dY.ravel(), mask, b, m, k, n)
    want = (X.astype(np.float64).T @ dY.astype(np.float64)).astype(f32)
    wmask = np.kron(mask, np.ones((b, b), bool))
    check(f"dw_masked values b={b} m={m}",
          np.max(np.abs(got[wmask] - want[wmask])) < 1e-3)
    check(f"dw_masked exact zeros b={b} m={m}", np.all(got[~wmask] == 0.0))

# ---------------------------------------------------------------------------
# 4. Bcsc transpose / refresh index math
# ---------------------------------------------------------------------------

def bcsc_from_dense(w, mask, b):
    rb, cb = mask.shape
    col_ptr = [0]
    row_idx = []
    vals = []
    for bc in range(cb):
        for br in range(rb):
            if mask[br, bc]:
                row_idx.append(br)
                vals.append(w[br * b:(br + 1) * b, bc * b:(bc + 1) * b].copy())
        col_ptr.append(len(row_idx))
    return dict(block=b, rb=rb, cb=cb, col_ptr=col_ptr, row_idx=row_idx, vals=vals)

def bcsc_to_dense(s):
    b = s["block"]
    out = np.zeros((s["rb"] * b, s["cb"] * b), f32)
    for bc in range(s["cb"]):
        for idx in range(s["col_ptr"][bc], s["col_ptr"][bc + 1]):
            br = s["row_idx"][idx]
            out[br * b:(br + 1) * b, bc * b:(bc + 1) * b] = s["vals"][idx]
    return out

def bcsc_transpose(s):
    b = s["block"]
    col_ptr = [0] * (s["rb"] + 1)
    for br in s["row_idx"]:
        col_ptr[br + 1] += 1
    for i in range(s["rb"]):
        col_ptr[i + 1] += col_ptr[i]
    row_idx = [0] * len(s["row_idx"])
    vals = [None] * len(s["vals"])
    cursor = list(col_ptr)
    for bc in range(s["cb"]):
        for idx in range(s["col_ptr"][bc], s["col_ptr"][bc + 1]):
            br = s["row_idx"][idx]
            dst = cursor[br]
            cursor[br] += 1
            row_idx[dst] = bc
            vals[dst] = s["vals"][idx].T.copy()
    return dict(block=b, rb=s["cb"], cb=s["rb"], col_ptr=col_ptr,
                row_idx=row_idx, vals=vals)

def refresh_transposed(t, w):
    """self stores Wᵀ; refresh payloads from un-transposed dense W."""
    b = t["block"]
    for bc in range(t["cb"]):
        for idx in range(t["col_ptr"][bc], t["col_ptr"][bc + 1]):
            br = t["row_idx"][idx]
            blk = np.zeros((b, b), f32)
            for j in range(b):
                for i in range(b):
                    blk[i, j] = w[bc * b + j, br * b + i]
            t["vals"][idx] = blk

for (b, rb, cb) in [(4, 3, 2), (8, 2, 4)]:
    W = rng.standard_normal((rb * b, cb * b)).astype(f32)
    mask = rng.random((rb, cb)) > 0.5
    s = bcsc_from_dense(W, mask, b)
    t = bcsc_transpose(s)
    check(f"bcsc transpose b={b}",
          np.array_equal(bcsc_to_dense(t), bcsc_to_dense(s).T))
    # sorted row ids per column (from_dense invariant)
    sorted_ok = all(
        all(t["row_idx"][i] < t["row_idx"][i + 1]
            for i in range(t["col_ptr"][c], t["col_ptr"][c + 1] - 1))
        for c in range(t["cb"]))
    check(f"bcsc transpose sorted b={b}", sorted_ok)
    W2 = (W * 1.5 - 0.25).astype(f32)
    refresh_transposed(t, W2)
    s2 = bcsc_from_dense(W2, mask, b)
    check(f"refresh_transposed b={b}",
          np.array_equal(bcsc_to_dense(t), bcsc_to_dense(s2).T))

# ---------------------------------------------------------------------------
# 5. elementwise / row ops backward vs finite differences (f64 for formulas)
# ---------------------------------------------------------------------------

def gelu(x):
    C = np.float64(0.7978846)
    return 0.5 * x * (1 + np.tanh(C * (x + 0.044715 * x ** 3)))

def gelu_grad(x):
    C = np.float64(0.7978846)
    A = 0.044715
    t = np.tanh(C * (x + A * x ** 3))
    return 0.5 * (1 + t) + 0.5 * x * (1 - t * t) * C * (1 + 3 * A * x * x)

def silu(x):
    return x / (1 + np.exp(-x))

def silu_grad(x):
    s = 1 / (1 + np.exp(-x))
    return s * (1 + x * (1 - s))

xs = np.linspace(-5, 5, 81)
eps = 1e-6
check("gelu_grad fd", np.max(np.abs(
    (gelu(xs + eps) - gelu(xs - eps)) / (2 * eps) - gelu_grad(xs))) < 1e-6)
check("silu_grad fd", np.max(np.abs(
    (silu(xs + eps) - silu(xs - eps)) / (2 * eps) - silu_grad(xs))) < 1e-6)

def layernorm(x, g, eps=1e-5):
    mu = x.mean()
    var = ((x - mu) ** 2).mean()
    return (x - mu) / np.sqrt(var + eps) * g

def layernorm_bwd(x, g, dy, eps=1e-5):
    n = len(x)
    mu = x.mean()
    var = ((x - mu) ** 2).mean()
    r = 1 / np.sqrt(var + eps)
    xhat = (x - mu) * r
    dyh = dy * g
    dx = r * (dyh - dyh.mean() - xhat * (dyh * xhat).mean())
    dg = dy * xhat
    return dx, dg

def rmsnorm(x, g, eps=1e-5):
    ms = (x * x).mean()
    return x / np.sqrt(ms + eps) * g

def rmsnorm_bwd(x, g, dy, eps=1e-5):
    n = len(x)
    ms = (x * x).mean()
    r = 1 / np.sqrt(ms + eps)
    dot = (dy * g * x).sum()
    dx = r * dy * g - (r ** 3 / n * dot) * x
    dg = dy * x * r
    return dx, dg

x = rng.standard_normal(10)
g = rng.standard_normal(10)
dy = rng.standard_normal(10)
for name, fwd, bwd in [("layernorm", layernorm, layernorm_bwd),
                       ("rmsnorm", rmsnorm, rmsnorm_bwd)]:
    dx, dg = bwd(x, g, dy)
    fd_dx = np.zeros(10)
    fd_dg = np.zeros(10)
    for j in range(10):
        for arr, fd in [(x, fd_dx), (g, fd_dg)]:
            orig = arr[j]
            arr[j] = orig + eps
            lp = (dy * fwd(x, g)).sum()
            arr[j] = orig - eps
            lm = (dy * fwd(x, g)).sum()
            arr[j] = orig
            fd[j] = (lp - lm) / (2 * eps)
    check(f"{name}_bwd dx fd", np.max(np.abs(dx - fd_dx)) < 1e-6)
    check(f"{name}_bwd dg fd", np.max(np.abs(dg - fd_dg)) < 1e-6)

def rope(v, pos, theta=10000.0):
    hd = len(v)
    half = hd // 2
    out = v.copy()
    for i in range(half):
        freq = theta ** (-i / half)
        ang = pos * freq
        a, b_ = v[i], v[i + half]
        out[i] = a * np.cos(ang) - b_ * np.sin(ang)
        out[i + half] = a * np.sin(ang) + b_ * np.cos(ang)
    return out

def rope_bwd(v, pos, theta=10000.0):
    hd = len(v)
    half = hd // 2
    out = v.copy()
    for i in range(half):
        freq = theta ** (-i / half)
        ang = pos * freq
        a, b_ = v[i], v[i + half]
        out[i] = a * np.cos(ang) + b_ * np.sin(ang)
        out[i + half] = -a * np.sin(ang) + b_ * np.cos(ang)
    return out

v = rng.standard_normal(8)
check("rope_bwd inverse", np.max(np.abs(rope_bwd(rope(v, 23), 23) - v)) < 1e-12)

# ---------------------------------------------------------------------------
# 6. attention backward chain vs finite differences
# ---------------------------------------------------------------------------

def attn_fwd(q, k, v):
    S, hd = q.shape
    scale = 1 / np.sqrt(hd)
    out = np.zeros_like(q)
    P = np.zeros((S, S))
    for i in range(S):
        s = (q[i] @ k[:i + 1].T) * scale
        e = np.exp(s - s.max())
        P[i, :i + 1] = e / e.sum()
        out[i] = P[i, :i + 1] @ v[:i + 1]
    return out, P

def attn_bwd(q, k, v, dout):
    S, hd = q.shape
    scale = 1 / np.sqrt(hd)
    _, P = attn_fwd(q, k, v)
    dv = P.T @ dout
    dp = dout @ v.T
    rowdot = (dp * P).sum(axis=1, keepdims=True)
    ds = P * (dp - rowdot) * scale
    dq = ds @ k
    dk = ds.T @ q
    return dq, dk, dv

S, hd = 5, 4
q = rng.standard_normal((S, hd))
k = rng.standard_normal((S, hd))
v = rng.standard_normal((S, hd))
dout = rng.standard_normal((S, hd))
dq, dk, dv = attn_bwd(q, k, v, dout)
for name, arr, got in [("dq", q, dq), ("dk", k, dk), ("dv", v, dv)]:
    fd = np.zeros_like(arr)
    for i in range(S):
        for j in range(hd):
            orig = arr[i, j]
            arr[i, j] = orig + eps
            lp = (dout * attn_fwd(q, k, v)[0]).sum()
            arr[i, j] = orig - eps
            lm = (dout * attn_fwd(q, k, v)[0]).sum()
            arr[i, j] = orig
            fd[i, j] = (lp - lm) / (2 * eps)
    check(f"attn_bwd {name} fd", np.max(np.abs(got - fd)) < 1e-5)

# ---------------------------------------------------------------------------
# 7. full model forward/backward (gpt2 + llama, masked MLP) vs fd — in f32,
#    calibrating the Rust test's 1e-3 directional gate
# ---------------------------------------------------------------------------

def init_params(cfg, seed):
    r = np.random.default_rng(seed)
    e, fdim, vdim = cfg["emb"], cfg["ffn"], cfg["vocab"]
    P = {}
    resid = 0.02 / np.sqrt(2 * cfg["layers"])
    P["tok_emb"] = (0.02 * r.standard_normal((vdim, e))).astype(f32)
    if cfg["kind"] == "gpt2":
        P["pos_emb"] = (0.02 * r.standard_normal((cfg["seq"], e))).astype(f32)
    for i in range(cfg["layers"]):
        pre = f"layer{i}."
        P[pre + "ln1"] = np.ones(e, f32)
        for wn in ["attn.wq", "attn.wk", "attn.wv"]:
            P[pre + wn] = (0.02 * r.standard_normal((e, e))).astype(f32)
        P[pre + "attn.wo"] = (resid * r.standard_normal((e, e))).astype(f32)
        P[pre + "ln2"] = np.ones(e, f32)
        P[pre + "mlp.w1"] = (0.02 * r.standard_normal((e, fdim))).astype(f32)
        if cfg["kind"] == "llama":
            P[pre + "mlp.w2"] = (0.02 * r.standard_normal((e, fdim))).astype(f32)
        P[pre + "mlp.w3"] = (resid * r.standard_normal((fdim, e))).astype(f32)
    P["final_norm"] = np.ones(e, f32)
    P["lm_head"] = (0.02 * r.standard_normal((e, vdim))).astype(f32)
    return P

def norm_rows(cfg, X, g):
    if cfg["kind"] == "llama":
        return np.stack([rmsnorm(r_.astype(np.float64), g.astype(np.float64))
                         for r_ in X]).astype(f32)
    return np.stack([layernorm(r_.astype(np.float64), g.astype(np.float64))
                     for r_ in X]).astype(f32)

def norm_bwd_rows(cfg, X, g, dY):
    dX = np.zeros_like(X, dtype=np.float64)
    dg = np.zeros(len(g), np.float64)
    bwd = rmsnorm_bwd if cfg["kind"] == "llama" else layernorm_bwd
    for i in range(X.shape[0]):
        dx, dgi = bwd(X[i].astype(np.float64), g.astype(np.float64),
                      dY[i].astype(np.float64))
        dX[i] = dx
        dg += dgi
    return dX.astype(f32), dg.astype(f32)

def masked(P, masks, name, b):
    W = P[name].copy()
    return W * np.kron(masks[name], np.ones((b, b), f32))

def model_forward(cfg, P, masks, tokens, targets, save=False):
    """Mirrors NativeBackend::forward (f32 matmuls, f64 loss)."""
    bsz, seq = cfg["batch"], cfg["seq"]
    m = bsz * seq
    e, h = cfg["emb"], cfg["heads"]
    hd = e // h
    b = cfg["block"]
    X = P["tok_emb"][tokens].reshape(m, e).astype(f32)
    if cfg["kind"] == "gpt2":
        X = (X.reshape(bsz, seq, e) + P["pos_emb"][None, :seq]).reshape(m, e).astype(f32)
    saved = []
    for i in range(cfg["layers"]):
        pre = f"layer{i}."
        x_in = X.copy()
        n1 = norm_rows(cfg, X, P[pre + "ln1"])
        q = (n1 @ P[pre + "attn.wq"]).astype(f32)
        kk = (n1 @ P[pre + "attn.wk"]).astype(f32)
        vv = (n1 @ P[pre + "attn.wv"]).astype(f32)
        # (B, h, S, hd)
        qh = q.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3).copy()
        kh = kk.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3).copy()
        vh = vv.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3).copy()
        if cfg["kind"] == "llama":
            for bb in range(bsz):
                for hh in range(h):
                    for s in range(seq):
                        qh[bb, hh, s] = rope(qh[bb, hh, s].astype(np.float64), s).astype(f32)
                        kh[bb, hh, s] = rope(kh[bb, hh, s].astype(np.float64), s).astype(f32)
        att = np.zeros((bsz, h, seq, hd), f32)
        for bb in range(bsz):
            for hh in range(h):
                att[bb, hh] = attn_fwd(qh[bb, hh].astype(np.float64),
                                       kh[bb, hh].astype(np.float64),
                                       vh[bb, hh].astype(np.float64))[0].astype(f32)
        att_m = att.transpose(0, 2, 1, 3).reshape(m, e)
        X = (X + att_m @ P[pre + "attn.wo"]).astype(f32)
        x_mid = X.copy()
        n2 = norm_rows(cfg, X, P[pre + "ln2"])
        w1m = masked(P, masks, pre + "mlp.w1", b)
        w3m = masked(P, masks, pre + "mlp.w3", b)
        h1 = (n2 @ w1m).astype(f32)
        if cfg["kind"] == "llama":
            w2m = masked(P, masks, pre + "mlp.w2", b)
            h2 = (n2 @ w2m).astype(f32)
            act = (silu(h1.astype(np.float64)) * h2).astype(f32)
        else:
            h2 = None
            act = gelu(h1.astype(np.float64)).astype(f32)
        X = (X + act @ w3m).astype(f32)
        if save:
            saved.append(dict(x_in=x_in, n1=n1, qh=qh, kh=kh, vh=vh,
                              att=att_m, x_mid=x_mid, n2=n2, h1=h1, h2=h2, act=act))
    x_final = X.copy()
    xf = norm_rows(cfg, X, P["final_norm"])
    logits = (xf @ P["lm_head"]).astype(f32)
    lmax = logits.max(axis=1, keepdims=True)
    lse = np.log(np.exp((logits - lmax).astype(np.float64)).sum(axis=1)) + lmax[:, 0]
    nll = lse - logits[np.arange(m), targets.ravel()]
    loss = nll.mean()
    return loss, dict(saved=saved, x_final=x_final, xf=xf, logits=logits)

def model_backward(cfg, P, masks, tokens, targets, fwd):
    bsz, seq = cfg["batch"], cfg["seq"]
    m = bsz * seq
    e, h = cfg["emb"], cfg["heads"]
    hd = e // h
    b = cfg["block"]
    G = {k_: np.zeros_like(v_) for k_, v_ in P.items()}
    logits = fwd["logits"]
    pmax = logits.max(axis=1, keepdims=True)
    ex = np.exp((logits - pmax).astype(f32))
    probs = (ex / ex.sum(axis=1, keepdims=True)).astype(f32)
    dlog = probs / f32(m)
    dlog[np.arange(m), targets.ravel()] -= f32(1.0 / m)
    G["lm_head"] = (fwd["xf"].T @ dlog).astype(f32)
    dxf = (dlog @ P["lm_head"].T).astype(f32)
    dX, G["final_norm"] = norm_bwd_rows(cfg, fwd["x_final"], P["final_norm"], dxf)
    for i in reversed(range(cfg["layers"])):
        pre = f"layer{i}."
        a = fwd["saved"][i]
        w1m = masked(P, masks, pre + "mlp.w1", b)
        w3m = masked(P, masks, pre + "mlp.w3", b)
        wmask1 = np.kron(masks[pre + "mlp.w1"], np.ones((b, b), f32))
        wmask3 = np.kron(masks[pre + "mlp.w3"], np.ones((b, b), f32))
        d_act = (dX @ w3m.T).astype(f32)
        G[pre + "mlp.w3"] = ((a["act"].T @ dX) * wmask3).astype(f32)
        if cfg["kind"] == "llama":
            w2m = masked(P, masks, pre + "mlp.w2", b)
            wmask2 = np.kron(masks[pre + "mlp.w2"], np.ones((b, b), f32))
            dh1 = (d_act * a["h2"] * silu_grad(a["h1"].astype(np.float64))).astype(f32)
            dh2 = (d_act * silu(a["h1"].astype(np.float64))).astype(f32)
            G[pre + "mlp.w1"] = ((a["n2"].T @ dh1) * wmask1).astype(f32)
            G[pre + "mlp.w2"] = ((a["n2"].T @ dh2) * wmask2).astype(f32)
            d_n2 = (dh1 @ w1m.T + dh2 @ w2m.T).astype(f32)
        else:
            dh1 = (d_act * gelu_grad(a["h1"].astype(np.float64))).astype(f32)
            G[pre + "mlp.w1"] = ((a["n2"].T @ dh1) * wmask1).astype(f32)
            d_n2 = (dh1 @ w1m.T).astype(f32)
        d_from_n2, G[pre + "ln2"] = norm_bwd_rows(cfg, a["x_mid"], P[pre + "ln2"], d_n2)
        d_x_mid = (dX + d_from_n2).astype(f32)
        d_att = (d_x_mid @ P[pre + "attn.wo"].T).astype(f32)
        G[pre + "attn.wo"] = (a["att"].T @ d_x_mid).astype(f32)
        d_out_h = d_att.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
        dqh = np.zeros((bsz, h, seq, hd))
        dkh = np.zeros((bsz, h, seq, hd))
        dvh = np.zeros((bsz, h, seq, hd))
        for bb in range(bsz):
            for hh in range(h):
                dq_, dk_, dv_ = attn_bwd(a["qh"][bb, hh].astype(np.float64),
                                         a["kh"][bb, hh].astype(np.float64),
                                         a["vh"][bb, hh].astype(np.float64),
                                         d_out_h[bb, hh].astype(np.float64))
                dqh[bb, hh], dkh[bb, hh], dvh[bb, hh] = dq_, dk_, dv_
        if cfg["kind"] == "llama":
            for bb in range(bsz):
                for hh in range(h):
                    for s in range(seq):
                        dqh[bb, hh, s] = rope_bwd(dqh[bb, hh, s], s)
                        dkh[bb, hh, s] = rope_bwd(dkh[bb, hh, s], s)
        dq = dqh.transpose(0, 2, 1, 3).reshape(m, e).astype(f32)
        dk = dkh.transpose(0, 2, 1, 3).reshape(m, e).astype(f32)
        dv = dvh.transpose(0, 2, 1, 3).reshape(m, e).astype(f32)
        d_n1 = (dq @ P[pre + "attn.wq"].T + dk @ P[pre + "attn.wk"].T
                + dv @ P[pre + "attn.wv"].T).astype(f32)
        G[pre + "attn.wq"] = (a["n1"].T @ dq).astype(f32)
        G[pre + "attn.wk"] = (a["n1"].T @ dk).astype(f32)
        G[pre + "attn.wv"] = (a["n1"].T @ dv).astype(f32)
        d_from_n1, G[pre + "ln1"] = norm_bwd_rows(cfg, a["x_in"], P[pre + "ln1"], d_n1)
        dX = (d_x_mid + d_from_n1).astype(f32)
    G["tok_emb"] = np.zeros_like(P["tok_emb"])
    flat = tokens.ravel()
    for i in range(m):
        G["tok_emb"][flat[i]] += dX[i]
    if cfg["kind"] == "gpt2":
        G["pos_emb"] = np.zeros_like(P["pos_emb"])
        dXr = dX.reshape(bsz, seq, e)
        G["pos_emb"][:seq] = dXr.sum(axis=0)
    return G

for kind in ["gpt2", "llama"]:
    cfg = dict(kind=kind, vocab=24, emb=16, ffn=32, layers=2, heads=2,
               seq=6, batch=2, block=8)
    r = np.random.default_rng(7)
    P = init_params(cfg, 7)
    masks = {}
    for i in range(cfg["layers"]):
        pre = f"layer{i}."
        names = ["mlp.w1", "mlp.w3"] + (["mlp.w2"] if kind == "llama" else [])
        for wn in names:
            shape = P[pre + wn].shape
            grid = (shape[0] // 8, shape[1] // 8)
            masks[pre + wn] = (r.random(grid) > 0.4).astype(f32)
    tokens = r.integers(0, 24, size=(2, 6))
    targets = r.integers(0, 24, size=(2, 6))
    loss, fwd = model_forward(cfg, P, masks, tokens, targets, save=True)
    G = model_backward(cfg, P, masks, tokens, targets, fwd)
    # masked-grad invariant
    for name, mask in masks.items():
        wm = np.kron(mask, np.ones((8, 8), f32))
        check(f"{kind} {name} grad masked", np.all(G[name][wm == 0] == 0.0))
    # global directional fd (the Rust gate)
    gnorm = np.sqrt(sum(float((g_ ** 2).sum()) for g_ in G.values()))
    eps_d = 1e-2
    Pp = {k_: (v_ + eps_d * G[k_] / gnorm).astype(f32) for k_, v_ in P.items()}
    Pm = {k_: (v_ - eps_d * G[k_] / gnorm).astype(f32) for k_, v_ in P.items()}
    lp, _ = model_forward(cfg, Pp, masks, tokens, targets)
    lm, _ = model_forward(cfg, Pm, masks, tokens, targets)
    fd = (lp - lm) / (2 * eps_d)
    rel = abs(fd - gnorm) / gnorm
    print(f"  {kind}: |g|={gnorm:.5f} fd={fd:.5f} rel={rel:.2e}")
    check(f"{kind} global directional fd rel<=1e-3", rel <= 1e-3)
    # per-tensor directional fd (the 2e-2 localization bound)
    worst = 0.0
    for name in P:
        tn = np.sqrt(float((G[name] ** 2).sum()))
        if tn < 1e-4:
            continue
        Pp = dict(P)
        Pm = dict(P)
        Pp[name] = (P[name] + eps_d * G[name] / tn).astype(f32)
        Pm[name] = (P[name] - eps_d * G[name] / tn).astype(f32)
        lp, _ = model_forward(cfg, Pp, masks, tokens, targets)
        lm, _ = model_forward(cfg, Pm, masks, tokens, targets)
        fd = (lp - lm) / (2 * eps_d)
        rel = abs(fd - tn) / tn
        worst = max(worst, rel)
        assert rel <= 2e-2, f"{kind}/{name}: rel {rel:.2e}"
    print(f"  {kind}: worst per-tensor rel {worst:.2e}")
    check(f"{kind} per-tensor fd", True)

# ---------------------------------------------------------------------------
# 8. AdamW vs the JAX reference formula
# ---------------------------------------------------------------------------
B1, B2, EPS, WD, LR = 0.9, 0.95, 1e-8, 0.01, 1e-3

def adam_rust(p, g, m_, v_, step):
    t = step + 1
    c1 = 1 - B1 ** t
    c2 = 1 - B2 ** t
    nm = B1 * m_ + (1 - B1) * g
    nv = B2 * v_ + (1 - B2) * g * g
    upd = (nm / c1) / (np.sqrt(nv / c2) + EPS)
    return p - LR * (upd + WD * p), nm, nv

p = rng.standard_normal(50).astype(f32)
g = rng.standard_normal(50).astype(f32)
m_ = np.zeros(50, f32)
v_ = np.zeros(50, f32)
for step in range(5):
    p, m_, v_ = adam_rust(p, g, m_, v_, step)
# reference: jax adam_update transliterated independently
pr = rng2 = None
p2 = p.copy()  # compare trajectories computed two ways
p_ref = np.array(p, f32)
# recompute from scratch with float64 reference
p64 = None
p_r = rng.standard_normal(50)
# direct one-step identity check instead:
p0 = np.full(3, 1.0, f32)
g0 = np.full(3, 0.5, f32)
m0 = np.zeros(3, f32)
v0 = np.zeros(3, f32)
p1, m1, v1 = adam_rust(p0, g0, m0, v0, 0)
# by hand: t=1, c1=0.1, c2=0.05; nm=0.05, nv=0.0125; upd=(0.5)/(sqrt(0.25)+eps)
want = 1.0 - LR * (0.5 / (np.sqrt(0.25) + EPS) + WD * 1.0)
check("adamw hand-checked step", np.max(np.abs(p1 - want)) < 1e-7)
check("adamw moments", abs(m1[0] - 0.05) < 1e-8 and abs(v1[0] - 0.0125) < 1e-8)

print(f"\nALL OK ({ok_count} checks)")
