"""Numpy float32 transliteration of PR 5's SIMD backend (rust/src/kernels/simd.rs).

No Rust toolchain ships in this container (same as PRs 1-4), so every piece
of new *math* is validated here against float64 oracles:

  1. the Cephes vector exp polynomial (exp_ps) with exact FMA emulation,
     over the full clamped range, vs np.exp in float64;
  2. silu/gelu/tanh/gelu' built on exp_ps vs float64 references AND vs the
     scalar f32 formulas (the parity the in-tree property tests gate);
  3. the AVX2 8x8 transpose network (unpacklo/unpackhi/shuffle_ps
     0x44,0xEE/permute2f128 0x20,0x31) and the NEON trn1/trn2 4x4 network,
     emulated lane-by-lane, == matrix transpose;
  4. the full pack_kt routine (blocked body + k/row remainders);
  5. the microkernel_d tiling loop (32/16/8/rem column chunks, 4/2 row
     steps): every C element visited exactly once, correct slot shapes,
     and the epilogue contract (exactly-once at final accumulation) for
     all 7 Epilogue variants, including the vector-lane offsets;
  6. tile_bspmm's last-resident-block epilogue placement + the
     pruned-column region rule (zero-preserving skip vs bias apply);
  7. the reordered fused MLPs (h2 first, SiluGate epilogue on the W1
     contraction) vs the unfused oracles;
  8. swiglu_bwd / gelu' lanes vs central finite differences;
  9. the three-pass softmax decomposition (row_max / exp_shift_sum /
     scale) and the streaming-softmax rescale with the new scale_max lane;
 10. dot-lane accumulator splitting and the hsum/hmax shuffle networks;
 11. the 64-byte scratch alignment window arithmetic.

Run: python3 python/tests/simd_check.py   (prints ALL OK on success)
"""

import numpy as np

checks = []


def check(name, ok):
    checks.append((name, bool(ok)))
    print(("PASS" if ok else "FAIL"), name)
    assert ok, name


f32 = np.float32
f64 = np.float64


def fma(a, b, c):
    """Exact f32 FMA: one rounding of the exact product-sum (f64 holds
    f32*f32 exactly, so rounding the f64 result == hardware fmadd)."""
    return f32(f64(a) * f64(b) + f64(c))


# ---------------------------------------------------------------------
# 1. exp_ps — Cephes polynomial, FMA where the Rust code uses it
# ---------------------------------------------------------------------

LOG2E = f32(1.4426950408889634)
C1 = f32(0.693359375)
C2 = f32(-2.12194440e-4)
P = [f32(x) for x in (1.9875691500e-4, 1.3981999507e-3, 8.3334519073e-3,
                      4.1665795894e-2, 1.6666665459e-1, 5.0000001201e-1)]


def exp_ps(x):
    x = np.clip(f32(x), f32(-88.0), f32(88.0))
    fx = np.floor(fma(x, LOG2E, f32(0.5)))
    r = f32(f32(x - f32(fx * C1)) - f32(fx * C2))
    r2 = f32(r * r)
    p = P[0]
    for c in P[1:]:
        p = fma(p, r, c)
    y = fma(p, r2, f32(r + f32(1.0)))
    n = fx.astype(np.int32) if hasattr(fx, 'astype') else np.int32(fx)
    pow2n = ((n + 127) << 23).astype(np.int32).view(f32) if hasattr(n, 'astype') \
        else np.int32((int(n) + 127) << 23).view(f32)
    return f32(y * pow2n)


xs = np.arange(-87.0, 8.0, 0.0037, dtype=f32)
got = exp_ps(xs)
want = np.exp(xs.astype(f64))
rel = np.abs(got.astype(f64) - want) / np.maximum(want, 1e-38)
check(f"exp_ps rel err over [-87,8): max {rel.max():.2e} < 3e-7", rel.max() < 3e-7)

# clamp region: saturates finite, never inf/nan
big = exp_ps(np.array([1e30, 200.0, -1e30], dtype=f32))
check("exp_ps clamp finite", np.all(np.isfinite(big)) and big[2] >= 0.0)

# ---------------------------------------------------------------------
# 2. activations built on exp_ps vs f64 refs and scalar-f32 formulas
# ---------------------------------------------------------------------

GC = f32(0.7978846)
GA = f32(0.044715)


def silu_ps(x):
    x = f32(x)
    return f32(x / f32(f32(1.0) + exp_ps(-x)))


def sigmoid_ps(x):
    x = f32(x)
    return f32(f32(1.0) / f32(f32(1.0) + exp_ps(-x)))


def gelu_u(x):
    x = f32(x)
    x2 = f32(x * x)
    inner = fma(f32(GA * x2), x, x)
    return f32(GC * inner)


def gelu_ps(x):
    x = f32(x)
    u = gelu_u(x)
    e = exp_ps(f32(u + u))
    return f32(x * f32(e / f32(e + f32(1.0))))


def tanh_ps(u):
    u = f32(u)
    e = exp_ps(f32(u + u))
    return f32(f32(e - f32(1.0)) / f32(e + f32(1.0)))


def gelu_grad_ps(x):
    x = f32(x)
    t = tanh_ps(gelu_u(x))
    x2 = f32(x * x)
    du = f32(GC * fma(f32(3.0) * GA, x2, f32(1.0)))
    sech2 = f32(f32(1.0) - f32(t * t))
    lhs = f32(f32(0.5) * f32(f32(1.0) + t))
    return fma(f32(f32(0.5) * x) * sech2, du, lhs)


def silu_scalar(x):  # ops::silu, f32 arithmetic with libm exp
    x = f32(x)
    return f32(x / f32(f32(1.0) + f32(np.exp(f32(-x)))))


def gelu_scalar(x):  # ops::gelu (tanh form)
    x = f32(x)
    inner = f32(GC * f32(x + f32(GA * f32(x * x * x))))
    return f32(f32(0.5) * x * f32(f32(1.0) + f32(np.tanh(inner))))


xs = np.arange(-12.0, 12.0, 0.0011, dtype=f32)
sv = silu_ps(xs)
sref = xs.astype(f64) / (1.0 + np.exp(-xs.astype(f64)))
err = np.abs(sv.astype(f64) - sref)
tol = 1e-6 + 1e-6 * np.abs(sref)
check(f"silu_ps vs f64 ref: max excess {(err - tol).max():.2e}", np.all(err <= tol))
scal = np.array([silu_scalar(v) for v in xs])
err = np.abs(sv.astype(f64) - scal.astype(f64))
check("silu_ps vs scalar-arm silu <= 1e-6+1e-6|x| (in-tree gate)",
      np.all(err <= 1e-6 + 1e-6 * np.abs(scal.astype(f64))))

gv = gelu_ps(xs)
x64 = xs.astype(f64)
gref = 0.5 * x64 * (1.0 + np.tanh(0.7978845608 * (x64 + 0.044715 * x64 ** 3)))
err = np.abs(gv.astype(f64) - gref)
check("gelu_ps vs f64 ref", np.all(err <= 2e-6 + 2e-6 * np.abs(gref)))
scal = np.array([gelu_scalar(v) for v in xs])
err = np.abs(gv.astype(f64) - scal.astype(f64))
check("gelu_ps vs scalar-arm gelu <= 1e-6+1e-6|x|",
      np.all(err <= 1e-6 + 1e-6 * np.abs(scal.astype(f64))))

tv = tanh_ps(xs)
err = np.abs(tv.astype(f64) - np.tanh(x64))
check("tanh_ps vs f64 tanh", np.all(err <= 2e-6))

# gelu' lane vs central finite differences of the f64 gelu
h = 1e-4
fd = (0.5 * (x64 + h) * (1 + np.tanh(0.7978845608 * ((x64 + h) + 0.044715 * (x64 + h) ** 3)))
      - 0.5 * (x64 - h) * (1 + np.tanh(0.7978845608 * ((x64 - h) + 0.044715 * (x64 - h) ** 3)))) / (2 * h)
gg = gelu_grad_ps(xs)
check(f"gelu_grad_ps vs finite diff: max {np.abs(gg - fd).max():.2e} < 1e-3",
      np.abs(gg.astype(f64) - fd).max() < 1e-3)

# swiglu_bwd lane formulas vs finite differences of silu(h1)*h2
rng = np.random.default_rng(7)
h1 = rng.standard_normal(4096).astype(f32)
h2 = rng.standard_normal(4096).astype(f32)
da = rng.standard_normal(4096).astype(f32)
s = sigmoid_ps(h1)
sil = f32(h1 * s)
grad = f32(s * fma(h1, f32(f32(1.0) - s), f32(1.0)))
dh1 = f32(f32(da * h2) * grad)
dh2 = f32(da * sil)
h164 = h1.astype(f64)
sil64 = h164 / (1 + np.exp(-h164))
fd1 = da.astype(f64) * h2.astype(f64) * (
    ((h164 + h) / (1 + np.exp(-(h164 + h))) - (h164 - h) / (1 + np.exp(-(h164 - h)))) / (2 * h))
check("swiglu_bwd dh1 vs finite diff", np.abs(dh1.astype(f64) - fd1).max() < 1e-3)
check("swiglu_bwd dh2 == d_act*silu(h1)", np.abs(dh2.astype(f64) - da.astype(f64) * sil64).max() < 2e-6)

# ---------------------------------------------------------------------
# 3. transpose networks
# ---------------------------------------------------------------------


def unpacklo(a, b):
    # per 128-bit lane: [a0 b0 a1 b1]
    return np.array([a[0], b[0], a[1], b[1], a[4], b[4], a[5], b[5]], dtype=a.dtype)


def unpackhi(a, b):
    return np.array([a[2], b[2], a[3], b[3], a[6], b[6], a[7], b[7]], dtype=a.dtype)


def shuffle_ps(a, b, imm):
    s = [(imm >> (2 * i)) & 3 for i in range(4)]
    out = np.empty(8, dtype=a.dtype)
    for lane in (0, 4):
        out[lane + 0] = a[lane + s[0]]
        out[lane + 1] = a[lane + s[1]]
        out[lane + 2] = b[lane + s[2]]
        out[lane + 3] = b[lane + s[3]]
    return out


def permute2f128(a, b, imm):
    def sel(code):
        src = a if (code & 2) == 0 else b
        half = code & 1
        return src[half * 4:half * 4 + 4]
    return np.concatenate([sel(imm & 0xF), sel((imm >> 4) & 0xF)])


def transpose8x8_net(rows):
    r = rows
    t = [unpacklo(r[0], r[1]), unpackhi(r[0], r[1]),
         unpacklo(r[2], r[3]), unpackhi(r[2], r[3]),
         unpacklo(r[4], r[5]), unpackhi(r[4], r[5]),
         unpacklo(r[6], r[7]), unpackhi(r[6], r[7])]
    s0 = shuffle_ps(t[0], t[2], 0x44); s1 = shuffle_ps(t[0], t[2], 0xEE)
    s2 = shuffle_ps(t[1], t[3], 0x44); s3 = shuffle_ps(t[1], t[3], 0xEE)
    s4 = shuffle_ps(t[4], t[6], 0x44); s5 = shuffle_ps(t[4], t[6], 0xEE)
    s6 = shuffle_ps(t[5], t[7], 0x44); s7 = shuffle_ps(t[5], t[7], 0xEE)
    return np.stack([
        permute2f128(s0, s4, 0x20), permute2f128(s1, s5, 0x20),
        permute2f128(s2, s6, 0x20), permute2f128(s3, s7, 0x20),
        permute2f128(s0, s4, 0x31), permute2f128(s1, s5, 0x31),
        permute2f128(s2, s6, 0x31), permute2f128(s3, s7, 0x31)])


m = rng.standard_normal((8, 8)).astype(f32)
check("AVX2 8x8 unpack/shuffle/permute network == transpose",
      np.array_equal(transpose8x8_net([m[i] for i in range(8)]), m.T))


def vtrn1q_f32(a, b):
    return np.array([a[0], b[0], a[2], b[2]], dtype=a.dtype)


def vtrn2q_f32(a, b):
    return np.array([a[1], b[1], a[3], b[3]], dtype=a.dtype)


def vtrn1q_f64(a, b):  # on f32x4 viewed as f64x2: take element 0 pairs
    return np.concatenate([a[0:2], b[0:2]])


def vtrn2q_f64(a, b):
    return np.concatenate([a[2:4], b[2:4]])


m4 = rng.standard_normal((4, 4)).astype(f32)
t0 = vtrn1q_f32(m4[0], m4[1]); t1 = vtrn2q_f32(m4[0], m4[1])
t2 = vtrn1q_f32(m4[2], m4[3]); t3 = vtrn2q_f32(m4[2], m4[3])
o = np.stack([vtrn1q_f64(t0, t2), vtrn1q_f64(t1, t3),
              vtrn2q_f64(t0, t2), vtrn2q_f64(t1, t3)])
check("NEON 4x4 trn network == transpose", np.array_equal(o, m4.T))


# ---------------------------------------------------------------------
# 4. pack_kt full routine (blocked body + remainders), both block sizes
# ---------------------------------------------------------------------


def pack_kt_emulated(src, rows, k, blk):
    """Mirror of avx2::pack_kt_impl / neon::pack_kt_impl index flow."""
    out = np.full(rows * k, np.nan, dtype=f32)
    r0 = 0
    while r0 + blk <= rows:
        k0 = 0
        while k0 + blk <= k:
            sub = src[r0:r0 + blk, k0:k0 + blk]
            tr = transpose8x8_net([sub[i] for i in range(8)]) if blk == 8 else sub.T
            for kk in range(blk):
                out[(k0 + kk) * rows + r0:(k0 + kk) * rows + r0 + blk] = tr[kk]
            k0 += blk
        for kk in range(k0, k):
            for i in range(blk):
                out[kk * rows + r0 + i] = src[r0 + i, kk]
        r0 += blk
    for r in range(r0, rows):
        for kk in range(k):
            out[kk * rows + r] = src[r, kk]
    return out


ok = True
for blk in (8, 4):
    for rows in (1, 3, 4, 5, 7, 8, 9, 12, 16, 17):
        for k in (1, 2, 4, 7, 8, 9, 16, 19):
            src = rng.standard_normal((rows, k)).astype(f32)
            got = pack_kt_emulated(src, rows, k, blk)
            want = src.T.reshape(-1)  # out[kk*rows + r] = src[r, kk]
            if not np.array_equal(got, want):
                ok = False
                print("pack_kt mismatch", blk, rows, k)
check("pack_kt emulation (blocked body + remainders) == transpose, 80 shapes", ok)


# ---------------------------------------------------------------------
# 5. microkernel_d tiling + epilogue exactly-once, all variants
# ---------------------------------------------------------------------


def ep_apply(ep, v, i, j):
    kind = ep[0]
    if kind == 'none':
        return f32(v)
    if kind == 'bias':
        return f32(v + ep[1][j])
    if kind == 'bias_gelu':
        return gelu_scalar(f32(v + ep[1][j]))
    if kind == 'bias_silu':
        return silu_scalar(f32(v + ep[1][j]))
    if kind == 'gelu':
        return gelu_scalar(v)
    if kind == 'silu':
        return silu_scalar(v)
    if kind == 'silu_gate':
        g, ldg = ep[1], ep[2]
        return f32(silu_scalar(v) * g[i * ldg + j])
    raise AssertionError(kind)


def ep_shift(ep, i0, j0):
    kind = ep[0]
    if kind in ('none', 'gelu', 'silu'):
        return ep
    if kind in ('bias', 'bias_gelu', 'bias_silu'):
        return (kind, ep[1][j0:])
    if kind == 'silu_gate':
        return (kind, ep[1][ep[2] * i0 + j0:], ep[2])
    raise AssertionError(kind)


def mk_scalar(ap, lda, rows, bp, ldb, cols, k, c, ldc, ep):
    """One register tile: sequential accumulate then epilogue at writeback
    (the scalar-arm semantics every SIMD arm is parity-gated against)."""
    for i in range(rows):
        for j in range(cols):
            acc = f32(0.0)
            for kk in range(k):
                acc = f32(acc + f32(ap[kk * lda + i] * bp[kk * ldb + j]))
            c[i * ldc + j] = ep_apply(ep, f32(c[i * ldc + j] + acc), i, j)


def microkernel_d_emulated(ap, lda, rows, bp, ldb, cols, k, c, ldc, ep):
    """Mirror of microkernel.rs::microkernel_d's tiling loop."""
    visited = np.zeros((rows, cols), dtype=int)
    j0 = 0
    while j0 < cols:
        rem = cols - j0
        take = 32 if rem >= 32 else 16 if rem >= 16 else 8 if rem >= 8 else rem
        rstep = 2 if take == 32 else 4
        i0 = 0
        while i0 < rows:
            r = min(rows - i0, rstep)
            # slot validity: specialized tiles require exact shapes
            if (r == 2 and take == 32) or (r == 4 and take in (16, 8)):
                pass  # specialized slot
            else:
                assert r <= 4 and take <= 32, (r, take)  # tail slot contract
            mk_scalar(ap[i0:], lda, r, bp[j0:], ldb, take, k,
                      c[i0 * ldc + j0:], ldc, ep_shift(ep, i0, j0))
            visited[i0:i0 + r, j0:j0 + take] += 1
            i0 += r
        j0 += take
    assert np.all(visited == 1), "every C element written exactly once"


def run_mk_case(rows, cols, k, ep_kind):
    lda, ldb, ldc = rows + 1, cols + 2, cols + 3
    ap = rng.standard_normal(max(k, 1) * lda).astype(f32)
    bp = rng.standard_normal(max(k, 1) * ldb).astype(f32)
    c0 = rng.standard_normal((rows - 1) * ldc + cols).astype(f32)
    bias = rng.standard_normal(cols).astype(f32)
    ldg = cols + 2
    gate = rng.standard_normal(rows * ldg).astype(f32)
    eps = {'none': ('none',), 'bias': ('bias', bias), 'bias_gelu': ('bias_gelu', bias),
           'bias_silu': ('bias_silu', bias), 'gelu': ('gelu',), 'silu': ('silu',),
           'silu_gate': ('silu_gate', gate, ldg)}
    ep = eps[ep_kind]
    c = c0.copy()
    # note: emulation slices copy in numpy; emulate rust's in-place via views
    cview = c  # 1-D ndarray slices are views -> in-place works
    microkernel_d_emulated(ap, lda, rows, bp, ldb, cols, k, cview, ldc, ep)
    # oracle: full-depth accumulate + epilogue once
    want = c0.copy().astype(f64)
    for i in range(rows):
        for j in range(cols):
            s = want[i * ldc + j]
            for kk in range(k):
                s += f64(ap[kk * lda + i]) * f64(bp[kk * ldb + j])
            want[i * ldc + j] = ep_apply(ep, f32(s), i, j)
    err = np.abs(c.astype(f64) - want)
    lim = 1e-4 + 1e-4 * np.abs(want)
    return np.all(err[:(rows - 1) * ldc + cols] <= lim[:(rows - 1) * ldc + cols])


ok = True
cases = 0
for ep_kind in ('none', 'bias', 'bias_gelu', 'bias_silu', 'gelu', 'silu', 'silu_gate'):
    for (rows, cols, k) in ((1, 1, 1), (4, 16, 5), (4, 8, 3), (2, 32, 7), (5, 70, 9),
                            (13, 33, 0), (7, 31, 4), (16, 48, 2), (3, 8, 6), (9, 40, 8)):
        cases += 1
        if not run_mk_case(rows, cols, k, ep_kind):
            ok = False
            print("mk case failed", ep_kind, rows, cols, k)
check(f"microkernel_d tiling+epilogue exactly-once, {cases} cases (incl. k=0)", ok)


# ---------------------------------------------------------------------
# 6/7. tile_bspmm epilogue placement + fused MLP ordering
# ---------------------------------------------------------------------


def bcsc(dense, mask, b):
    """column-major resident block list per block column."""
    rb, cb = mask.shape
    cols = []
    for bc in range(cb):
        cols.append([br for br in range(rb) if mask[br, bc]])
    return cols


def tile_bspmm_emulated(x, w, mask, b, ep):
    """Mirror of bspmm.rs::tile_bspmm_packed: accumulate per block column,
    epilogue on the LAST resident block only; pruned columns get the
    region rule."""
    m, k = x.shape
    n = w.shape[1]
    y = np.zeros((m, n), dtype=f32)
    cols = bcsc(w, mask, b)
    for bc, residents in enumerate(cols):
        if not residents:
            if ep[0] in ('bias', 'bias_gelu', 'bias_silu'):  # not zero-preserving
                for i in range(m):
                    for j in range(b):
                        y[i, bc * b + j] = ep_apply(ep, y[i, bc * b + j], i, bc * b + j)
            continue
        for bi, br in enumerate(residents):
            blk = w[br * b:(br + 1) * b, bc * b:(bc + 1) * b]
            acc = (x[:, br * b:(br + 1) * b].astype(f64) @ blk.astype(f64)).astype(f32)
            last = bi + 1 == len(residents)
            for i in range(m):
                for j in range(b):
                    v = f32(y[i, bc * b + j] + acc[i, j])
                    y[i, bc * b + j] = ep_apply(ep, v, i, bc * b + j) if last else v
    return y


b = 4
rb, cb, m = 3, 4, 5
x = rng.standard_normal((m, rb * b)).astype(f32)
w = rng.standard_normal((rb * b, cb * b)).astype(f32)
mask = rng.random((rb, cb)) > 0.4
mask[:, 0] = False  # force one fully-pruned column
wm = w * np.repeat(np.repeat(mask, b, 0), b, 1)
bias = rng.standard_normal(cb * b).astype(f32)
gate = rng.standard_normal((m, cb * b)).astype(f32)

ok = True
for ep in (('none',), ('gelu',), ('silu',), ('silu_gate', gate.reshape(-1), cb * b),
           ('bias', bias), ('bias_gelu', bias), ('bias_silu', bias)):
    got = tile_bspmm_emulated(x, wm, mask, b, ep)
    base = x.astype(f64) @ wm.astype(f64)
    want = np.empty_like(base)
    for i in range(m):
        for j in range(cb * b):
            want[i, j] = ep_apply(ep, f32(base[i, j]), i, j)
    if np.abs(got.astype(f64) - want).max() > 1e-4 + 1e-4 * np.abs(want).max():
        ok = False
        print("tile_bspmm ep failed", ep[0])
check("tile_bspmm last-block epilogue + pruned-column rule, 7 variants", ok)

# fused MLP ordering: h2 first, then h1 with SiluGate == unfused oracle
e, f_dim = rb * b, cb * b
w1 = rng.standard_normal((e, f_dim)).astype(f32)
w2 = rng.standard_normal((e, f_dim)).astype(f32)
w3 = rng.standard_normal((f_dim, e)).astype(f32)
m1 = rng.random((rb, cb)) > 0.3
m2 = rng.random((rb, cb)) > 0.3
m3 = rng.random((cb, rb)) > 0.3
w1m = w1 * np.repeat(np.repeat(m1, b, 0), b, 1)
w2m = w2 * np.repeat(np.repeat(m2, b, 0), b, 1)
w3m = w3 * np.repeat(np.repeat(m3, b, 0), b, 1)
h2v = tile_bspmm_emulated(x, w2m, m2, b, ('none',))
h1v = tile_bspmm_emulated(x, w1m, m1, b, ('silu_gate', h2v.reshape(-1), f_dim))
yv = tile_bspmm_emulated(h1v, w3m, m3, b, ('none',))
h1_64 = x.astype(f64) @ w1m.astype(f64)
h2_64 = x.astype(f64) @ w2m.astype(f64)
act = (h1_64 / (1 + np.exp(-h1_64))) * h2_64
want = act @ w3m.astype(f64)
check("fused_mlp (h2-first + SiluGate epilogue) vs unfused oracle",
      np.abs(yv.astype(f64) - want).max() < 1e-3)

hg = tile_bspmm_emulated(x, w1m, m1, b, ('gelu',))
yg = tile_bspmm_emulated(hg, w3m, m3, b, ('none',))
gact = 0.5 * h1_64 * (1 + np.tanh(0.7978845608 * (h1_64 + 0.044715 * h1_64 ** 3)))
check("gelu_mlp (Gelu epilogue) vs unfused oracle",
      np.abs(yg.astype(f64) - (gact @ w3m.astype(f64))).max() < 1e-3)


# ---------------------------------------------------------------------
# 9. softmax decomposition + streaming rescale with the new lanes
# ---------------------------------------------------------------------


def row_max(v):
    return f32(v.max()) if len(v) else f32(-np.inf)


def scale_max(v, scale):
    v *= f32(scale)
    return row_max(v)


def exp_shift_sum(v, shift):
    v[:] = np.exp(v.astype(f64) - f64(shift)).astype(f32)
    return f32(v.astype(f64).sum())  # order differs per arm; gate vs f64


for n in (1, 2, 7, 9, 64):
    v = rng.standard_normal(n).astype(f32) * 3
    ref = np.exp(v.astype(f64) - v.astype(f64).max())
    ref /= ref.sum()
    w_ = v.copy()
    mx = row_max(w_)
    sm = exp_shift_sum(w_, mx)
    w_ *= f32(1.0 / sm)
    assert np.abs(w_.astype(f64) - ref).max() < 1e-6, n
check("three-pass softmax == oracle (5 lengths)", True)

# streaming softmax across k-tiles using scale_max (the causal_tile flow)
seq, tk, scale = 37, 8, f32(0.33)
scores = rng.standard_normal(seq).astype(f32)
mcur, lcur, acc = f32(-np.inf), f32(0.0), 0.0
vvals = rng.standard_normal(seq).astype(f32)
for k0 in range(0, seq, tk):
    srow = scores[k0:k0 + tk].copy()
    rmax = scale_max(srow, scale)
    new_m = max(mcur, rmax)
    alpha = f32(np.exp(f64(mcur) - f64(new_m))) if np.isfinite(new_m) else f32(1.0)
    acc = acc * f64(alpha)
    rsum = exp_shift_sum(srow, new_m)
    acc += (srow.astype(f64) * vvals[k0:k0 + tk].astype(f64)).sum()
    lcur = f32(lcur * alpha + rsum)
    mcur = new_m
stream = acc / f64(lcur)
p = np.exp(scores.astype(f64) * f64(scale))
p /= p.sum()
check("streaming softmax with scale_max/exp_shift_sum lanes == naive",
      abs(stream - (p * vvals.astype(f64)).sum()) < 1e-5)


# ---------------------------------------------------------------------
# 10. dot-lane splitting + hsum/hmax shuffle networks
# ---------------------------------------------------------------------


def dot_lanes_split(a, b_, w):
    """two accumulators over 2w-wide chunks, one w chunk, scalar tail —
    mirrors avx2::dot_impl (w=8) / neon::dot_impl (w=4)."""
    n = len(a)
    acc0 = np.zeros(w, dtype=f64)
    acc1 = np.zeros(w, dtype=f64)
    i = 0
    while i + 2 * w <= n:
        acc0 += a[i:i + w].astype(f64) * b_[i:i + w].astype(f64)
        acc1 += a[i + w:i + 2 * w].astype(f64) * b_[i + w:i + 2 * w].astype(f64)
        i += 2 * w
    if i + w <= n:
        acc0 += a[i:i + w].astype(f64) * b_[i:i + w].astype(f64)
        i += w
    s = (acc0 + acc1).sum()
    for j in range(i, n):
        s += f64(a[j]) * f64(b_[j])
    return s


for n in (0, 1, 7, 8, 15, 16, 17, 31, 64, 65):
    a = rng.standard_normal(n).astype(f32)
    b_ = rng.standard_normal(n).astype(f32)
    for w in (8, 4):
        got = dot_lanes_split(a, b_, w)
        want = (a.astype(f64) * b_.astype(f64)).sum()
        assert abs(got - want) < 1e-9 * max(1, n), (n, w)
check("dot lane accumulator splitting covers every element once", True)


def hsum_net(v):
    # _mm_add_ps(lo, hi) -> movehl -> shuffle(0b01) -> add_ss
    q = v[:4] + v[4:]
    d = q + np.array([q[2], q[3], q[2], q[3]], dtype=q.dtype)
    s = d[0] + d[1]
    return s


def hmax_net(v):
    q = np.maximum(v[:4], v[4:])
    d = np.maximum(q, np.array([q[2], q[3], q[2], q[3]], dtype=q.dtype))
    return max(d[0], d[1])


v = rng.standard_normal(8).astype(f64)
check("hsum shuffle network sums all 8 lanes", abs(hsum_net(v) - v.sum()) < 1e-12)
check("hmax shuffle network maxes all 8 lanes", hmax_net(v) == v.max())


# ---------------------------------------------------------------------
# 11. scratch 64-byte alignment window arithmetic
# ---------------------------------------------------------------------

ok = True
for base in range(0, 4 * 64, 4):  # any 4-byte-aligned Vec allocation
    # align_offset semantics: elements to advance so (base + 4*off) % 64 == 0
    off = ((-base) % 64) // 4
    if off > 15 or (base + 4 * off) % 64 != 0:
        ok = False
for ln in (0, 1, 13):
    # backing length = len + 15 always covers the window
    if not all(((-b) % 64) // 4 + ln <= ln + 15 for b in range(0, 256, 4)):
        ok = False
check("scratch 64B window: off <= 15, aligned, always inside len+15 backing", ok)


print()
names = [n for n, _ in checks]
assert len(names) == len(set(names)), "duplicate check names"
failed = [n for n, okk in checks if not okk]
print(f"{len(checks)} checks, {len(checks) - len(failed)} passed.")
print("ALL OK" if not failed else f"FAILED: {failed}")
assert not failed
