"""Pure-python/numpy transliteration of PR 7's copy-on-write KV prefix
sharing (rust/src/model/kv.rs, the engine resume path, and the tail-only
admission charge in rust/src/coordinator/server.rs).

No Rust toolchain ships in this container (same as PRs 1-6), so the new
sharing logic is pinned here against independent oracles:

  1. the chained FNV-1a prefix hash (fnv1a_token over each token's four
     little-endian bytes, offset basis 0xcbf29ce484222325): per-page chain
     values vs a one-shot byte-stream FNV-1a oracle, the extension
     property (a longer prompt's key chain extends the shorter's without
     rehashing), and divergence (the first differing page changes every
     key from that page on);
  2. page-table math: pages_for = ceil-div, page_floats, and the
     head-major stripe layout ((layer*2 + which)*heads + head)*page*hd
     tiling a page's floats exactly once (a partition check), plus
     write_pos / k_head index arithmetic vs a dense
     [layer][k|v][head][pos] oracle store;
  3. the prefill_resume gather (rows = min(seq - base, page),
     dst = h*seq*hd + base*hd) reassembling paged K/V into the flat
     (heads, seq, hd) attention operand == a never-paged fill;
  4. a reference-counted pool simulation (attach / probe / register /
     make_private / drop with the exact-token verification,
     skip-live-donor, single-key-per-page and purge-on-last-drop rules)
     driven by randomized session mixes: logical >= physical always, CoW
     is logical-neutral and +1 physical, canary writes never reach the
     donor page, hash collisions are rejected by token comparison, and
     pages, mappings and index entries all drain to zero at retirement;
  5. the tail-only admission charge (full = pages_for(len+1), charge =
     full - probe, probe discounting the page a full hit copy-on-writes):
     fuzzed across page-1/page/page+1 boundaries, never negative, and
     always >= the physical pages the resumed prefill + one decode step
     actually draw;
  6. the deferred-retry accounting property: a deferred request is
     re-probed fresh each admission sweep (it holds no reservation while
     queued), so a request deferred before its donor registered admits on
     the tail-only charge afterwards and peak physical stays <= capacity
     -- the double-count the fuzz extension guards against;
  7. the offset-attention tiling schedule (k-tile boundaries at absolute
     multiples of TK, kend = offset + i1, valid = clamp(gi+1-k0, 0, tk)):
     for every global row the resume-path (k0, valid) schedule restricted
     to contributing tiles equals the full-prefill schedule -- identical
     float ops, hence the bitwise-identical resume the Rust tests gate --
     and valid == 0 tiles arise only when offset > 0.

Run: python3 python/tests/prefix_share_check.py   (prints ALL OK on success)
"""

import random

import numpy as np

checks = []


def check(name, ok):
    checks.append((name, bool(ok)))
    print(("PASS" if ok else "FAIL"), name)
    assert ok, name


# ---------------------------------------------------------------------
# 1. chained FNV-1a prefix hash
# ---------------------------------------------------------------------

FNV_OFFSET = 0xCBF2_9CE4_8422_2325
FNV_PRIME = 0x100_0000_01B3
MASK64 = (1 << 64) - 1


def fnv1a_token(h, token):
    """rust/src/model/kv.rs fnv1a_token: fold the token's 4 LE bytes."""
    for b in int(token).to_bytes(4, "little"):
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def fnv1a_bytes(data):
    """Independent oracle: textbook FNV-1a over a raw byte stream."""
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def chain_keys(tokens, page):
    """Index key per full page: chain value after page pi's tokens keys
    the (pi+1)*page-token prefix. Only full pages are keyed."""
    keys = []
    h = FNV_OFFSET
    for pi in range(len(tokens) // page):
        for t in tokens[pi * page : (pi + 1) * page]:
            h = fnv1a_token(h, t)
        keys.append(h)
    return keys


rng = random.Random(0xB1A57)

toks = [rng.randrange(1 << 32) for _ in range(48)]
for page in (1, 3, 4, 16):
    keys = chain_keys(toks, page)
    oracle = [
        fnv1a_bytes(b"".join(int(t).to_bytes(4, "little") for t in toks[: (pi + 1) * page]))
        for pi in range(len(toks) // page)
    ]
    check(f"hash chain == byte-stream FNV-1a oracle (page {page})", keys == oracle)

# extension property: a longer prompt's chain extends the shorter's
short, long_ = toks[:16], toks[:32]
check(
    "extending a prompt extends its key chain without rehashing",
    chain_keys(long_, 4)[:4] == chain_keys(short, 4),
)
# divergence: first differing page changes every key from that page on
div = list(toks)
div[9] ^= 1  # inside page 2 of page=4
ka, kb = chain_keys(toks, 4), chain_keys(div, 4)
check(
    "divergent token changes keys from its page onward, not before",
    ka[:2] == kb[:2] and all(a != b for a, b in zip(ka[2:], kb[2:])),
)
# partial pages are never keyed
check("only full pages are keyed", len(chain_keys(toks[:11], 4)) == 2)


# ---------------------------------------------------------------------
# 2. page-table / stripe math
# ---------------------------------------------------------------------


class Geom:
    """KvGeom transliteration: layers/heads/head_dim/page + layout math."""

    def __init__(self, layers, heads, head_dim, page):
        self.layers, self.heads, self.head_dim, self.page = layers, heads, head_dim, page

    def stripe(self, layer, which, head):
        return ((layer * 2 + which) * self.heads + head) * self.page * self.head_dim

    def page_floats(self):
        return 2 * self.layers * self.heads * self.page * self.head_dim

    def pages_for(self, n):
        return -(-n // self.page)  # ceil div, matches usize::div_ceil


g = Geom(layers=3, heads=5, head_dim=4, page=7)
check(
    "pages_for is ceil-div (0..3 pages at the boundaries)",
    [g.pages_for(n) for n in (0, 1, 6, 7, 8, 13, 14, 15)] == [0, 1, 1, 1, 2, 2, 2, 3],
)
check("page_floats = 2*layers*heads*page*hd", g.page_floats() == 2 * 3 * 5 * 7 * 4)

# the stripes partition the page's floats exactly once
covered = []
for l in range(g.layers):
    for w in (0, 1):
        for h in range(g.heads):
            o = g.stripe(l, w, h)
            covered.append((o, o + g.page * g.head_dim))
covered.sort()
flat = [x for r in covered for x in r]
check(
    "K/V head stripes tile the page exactly once (no gap, no overlap)",
    flat[0] == 0 and flat[-1] == g.page_floats() and all(
        covered[i][1] == covered[i + 1][0] for i in range(len(covered) - 1)
    ),
)


def kv_value(l, which, h, pos, d):
    """Deterministic fill pattern, distinct per coordinate."""
    base = float(l * 10007 + which * 5003 + h * 331 + pos * 17 + d)
    return base if which == 0 else -base


def paged_store(g, seq):
    """Simulated page store filled through write_pos arithmetic."""
    pages = [np.zeros(g.page_floats(), dtype=np.float32) for _ in range(g.pages_for(seq))]
    for l in range(g.layers):
        for h in range(g.heads):
            for pos in range(seq):
                pi, po = pos // g.page, pos % g.page
                for which in (0, 1):
                    o = g.stripe(l, which, h) + po * g.head_dim
                    pages[pi][o : o + g.head_dim] = [
                        kv_value(l, which, h, pos, d) for d in range(g.head_dim)
                    ]
    return pages


seq = 2 * g.page + 3  # ragged tail page
pages = paged_store(g, seq)
ok = True
for l in range(g.layers):
    for h in range(g.heads):
        for pos in range(seq):
            pi, po = pos // g.page, pos % g.page
            k_stripe = pages[pi][g.stripe(l, 0, h) : g.stripe(l, 0, h) + g.page * g.head_dim]
            got = k_stripe[po * g.head_dim : (po + 1) * g.head_dim]
            want = [kv_value(l, 0, h, pos, d) for d in range(g.head_dim)]
            ok &= list(got) == want
check("write_pos/k_head round-trip vs dense oracle (ragged tail page)", ok)


# ---------------------------------------------------------------------
# 3. prefill_resume gather: paged pages -> flat (heads, seq, hd)
# ---------------------------------------------------------------------

for seq in (g.page - 1, g.page, g.page + 1, 3 * g.page + 2):
    pages = paged_store(g, seq)
    l = 1
    hd = g.head_dim
    # engine gather: rows = min(seq - base, page), dst = h*seq*hd + base*hd
    kf = np.zeros(g.heads * seq * hd, dtype=np.float32)
    for h in range(g.heads):
        for pi in range(g.pages_for(seq)):
            base = pi * g.page
            rows = min(seq - base, g.page)
            dst = h * seq * hd + base * hd
            src = pages[pi][g.stripe(l, 0, h) : g.stripe(l, 0, h) + g.page * hd]
            kf[dst : dst + rows * hd] = src[: rows * hd]
    # never-paged oracle
    oracle = np.array(
        [
            kv_value(l, 0, h, pos, d)
            for h in range(g.heads)
            for pos in range(seq)
            for d in range(hd)
        ],
        dtype=np.float32,
    )
    check(f"resume gather == flat fill (seq {seq}, page {g.page})", np.array_equal(kf, oracle))


# ---------------------------------------------------------------------
# 4. refcounted pool simulation: attach/probe/register/CoW/drop
# ---------------------------------------------------------------------


class Pool:
    """Python model of KvPagePool. Pages are dict ids; the index holds a
    page id (the Rust Weak) that counts as a reference only for CoW
    purposes, never for liveness."""

    def __init__(self, page, max_pages=None, prefix_cache=True):
        self.page, self.max_pages, self.prefix_cache = page, max_pages, prefix_cache
        self.next_id = 0
        self.pages = {}  # id -> {refs, data, key}
        self.index = {}  # key -> {page, tokens, len}
        self.in_use = self.logical = 0
        self.lookups = self.hits = self.pages_shared = self.cow_copies = 0

    def alloc(self):
        if self.max_pages is not None and self.in_use >= self.max_pages:
            raise MemoryError("kv page pool exhausted")
        pid = self.next_id
        self.next_id += 1
        self.pages[pid] = {"refs": 1, "data": np.zeros(4, dtype=np.float32), "key": None}
        self.in_use += 1
        self.logical += 1
        return pid

    def drop_ref(self, pid):
        """One Arc clone dropped. Logical accounting is the caller's job
        (KvCache::Drop / make_private do unmap_logical explicitly)."""
        p = self.pages[pid]
        p["refs"] -= 1
        if p["refs"] == 0:
            self.in_use -= 1
            k = p["key"]
            # Drop purges the entry only if it still points at this page
            if k is not None and self.index.get(k, {}).get("page") == pid:
                del self.index[k]
            del self.pages[pid]

    def entry_live(self, e):
        return e["page"] in self.pages

    def attach(self, tokens):
        if not self.prefix_cache or self.page == 0 or len(tokens) < self.page:
            return []
        self.lookups += 1
        out = []
        h = FNV_OFFSET
        for pi in range(len(tokens) // self.page):
            for t in tokens[pi * self.page : (pi + 1) * self.page]:
                h = fnv1a_token(h, t)
            plen = (pi + 1) * self.page
            e = self.index.get(h)
            if e is None or e["len"] != plen or len(e["tokens"]) < plen:
                break
            if e["tokens"][:plen] != tuple(tokens[:plen]) or not self.entry_live(e):
                break
            self.pages[e["page"]]["refs"] += 1
            out.append(e["page"])
        if out:
            self.hits += 1
            self.pages_shared += len(out)
            self.logical += len(out)
        return out

    def probe(self, tokens):
        if not self.prefix_cache or self.page == 0 or len(tokens) < self.page:
            return 0
        m = 0
        h = FNV_OFFSET
        for pi in range(len(tokens) // self.page):
            for t in tokens[pi * self.page : (pi + 1) * self.page]:
                h = fnv1a_token(h, t)
            plen = (pi + 1) * self.page
            e = self.index.get(h)
            ok = (
                e is not None
                and e["len"] == plen
                and len(e["tokens"]) >= plen
                and e["tokens"][:plen] == tuple(tokens[:plen])
                and self.entry_live(e)
            )
            if not ok:
                break
            m += 1
        return m - 1 if m > 0 and m * self.page == len(tokens) else m

    def register(self, tokens, pages):
        if not self.prefix_cache or self.page == 0 or len(tokens) < self.page:
            return
        m = min(len(tokens) // self.page, len(pages))
        toks = tuple(tokens)
        h = FNV_OFFSET
        for pi in range(m):
            for t in tokens[pi * self.page : (pi + 1) * self.page]:
                h = fnv1a_token(h, t)
            e = self.index.get(h)
            if e is not None and self.entry_live(e):
                continue  # a live donor already publishes this prefix
            p = self.pages[pages[pi]]
            if p["key"] is None:
                p["key"] = h  # OnceLock: set before the index points here
            elif p["key"] != h:
                continue  # a page registers under exactly one key
            self.index[h] = {"page": pages[pi], "tokens": toks, "len": (pi + 1) * self.page}


class Cache:
    """Python model of KvCache over the Pool above."""

    def __init__(self, pool):
        self.pool, self.pages, self.len = pool, [], 0

    def attach_prefix(self, tokens):
        if self.len != 0 or self.pages:
            return 0
        got = self.pool.attach(tokens)
        self.pages.extend(got)
        return len(got)

    def ensure(self, positions):
        need = -(-positions // self.pool.page)
        while len(self.pages) < need:
            self.pages.append(self.pool.alloc())

    def page_is_private(self, pi):
        # Arc::get_mut: one strong ref and no index weak ref
        p = self.pool.pages[self.pages[pi]]
        registered = (
            p["key"] is not None
            and self.pool.index.get(p["key"], {}).get("page") == self.pages[pi]
        )
        return p["refs"] == 1 and not registered

    def make_private(self, pi):
        if self.page_is_private(pi):
            return
        fresh = self.pool.alloc()
        self.pool.pages[fresh]["data"] = self.pool.pages[self.pages[pi]]["data"].copy()
        self.pool.cow_copies += 1
        old = self.pages[pi]
        self.pages[pi] = fresh
        self.pool.logical -= 1  # alloc counted the copy; swap is neutral
        self.pool.drop_ref(old)

    def ensure_writable(self, positions):
        self.ensure(positions)
        if positions > 0:
            self.make_private((positions - 1) // self.pool.page)

    def drop(self):
        self.pool.logical -= len(self.pages)
        for pid in self.pages:
            self.pool.drop_ref(pid)
        self.pages, self.len = [], 0


def sim_prefill(pool, cache, tokens):
    """Engine prefill dispatcher: attach, resume point, CoW of the first
    written page, register. Returns pages attached."""
    m = cache.attach_prefix(tokens)
    seq = len(tokens)
    r0 = seq - 1 if m > 0 and m * pool.page == seq else m * pool.page
    cache.ensure(seq)
    if seq > 0:
        cache.make_private(r0 // pool.page)
    cache.len = seq
    pool.register(tokens, cache.pages)
    return m


# -- canary isolation, the core CoW property --
pool = Pool(page=4)
prefix = toks[:8]
donor = Cache(pool)
sim_prefill(pool, donor, prefix)
for pi, pid in enumerate(donor.pages):
    pool.pages[pid]["data"][:] = [100.0 * pi + d for d in range(4)]
donor_snapshot = [pool.pages[pid]["data"].copy() for pid in donor.pages]

follower = Cache(pool)
m = follower.attach_prefix(prefix + [7, 7])
check("attach maps both full prefix pages, none of the tail", m == 2)
check("sharing is logical: 2 physical pages serve 4 mappings", (pool.in_use, pool.logical) == (2, 4))
before = pool.in_use
follower.make_private(0)
check(
    "CoW is +1 physical, logical-neutral, counted once",
    (pool.in_use, pool.logical, pool.cow_copies) == (before + 1, 4, 1),
)
check("CoW copy is a different page id", follower.pages[0] != donor.pages[0])
check(
    "CoW copy carries the donor's bits",
    np.array_equal(pool.pages[follower.pages[0]]["data"], donor_snapshot[0]),
)
pool.pages[follower.pages[0]]["data"][:] = 9e6  # canary
check(
    "canary write never reaches the donor page",
    all(
        np.array_equal(pool.pages[pid]["data"], snap)
        for pid, snap in zip(donor.pages, donor_snapshot)
    ),
)
# registered pages CoW even at refcount 1: drop the follower, then ask the
# donor to write its own published page
follower.drop()
check("donor page 0 still index-published => not private", not donor.page_is_private(0))
donor.make_private(0)
check("registered page CoWs even at refcount 1", pool.cow_copies == 2)
donor.drop()
check(
    "pages, mappings and index entries drain to zero",
    (pool.in_use, pool.logical, len(pool.pages), len(pool.index)) == (0, 0, 0, 0),
)

# -- hash collision is rejected by exact token verification --
pool = Pool(page=4)
donor = Cache(pool)
sim_prefill(pool, donor, toks[:8])
other = [t ^ 3 for t in toks[:8]]
key = chain_keys(other, 4)[0]
pool.index[key] = {"page": donor.pages[0], "tokens": tuple(toks[:8]), "len": 4}
f = Cache(pool)
check("colliding entry with wrong tokens attaches nothing", f.attach_prefix(other) == 0)
f.drop()
donor.drop()

# -- randomized chaos mix: invariants hold at every step --
for case in range(30):
    crng = random.Random(0xC0FFEE + case)
    page = crng.choice([2, 3, 4, 8])
    pool = Pool(page=page, max_pages=None)
    family = [crng.randrange(64) for _ in range(page * crng.randrange(1, 4))]
    live = []
    ok = True
    for i in range(crng.randrange(4, 12)):
        kind = crng.randrange(4)
        if kind == 0:
            prompt = list(family)  # exact clone: full hit, CoW resume
        elif kind == 3:
            prompt = [crng.randrange(64) for _ in range(crng.randrange(1, 2 * page))]
        else:
            prompt = family + [crng.randrange(64) for _ in range(crng.randrange(1, page + 2))]
        c = Cache(pool)
        sim_prefill(pool, c, prompt)
        for _ in range(crng.randrange(0, 4)):  # decode steps
            c.ensure_writable(c.len + 1)
            c.len += 1
        live.append(c)
        ok &= pool.logical >= pool.in_use
        ok &= pool.logical == sum(len(s.pages) for s in live)
        if crng.random() < 0.4 and live:
            live.pop(crng.randrange(len(live))).drop()
            ok &= pool.logical >= pool.in_use
    while live:
        live.pop(crng.randrange(len(live))).drop()
    ok &= (pool.in_use, pool.logical, len(pool.pages), len(pool.index)) == (0, 0, 0, 0)
    if not ok:
        check(f"chaos mix case {case} invariants", False)
check("30 randomized session mixes: logical>=physical, exact mapping counts, drain to zero", True)


# ---------------------------------------------------------------------
# 5. tail-only admission charge vs actual draw
# ---------------------------------------------------------------------


def pages_for(n, page):
    return -(-n // page)


ok = True
worst = None
for case in range(200):
    crng = random.Random(0xAD317 + case)
    page = crng.choice([2, 3, 4, 8])
    pool = Pool(page=page)
    family = [crng.randrange(64) for _ in range(page * crng.randrange(1, 4))]
    donor = Cache(pool)
    sim_prefill(pool, donor, family)
    # boundary-heavy follower lengths: page-1, page, page+1 around the
    # shared prefix, plus a random tail
    tail = crng.choice([-1, 0, 1, crng.randrange(0, 2 * page)])
    plen = max(1, len(family) + tail)
    prompt = (family + [crng.randrange(64) for _ in range(max(0, tail))])[:plen]
    full = pages_for(plen + 1, page)
    probe = pool.probe(prompt)
    charge = full - probe
    ok &= 0 <= probe <= full  # never negative, never underflows
    f = Cache(pool)
    before = pool.in_use
    sim_prefill(pool, f, prompt)
    f.ensure_writable(f.len + 1)  # first decode step the charge reserves
    drawn = pool.in_use - before
    if not (charge >= drawn):
        ok, worst = False, (case, page, plen, probe, charge, drawn)
    f.drop()
    donor.drop()
    ok &= (pool.in_use, pool.logical) == (0, 0)
check(f"200 fuzzed admissions: charge = full - probe covers the actual draw {worst or ''}", ok)

# pinned boundary cases, page = 4, donor holds an 8-token prefix
pool = Pool(page=4)
donor = Cache(pool)
sim_prefill(pool, donor, toks[:8])
probes = [pool.probe(toks[:n]) for n in (3, 4, 5, 7, 8, 9, 12)]
check(
    "probe at page-1/page/page+1 boundaries (full hit discounts the CoW page)",
    probes == [0, 0, 1, 1, 1, 2, 2],
)
# n=4: the 4-token prefix's key is in the index (donor len 8 => entry len
# is 4 for page 0) — m=1, full cover => probe 0 pays for the CoW copy.
# n=8: full hit on both pages => probe 2-1=1. n=9/12: partial, probe 2.
donor.drop()


# ---------------------------------------------------------------------
# 6. deferred retry re-probes fresh: no double-count
# ---------------------------------------------------------------------

# Sweep 0: an unrelated blocker and the donor each admit at full charge
# (3 pages: 2-page prefill + the reserved decode step), eating all 6 free
# pages, so the follower -- despite its tail-only charge of 1 -- defers.
# The deferral must hold NO reservation: when the blocker retires, sweep 1
# re-probes the follower fresh and admits it for charge 1. A stale sweep-0
# charge kept on the books (the double-count the fuzz extension guards
# against) would either wedge the queue or over-admit past capacity.
page, cap = 4, 6
pool = Pool(page=page, max_pages=cap)
prefix = toks[:8]
blocker_prompt = [t ^ 9 for t in toks[8:16]]  # unrelated, same length
donor_prompt = list(prefix)
follower_prompt = prefix + [toks[20]]
max_new = {tuple(blocker_prompt): 1, tuple(donor_prompt): 4, tuple(follower_prompt): 2}


def reserve(sessions):
    """Server sweep reserve: one decode step per in-flight session."""
    return sum(pages_for(s.len + 1, page) - len(s.pages) for s in sessions)


inflight = []  # (cache, rounds_left)
queued = [blocker_prompt, donor_prompt, follower_prompt]
admitted_at = {}
peak = 0
for sweep in range(4):
    free = cap - pool.in_use - reserve([c for c, _ in inflight])
    still = []
    for prompt in queued:
        # fresh probe every sweep -- deferred requests carry nothing over
        charge = pages_for(len(prompt) + 1, page) - pool.probe(prompt)
        if charge <= free:
            c = Cache(pool)
            sim_prefill(pool, c, prompt)  # prefill runs within the sweep
            inflight.append((c, max_new[tuple(prompt)]))
            free -= charge
            admitted_at[tuple(prompt)] = (sweep, charge)
        else:
            still.append(prompt)  # deferred: holds NO reservation
    queued = still
    peak = max(peak, pool.in_use)
    nxt = []
    for c, left in inflight:  # one decode round, retire at max_new
        c.ensure_writable(c.len + 1)
        c.len += 1
        peak = max(peak, pool.in_use)
        if left > 1:
            nxt.append((c, left - 1))
        else:
            c.drop()
    inflight = nxt

check(
    "blocker and donor admit at full charge in sweep 0, follower defers",
    admitted_at[tuple(blocker_prompt)] == (0, 3)
    and admitted_at[tuple(donor_prompt)] == (0, 3)
    and admitted_at[tuple(follower_prompt)][0] == 1,
)
check(
    "deferred follower re-probes fresh and admits on the tail-only charge",
    admitted_at[tuple(follower_prompt)][1] == pages_for(10, page) - 2,  # 3 - 2 = 1
)
check("no queued request left behind", not queued)
check("no double-count: peak physical never exceeds capacity", peak <= cap)
for c, _ in inflight:
    c.drop()
check("admission sim drains clean", (pool.in_use, pool.logical) == (0, 0))


# ---------------------------------------------------------------------
# 7. offset-attention tiling schedule == full-prefill schedule
# ---------------------------------------------------------------------

TQ, TK = 32, 64


def schedule(offset, q_rows):
    """Per global row: the (k0, k1, valid) k-tile walk of causal_tile.
    kend = offset + i1; tile boundaries at absolute multiples of TK."""
    sched = {}
    for qt in range(-(-q_rows // TQ)):
        i0, i1 = qt * TQ, min(qt * TQ + TQ, q_rows)
        kend = offset + i1
        k0 = 0
        while k0 < kend:
            k1 = min(k0 + TK, kend)
            for i in range(i0, i1):
                gi = offset + i
                valid = min(max(gi + 1 - k0, 0), k1 - k0)
                sched.setdefault(gi, []).append((k0, k1, valid))
            k0 = k1
    return sched


ok = True
zero_seen_with_offset = False
zero_seen_full = False
shapes = [
    (s, rn)
    for s in (1, 31, 32, 33, 63, 64, 65, 96, 100, 127, 128, 130, 200)
    for rn in (1, 2, s // 2 or 1, s - 1 or 1, s)
    if 0 < rn <= s
]
for seq, rn in shapes:
    offset = seq - rn
    full = schedule(0, seq)
    res = schedule(offset, rn)
    zero_seen_full |= any(v == 0 for row in full.values() for (_, _, v) in row)
    if offset > 0:
        zero_seen_with_offset |= any(v == 0 for row in res.values() for (_, _, v) in row)
    for gidx in range(offset, seq):
        # contributing tiles: valid > 0. A valid==0 tile zeroes its P
        # column and skips the row stats, so it adds nothing — only the
        # contributing walks must coincide for bitwise identity. k1 may
        # legitimately differ past the row's causal limit gi+1 (the tail
        # is zero-padded P columns), so compare (k0, valid) with valid
        # truncated to the row's limit — identical nonzero work.
        a = [(k0, v) for (k0, _, v) in full[gidx] if v > 0]
        b = [(k0, v) for (k0, _, v) in res[gidx] if v > 0]
        if a != b:
            ok = False
check(f"{len(shapes)} resume shapes: contributing (k0, valid) walks match full prefill", ok)
check("valid == 0 tiles never occur at offset 0 (TQ divides TK)", not zero_seen_full)
check("valid == 0 tiles do occur with offset > 0 (guard is live)", zero_seen_with_offset)


# ---------------------------------------------------------------------

failed = [n for n, ok in checks if not ok]
assert not failed, failed
print(f"ALL OK ({len(checks)} checks)")
