"""Pure-python/numpy transliteration of PR 9's BLASST dynamic attention
sparsity (rust/src/kernels/attention.rs causal_tile + the thresh decode
kernel, and the per-page K norm stamps in rust/src/model/kv.rs).

No Rust toolchain ships in this container (same as PRs 1-8), so the new
skip rule is pinned here against independent oracles:

  1. the prefill k-tile skip rule (skip a causally-live row when
     scale*max(scores) < m[i] - tau, first contributing tile never skips
     because m starts at -inf, NaN falls through to the exact path):
     a float32 transliteration of the tiled streaming-softmax kernel
     with and without the threshold, checking
       - tau=off and huge-tau outputs are BITWISE identical to the exact
         tiled path (np.float32 arrays compared via tobytes()),
       - the advertised mass bound: each skipped (row, tile)'s true
         post-softmax mass, measured on the exact kernel's weights, is
         <= valid_count * e^(-tau),
       - surviving rows run the identical m/l recurrence: the running
         max/sum chain restricted to surviving tiles matches the exact
         chain bit-for-bit at every step,
       - skipped-row counts are non-increasing in tau and drift collapses
         to exactly zero at huge tau;
  2. the paged-decode page-skip rule (Cauchy-Schwarz bound
     qnorm * k_stamp * scale < m - tau; skipped slots filled with -inf so
     one softmax over the whole buffer keeps no-skip runs bit-identical):
       - soundness: the stamp bound dominates every true score in the
         page, for random and adversarial (RoPE-rotated) keys,
       - huge-tau decode output is bitwise identical to the exact paged
         decode, page boundaries swept at page-1/page/page+1,
       - each skipped position's exact softmax weight is < e^(-tau)
         relative to the row max;
  3. the per-page stamp lifecycle (rust/src/model/kv.rs): fresh pages
     start at zero, writes fold in the new K row's norm with a monotone
     max (overwrite by a smaller key keeps the old sound bound), CoW
     copies the donor's stamps and leaves the donor untouched, recycled
     page buffers never leak stale stamps;
  4. the threshold validation rule shared by the CLI and the engine
     (finite and >= 0; NaN/inf/negatives rejected, 0 accepted).

Run: python3 python/tests/attn_threshold_check.py   (prints ALL OK)
"""

import math

import numpy as np

checks = []


def check(name, ok):
    checks.append((name, bool(ok)))
    print(("PASS" if ok else "FAIL"), name)
    assert ok, name


f32 = np.float32

# tile shape constants from rust/src/kernels/attention.rs
TQ, TK = 32, 64

# ---------------------------------------------------------------------
# 1. prefill k-tile skip rule
# ---------------------------------------------------------------------


def tiled_prefill(q, k, v, offset, tau=None, trace=None, skipped_out=None):
    """float32 transliteration of causal_tile for one head.

    q: (q_rows, hd) queries for global rows offset..offset+q_rows;
    k, v: (kv_len, hd). tau=None is the exact path (the Rust plain entry
    points delegate to the thresh kernel with None, so this single
    function mirrors the real code shape). trace, when a list, records
    (qt, k0, i, m, l) after every surviving-row update; skipped_out,
    when a list, records (global_row, k0, k1) per thresholded row.
    """
    q = q.astype(f32)
    k = k.astype(f32)
    v = v.astype(f32)
    q_rows, hd = q.shape
    scale = f32(1.0 / math.sqrt(hd))
    out = np.zeros((q_rows, hd), dtype=f32)
    for qt in range((q_rows + TQ - 1) // TQ):
        i0, i1 = qt * TQ, min(qt * TQ + TQ, q_rows)
        tq = i1 - i0
        m = np.full(tq, -np.inf, dtype=f32)
        l = np.zeros(tq, dtype=f32)
        acc = np.zeros((tq, hd), dtype=f32)
        kend = offset + i1
        k0 = 0
        while k0 < kend:
            k1 = min(k0 + TK, kend)
            tk = k1 - k0
            # score GEMM always runs -- it produces the statistic the
            # skip test thresholds (float32 accumulate like the kernel)
            s = q[i0:i1] @ k[k0:k1].T
            p = np.zeros((tq, tk), dtype=f32)
            live = 0
            thresh_skips = 0
            for i in range(tq):
                gi = offset + i0 + i
                valid = min(max(gi + 1 - k0, 0), tk)
                if valid == 0:
                    continue  # causally dead: P column already zero
                if tau is not None:
                    # scale*max is the scaled row max (scale > 0 is
                    # monotone); m starts at -inf so the first
                    # contributing tile can never skip
                    if f32(np.max(s[i, :valid])) * scale < m[i] - f32(tau):
                        if skipped_out is not None:
                            skipped_out.append((gi, k0, k1))
                        thresh_skips += 1
                        continue
                live += 1
                row = s[i, :valid] * scale
                row_max = f32(np.max(row))
                new_m = max(m[i], row_max)
                alpha = f32(np.exp(f32(m[i] - new_m)))
                if alpha != f32(1.0):
                    acc[i] *= alpha
                e = np.exp(row - new_m).astype(f32)
                p[i, :valid] = e
                l[i] = f32(l[i] * alpha + f32(np.sum(e)))
                m[i] = new_m
                if trace is not None:
                    trace.append((qt, k0, i, float(m[i]), float(l[i])))
            if tau is not None and live == 0 and thresh_skips > 0:
                k0 = k1  # P tile all zero: the P.V GEMM is pure skipped work
                continue
            acc += (p @ v[k0:k1]).astype(f32)
            k0 = k1
        out[i0:i1] = acc / l[:, None]
    return out


rng = np.random.default_rng(9)
hd, q_rows, kv_len = 16, 70, 70
q = rng.standard_normal((q_rows, hd)).astype(f32)
k = rng.standard_normal((kv_len, hd)).astype(f32)
v = rng.standard_normal((kv_len, hd)).astype(f32)
# spike a few keys so finite tau actually skips something
for j in (3, 40):
    k[j] *= f32(6.0)

exact = tiled_prefill(q, k, v, 0)
check(
    "prefill: tau=off is the exact path (bitwise)",
    tiled_prefill(q, k, v, 0, tau=None).tobytes() == exact.tobytes(),
)
check(
    "prefill: huge tau is bitwise identical to exact",
    tiled_prefill(q, k, v, 0, tau=1e30).tobytes() == exact.tobytes(),
)

# offset (prefix-resume) arm: rows offset.. of the full kernel
off = 33
exact_off = tiled_prefill(q[off:], k, v, off)
check(
    "prefill offset: huge tau bitwise identical to exact",
    tiled_prefill(q[off:], k, v, off, tau=1e30).tobytes() == exact_off.tobytes(),
)
check(
    "prefill offset: exact resume rows match full-prefill rows",
    np.max(np.abs(exact_off - exact[off:])) < 2e-6,
)

# mass bound: for each thresholded (row, tile), the true post-softmax
# mass of the skipped span is <= valid_count * e^(-tau)
TAU = 3.0
skipped = []
armed = tiled_prefill(q, k, v, 0, tau=TAU, skipped_out=skipped)
check("prefill: finite tau actually skipped rows on spiky keys", len(skipped) > 0)
scale = 1.0 / math.sqrt(hd)
ok_mass = True
ok_first = True
for gi, k0, k1 in skipped:
    ok_first &= k0 > 0  # the first contributing tile can never skip
    sc = (q[gi].astype(np.float64) @ k[: gi + 1].astype(np.float64).T) * scale
    w = np.exp(sc - np.max(sc))
    w /= w.sum()
    valid = min(gi + 1, k1) - k0
    ok_mass &= w[k0 : k0 + valid].sum() <= valid * math.exp(-TAU) * (1 + 1e-6)
check("prefill: first contributing tile never skips", ok_first)
check("prefill: skipped mass <= count * e^(-tau) per (row, tile)", ok_mass)

# surviving rows run the identical m/l recurrence: the armed trace is a
# subsequence of the exact trace (same bits at every surviving step)
tr_exact, tr_armed = [], []
tiled_prefill(q, k, v, 0, trace=tr_exact)
tiled_prefill(q, k, v, 0, tau=TAU, trace=tr_armed)
exact_steps = {(qt, k0, i): (m, l) for qt, k0, i, m, l in tr_exact}
skipset = {(gi, k0) for gi, k0, _ in skipped}
ok_chain = True
for qt, k0, i, m, l in tr_armed:
    gi = qt * TQ + i
    # a surviving step whose row never skipped before this tile must
    # carry the exact chain's bits; after a skip the chains diverge
    # (that divergence is the approximation), so only compare prefixes
    if any((gi, kk) in skipset for kk in range(0, k0, TK)):
        continue
    ok_chain &= exact_steps[(qt, k0, i)] == (m, l)
check("prefill: pre-skip m/l chain is bitwise the exact chain", ok_chain)

# monotonicity: rows skipped non-increasing in tau, drift -> 0
prev_skips, prev_drift = None, None
ok_mono, ok_drift = True, True
for tau in (0.5, 2.0, 4.0, 8.0, 1e30):
    sk = []
    o = tiled_prefill(q, k, v, 0, tau=tau, skipped_out=sk)
    drift = float(np.max(np.abs(o - exact)))
    if prev_skips is not None:
        ok_mono &= len(sk) <= prev_skips
        ok_drift &= drift <= prev_drift + 1e-6
    prev_skips, prev_drift = len(sk), drift
check("prefill: skipped rows non-increasing in tau", ok_mono)
check("prefill: drift non-increasing in tau", ok_drift)
check("prefill: huge-tau drift is exactly zero", prev_drift == 0.0)

# ---------------------------------------------------------------------
# 2. paged-decode page-skip rule
# ---------------------------------------------------------------------


def decode_paged(qv, kpages, vpages, pos, page, stamps=None, tau=None):
    """float32 transliteration of decode_head_paged_(thresh_)into."""
    qv = qv.astype(f32)
    hd = qv.shape[0]
    scale = f32(1.0 / math.sqrt(hd))
    n = pos + 1
    n_pages = (n + page - 1) // page
    scores = np.empty(n, dtype=f32)
    skipped = np.zeros(n_pages, dtype=bool)
    if tau is not None:
        qnorm = f32(math.sqrt(float(np.dot(qv, qv))))
        m = f32(-np.inf)
    for pi in range(n_pages):
        base = pi * page
        cnt = min(n - base, page)
        if tau is not None and qnorm * f32(stamps[pi]) * scale < m - f32(tau):
            scores[base : base + cnt] = -np.inf
            skipped[pi] = True
            continue
        for j in range(cnt):
            scores[base + j] = f32(np.dot(qv, kpages[pi][j])) * scale
        if tau is not None:
            m = max(m, f32(np.max(scores[base : base + cnt])))
    # one softmax over the whole buffer (exp(-inf - max) = 0)
    mx = f32(np.max(scores))
    e = np.exp(scores - mx).astype(f32)
    w = (e / f32(np.sum(e))).astype(f32)
    out = np.zeros(hd, dtype=f32)
    for pi in range(n_pages):
        if skipped[pi]:
            continue
        base = pi * page
        cnt = min(n - base, page)
        for j in range(cnt):
            out += w[base + j] * vpages[pi][j]
    return out, int(skipped.sum()), w


def rope(x, theta):
    """Pairwise rotation -- an isometry, so K norms (and stamps) hold."""
    y = x.copy()
    for d0 in range(0, x.shape[-1], 2):
        c, s = math.cos(theta * (d0 + 1)), math.sin(theta * (d0 + 1))
        y[..., d0] = c * x[..., d0] - s * x[..., d0 + 1]
        y[..., d0 + 1] = s * x[..., d0] + c * x[..., d0 + 1]
    return y


page = 4
ok_bitwise, ok_sound, ok_weight = True, True, True
saw_skip = False
for pos in (page - 2, page - 1, page, page + 1, 3 * page, 3 * page + 1):
    n = pos + 1
    n_pages = (n + page - 1) // page
    qv = rng.standard_normal(hd).astype(f32)
    kp, vp, stamps = [], [], []
    for pi in range(n_pages):
        cnt = min(n - pi * page, page)
        kk = rng.standard_normal((page, hd)).astype(f32)
        if pi == 0:
            kk *= f32(5.0)  # early spike => later quiet pages can skip
        kk = rope(kk, 0.3 * pi).astype(f32)
        kp.append(kk)
        vp.append(rng.standard_normal((page, hd)).astype(f32))
        # stamp = max written K row norm (only written rows count)
        stamps.append(float(np.max(np.linalg.norm(kk[:cnt].astype(np.float64), axis=1))))
    exact_out, _, w_exact = decode_paged(qv, kp, vp, pos, page)
    huge_out, huge_skips, _ = decode_paged(qv, kp, vp, pos, page, stamps, tau=1e30)
    ok_bitwise &= huge_out.tobytes() == exact_out.tobytes() and huge_skips == 0
    # soundness: the Cauchy-Schwarz stamp bound dominates every true score
    scale64 = 1.0 / math.sqrt(hd)
    qn = float(np.linalg.norm(qv.astype(np.float64)))
    for pi in range(n_pages):
        cnt = min(n - pi * page, page)
        true_best = float(np.max(kp[pi][:cnt].astype(np.float64) @ qv.astype(np.float64))) * scale64
        ok_sound &= qn * stamps[pi] * scale64 >= true_best - 1e-9
    # finite tau: every skipped position's exact weight < e^(-tau) of max
    tau = 2.0
    _, skips, w_armed = decode_paged(qv, kp, vp, pos, page, stamps, tau=tau)
    saw_skip |= skips > 0
    for j in np.nonzero(w_armed == 0.0)[0]:
        ok_weight &= w_exact[j] <= math.exp(-tau) * float(np.max(w_exact)) * (1 + 1e-6)
check("decode: huge tau bitwise identical to exact across page boundaries", ok_bitwise)
check("decode: stamp bound dominates every true score (RoPE keys)", ok_sound)
check("decode: finite tau skipped at least one page", saw_skip)
check("decode: skipped weights < e^(-tau) of the row max", ok_weight)

# ---------------------------------------------------------------------
# 3. per-page stamp lifecycle
# ---------------------------------------------------------------------

layers, heads = 2, 3


class Page:
    """KvPage: stamps live on the struct, never on a recycled buffer."""

    def __init__(self):
        self.kmax = np.zeros(layers * heads, dtype=f32)

    def write(self, layer, head, krow):
        norm = f32(math.sqrt(float(np.dot(krow, krow))))
        slot = layer * heads + head
        self.kmax[slot] = max(self.kmax[slot], norm)  # monotone

    def cow(self):
        c = Page()
        c.kmax = self.kmax.copy()  # donor bits carry the donor bounds
        return c


p = Page()
check("stamps: fresh page starts at zero", float(p.kmax.max()) == 0.0)
p.write(1, 2, np.array([3.0, 4.0], dtype=f32))
p.write(1, 2, np.array([1.0, 0.0], dtype=f32))
check("stamps: write folds max norm, overwrite keeps the bound", p.kmax[1 * heads + 2] == f32(5.0))
check("stamps: untouched (layer, head) slots stay zero", p.kmax[0 * heads + 1] == f32(0.0))
c = p.cow()
check("stamps: CoW copies the donor stamp", c.kmax[1 * heads + 2] == f32(5.0))
c.write(1, 2, np.array([6.0, 8.0], dtype=f32))
check(
    "stamps: copy raises its own bound, donor untouched",
    c.kmax[1 * heads + 2] == f32(10.0) and p.kmax[1 * heads + 2] == f32(5.0),
)
check("stamps: recycled buffer reuse starts from a fresh Page", float(Page().kmax.max()) == 0.0)

# ---------------------------------------------------------------------
# 4. the validation rule (CLI get_threshold + engine build)
# ---------------------------------------------------------------------


def valid_tau(t):
    return math.isfinite(t) and t >= 0.0


check(
    "validation: NaN, +/-inf and negatives rejected; 0 and 8.5 accepted",
    not valid_tau(float("nan"))
    and not valid_tau(float("inf"))
    and not valid_tau(float("-inf"))
    and not valid_tau(-1.0)
    and valid_tau(0.0)
    and valid_tau(8.5),
)

print("ALL OK" if all(ok for _, ok in checks) else "FAILURES")
assert all(ok for _, ok in checks)
