"""L1 kernel correctness: Pallas BSpMM + fused MLP vs pure-jnp oracles.

Hypothesis sweeps shapes/blocks/sparsities/dtypes (per DESIGN.md §9); a few
pinned cases guard the exact geometries that get AOT'd for Rust.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.bspmm import bspmm
from compile.kernels.fused_mlp import fused_gate, fused_mlp

jax.config.update("jax_platform_name", "cpu")


def rand_mask(rng, kb, nb, sparsity):
    """Random block mask with approximately the requested sparsity."""
    n_total = kb * nb
    n_zero = min(n_total - 1, int(round(sparsity * n_total)))
    flat = np.ones(n_total, np.float32)
    flat[rng.choice(n_total, size=n_zero, replace=False)] = 0.0
    return jnp.asarray(flat.reshape(kb, nb))


shape_strategy = st.tuples(
    st.sampled_from([16, 32, 64, 96]),          # m
    st.sampled_from([32, 64, 96, 128]),         # k
    st.sampled_from([32, 64, 128]),             # n
    st.sampled_from([16, 32]),                  # block
    st.floats(min_value=0.0, max_value=0.95),   # sparsity
    st.integers(min_value=0, max_value=2**31),  # seed
)


@hypothesis.given(shape_strategy)
@hypothesis.settings(max_examples=25, deadline=None)
def test_bspmm_matches_ref_hypothesis(args):
    m, k, n, b, sparsity, seed = args
    hypothesis.assume(k % b == 0 and n % b == 0)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    mask = rand_mask(rng, k // b, n // b, sparsity)
    got = bspmm(x, w, mask, block=b)
    want = ref.bspmm_ref(x, w, mask, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("blk_m", [8, 16, 64])
def test_bspmm_blk_m_sweep(blk_m):
    """blk_M (the paper's dense-operand tile height) must not change results."""
    rng = np.random.default_rng(7)
    m, k, n, b = 64, 64, 96, 32
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    mask = rand_mask(rng, k // b, n // b, 0.5)
    got = bspmm(x, w, mask, block=b, blk_m=blk_m)
    want = ref.bspmm_ref(x, w, mask, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_bspmm_fully_sparse_is_zero():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    mask = jnp.zeros((2, 2), jnp.float32)
    assert float(jnp.abs(bspmm(x, w, mask, block=32)).max()) == 0.0


def test_bspmm_dense_mask_equals_matmul():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    mask = jnp.ones((2, 2), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(bspmm(x, w, mask, block=32)), np.asarray(x @ w), atol=1e-4
    )


def test_bspmm_bf16():
    """Paper reports BF16 results (§5.1); interpret-mode must agree too."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.bfloat16)
    mask = rand_mask(rng, 2, 2, 0.5)
    got = bspmm(x, w, mask, block=32)
    want = ref.bspmm_ref(x, w, mask, 32)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=0.15
    )


mlp_strategy = st.tuples(
    st.sampled_from([16, 32, 64]),              # m (rows)
    st.sampled_from([32, 64]),                  # k (emb)
    st.sampled_from([32, 64, 128]),             # f (ffn)
    st.floats(min_value=0.0, max_value=0.95),   # sparsity
    st.integers(min_value=0, max_value=2**31),  # seed
)


@hypothesis.given(mlp_strategy)
@hypothesis.settings(max_examples=15, deadline=None)
def test_fused_mlp_matches_ref_hypothesis(args):
    m, k, f, sparsity, seed = args
    b = 32
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w1 = jnp.asarray(0.2 * rng.normal(size=(k, f)), jnp.float32)
    w2 = jnp.asarray(0.2 * rng.normal(size=(k, f)), jnp.float32)
    w3 = jnp.asarray(0.2 * rng.normal(size=(f, k)), jnp.float32)
    m1 = rand_mask(rng, k // b, f // b, sparsity)
    m2 = rand_mask(rng, k // b, f // b, sparsity)
    m3 = rand_mask(rng, f // b, k // b, sparsity)
    got = fused_mlp(x, w1, w2, w3, m1, m2, m3, block=b)
    want = ref.fused_mlp_ref(x, w1, w2, w3, m1, m2, m3, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_fused_gate_silu_epilogue():
    """The gate kernel's fused epilogue == unfused silu(XW1)*(XW2)."""
    rng = np.random.default_rng(11)
    m, k, f, b = 32, 64, 96, 32
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w1 = jnp.asarray(0.3 * rng.normal(size=(k, f)), jnp.float32)
    w2 = jnp.asarray(0.3 * rng.normal(size=(k, f)), jnp.float32)
    m1 = rand_mask(rng, k // b, f // b, 0.3)
    m2 = rand_mask(rng, k // b, f // b, 0.3)
    got = fused_gate(x, w1, w2, m1, m2, block=b)
    want = ref.silu(ref.bspmm_ref(x, w1, m1, b)) * ref.bspmm_ref(x, w2, m2, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_fused_mlp_pruned_blocks_do_not_contribute():
    """Zeroed blocks must not affect the output even if W has garbage there."""
    rng = np.random.default_rng(13)
    m, k, f, b = 32, 64, 64, 32
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(k, f)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(k, f)), jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(f, k)), jnp.float32)
    m1 = rand_mask(rng, 2, 2, 0.5)
    m2 = rand_mask(rng, 2, 2, 0.5)
    m3 = rand_mask(rng, 2, 2, 0.5)
    base = fused_mlp(x, w1, w2, w3, m1, m2, m3, block=b)
    # poison the pruned blocks of w1 with huge values
    poison = np.asarray(w1).copy()
    em1 = np.asarray(ref.expand_mask(m1, b))
    poison[em1 == 0] = 1e9
    got = fused_mlp(x, jnp.asarray(poison), w2, w3, m1, m2, m3, block=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-4)


def test_aot_kernel_shapes_pinned():
    """Guard the exact shapes aot.py exports for the Rust composition test."""
    from compile.aot import KERNEL_SHAPES

    m, k, n, b = KERNEL_SHAPES["bspmm_pallas"]
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    mask = rand_mask(rng, k // b, n // b, 0.5)
    np.testing.assert_allclose(
        np.asarray(bspmm(x, w, mask, block=b)),
        np.asarray(ref.bspmm_ref(x, w, mask, b)),
        atol=1e-4,
    )
