"""Pure-python transliteration of PR 8's replicated serving fleet
(rust/src/coordinator/fleet.rs, replica.rs, and the deterministic jitter
plumbing in util/faults.rs + coordinator/server.rs).

No Rust toolchain ships in this container, so the fleet's deterministic
surfaces are pinned here against independent oracles:

  1. the RNG substrate: splitmix64 (published reference vector) seeding
     xoshiro256**, and the Lemire multiply-shift `below(n)` sampler;
  2. seed derivations: `Faults::fork_rng` (armed and disabled forms,
     salt-0 root-plan identity), per-site `stream_seed`, and the crc32
     label hashing (== zlib.crc32, the equivalence the checkpoint check
     already pins);
  3. backoff schedules: the round-retry schedule `retry_backoff_us`
     (exponential, capped shift, jitter < 200 us) and the replica restart
     schedule `restart_backoff_ms` (base clamp, shift cap at 4, jitter in
     [0, base)), both replaying bit-for-bit from their forked streams;
  4. placement: `placement_mix` (splitmix64 finalizer, pinned values
     including mix(0,0) == 0), and the `Placer` policy — least-loaded
     among healthy non-draining replicas, seeded-hash tie-break, no
     arrival consumed when nothing is eligible, pure replay of a recorded
     view sequence, and the 1-replica identity path;
  5. failover replay accounting: `prompt ++ emitted` budget conservation,
     the survivor's admission charge `pages_for(len + 1)` equal to the
     continuation the dead replica would have run (page-boundary fuzz),
     and saturating deadline reduction;
  6. drain/restart bookkeeping: a discrete-event simulation of the
     router's rules (draining slots take no placements, acks fire only at
     zero outstanding, cycled replicas rejoin, nothing is dropped) and
     the heartbeat stall detector (a <= 20 ms idle bump cadence never
     false-deposes at the 250 ms default; a frozen heartbeat always
     does).

Run: python3 python/tests/fleet_check.py   (prints ALL OK on success)
"""

import zlib

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15

checks = []


def check(name, ok):
    checks.append((name, bool(ok)))
    print(("PASS" if ok else "FAIL"), name)
    assert ok, name


# ---------------------------------------------------------------------
# 1. RNG substrate (util/rng.rs)
# ---------------------------------------------------------------------

def splitmix64_next(state):
    state = (state + GOLDEN) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded through splitmix64 — util/rng.rs verbatim."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s, v = splitmix64_next(s)
            self.s.append(v)

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def below(self, n):
        assert n > 0
        return (self.next_u64() * n) >> 64


# the published splitmix64 reference vector for state 0
_, first = splitmix64_next(0)
check("splitmix64 reference vector: next(0) == 0xE220A8397B1DCDAF",
      first == 0xE220A8397B1DCDAF)

a, b = Rng(42), Rng(42)
check("xoshiro256**: same seed, same stream",
      [a.next_u64() for _ in range(8)] == [b.next_u64() for _ in range(8)])
check("xoshiro256**: different seeds diverge",
      Rng(1).next_u64() != Rng(2).next_u64())

r = Rng(7)
draws = [r.below(5) for _ in range(500)]
check("below(n): always < n and every residue reachable",
      all(0 <= d < 5 for d in draws) and set(draws) == set(range(5)))


# ---------------------------------------------------------------------
# 2. Seed derivations (util/faults.rs)
# ---------------------------------------------------------------------

def crc32(s):
    return zlib.crc32(s.encode()) & 0xFFFFFFFF


def fork_rng_seed(spec, label, salt, armed):
    """Faults::fork_rng — the jitter stream every backoff draws from."""
    l = crc32(label)
    if not armed:
        return (0xB0FF ^ l) & MASK
    return (((crc32(spec) << 32) ^ l ^ ((salt * GOLDEN) & MASK)) ^ 0xB0FF) & MASK


def stream_seed(seed, site, salt):
    """SiteState::stream_seed — the per-site fault draw stream."""
    return (seed ^ crc32(site) ^ ((salt * GOLDEN) & MASK)) & MASK


SITES = [
    "decode_round_panic", "decode_round_error", "prefill_error",
    "kv_pool_exhausted", "decode_stall_ms", "ckpt_torn_write",
    "scheduler_panic", "replica_crash", "replica_stall_ms",
    "heartbeat_drop",
]

spec = "replica_crash:0.02:1,replica_stall_ms:0.05:1:60,heartbeat_drop:0.3:1"
check("fork_rng: disabled form is 0xB0FF ^ crc32(label)",
      fork_rng_seed("", "round_retry", 0, False) == 0xB0FF ^ crc32("round_retry"))
check("fork_rng: salt 0 keeps the root-plan identity (no salt term)",
      fork_rng_seed(spec, "round_retry", 0, True)
      == ((crc32(spec) << 32) ^ crc32("round_retry") ^ 0xB0FF))
check("fork_rng: labels separate streams",
      fork_rng_seed(spec, "round_retry", 0, True)
      != fork_rng_seed(spec, "replica_restart:0", 0, True))
check("fork_rng: replica salts separate streams",
      len({fork_rng_seed(spec, "replica_restart", s, True) for s in range(8)}) == 8)
check("stream_seed: salt 0 is seed ^ crc32(site)",
      all(stream_seed(9, s, 0) == 9 ^ crc32(s) for s in SITES))
check("stream_seed: the 10 sites draw 10 distinct streams",
      len({stream_seed(9, s, 0) for s in SITES}) == len(SITES))


# ---------------------------------------------------------------------
# 3. Backoff schedules (coordinator/server.rs, coordinator/fleet.rs)
# ---------------------------------------------------------------------

def retry_backoff_us(attempt, rng):
    return (100 << min(attempt, 4)) + rng.below(200)


def restart_backoff_ms(base, attempt, rng):
    base = max(base, 1)
    return (base << min(attempt, 4)) + rng.below(base)


r = Rng(fork_rng_seed(spec, "round_retry", 0, True))
sched = [retry_backoff_us(a, r) for a in range(1, 9)]
bases = [100 << min(a, 4) for a in range(1, 9)]
check("retry_backoff_us: exponential base, shift capped at 4, jitter < 200",
      all(b <= v < b + 200 for b, v in zip(bases, sched))
      and bases[3:] == [1600] * 5)
r2 = Rng(fork_rng_seed(spec, "round_retry", 0, True))
check("retry_backoff_us: schedule replays bit-for-bit from the spec",
      sched == [retry_backoff_us(a, r2) for a in range(1, 9)])

r = Rng(fork_rng_seed(spec, "replica_restart:0", 3, True))
vals = [restart_backoff_ms(250, a, r) for a in range(8)]
check("restart_backoff_ms: value in [base<<min(a,4), base<<min(a,4) + base)",
      all((250 << min(a, 4)) <= v < (250 << min(a, 4)) + 250
          for a, v in enumerate(vals)))
check("restart_backoff_ms: shift cap — attempts 4.. share the 16x base",
      all((250 << 4) <= v < (250 << 4) + 250 for v in vals[4:]))
r2 = Rng(fork_rng_seed(spec, "replica_restart:0", 3, True))
check("restart_backoff_ms: chaos restart schedule replays bit-for-bit",
      vals == [restart_backoff_ms(250, a, r2) for a in range(8)])
check("restart_backoff_ms: base clamp makes base=0 behave as base=1",
      all((1 << min(a, 4)) <= restart_backoff_ms(0, a, Rng(a)) < (1 << min(a, 4)) + 1
          for a in range(8)))


# ---------------------------------------------------------------------
# 4. Placement (coordinator/fleet.rs: placement_mix + Placer)
# ---------------------------------------------------------------------

def placement_mix(seed, arrival):
    z = (seed ^ ((arrival * GOLDEN) & MASK)) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return (z ^ (z >> 31)) & MASK


class Placer:
    """Least-loaded healthy non-draining, seeded-hash tie-break."""

    def __init__(self, seed):
        self.seed = seed
        self.arrivals = 0

    def place(self, views):
        """views: list of (id, healthy, draining, load)."""
        elig = [v for v in views if v[1] and not v[2]]
        if not elig:
            return None  # no arrival consumed
        best = min(v[3] for v in elig)
        ties = [v[0] for v in elig if v[3] == best]
        arrival = self.arrivals
        self.arrivals += 1
        return arrival, ties[placement_mix(self.seed, arrival) % len(ties)]


check("placement_mix(0, 0) == 0 (finalizer fixed point, pinned)",
      placement_mix(0, 0) == 0)
check("placement_mix(seed, 0) is the bare splitmix64 finalizer of seed",
      placement_mix(0xDEAD, 0)
      == (lambda z: (z ^ (z >> 31)))(
          ((((0xDEAD ^ (0xDEAD >> 30)) * 0xBF58476D1CE4E5B9) & MASK) ^
           (((((0xDEAD ^ (0xDEAD >> 30)) * 0xBF58476D1CE4E5B9) & MASK)) >> 27))
          * 0x94D049BB133111EB & MASK))
bitflips = [bin(placement_mix(3, a) ^ placement_mix(3, a + 1)).count("1")
            for a in range(64)]
check("placement_mix: consecutive arrivals decorrelate (avalanche > 16 bits avg)",
      sum(bitflips) / len(bitflips) > 16)

p = Placer(3)
got = p.place([(0, True, False, 4), (1, True, False, 2), (2, False, False, 0),
               (3, True, True, 0)])
check("placer: least-loaded among eligible (unhealthy + draining skipped)",
      got == (0, 1))
check("placer: no eligible replica consumes no arrival",
      Placer(3).place([(0, False, False, 0), (1, True, True, 0)]) is None)
p = Placer(5)
before = p.arrivals
p.place([(0, False, False, 0)])
check("placer: arrivals counter untouched on a failed placement",
      p.arrivals == before)

p = Placer(1)
picks = {p.place([(0, True, False, 0), (1, True, False, 0),
                  (2, True, False, 0)])[1] for _ in range(32)}
check("placer: 3-way ties rotate across all replicas (no starvation)",
      picks == {0, 1, 2})

# purity oracle: replay a recorded (views, chosen) log through a fresh
# placer — the fleet's PlacedEvent invariant
log = []
p = Placer(11)
rng = Rng(99)
loads = [0, 0, 0]
for i in range(40):
    views = [(j, rng.below(10) > 0, rng.below(10) == 0, loads[j])
             for j in range(3)]
    got = p.place(views)
    if got is None:
        continue
    arrival, chosen = got
    log.append((arrival, views, chosen))
    loads[chosen] += 1
    if rng.below(3) == 0 and loads[chosen] > 0:
        loads[chosen] -= 1
replay = Placer(11)
check("placer: a recorded decision log replays bit-for-bit (purity)",
      all(replay.place(v) == (a, c) for a, v, c in log) and len(log) > 10)
check("placer: one-replica fleet is the identity path (always slot 0)",
      all(Placer(s).place([(0, True, False, l)]) == (0, 0)
          for s in range(5) for l in range(3)))


# ---------------------------------------------------------------------
# 5. Failover replay accounting (fleet.rs replay_request + kv pages_for)
# ---------------------------------------------------------------------

def pages_for(positions, page):
    return -(-positions // page)  # ceil-div, kv.rs KvGeom::pages_for


def replay(prompt_len, emitted, max_new, deadline, elapsed):
    """replay_request: prompt ++ emitted, budget and deadline reduced."""
    new_len = prompt_len + len(emitted)
    new_max = max(0, max_new - len(emitted))
    new_deadline = None if deadline is None else max(0, deadline - elapsed)
    return new_len, new_max, new_deadline


check("pages_for: ceil-div identity on the boundary lattice",
      all(pages_for(n, pg) == (n + pg - 1) // pg
          for pg in (3, 4, 8, 64) for n in range(1, 200)))

ok = True
rng = Rng(4242)
for _ in range(400):
    page = [3, 4, 8, 16][rng.below(4)]
    plen = 1 + rng.below(40)
    max_new = 1 + rng.below(12)
    e = rng.below(max_new)  # tokens emitted before the crash
    emitted = list(range(e))
    new_len, new_max, _ = replay(plen, emitted, max_new, None, 0)
    # budget conservation: emitted + remaining == original
    if e + new_max != max_new:
        ok = False
    # the survivor's admission charge equals the continuation the dead
    # replica would have run: one decode step past prompt+emitted
    if pages_for(new_len + 1, page) != pages_for(plen + e + 1, page):
        ok = False
    # and the dead incarnation frees at least that many pages minus the
    # one growth page the next decode step may add
    if pages_for(plen + e + 1, page) - pages_for(plen + e, page) not in (0, 1):
        ok = False
check("failover replay: budget conserved, survivor charge == continuation, "
      "one growth page max (400-case fuzz)", ok)
check("failover replay: deadline reduction saturates at 0, None passes through",
      replay(4, [1, 2], 8, 100, 250)[2] == 0
      and replay(4, [1, 2], 8, 100, 30)[2] == 70
      and replay(4, [1, 2], 8, None, 30)[2] is None)
check("failover replay: an exhausted budget means serve-from-emitted, not replay",
      replay(4, [1, 2, 3], 3, None, 0)[1] == 0)


# ---------------------------------------------------------------------
# 6. Drain/restart bookkeeping + stall detection (router_loop rules)
# ---------------------------------------------------------------------

# discrete-event simulation of the router's drain ladder: submit work,
# drain a slot mid-load, verify no placement lands on it, ack only at
# zero outstanding, cycle it, verify it rejoins — and nothing is dropped
placer = Placer(2)
outstanding = {0: set(), 1: set(), 2: set()}
draining = {0: False, 1: False, 2: False}
drains = planned_restarts = 0
completed = set()
drain_acked_at = None
events = []
for step in range(60):
    if step == 10:
        draining[1] = True  # Fleet::drain(1) lands while slot 1 is busy
        drains += 1
    # replicas serve concurrently: each busy slot retires one session
    # every other step; retirements start after the drain lands so the
    # ack is gated on real in-flight work
    if step % 2 == 1 and step > 10:
        for s in outstanding:
            if outstanding[s]:
                completed.add(outstanding[s].pop())
    if draining[1] and not outstanding[1] and drain_acked_at is None:
        drain_acked_at = step  # ack fires only now
        planned_restarts += 1  # restart_replica: cycle + rejoin
        draining[1] = False
    views = [(s, True, draining[s], len(outstanding[s])) for s in (0, 1, 2)]
    got = placer.place(views)
    if got is not None:
        _, chosen = got
        outstanding[chosen].add(("req", step))
        events.append((step, chosen))
while any(outstanding.values()):
    loaded = max(outstanding, key=lambda s: len(outstanding[s]))
    completed.add(outstanding[loaded].pop())

placed_on_1_while_draining = [s for s, c in events
                              if c == 1 and 10 <= s < drain_acked_at]
check("drain: a draining slot receives zero placements", not placed_on_1_while_draining)
check("drain: the ack fires only once outstanding hits zero",
      drain_acked_at is not None and drain_acked_at > 10)
check("drain: the cycled replica rejoins placement after its restart",
      any(c == 1 and s >= drain_acked_at for s, c in events))
check("drain: bookkeeping counts one drain and one planned restart, nothing dropped",
      (drains, planned_restarts) == (1, 1) and len(completed) == len(events))

# heartbeat stall ladder: the scheduler bumps every <= 20 ms when idle, so
# the 250 ms default threshold can never false-depose; a frozen counter
# always trips it within stall_ms + one poll tick
def stall_detector(bumps_at, stall_ms, horizon_ms, tick_ms=2):
    """bumps_at: sorted ms timestamps of heartbeat bumps; returns depose time."""
    last_bump_seen, last_change = 0, 0
    hb = 0
    for now in range(0, horizon_ms, tick_ms):
        while hb < len(bumps_at) and bumps_at[hb] <= now:
            hb += 1
        if hb != last_bump_seen:
            last_bump_seen, last_change = hb, now
        elif now - last_change > stall_ms:
            return now
    return None


idle_bumps = list(range(0, 2000, 20))  # worst-case idle cadence
check("stall detector: a live idle scheduler (20 ms bumps) never trips 250 ms",
      stall_detector(idle_bumps, 250, 2000) is None)
frozen = list(range(0, 500, 5))  # healthy, then frozen after t=495
t = stall_detector(frozen, 250, 2000)
check("stall detector: a frozen heartbeat deposes within stall_ms + two ticks",
      t is not None and 495 + 250 < t <= 495 + 250 + 4)
check("stall detector: heartbeat_drop noise (one skipped bump) stays below 250 ms",
      stall_detector([b for b in idle_bumps if b != 200], 250, 2000) is None)


# ---------------------------------------------------------------------

failed = [n for n, ok in checks if not ok]
assert not failed, failed
print(f"ALL OK ({len(checks)} checks)")
