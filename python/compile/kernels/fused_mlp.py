"""L1 — fused block-sparse MLP gate kernel (paper §3.3.3).

The paper fuses the memory-bound nonlinearity into the compute-bound SpMM so
the gated hidden state ``H = SiLU(X W1) ⊙ (X W2)`` never round-trips through
HBM. We express that as a single Pallas kernel whose ``(i, j)`` grid step:

  1. loops over the K block-column of both ``W1`` and ``W2``,
  2. predicates each ``b×b`` block MAC on its block-mask entry (pruned
     blocks contribute neither FLOPs nor — on a real TPU — DMA traffic),
  3. applies the SiLU gate as the *epilogue* of the contraction, writing the
     already-gated tile.

The down-projection ``Y = H W3`` is the plain ``bspmm`` kernel. Both are
lowered ``interpret=True`` (see bspmm.py for why).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bspmm import bspmm


def _gate_kernel(x_ref, w1_ref, w2_ref, m1_ref, m2_ref, h_ref, *, nk: int, block: int, act: str):
    """One (i, j) grid step producing the gated hidden tile H[i, j]."""
    blk_m = h_ref.shape[0]
    bn = h_ref.shape[1]

    def body(kk, accs):
        acc1, acc2 = accs
        x_blk = pl.load(x_ref, (slice(None), pl.ds(kk * block, block)))
        w1_blk = pl.load(w1_ref, (pl.ds(kk * block, block), slice(None)))
        w2_blk = pl.load(w2_ref, (pl.ds(kk * block, block), slice(None)))
        m1 = pl.load(m1_ref, (pl.ds(kk, 1), slice(None)))[0, 0]
        m2 = pl.load(m2_ref, (pl.ds(kk, 1), slice(None)))[0, 0]
        # Predicated MACs: a pruned block contributes nothing. (On TPU the
        # DMA itself is predicated; under interpret we gate the MAC value.)
        p1 = jnp.dot(x_blk, w1_blk, preferred_element_type=jnp.float32)
        p2 = jnp.dot(x_blk, w2_blk, preferred_element_type=jnp.float32)
        acc1 = acc1 + jnp.where(m1 != 0, p1, 0.0)
        acc2 = acc2 + jnp.where(m2 != 0, p2, 0.0)
        return acc1, acc2

    zero = jnp.zeros((blk_m, bn), jnp.float32)
    acc1, acc2 = jax.lax.fori_loop(0, nk, body, (zero, zero))
    # Fused epilogue: the nonlinearity + gating happen in VMEM, before the
    # tile is written back — H never exists un-gated in HBM.
    if act == "silu":
        gated = acc1 * jnp.reciprocal(1.0 + jnp.exp(-acc1)) * acc2
    elif act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi)
        gated = 0.5 * acc1 * (1.0 + jnp.tanh(c * (acc1 + 0.044715 * acc1**3)))
    else:
        raise ValueError(f"unknown act {act!r}")
    h_ref[...] = gated.astype(h_ref.dtype)


def fused_gate(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    m1: jnp.ndarray,
    m2: jnp.ndarray,
    *,
    block: int,
    blk_m: int = 0,
    act: str = "silu",
    interpret: bool = True,
) -> jnp.ndarray:
    """``H = act(X W1) ⊙ (X W2)`` with block-masked W1/W2, fused epilogue.

    For ``act="gelu"`` the ``w2``/``m2`` operands are still contracted but the
    epilogue ignores the gate (pass ``w2 = w1`` to share); prefer
    :func:`fused_mlp` which handles both layouts.
    """
    m, k = x.shape
    k2, f = w1.shape
    assert k == k2 and w2.shape == (k, f)
    assert k % block == 0 and f % block == 0
    nk, nf = k // block, f // block
    assert m1.shape == (nk, nf) and m2.shape == (nk, nf)
    if blk_m == 0:
        blk_m = min(m, 128)
    assert m % blk_m == 0, (m, blk_m)

    grid = (m // blk_m, nf)
    return pl.pallas_call(
        functools.partial(_gate_kernel, nk=nk, block=block, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block), lambda i, j: (0, j)),
            pl.BlockSpec((k, block), lambda i, j: (0, j)),
            pl.BlockSpec((nk, 1), lambda i, j: (0, j)),
            pl.BlockSpec((nk, 1), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((blk_m, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, f), x.dtype),
        interpret=interpret,
    )(x, w1, w2, m1, m2)


def fused_mlp(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    w3: jnp.ndarray,
    m1: jnp.ndarray,
    m2: jnp.ndarray,
    m3: jnp.ndarray,
    *,
    block: int,
    blk_m: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Full sparse MLP (paper Eq. 1): ``Y = (SiLU(X W1) ⊙ (X W2)) W3``."""
    h = fused_gate(
        x, w1, w2, m1, m2, block=block, blk_m=blk_m, act="silu", interpret=interpret
    )
    return bspmm(h, w3, m3, block=block, blk_m=blk_m, interpret=interpret)
