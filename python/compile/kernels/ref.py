"""Pure-jnp correctness oracles for the BLaST kernels.

These are the ground truth the Pallas kernels (and, transitively, the AOT'd
HLO executed from Rust) are validated against in ``python/tests``. They are
deliberately written in the most obvious way possible — readability over
speed — so that a bug here is implausible.

Shapes and conventions (mirrors paper §3.3, ``Y = XW`` variant):
  * ``x``      — activations, ``(seq, k)`` (a flattened ``(batch*seq, k)``).
  * ``w``      — weight matrix, ``(k, n)``.
  * ``mask``   — block mask, ``(k // b, n // b)`` with entries in {0, 1};
                 ``mask[i, j] == 0`` means the ``b×b`` block is pruned.
  * block size ``b`` must divide both ``k`` and ``n``.
"""

from __future__ import annotations

import jax.numpy as jnp


def expand_mask(mask: jnp.ndarray, block: int) -> jnp.ndarray:
    """Expand a block mask ``(kb, nb)`` to elementwise ``(kb*b, nb*b)``."""
    return jnp.repeat(jnp.repeat(mask, block, axis=0), block, axis=1)


def masked_weight(w: jnp.ndarray, mask: jnp.ndarray, block: int) -> jnp.ndarray:
    """Apply a block mask to a dense weight matrix (the pruned ``W_new``)."""
    kb, nb = mask.shape
    assert w.shape == (kb * block, nb * block), (w.shape, mask.shape, block)
    return w * expand_mask(mask, block).astype(w.dtype)


def bspmm_ref(
    x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray, block: int
) -> jnp.ndarray:
    """Reference block-sparse matmul: ``Y = X @ (W ⊙ expand(mask))``."""
    return x @ masked_weight(w, mask, block)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * jnp.reciprocal(1.0 + jnp.exp(-x))


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    # tanh approximation, matching jax.nn.gelu(approximate=True)
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def fused_mlp_ref(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    w3: jnp.ndarray,
    m1: jnp.ndarray,
    m2: jnp.ndarray,
    m3: jnp.ndarray,
    block: int,
) -> jnp.ndarray:
    """Reference Llama-style sparse MLP (paper Eq. 1):

    ``Y = (SiLU(X W1) ⊙ (X W2)) W3`` with per-matrix block masks.
    """
    h = silu(bspmm_ref(x, w1, m1, block)) * bspmm_ref(x, w2, m2, block)
    return bspmm_ref(h, w3, m3, block)


def gelu_mlp_ref(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    m1: jnp.ndarray,
    m3: jnp.ndarray,
    block: int,
) -> jnp.ndarray:
    """Reference GPT-2-style sparse MLP: ``Y = GELU(X W1) W3``."""
    return bspmm_ref(gelu(bspmm_ref(x, w1, m1, block)), w3, m3, block)
