"""L1 — BLaST BSpMM as a Pallas kernel.

``Y = X @ (W ⊙ expand(mask))`` where ``mask`` is a block mask over ``b×b``
tiles of ``W``. This is the TPU re-think of the paper's Triton BCSC kernel
(§3.3 / Listing 2):

  * The CUDA kernel streams surviving BCSC blocks and issues one MMA per
    block. Here each ``(i, j, k)`` grid step owns one ``(blk_m, b)`` tile of
    ``X`` and one ``b×b`` block of ``W``; the tile MAC (``jnp.dot``) maps to
    the MXU systolic array instead of a warp-level MMA fragment.
  * The paper skips pruned blocks by construction (they are absent from the
    BCSC stream). Pallas grids are static, so we *predicate* the block MAC
    on the mask entry with ``pl.when``: on a real TPU the pruned block's
    HBM→VMEM DMA and its MXU issue are both elided, which is the same data
    movement the BCSC stream achieves (DESIGN.md §Hardware-Adaptation).
  * ``blk_m`` plays the role of the paper's ``blk_M`` (rows of the dense
    operand reusing the loaded sparse block); ``b`` is the paper's
    ``blk_N``/``blk_K`` sparse block size.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against ``ref.bspmm_ref`` and TPU
performance is estimated analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bspmm_kernel(x_ref, w_ref, m_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: Y[i, j] += X[i, k] @ W[k, j] if mask[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(m_ref[0, 0] != 0)
    def _mac():
        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def bspmm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block: int,
    blk_m: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Block-sparse matmul ``Y = X @ (W ⊙ expand(mask))``.

    Args:
      x:     ``(m, k)`` activations (callers flatten leading batch dims).
      w:     ``(k, n)`` weights.
      mask:  ``(k // block, n // block)`` block mask, 0 = pruned.
      block: sparse block size ``b`` (paper's ``blk_N``); must divide k, n.
      blk_m: rows of ``x`` per grid step (paper's ``blk_M``); defaults to
             ``min(m, 128)`` — the MXU-native tile height.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert k % block == 0 and n % block == 0, (k, n, block)
    assert mask.shape == (k // block, n // block), (mask.shape, k, n, block)
    if blk_m == 0:
        blk_m = min(m, 128)
    assert m % blk_m == 0, (m, blk_m)
    nk = k // block

    grid = (m // blk_m, n // block, nk)
    return pl.pallas_call(
        functools.partial(_bspmm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_m, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, block), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((blk_m, block), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, mask)
