"""AOT compilation: lower every L2 entry point to HLO *text* + manifest.

Run once via ``make artifacts``; Rust loads the results through
``HloModuleProto::from_text_file`` (PJRT CPU). HLO text — not
``.serialize()`` — is the interchange format: jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

The manifest (``artifacts/manifest.json``) is the ABI contract with the Rust
runtime: for every entry it records the flat positional input/output lists
(name, shape, dtype) plus the model geometry, parameter spec and mask spec.

Usage:  cd python && python -m compile.aot --out ../artifacts [--full]
        (--full additionally lowers the ~100M `e2e-100m` twin)
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.bspmm import bspmm
from .kernels.fused_mlp import fused_mlp

# ---------------------------------------------------------------------------
# Config registry (scaled twins of the paper geometries — DESIGN.md §7)
# ---------------------------------------------------------------------------

CONFIGS: Dict[str, M.ModelConfig] = {
    c.name: c
    for c in [
        # test-scale twin used by pytest + rust integration tests
        M.ModelConfig("micro", "gpt2", 256, 64, 128, 2, 2, 32, 2, 32,
                      paper_equiv="GPT2-small"),
        # llama twin at test scale — carries the Pallas-composition proof
        M.ModelConfig("micro-llama", "llama", 256, 64, 128, 2, 2, 32, 2, 32,
                      paper_equiv="Llama-3.2-1B"),
        # pretraining twins (Table 2 / Fig. 8 / ablation tables)
        M.ModelConfig("gpt2s-sim", "gpt2", 2048, 256, 1024, 4, 4, 128, 8, 32,
                      paper_equiv="GPT2-small"),
        # block-size ablation twins (Table 4 / Fig. 10): b=1 is the
        # unstructured-pruning point, b=16 the smallest blocked point;
        # b ∈ {64, 128} reuse gpt2s-sim via coarse mask grouping in Rust.
        M.ModelConfig("gpt2s-sim-b1", "gpt2", 2048, 256, 1024, 4, 4, 128, 8, 1,
                      paper_equiv="GPT2-small"),
        M.ModelConfig("gpt2s-sim-b16", "gpt2", 2048, 256, 1024, 4, 4, 128, 8, 16,
                      paper_equiv="GPT2-small"),
        M.ModelConfig("llama-sim", "llama", 2048, 256, 1024, 4, 4, 128, 8, 32,
                      paper_equiv="Llama-3.2-1B"),
        # end-to-end driver twins (EXPERIMENTS.md headline run)
        M.ModelConfig("e2e-small", "gpt2", 4096, 512, 2048, 8, 8, 256, 4, 64,
                      paper_equiv="GPT2-medium"),
        M.ModelConfig("e2e-100m", "gpt2", 8192, 768, 3072, 12, 12, 256, 4, 64,
                      paper_equiv="GPT2-large"),
        # vision twin (Table 3 / Fig. 9)
        M.ModelConfig("vit-sim", "vit", 0, 128, 512, 4, 4, 17, 32, 32,
                      num_classes=10, patch_dim=192, paper_equiv="ViT-B/16"),
        # GLUE-like sequence-classification twin (Table 1)
        M.ModelConfig("glue-sim", "vit", 0, 128, 512, 4, 4, 33, 32, 32,
                      num_classes=2, patch_dim=64, paper_equiv="Llama-3.2-1B+GLUE"),
    ]
}

LEARNING_RATES = {
    "micro": 1e-3, "micro-llama": 1e-3,
    "gpt2s-sim": 6e-4, "gpt2s-sim-b1": 6e-4, "gpt2s-sim-b16": 6e-4,
    "llama-sim": 6e-4,
    "e2e-small": 3e-4, "e2e-100m": 2.5e-4,
    "vit-sim": 1e-3, "glue-sim": 1e-3,
}


def _spec(shape: Sequence[int], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(name: str, s: jax.ShapeDtypeStruct) -> dict:
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids re-assigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Flat-ABI wrappers: dict pytrees → positional array lists
# ---------------------------------------------------------------------------


def _flat_entries(cfg: M.ModelConfig):
    """(param names+shapes, mask names+shapes) in ABI order."""
    pspec = M.param_spec(cfg)
    mspec = M.mask_spec(cfg)
    return pspec, mspec


def flatten_io(cfg: M.ModelConfig):
    pspec, mspec = _flat_entries(cfg)
    pnames = [n for n, _ in pspec]
    mnames = [n for n, _ in mspec]

    def to_params(args: Sequence[jnp.ndarray]) -> M.Params:
        return dict(zip(pnames, args))

    def to_masks(args: Sequence[jnp.ndarray]) -> M.Masks:
        return dict(zip(mnames, args))

    return pnames, mnames, to_params, to_masks


def make_entry_fns(cfg: M.ModelConfig, lr: float):
    """Build the flat-positional entry functions for one config."""
    pnames, mnames, to_params, to_masks = flatten_io(cfg)
    P, K = len(pnames), len(mnames)
    step_fn = M.make_train_step(cfg, lr)

    def train_step(*args):
        params = to_params(args[:P])
        m = to_params(args[P : 2 * P])
        v = to_params(args[2 * P : 3 * P])
        step = args[3 * P]
        masks = to_masks(args[3 * P + 1 : 3 * P + 1 + K])
        inputs, labels = args[3 * P + 1 + K], args[3 * P + 2 + K]
        new_p, new_m, new_v, new_step, loss, mlp_g = step_fn(
            params, m, v, step, masks, inputs, labels
        )
        out = [new_p[n] for n in pnames]
        out += [new_m[n] for n in pnames]
        out += [new_v[n] for n in pnames]
        out += [new_step, loss]
        out += [mlp_g[n] for n in cfg.mlp_weight_names()]
        return tuple(out)

    def eval_loss(*args):
        params = to_params(args[:P])
        masks = to_masks(args[P : P + K])
        inputs, labels = args[P + K], args[P + K + 1]
        if cfg.kind == "vit":
            logits = M.vit_logits(cfg, params, masks, inputs)
            logp = jax.nn.log_softmax(logits, axis=-1)
            loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
            return (loss, logits)
        loss = M.lm_loss(cfg, params, masks, inputs, labels)
        return (loss,)

    def eval_loss_pallas(*args):
        params = to_params(args[:P])
        masks = to_masks(args[P : P + K])
        inputs, labels = args[P + K], args[P + K + 1]
        return (M.lm_loss(cfg, params, masks, inputs, labels, use_pallas=True),)

    def prefill(*args):
        params = to_params(args[:P])
        masks = to_masks(args[P : P + K])
        tokens = args[P + K]
        return M.prefill(cfg, params, masks, tokens)

    def decode_step(*args):
        params = to_params(args[:P])
        masks = to_masks(args[P : P + K])
        kc, vc, token, pos = args[P + K : P + K + 4]
        return M.decode_step(cfg, params, masks, kc, vc, token, pos)

    return {
        "train_step": train_step,
        "eval_loss": eval_loss,
        "eval_loss_pallas": eval_loss_pallas,
        "prefill": prefill,
        "decode_step": decode_step,
    }


def entry_specs(cfg: M.ModelConfig, kind: str):
    """Input (name, ShapeDtypeStruct) list for an entry kind, ABI order."""
    pspec, mspec = _flat_entries(cfg)
    params = [(n, _spec(s)) for n, s in pspec]
    masks = [("mask:" + n, _spec(s)) for n, s in mspec]
    if cfg.kind == "vit":
        data = [
            ("inputs", _spec((cfg.batch, cfg.seq - 1, cfg.patch_dim))),
            ("labels", _spec((cfg.batch,), jnp.int32)),
        ]
    else:
        data = [
            ("inputs", _spec((cfg.batch, cfg.seq), jnp.int32)),
            ("labels", _spec((cfg.batch, cfg.seq), jnp.int32)),
        ]
    kv = (cfg.layers, cfg.batch, cfg.heads, cfg.seq, cfg.head_dim)
    if kind == "train_step":
        opt = [("m:" + n, s) for n, s in params] + [("v:" + n, s) for n, s in params]
        return (
            params
            + opt
            + [("step", _spec((), jnp.int32))]
            + masks
            + data
        )
    if kind in ("eval_loss", "eval_loss_pallas"):
        return params + masks + data
    if kind == "prefill":
        return params + masks + [("tokens", _spec((cfg.batch, cfg.seq), jnp.int32))]
    if kind == "decode_step":
        return params + masks + [
            ("kcache", _spec(kv)),
            ("vcache", _spec(kv)),
            ("token", _spec((cfg.batch,), jnp.int32)),
            ("pos", _spec((), jnp.int32)),
        ]
    raise ValueError(kind)


def output_names(cfg: M.ModelConfig, kind: str) -> List[str]:
    pnames = [n for n, _ in M.param_spec(cfg)]
    if kind == "train_step":
        return (
            pnames
            + ["m:" + n for n in pnames]
            + ["v:" + n for n in pnames]
            + ["step", "loss"]
            + ["grad:" + n for n in cfg.mlp_weight_names()]
        )
    if kind == "eval_loss":
        return ["loss", "logits"] if cfg.kind == "vit" else ["loss"]
    if kind == "eval_loss_pallas":
        return ["loss"]
    if kind == "prefill":
        return ["logits", "kcache", "vcache"]
    if kind == "decode_step":
        return ["logits", "kcache", "vcache"]
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Standalone kernel artifacts (L1 → L3 composition proof)
# ---------------------------------------------------------------------------

KERNEL_SHAPES = {
    # (m, k, n, block) — small enough for fast interpret-mode HLO
    "bspmm_pallas": (64, 128, 128, 32),
    "fused_mlp_pallas": (64, 128, 256, 32),
}


def kernel_entries():
    out = []
    m, k, n, b = KERNEL_SHAPES["bspmm_pallas"]

    def bspmm_fn(x, w, mask):
        return (bspmm(x, w, mask, block=b),)

    out.append(
        (
            "bspmm_pallas",
            bspmm_fn,
            [
                ("x", _spec((m, k))),
                ("w", _spec((k, n))),
                ("mask", _spec((k // b, n // b))),
            ],
            ["y"],
            {"m": m, "k": k, "n": n, "block": b},
        )
    )

    m2, k2, f2, b2 = KERNEL_SHAPES["fused_mlp_pallas"]

    def mlp_fn(x, w1, w2, w3, m1, mm2, m3):
        return (fused_mlp(x, w1, w2, w3, m1, mm2, m3, block=b2),)

    out.append(
        (
            "fused_mlp_pallas",
            mlp_fn,
            [
                ("x", _spec((m2, k2))),
                ("w1", _spec((k2, f2))),
                ("w2", _spec((k2, f2))),
                ("w3", _spec((f2, k2))),
                ("m1", _spec((k2 // b2, f2 // b2))),
                ("m2", _spec((k2 // b2, f2 // b2))),
                ("m3", _spec((f2 // b2, k2 // b2))),
            ],
            ["y"],
            {"m": m2, "k": k2, "n": f2, "block": b2},
        )
    )
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

# (config, [entry kinds]) lowered by default; e2e-100m needs --full
PLAN = [
    ("micro", ["train_step", "eval_loss"]),
    ("micro-llama", ["train_step", "eval_loss", "eval_loss_pallas",
                     "prefill", "decode_step"]),
    ("gpt2s-sim", ["train_step", "eval_loss"]),
    ("gpt2s-sim-b1", ["train_step", "eval_loss"]),
    ("gpt2s-sim-b16", ["train_step", "eval_loss"]),
    ("llama-sim", ["train_step", "eval_loss", "prefill", "decode_step"]),
    ("e2e-small", ["train_step", "eval_loss", "prefill", "decode_step"]),
    ("vit-sim", ["train_step", "eval_loss"]),
    ("glue-sim", ["train_step", "eval_loss"]),
]
PLAN_FULL = PLAN + [("e2e-100m", ["train_step", "eval_loss"])]


def lower_entry(cfg: M.ModelConfig, kind: str, out_dir: str) -> dict:
    fns = make_entry_fns(cfg, LEARNING_RATES[cfg.name])
    specs = entry_specs(cfg, kind)
    lowered = jax.jit(fns[kind]).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    fname = f"{cfg.name}_{kind}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return {
        "name": f"{cfg.name}_{kind}",
        "file": fname,
        "config": cfg.name,
        "kind": kind,
        "inputs": [_io_entry(n, s) for n, s in specs],
        "outputs": output_names(cfg, kind),
        "hlo_bytes": len(text),
    }


def config_manifest(cfg: M.ModelConfig) -> dict:
    pspec, mspec = _flat_entries(cfg)
    nparams = sum(int(jnp.prod(jnp.array(s))) for _, s in pspec)
    d = dataclasses_asdict(cfg)
    d.update(
        {
            "lr": LEARNING_RATES[cfg.name],
            "param_count": nparams,
            "params": [{"name": n, "shape": list(s)} for n, s in pspec],
            "masks": [{"name": n, "shape": list(s)} for n, s in mspec],
            "mlp_weights": cfg.mlp_weight_names(),
            "head_dim": cfg.head_dim,
        }
    )
    return d


def dataclasses_asdict(cfg) -> dict:
    import dataclasses as dc

    return {f.name: getattr(cfg, f.name) for f in dc.fields(cfg)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also lower the ~100M e2e-100m twin")
    ap.add_argument("--only", default="",
                    help="comma-separated config names to (re)build")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    plan = PLAN_FULL if args.full else PLAN
    if args.only:
        keep = set(args.only.split(","))
        plan = [(c, ks) for c, ks in plan if c in keep]

    entries = []
    for cname, kinds in plan:
        cfg = CONFIGS[cname]
        for kind in kinds:
            e = lower_entry(cfg, kind, args.out)
            entries.append(e)
            print(f"lowered {e['name']:40s} {e['hlo_bytes']:>9d} B")

    for name, fn, specs, outs, meta in kernel_entries():
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": fname,
                "config": None,
                "kind": "kernel",
                "inputs": [_io_entry(n, s) for n, s in specs],
                "outputs": outs,
                "meta": meta,
                "hlo_bytes": len(text),
            }
        )
        print(f"lowered {name:40s} {len(text):>9d} B")

    manifest = {
        "version": 1,
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        "configs": {c: config_manifest(CONFIGS[c]) for c, _ in plan},
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} entries to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
