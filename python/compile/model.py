"""L2 — JAX model definitions for the BLaST reproduction.

Defines the Transformer variants the paper evaluates (GPT-2-style decoder,
Llama-style decoder, ViT-style encoder classifier) plus the AOT entry points
the Rust coordinator executes:

  * ``train_step``      — fused fwd + bwd + Adam update with block-masked
                          MLP weights; returns the MLP weight gradients so
                          the Rust prune-and-grow controller (L3) can run
                          the paper's §3.2 algorithm.
  * ``eval_loss``       — test loss (Rust converts to perplexity).
  * ``prefill``         — prompt pass producing last-position logits + KV.
  * ``decode_step``     — single-token KV-cached decode.
  * ``classify_*``      — ViT / GLUE-style classification head variants.

Masking semantics (paper §3.2): the *pruned* weight ``W ⊙ expand(M)`` is
used in both the forward and the backward pass (no straight-through
estimator). Autodiff through the mask multiplication therefore yields
*masked* gradients — exactly the ``G_i`` matrices the paper feeds to
``S(G_i)`` in the grow step. The dense weights are kept intact in the
optimizer state and keep receiving (masked) Adam updates, mirroring
"the dense weight and gradient matrices are kept intact".

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once; Python never runs on the Rust request path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.fused_mlp import fused_mlp as fused_mlp_pallas

Params = Dict[str, jnp.ndarray]
Masks = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of one model variant.

    ``paper_equiv`` names the paper geometry this scaled twin stands for
    (DESIGN.md §7); analytic models (Figs. 5/7) use the real geometry, the
    wall-clock runs use the twin.
    """

    name: str
    kind: str  # "gpt2" | "llama" | "vit"
    vocab: int
    emb: int
    ffn: int
    layers: int
    heads: int
    seq: int
    batch: int
    block: int  # sparse block size b (paper's blk_N)
    num_classes: int = 0  # vit / classifier only
    patch_dim: int = 0  # vit only: flattened patch size (p*p*3)
    paper_equiv: str = ""

    @property
    def head_dim(self) -> int:
        return self.emb // self.heads

    def mlp_weight_names(self) -> List[str]:
        """Names of the sparsifiable MLP weight matrices, in layer order."""
        names = []
        for i in range(self.layers):
            if self.kind == "llama":
                names += [f"layer{i}.mlp.w1", f"layer{i}.mlp.w2", f"layer{i}.mlp.w3"]
            else:
                names += [f"layer{i}.mlp.w1", f"layer{i}.mlp.w3"]
        return names


def _lm_param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    e, f, v = cfg.emb, cfg.ffn, cfg.vocab
    spec: List[Tuple[str, Tuple[int, ...]]] = [("tok_emb", (v, e))]
    if cfg.kind == "gpt2":
        spec.append(("pos_emb", (cfg.seq, e)))
    for i in range(cfg.layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (e,)),
            (p + "attn.wq", (e, e)),
            (p + "attn.wk", (e, e)),
            (p + "attn.wv", (e, e)),
            (p + "attn.wo", (e, e)),
            (p + "ln2", (e,)),
            (p + "mlp.w1", (e, f)),
        ]
        if cfg.kind == "llama":
            spec.append((p + "mlp.w2", (e, f)))
        spec.append((p + "mlp.w3", (f, e)))
    spec += [("final_norm", (e,)), ("lm_head", (e, v))]
    return spec


def _vit_param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    e, f = cfg.emb, cfg.ffn
    npatch = cfg.seq - 1  # one slot reserved for the CLS token
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("patch_proj", (cfg.patch_dim, e)),
        ("cls_token", (e,)),
        ("pos_emb", (cfg.seq, e)),
    ]
    for i in range(cfg.layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (e,)),
            (p + "attn.wq", (e, e)),
            (p + "attn.wk", (e, e)),
            (p + "attn.wv", (e, e)),
            (p + "attn.wo", (e, e)),
            (p + "ln2", (e,)),
            (p + "mlp.w1", (e, f)),
            (p + "mlp.w3", (f, e)),
        ]
    spec += [("final_norm", (e,)), ("head", (e, cfg.num_classes))]
    _ = npatch
    return spec


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the ABI between aot.py and Rust."""
    return _vit_param_spec(cfg) if cfg.kind == "vit" else _lm_param_spec(cfg)


def mask_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, int]]]:
    """Ordered (mlp-weight-name, block-mask-shape) list."""
    shapes = dict(param_spec(cfg))
    b = cfg.block
    out = []
    for name in cfg.mlp_weight_names():
        k, n = shapes[name]
        assert k % b == 0 and n % b == 0, (name, k, n, b)
        out.append((name, (k // b, n // b)))
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Scaled-normal init (0.02 / sqrt(2L) on residual-out projections)."""
    key = jax.random.PRNGKey(seed)
    params: Params = {}
    resid_scale = 0.02 / math.sqrt(2 * cfg.layers)
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "final_norm")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "cls_token":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            scale = resid_scale if name.endswith(("attn.wo", "mlp.w3")) else 0.02
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def full_masks(cfg: ModelConfig) -> Masks:
    """All-ones (fully dense) block masks."""
    return {n: jnp.ones(s, jnp.float32) for n, s in mask_spec(cfg)}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def layernorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def _norm(cfg: ModelConfig, x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(x, g) if cfg.kind == "llama" else layernorm(x, g)


def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, head_dim); positions: (seq,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (seq, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _split_heads(x: jnp.ndarray, heads: int) -> jnp.ndarray:
    b, s, e = x.shape
    return x.reshape(b, s, heads, e // heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _attention(
    cfg: ModelConfig,
    p: Params,
    prefix: str,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    causal: bool,
) -> jnp.ndarray:
    """Dense multi-head attention over full sequence (train / prefill)."""
    q = _split_heads(x @ p[prefix + "attn.wq"], cfg.heads)
    k = _split_heads(x @ p[prefix + "attn.wk"], cfg.heads)
    v = _split_heads(x @ p[prefix + "attn.wv"], cfg.heads)
    if cfg.kind == "llama":
        q, k = _rope(q, positions), _rope(k, positions)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
    if causal:
        s = x.shape[1]
        neg = jnp.finfo(jnp.float32).min
        causal_mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(causal_mask[None, None], scores, neg)
    att = jax.nn.softmax(scores, axis=-1)
    out = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", att, v))
    return out @ p[prefix + "attn.wo"]


def _mlp(
    cfg: ModelConfig,
    p: Params,
    masks: Masks,
    prefix: str,
    x: jnp.ndarray,
    use_pallas: bool,
) -> jnp.ndarray:
    """Block-sparse MLP. The masked-dense formulation is numerically
    identical to the Pallas kernel path (asserted in python/tests); the
    Pallas path proves L1→L2 composition and is emitted for the micro
    config, while large training graphs use the XLA-fused dense form for
    CPU wall-clock sanity (DESIGN.md §1/L1)."""
    b = cfg.block
    bsz, s, e = x.shape
    w1, w3 = p[prefix + "mlp.w1"], p[prefix + "mlp.w3"]
    m1, m3 = masks[prefix + "mlp.w1"], masks[prefix + "mlp.w3"]
    if cfg.kind == "llama":
        w2, m2 = p[prefix + "mlp.w2"], masks[prefix + "mlp.w2"]
        if use_pallas:
            y = fused_mlp_pallas(
                x.reshape(bsz * s, e), w1, w2, w3, m1, m2, m3, block=b
            )
            return y.reshape(bsz, s, e)
        return ref.fused_mlp_ref(
            x.reshape(bsz * s, e), w1, w2, w3, m1, m2, m3, b
        ).reshape(bsz, s, e)
    # gpt2 / vit: GELU MLP
    y = ref.gelu_mlp_ref(x.reshape(bsz * s, e), w1, w3, m1, m3, b)
    return y.reshape(bsz, s, e)


def _block(
    cfg: ModelConfig,
    p: Params,
    masks: Masks,
    i: int,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    causal: bool,
    use_pallas: bool,
) -> jnp.ndarray:
    pre = f"layer{i}."
    x = x + _attention(cfg, p, pre, _norm(cfg, x, p[pre + "ln1"]), positions, causal)
    x = x + _mlp(cfg, p, masks, pre, _norm(cfg, x, p[pre + "ln2"]), use_pallas)
    return x


# ---------------------------------------------------------------------------
# LM forward / loss
# ---------------------------------------------------------------------------


def apply_masks(cfg: ModelConfig, params: Params, masks: Masks) -> Params:
    """Replace each sparsifiable W by its pruned form W ⊙ expand(M)."""
    out = dict(params)
    for name in cfg.mlp_weight_names():
        out[name] = ref.masked_weight(params[name], masks[name], cfg.block)
    return out


def lm_logits(
    cfg: ModelConfig,
    params: Params,
    masks: Masks,
    tokens: jnp.ndarray,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Full-sequence logits. tokens: (batch, seq) int32."""
    p = apply_masks(cfg, params, masks)
    # masks already folded into p; pass all-ones to _mlp to avoid double-mask
    ones = {n: jnp.ones_like(m) for n, m in masks.items()}
    x = p["tok_emb"][tokens]
    s = tokens.shape[1]
    positions = jnp.arange(s)
    if cfg.kind == "gpt2":
        x = x + p["pos_emb"][None, :s]
    for i in range(cfg.layers):
        x = _block(cfg, p, ones if not use_pallas else masks, i, x, positions, True, use_pallas)
    x = _norm(cfg, x, p["final_norm"])
    return x @ p["lm_head"]


def lm_loss(
    cfg: ModelConfig,
    params: Params,
    masks: Masks,
    tokens: jnp.ndarray,
    targets: jnp.ndarray,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Mean cross-entropy. targets: (batch, seq) int32 (next tokens)."""
    logits = lm_logits(cfg, params, masks, tokens, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# ViT forward / loss
# ---------------------------------------------------------------------------


def vit_logits(
    cfg: ModelConfig, params: Params, masks: Masks, patches: jnp.ndarray
) -> jnp.ndarray:
    """patches: (batch, seq-1, patch_dim) pre-patchified images."""
    p = apply_masks(cfg, params, masks)
    ones = {n: jnp.ones_like(m) for n, m in masks.items()}
    bsz = patches.shape[0]
    x = patches @ p["patch_proj"]
    cls = jnp.broadcast_to(p["cls_token"], (bsz, 1, cfg.emb))
    x = jnp.concatenate([cls, x], axis=1) + p["pos_emb"][None]
    positions = jnp.arange(cfg.seq)
    for i in range(cfg.layers):
        x = _block(cfg, p, ones, i, x, positions, False, False)
    x = _norm(cfg, x, p["final_norm"])
    return x[:, 0] @ p["head"]


def vit_loss(
    cfg: ModelConfig,
    params: Params,
    masks: Masks,
    patches: jnp.ndarray,
    labels: jnp.ndarray,
) -> jnp.ndarray:
    logits = vit_logits(cfg, params, masks, patches)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


# ---------------------------------------------------------------------------
# Adam train step (fwd + bwd + update fused into one HLO)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def adam_update(
    params: Params,
    grads: Params,
    m: Params,
    v: Params,
    step: jnp.ndarray,
    lr: float,
    wd: float = 0.0,
) -> Tuple[Params, Params, Params]:
    """Bias-corrected AdamW over the flat param dict."""
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - ADAM_B1**t
    c2 = 1.0 - ADAM_B2**t
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        nm = ADAM_B1 * m[k] + (1 - ADAM_B1) * g
        nv = ADAM_B2 * v[k] + (1 - ADAM_B2) * g * g
        upd = (nm / c1) / (jnp.sqrt(nv / c2) + ADAM_EPS)
        new_p[k] = params[k] - lr * (upd + wd * params[k])
        new_m[k], new_v[k] = nm, nv
    return new_p, new_m, new_v


def make_train_step(cfg: ModelConfig, lr: float, wd: float = 0.01):
    """Returns f(params, m, v, step, masks, tokens, targets) ->
    (params', m', v', step+1, loss, mlp_grads)."""

    loss_fn = vit_loss if cfg.kind == "vit" else lm_loss

    def step_fn(params, m, v, step, masks, inputs, labels):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, masks, inputs, labels)
        )(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr, wd)
        mlp_grads = {k: grads[k] for k in cfg.mlp_weight_names()}
        return new_p, new_m, new_v, step + 1, loss, mlp_grads

    return step_fn


# ---------------------------------------------------------------------------
# KV-cached inference (prefill + decode)
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig, params: Params, masks: Masks, tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prompt pass. tokens: (batch, seq). Returns (last_logits, K, V) with
    K/V: (layers, batch, heads, max_seq, head_dim); positions beyond the
    prompt are zero-filled and masked out during decode."""
    p = apply_masks(cfg, params, masks)
    ones = {n: jnp.ones_like(m) for n, m in masks.items()}
    bsz, s = tokens.shape
    x = p["tok_emb"][tokens]
    positions = jnp.arange(s)
    if cfg.kind == "gpt2":
        x = x + p["pos_emb"][None, :s]
    ks, vs = [], []
    for i in range(cfg.layers):
        pre = f"layer{i}."
        xn = _norm(cfg, x, p[pre + "ln1"])
        q = _split_heads(xn @ p[pre + "attn.wq"], cfg.heads)
        k = _split_heads(xn @ p[pre + "attn.wk"], cfg.heads)
        vv = _split_heads(xn @ p[pre + "attn.wv"], cfg.heads)
        if cfg.kind == "llama":
            q, k = _rope(q, positions), _rope(k, positions)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
        neg = jnp.finfo(jnp.float32).min
        scores = jnp.where(jnp.tril(jnp.ones((s, s), bool))[None, None], scores, neg)
        att = jax.nn.softmax(scores, axis=-1)
        out = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", att, vv))
        x = x + out @ p[pre + "attn.wo"]
        x = x + _mlp(cfg, p, ones, pre, _norm(cfg, x, p[pre + "ln2"]), False)
        # pad K/V to the model's max seq for a fixed-shape decode cache
        pad = cfg.seq - s
        ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0))))
    x = _norm(cfg, x, p["final_norm"])
    logits = x[:, -1] @ p["lm_head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(
    cfg: ModelConfig,
    params: Params,
    masks: Masks,
    kcache: jnp.ndarray,
    vcache: jnp.ndarray,
    token: jnp.ndarray,
    pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step. token: (batch,) int32; pos: () int32 — the index the
    new token occupies. Returns (logits, K', V')."""
    p = apply_masks(cfg, params, masks)
    ones = {n: jnp.ones_like(m) for n, m in masks.items()}
    bsz = token.shape[0]
    x = p["tok_emb"][token][:, None]  # (b, 1, e)
    if cfg.kind == "gpt2":
        x = x + jax.lax.dynamic_slice_in_dim(p["pos_emb"], pos, 1)[None]
    positions = pos[None]
    new_k, new_v = [], []
    valid = (jnp.arange(cfg.seq) <= pos)[None, None, None, :]  # (1,1,1,S)
    for i in range(cfg.layers):
        pre = f"layer{i}."
        xn = _norm(cfg, x, p[pre + "ln1"])
        q = _split_heads(xn @ p[pre + "attn.wq"], cfg.heads)  # (b,h,1,d)
        k1 = _split_heads(xn @ p[pre + "attn.wk"], cfg.heads)
        v1 = _split_heads(xn @ p[pre + "attn.wv"], cfg.heads)
        if cfg.kind == "llama":
            q, k1 = _rope(q, positions), _rope(k1, positions)
        kc = jax.lax.dynamic_update_slice(
            kcache[i], k1, (0, 0, pos.astype(jnp.int32), 0)
        )
        vc = jax.lax.dynamic_update_slice(
            vcache[i], v1, (0, 0, pos.astype(jnp.int32), 0)
        )
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kc) / math.sqrt(cfg.head_dim)
        neg = jnp.finfo(jnp.float32).min
        scores = jnp.where(valid, scores, neg)
        att = jax.nn.softmax(scores, axis=-1)
        out = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", att, vc))
        x = x + out @ p[pre + "attn.wo"]
        x = x + _mlp(cfg, p, ones, pre, _norm(cfg, x, p[pre + "ln2"]), False)
        new_k.append(kc)
        new_v.append(vc)
    x = _norm(cfg, x, p["final_norm"])
    logits = x[:, 0] @ p["lm_head"]
    _ = bsz
    return logits, jnp.stack(new_k), jnp.stack(new_v)
