//! Chaos-training integration suite: the guarded pretraining runtime
//! under seeded fault injection at the four training sites (`grad_nan`,
//! `grad_explode`, `loss_spike_mul`, `mask_corrupt`).
//!
//! The invariants, mirroring `blast exp chaos --train`:
//!
//! 1. **zero-overhead guarantee** — with no guard armed the trainer never
//!    consults the training fault sites, and a *permissive* guard is
//!    bit-identical to guards-off (loss stream, parameters, masks);
//! 2. **every anomaly is answered** — skips/reverts/rollbacks are
//!    recorded, the optimizer state stays finite, and the final
//!    checkpoint quick-verifies;
//! 3. **budgets fail loudly** — exhausting the rollback budget aborts
//!    with an exact, seed-independent trajectory.
//!
//! The pinned fire counts (`grad_nan:0.25:5` → 9 fires over 24 checks,
//! etc.) are cross-checked bit-for-bit by the numpy transliteration in
//! `python/tests/train_guard_check.py`; a mismatch means the RNG or
//! stream-seed derivation drifted, not the test.

use std::collections::BTreeMap;
use std::path::PathBuf;

use blast::model::params::ParamStore;
use blast::sparse::BlockMask;
use blast::train::pretrain::{PretrainOptions, Trainer};
use blast::train::GuardConfig;
use blast::util::faults::{FaultSite, Faults};

fn opts(iters: usize, seed: u64) -> PretrainOptions {
    PretrainOptions {
        total_iters: iters,
        s_max: 0.5,
        step_size: 5,
        seed,
        ..Default::default()
    }
}

fn trainer(iters: usize, seed: u64) -> Trainer<'static> {
    Trainer::new_native("micro", opts(iters, seed)).unwrap()
}

fn finite_params(t: &Trainer) -> bool {
    t.params().in_order().all(|(_, w)| w.data().iter().all(|v| v.is_finite()))
}

fn loss_bits(t: &Trainer) -> Vec<u32> {
    t.log.iter().map(|l| l.loss.to_bits()).collect()
}

fn param_bits(t: &Trainer) -> Vec<(String, Vec<u32>)> {
    t.params()
        .in_order()
        .map(|(n, w)| (n.clone(), w.data().iter().map(|v| v.to_bits()).collect()))
        .collect()
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("blast_chaos_training_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Armed training sites + no guard: the unguarded path must never consult
/// them (prob 1 would fire on the very first check), and the run is
/// bit-identical to a faultless twin.
#[test]
fn unguarded_trainer_never_consults_training_fault_sites() {
    let mut plain = trainer(8, 33);
    plain.run(8).unwrap();

    let faults = Faults::parse(
        "grad_nan:1:1,grad_explode:1:1,loss_spike_mul:1:1:100,mask_corrupt:1:1",
    )
    .unwrap();
    let mut armed = trainer(8, 33);
    armed.set_faults(faults.clone());
    armed.run(8).unwrap();

    assert_eq!(faults.total_fired(), 0, "unguarded path consulted a training site");
    assert_eq!(loss_bits(&plain), loss_bits(&armed));
    assert_eq!(param_bits(&plain), param_bits(&armed));
}

/// A permissive guard routes every step through the split
/// `grad_step`/`apply_update` path yet changes nothing: losses,
/// parameters, masks and the optimizer step all match guards-off
/// bit-for-bit, and the guard never intervenes.
#[test]
fn permissive_guard_is_bit_identical_to_guards_off() {
    let mut plain = trainer(12, 62);
    plain.run(12).unwrap();

    let mut guarded = trainer(12, 62);
    guarded.arm_guard(GuardConfig::permissive());
    guarded.run(12).unwrap();

    assert_eq!(loss_bits(&plain), loss_bits(&guarded));
    assert_eq!(param_bits(&plain), param_bits(&guarded));
    assert_eq!(plain.masks(), guarded.masks());
    assert_eq!(plain.state().step, guarded.state().step);
    let s = guarded.guard().unwrap().stats();
    assert_eq!(
        (s.skips, s.clips, s.rollbacks, s.mask_reverts, s.mask_updates_deferred),
        (0, 0, 0, 0, 0),
        "permissive guard intervened: {s:?}"
    );
    assert_eq!(s.steps_accepted, 12);
}

/// `grad_nan:0.25:5` over 24 iterations: the stream fires 9 times with a
/// longest run of 2 (pinned in train_guard_check.py), so the trajectory
/// is exact — 9 skips, 15 accepted steps, no NaN ever reaching Adam.
#[test]
fn grad_nan_burst_matches_python_pinned_trajectory() {
    let faults = Faults::parse("grad_nan:0.25:5").unwrap();
    let mut t = trainer(24, 21);
    t.set_faults(faults.clone());
    t.arm_guard(GuardConfig::default());
    t.run(24).unwrap();

    assert_eq!(faults.fired(FaultSite::GradNan), 9);
    let s = t.guard().unwrap().stats();
    assert_eq!(s.skips, 9);
    assert_eq!(s.steps_accepted, 15);
    assert!(finite_params(&t), "NaN leaked into parameters");
    assert!(t.log.last().unwrap().loss.is_finite());

    let ckpt = scratch_dir("nan_ckpt").join("final.blst");
    std::fs::create_dir_all(ckpt.parent().unwrap()).unwrap();
    t.save_checkpoint(&ckpt).unwrap();
    ParamStore::quick_verify(&ckpt).unwrap();
    let _ = std::fs::remove_dir_all(ckpt.parent().unwrap());
}

/// `grad_explode:0.3:11` scales gradients by 1e6 — far past the 1e3
/// explosion threshold — on each of its 7 pinned fires over 16 checks;
/// every fire must be skipped, never clipped into Adam.
#[test]
fn grad_explode_storm_skips_every_fire() {
    let faults = Faults::parse("grad_explode:0.3:11:1000000").unwrap();
    let mut t = trainer(16, 21);
    t.set_faults(faults.clone());
    t.arm_guard(GuardConfig::default());
    t.run(16).unwrap();

    assert_eq!(faults.fired(FaultSite::GradExplode), 7);
    let s = t.guard().unwrap().stats();
    assert_eq!(s.skips, 7);
    assert_eq!(s.steps_accepted, 9);
    assert!(finite_params(&t));
    assert!(t.log.last().unwrap().loss.is_finite());
}

/// The spike site is armed only after one clean iteration (a spike landing
/// before the EWMA baseline exists is accepted by design). Past that,
/// every 100× spiked loss sits far above `EWMA · 3` and must be skipped —
/// and skipped losses never feed the EWMA, so one fire cannot mask the
/// next. 6 fires pinned over the 23 armed checks.
#[test]
fn loss_spike_storm_skips_every_fire_after_warmup() {
    let mut t = trainer(24, 21);
    t.arm_guard(GuardConfig::default());
    t.run(1).unwrap();

    let faults = Faults::parse("loss_spike_mul:0.3:7:100").unwrap();
    t.set_faults(faults.clone());
    t.run(23).unwrap();

    assert_eq!(faults.fired(FaultSite::LossSpikeMul), 6);
    let s = t.guard().unwrap().stats();
    assert_eq!(s.skips, 6);
    assert_eq!(s.steps_accepted, 18);
    assert_eq!(s.last_anomaly, Some("loss_spike"));
    assert!(finite_params(&t));
}

/// `mask_corrupt:1` + a paranoid budget (probe passes only if the update
/// *halves* the loss — impossible): every attempted update is corrupted,
/// probed, and reverted, deterministically. Updates land at iterations
/// 0/5/10; the revert at 0 starts a 2-update cooldown deferring 5 and 10,
/// so the corruption never reaches the masks: they stay bit-identical to
/// the initial full grids, and the run's checkpoint quick-verifies.
#[test]
fn paranoid_mask_budget_reverts_every_corrupted_update() {
    let faults = Faults::parse("mask_corrupt:1:3").unwrap();
    let mut t = trainer(12, 21);
    t.set_faults(faults.clone());
    t.arm_guard(GuardConfig {
        mask_budget: -0.5,
        ..GuardConfig::default()
    });
    t.run(12).unwrap();

    let s = t.guard().unwrap().stats();
    assert_eq!(s.mask_reverts, 1);
    assert_eq!(s.mask_updates_deferred, 2);
    assert_eq!(faults.fired(FaultSite::MaskCorrupt), 1);
    assert_eq!(t.controller().mean_sparsity(), 0.0, "corruption reached the masks");
    let full: BTreeMap<String, BlockMask> = trainer(12, 21).masks().clone();
    assert_eq!(t.masks(), &full);
    assert!(finite_params(&t));

    let ckpt = scratch_dir("mask_ckpt").join("final.blst");
    std::fs::create_dir_all(ckpt.parent().unwrap()).unwrap();
    t.save_checkpoint(&ckpt).unwrap();
    ParamStore::quick_verify(&ckpt).unwrap();
    let _ = std::fs::remove_dir_all(ckpt.parent().unwrap());
}

/// `grad_nan:1` never draws the RNG, so the escalation is exact for any
/// seed: 3 skips exhaust the skip budget, the anchored rollback re-forks
/// the data order, and after `max_rollbacks = 2` the third escalation
/// aborts with the budget error — 9 skips, 2 rollbacks, 2 data forks.
#[test]
fn skip_escalation_exhausts_rollback_budget_deterministically() {
    let dir = scratch_dir("escalation");
    let faults = Faults::parse("grad_nan:1:1").unwrap();
    let mut t = trainer(24, 21);
    t.set_faults(faults.clone());
    t.arm_guard(GuardConfig {
        max_skips: 3,
        max_rollbacks: 2,
        ..GuardConfig::default()
    });
    let err = t
        .run_with_autosave(24, &dir, 4, 8, &faults)
        .expect_err("rollback budget should exhaust");
    assert!(
        format!("{err:#}").contains("rollback budget"),
        "unexpected error: {err:#}"
    );

    let s = t.guard().unwrap().stats();
    assert_eq!(s.rollbacks, 2);
    assert_eq!(s.skips, 9);
    assert_eq!(s.steps_accepted, 0);
    assert_eq!(t.data_fork(), 2);
    // the anchor (the initial iteration-0 autosave) is still restorable
    let anchor = t.rollback_anchor().expect("anchor pinned").to_path_buf();
    ParamStore::quick_verify(&anchor).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// All four sites at once against loosened budgets: the run must complete
/// with finite state, the rollback anchor must quick-verify, and resuming
/// from it must continue cleanly.
#[test]
fn everything_at_once_storm_completes_with_verified_anchor() {
    let dir = scratch_dir("all_sites");
    let faults = Faults::parse(
        "grad_nan:0.1:4,grad_explode:0.1:4:1000000,loss_spike_mul:0.15:4:100,mask_corrupt:0.5:4",
    )
    .unwrap();
    let mut t = trainer(24, 21);
    t.set_faults(faults.clone());
    t.arm_guard(GuardConfig {
        max_skips: 12,
        max_rollbacks: 50,
        mask_budget: 0.1,
        // a persistent-corruption regime is flat, not rising — loosen the
        // divergence trigger so the storm can't ping-pong the rollback
        // budget and the other guard layers stay observable
        div_tol: 0.5,
        ..GuardConfig::default()
    });
    t.run_with_autosave(24, &dir, 4, 3, &faults).unwrap();

    assert!(t.log.last().unwrap().loss.is_finite());
    assert!(finite_params(&t));
    // seed 4's streams pin 4 grad_explode + 1 loss_spike fire — at least
    // one anomaly was answered by a skip
    assert!(t.guard().unwrap().stats().skips >= 1);
    let anchor = t.rollback_anchor().expect("anchor pinned").to_path_buf();
    ParamStore::quick_verify(&anchor).unwrap();

    let mut resumed = Trainer::resume_from(&anchor).unwrap();
    resumed.run(2).unwrap();
    assert!(resumed.log.last().unwrap().loss.is_finite());
    let _ = std::fs::remove_dir_all(&dir);
}
