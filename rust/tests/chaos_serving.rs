//! Chaos-serving integration suite: the coordinator under seeded fault
//! injection (see `util::faults`). Every test drives a real engine and
//! asserts the liveness invariants of the supervised runtime:
//!
//! 1. exactly one completion per submitted request — success or error,
//!    never a duplicate, never a drop;
//! 2. no deadlock (bounded waits everywhere);
//! 3. KV page accounting returns to zero once the load drains — physical
//!    pages *and* (with the prefix cache on) logical shared mappings;
//! 4. with no fault plan armed, behavior is bit-identical to the plain
//!    coordinator (zero-overhead guarantee).
//!
//! Seeds are fixed for reproducibility; `BLAST_CHAOS_SEED` reruns the
//! whole matrix elsewhere in seed space (the CI chaos lane uses this).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

use blast::coordinator::{
    BatcherConfig, CompletionWait, Coordinator, Fleet, FleetConfig, ReplicaStatus, Request,
};
use blast::model::config::{ModelKind, NativeConfig};
use blast::model::engine::{AttnOptions, Engine, MlpMode};
use blast::model::kv::{KvCache, KvGeom, KvOptions, KvPagePool};
use blast::model::params::ParamStore;
use blast::sparse::BlockMask;
use blast::tensor::Tensor;
use blast::util::faults::{FaultSite, Faults};
use blast::util::rng::Rng;

fn cfg() -> NativeConfig {
    NativeConfig {
        name: "chaos-test".into(),
        kind: ModelKind::Llama,
        vocab: 64,
        emb: 32,
        ffn: 64,
        layers: 2,
        heads: 4,
        max_seq: 64,
        block: 8,
    }
}

fn params(cfg: &NativeConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut s = ParamStore::new();
    let e = cfg.emb;
    s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
    for i in 0..cfg.layers {
        let p = |n: &str| format!("layer{i}.{n}");
        s.insert(p("ln1"), Tensor::full(&[e], 1.0));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
        }
        s.insert(p("ln2"), Tensor::full(&[e], 1.0));
        for (n, r, c) in cfg.mlp_shapes() {
            s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
        }
    }
    s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
    s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
    s
}

fn masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
    let mut rng = Rng::new(seed);
    let mut m = BTreeMap::new();
    for i in 0..cfg.layers {
        for (n, r, c) in cfg.mlp_shapes() {
            m.insert(
                format!("layer{i}.{n}"),
                BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
            );
        }
    }
    m
}

fn engine(kv: KvOptions) -> Arc<Engine> {
    engine_with_attn(kv, AttnOptions::default())
}

fn engine_with_attn(kv: KvOptions, attn: AttnOptions) -> Arc<Engine> {
    let c = cfg();
    Arc::new(
        Engine::new_with_opts(
            c.clone(),
            &params(&c, 1),
            &masks(&c, 0.5, 2),
            MlpMode::Sparse,
            kv,
            attn,
        )
        .unwrap(),
    )
}

/// Base seed for the fault-plan matrix; `BLAST_CHAOS_SEED` moves the whole
/// suite to a different (still deterministic) point in seed space.
fn chaos_seed() -> u64 {
    std::env::var("BLAST_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Outcome of one drained load.
struct Drained {
    /// id → (tokens, error) — exactly one entry per answered request.
    completions: HashMap<u64, (Vec<u32>, Option<String>)>,
    disconnected: bool,
}

/// Submit `plan` (id, prompt_len, max_new) and drain every completion,
/// enforcing invariant 1 (exactly-one) and 2 (no deadlock: 30 s bound).
fn serve_and_drain(
    coord: &mut Coordinator,
    plan: &[(u64, usize, usize)],
    deadline_ms: Option<u64>,
) -> Drained {
    let with_prompts: Vec<(u64, Vec<u32>, usize)> = plan
        .iter()
        .map(|&(id, plen, max_new)| {
            let prompt = (0..plen).map(|j| ((id as usize * 7 + j * 3) % 64) as u32).collect();
            (id, prompt, max_new)
        })
        .collect();
    serve_prompts_and_drain(coord, &with_prompts, deadline_ms)
}

/// Like [`serve_and_drain`] but with explicit per-session prompts, so
/// loads can share token prefixes (the CoW sharing matrix needs that).
fn serve_prompts_and_drain(
    coord: &mut Coordinator,
    plan: &[(u64, Vec<u32>, usize)],
    deadline_ms: Option<u64>,
) -> Drained {
    let mut accepted = HashSet::new();
    for (id, prompt, max_new) in plan {
        let (id, max_new) = (*id, *max_new);
        let ok = coord
            .submit(Request {
                id,
                prompt: prompt.clone(),
                max_new,
                eos: None,
                deadline_ms,
            })
            .is_ok();
        if ok {
            accepted.insert(id);
        } else {
            // only a dead coordinator may refuse: the queue is sized for
            // the whole plan
            break;
        }
    }
    let mut completions = HashMap::new();
    let mut disconnected = false;
    while completions.len() < accepted.len() {
        match coord.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                assert!(
                    accepted.contains(&c.id),
                    "completion for an id that was never accepted: {}",
                    c.id
                );
                assert!(
                    completions.insert(c.id, (c.tokens, c.error)).is_none(),
                    "duplicate completion for request {}",
                    c.id
                );
            }
            CompletionWait::Disconnected => {
                disconnected = true;
                break;
            }
            CompletionWait::TimedOut => panic!(
                "deadlock: {}/{} completions after 30s",
                completions.len(),
                accepted.len()
            ),
        }
    }
    // if submissions were refused, the only legitimate cause is a dead
    // coordinator — confirm the stream is closed rather than silently
    // under-reporting
    if accepted.len() < plan.len() && !disconnected {
        disconnected = coord.next_completion(Duration::from_secs(5)).is_disconnected();
    }
    Drained { completions, disconnected }
}

fn std_plan(n: u64) -> Vec<(u64, usize, usize)> {
    (0..n).map(|i| (i, 2 + (i as usize % 5), 1 + (i as usize % 6))).collect()
}

/// One full chaos run: bounded pool, fault plan, invariant checks 1–3.
fn chaos_run(spec: &str, deadline_ms: Option<u64>) -> Drained {
    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let pool = eng.kv_pool().clone();
    let faults = Faults::parse(spec).unwrap();
    let mut coord = Coordinator::start_with_faults(
        eng,
        BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
        faults,
    );
    let drained = serve_and_drain(&mut coord, &std_plan(24), deadline_ms);
    coord.stop();
    assert_eq!(
        pool.pages_in_use(),
        0,
        "KV pages leaked after drain under plan {spec:?}"
    );
    drained
}

#[test]
fn round_panics_cannot_kill_or_wedge_the_coordinator() {
    let s = chaos_seed();
    let d = chaos_run(&format!("decode_round_panic:0.15:{s}"), None);
    assert!(!d.disconnected, "round panics must stay inside round isolation");
    assert_eq!(d.completions.len(), 24);
    // under round isolation most requests still succeed via the
    // sequential fallback; a session-level redraw may error some
    let ok = d.completions.values().filter(|(_, e)| e.is_none()).count();
    assert!(ok > 0, "no request succeeded under round panics");
}

#[test]
fn transient_round_errors_are_retried_and_absorbed() {
    let s = chaos_seed();
    let d = chaos_run(&format!("decode_round_error:0.2:{}", s + 1), None);
    assert!(!d.disconnected);
    assert_eq!(d.completions.len(), 24);
    // transient errors are retried at round level and, at worst, fall
    // back to per-session decode — they never fail a request on their own
    for (id, (_, err)) in &d.completions {
        assert!(err.is_none(), "request {id} failed on a transient fault: {err:?}");
    }
}

#[test]
fn prefill_errors_fail_only_their_own_request() {
    let s = chaos_seed();
    let d = chaos_run(&format!("prefill_error:0.25:{}", s + 2), None);
    assert!(!d.disconnected);
    assert_eq!(d.completions.len(), 24);
    let failed = d.completions.values().filter(|(_, e)| e.is_some()).count();
    let ok = 24 - failed;
    assert!(ok > 0, "prefill faults must not take down unaffected requests");
    for (tokens, err) in d.completions.values() {
        if let Some(e) = err {
            assert!(e.contains("prefill"), "unexpected error class: {e}");
            assert!(tokens.is_empty(), "a failed prefill cannot have produced tokens");
        }
    }
}

#[test]
fn injected_pool_exhaustion_retires_sessions_cleanly() {
    let s = chaos_seed();
    let d = chaos_run(&format!("kv_pool_exhausted:0.15:{}", s + 3), None);
    assert!(!d.disconnected);
    assert_eq!(d.completions.len(), 24);
    // exhaustion is non-transient: the batched round falls back to
    // sequential, where re-injection retires sessions with partial
    // output — still a *successful* completion, never a wedge
    for (id, (_, err)) in &d.completions {
        assert!(err.is_none(), "request {id}: {err:?}");
    }
}

#[test]
fn everything_at_once_still_answers_every_request() {
    let s = chaos_seed() + 4;
    let spec = format!(
        "decode_round_panic:0.05:{s},decode_round_error:0.1:{s},prefill_error:0.1:{s},\
         kv_pool_exhausted:0.05:{s},decode_stall_ms:0.1:{s}:5"
    );
    let d = chaos_run(&spec, None);
    assert!(!d.disconnected);
    assert_eq!(d.completions.len(), 24, "every request answered exactly once");
}

#[test]
fn stalled_rounds_trip_deadlines_with_partial_output() {
    let s = chaos_seed();
    // every round stalls 60 ms; a 100 ms deadline must cut streams short
    let d = chaos_run(&format!("decode_stall_ms:1:{}:60", s + 5), Some(100));
    assert!(!d.disconnected);
    assert_eq!(d.completions.len(), 24);
    let missed = d
        .completions
        .values()
        .filter(|(_, e)| e.as_deref().is_some_and(|e| e.contains("deadline")))
        .count();
    assert!(missed > 0, "stalls of 60ms against a 100ms deadline must miss some");
}

#[test]
fn watchdog_fails_pending_requests_when_scheduler_dies() {
    let s = chaos_seed();
    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let pool = eng.kv_pool().clone();
    let faults = Faults::parse(&format!("scheduler_panic:1:{}", s + 6)).unwrap();
    let mut coord = Coordinator::start_with_faults(
        eng,
        BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
        faults.clone(),
    );
    let drained = serve_and_drain(&mut coord, &std_plan(12), None);
    // the scheduler died on its first pass: the stream must end with
    // Disconnected (never a hang), anything answered carries an error
    assert!(drained.disconnected, "a dead scheduler must close the stream");
    for (id, (_, err)) in &drained.completions {
        assert!(err.is_some(), "request {id} cannot succeed under scheduler_panic:1");
    }
    assert!(faults.fired(FaultSite::SchedulerPanic) >= 1);
    assert!(coord.metrics_summary().contains("watchdog_trips=1"));
    coord.stop();
    assert_eq!(pool.pages_in_use(), 0);
}

/// The zero-overhead guarantee, observable form: a disabled injector and a
/// zero-probability plan serve bit-identical token streams to the plain
/// coordinator.
#[test]
fn no_faults_parity_with_plain_coordinator() {
    let mut all: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for variant in 0..3 {
        let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
        let faults = match variant {
            0 => None, // plain Coordinator::start
            1 => Some(Faults::disabled()),
            _ => Some(Faults::parse("decode_round_panic:0:1,prefill_error:0:1").unwrap()),
        };
        let bc = BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() };
        let mut coord = match faults {
            None => Coordinator::start(eng, bc),
            Some(f) => Coordinator::start_with_faults(eng, bc, f),
        };
        let d = serve_and_drain(&mut coord, &std_plan(16), None);
        assert!(!d.disconnected);
        let mut got: Vec<(u64, Vec<u32>)> = d
            .completions
            .into_iter()
            .map(|(id, (tokens, err))| {
                assert!(err.is_none(), "request {id}: {err:?}");
                (id, tokens)
            })
            .collect();
        got.sort_by_key(|(id, _)| *id);
        coord.stop();
        all.push(got);
    }
    assert_eq!(all[0], all[1], "disabled injector must be bit-identical to plain");
    assert_eq!(all[0], all[2], "zero-probability plan must be bit-identical to plain");
}

/// Satellite: KV page accounting under *every* retirement path. Randomized
/// scenarios mix fault sites, deadlines, tight pools and load shapes; after
/// each drain the pool must be exactly empty — no leak, and (checked by the
/// pool's own accounting) no double-free.
#[test]
fn kv_pages_never_leak_across_randomized_retirement_paths() {
    let mut rng = Rng::new(chaos_seed() ^ 0xC4A0);
    for case in 0..12 {
        let tight_pool = rng.below(2) == 0;
        let kv = KvOptions {
            page: [3, 4, 8][rng.below(3)],
            pool_pages: Some(if tight_pool { 6 + rng.below(6) } else { 64 }),
            prefix_cache: true,
        };
        let site = [
            "decode_round_panic",
            "decode_round_error",
            "prefill_error",
            "kv_pool_exhausted",
            "decode_stall_ms",
        ][rng.below(5)];
        let spec = format!("{site}:0.2:{}", 100 + case);
        let deadline = if rng.below(3) == 0 { Some(50 + rng.below(100) as u64) } else { None };
        let eng = engine(kv);
        let pool = eng.kv_pool().clone();
        let mut coord = Coordinator::start_with_faults(
            eng,
            BatcherConfig {
                max_batch: 1 + rng.below(4),
                max_queue: 64,
                ..BatcherConfig::default()
            },
            Faults::parse(&spec).unwrap(),
        );
        let n = 6 + rng.below(10) as u64;
        let plan: Vec<(u64, usize, usize)> = (0..n)
            .map(|i| (i, 1 + rng.below(8), 1 + rng.below(8)))
            .collect();
        let d = serve_and_drain(&mut coord, &plan, deadline);
        assert!(!d.disconnected, "case {case} ({spec}): unexpected worker death");
        assert_eq!(
            d.completions.len(),
            plan.len(),
            "case {case} ({spec}): request lost"
        );
        coord.stop();
        assert_eq!(
            pool.pages_in_use(),
            0,
            "case {case} ({spec}, deadline {deadline:?}): KV pages leaked"
        );
    }
}

/// Satellite: the CoW refcount/leak property under chaos. Randomized
/// session mixes share one page-aligned hot prefix per case — most extend
/// it with a distinct tail, some repeat it exactly (the full-hit CoW
/// path), some are unrelated — crossed with the fault×deadline×batch
/// matrix. After every drain the pool must be empty *twice over*: zero
/// physical pages in use (all refcounts returned to zero) and zero
/// logical mappings (no shared-page bookkeeping survived its sessions).
#[test]
fn shared_prefix_mix_never_leaks_pages_or_mappings() {
    let mut rng = Rng::new(chaos_seed() ^ 0x51A2);
    for case in 0..12usize {
        let page = [3, 4, 8][rng.below(3)];
        let tight_pool = rng.below(2) == 0;
        let kv = KvOptions {
            page,
            pool_pages: Some(if tight_pool { 8 + rng.below(8) } else { 64 }),
            prefix_cache: true,
        };
        let site = [
            "decode_round_panic",
            "decode_round_error",
            "prefill_error",
            "kv_pool_exhausted",
            "decode_stall_ms",
        ][rng.below(5)];
        let spec = format!("{site}:0.2:{}", 500 + case);
        let deadline = if rng.below(3) == 0 { Some(60 + rng.below(120) as u64) } else { None };
        let eng = engine(kv);
        let pool = eng.kv_pool().clone();
        let mut coord = Coordinator::start_with_faults(
            eng,
            BatcherConfig {
                max_batch: 1 + rng.below(4),
                max_queue: 64,
                ..BatcherConfig::default()
            },
            Faults::parse(&spec).unwrap(),
        );
        let prefix: Vec<u32> = (0..page * (1 + rng.below(2)))
            .map(|j| ((case * 11 + j * 5) % 64) as u32)
            .collect();
        let n = 8 + rng.below(8) as u64;
        let plan: Vec<(u64, Vec<u32>, usize)> = (0..n)
            .map(|i| {
                let prompt = match rng.below(4) {
                    // exact repeat: attach maps every page, CoW recomputes
                    // only the last position
                    0 => prefix.clone(),
                    // unrelated prompt: no sharing, keeps the index honest
                    3 => (0..2 + rng.below(6))
                        .map(|j| ((i as usize * 13 + j * 7 + 1) % 64) as u32)
                        .collect(),
                    // the hot path: shared prefix + distinct private tail
                    _ => {
                        let mut p = prefix.clone();
                        p.extend(
                            (0..1 + rng.below(4)).map(|j| ((i as usize * 17 + j * 3) % 64) as u32),
                        );
                        p
                    }
                };
                (i, prompt, 1 + rng.below(6))
            })
            .collect();
        let d = serve_prompts_and_drain(&mut coord, &plan, deadline);
        assert!(!d.disconnected, "case {case} ({spec}): unexpected worker death");
        assert_eq!(d.completions.len(), plan.len(), "case {case} ({spec}): request lost");
        coord.stop();
        assert_eq!(
            (pool.pages_in_use(), pool.logical_pages()),
            (0, 0),
            "case {case} ({spec}, deadline {deadline:?}): KV pages or shared mappings leaked"
        );
        let stats = pool.prefix_stats();
        assert_eq!(
            (stats.logical_pages, stats.physical_pages),
            (0, 0),
            "case {case} ({spec}): prefix-stats gauges must drain with the pool"
        );
    }
}

/// Satellite: a CoW copy never aliases a still-shared page. Randomized
/// donor/follower pairs on a bare pool: the follower attaches the donor's
/// registered prefix, copies-on-write a random shared page, then writes a
/// canary into the copy — the donor's bits must re-read unchanged, the
/// copy must live at a different address, and either drop order must
/// drain the pool to zero pages and zero mappings.
#[test]
fn cow_copies_never_alias_their_donor_under_randomized_lifetimes() {
    let mut rng = Rng::new(chaos_seed() ^ 0x0C0A);
    for case in 0..16usize {
        let page = [2, 3, 4][rng.below(3)];
        let geom = KvGeom { layers: 2, heads: 3, head_dim: 4, page };
        let hd = geom.head_dim;
        let pool = KvPagePool::new(geom, None, true);
        let pfx_pages = 1 + rng.below(3);
        let len = page * pfx_pages;
        let tokens: Vec<u32> = (0..len).map(|j| ((case * 29 + j * 13 + 3) % 64) as u32).collect();

        let mut donor = KvCache::new(pool.clone());
        donor.ensure(len).unwrap();
        for pos in 0..len {
            for l in 0..geom.layers {
                for h in 0..geom.heads {
                    let base = (l * 997 + h * 131 + pos * 17 + case) as f32;
                    let k: Vec<f32> = (0..hd).map(|d| base + d as f32).collect();
                    let v: Vec<f32> = k.iter().map(|x| -x).collect();
                    donor.write_pos(l, h, pos, &k, &v);
                }
            }
        }
        donor.len = len;
        donor.register_prefix(&tokens);

        let mut follower = KvCache::new(pool.clone());
        assert_eq!(follower.attach_prefix(&tokens), pfx_pages, "case {case}");
        assert_eq!(pool.pages_in_use(), pfx_pages, "case {case}: attach must not allocate");
        assert_eq!(pool.logical_pages(), 2 * pfx_pages, "case {case}");

        let (pi, l, h) = (rng.below(pfx_pages), rng.below(geom.layers), rng.below(geom.heads));
        let donor_k = donor.k_head(l, h, pi).to_vec();
        let donor_v = donor.v_head(l, h, pi).to_vec();
        follower.make_private(pi).unwrap();
        // the copy carries the donor's bits but lives elsewhere, and the
        // swap is logical-neutral: one mapping moved, one page allocated
        assert_eq!(follower.k_head(l, h, pi), &donor_k[..], "case {case}: copy must be faithful");
        assert!(
            !std::ptr::eq(donor.k_head(l, h, pi).as_ptr(), follower.k_head(l, h, pi).as_ptr()),
            "case {case}: CoW copy aliases the shared page"
        );
        assert_eq!(pool.pages_in_use(), pfx_pages + 1, "case {case}");
        assert_eq!(pool.logical_pages(), 2 * pfx_pages, "case {case}");
        assert_eq!(pool.prefix_stats().cow_copies, 1, "case {case}");

        // canary write into the copy; the donor must re-read unchanged
        let canary: Vec<f32> = (0..hd).map(|d| 9e6 + (case * hd + d) as f32).collect();
        let pos = pi * page + rng.below(page);
        follower.write_pos(l, h, pos, &canary, &canary);
        assert_eq!(donor.k_head(l, h, pi), &donor_k[..], "case {case}: donor K corrupted");
        assert_eq!(donor.v_head(l, h, pi), &donor_v[..], "case {case}: donor V corrupted");
        assert_ne!(follower.k_head(l, h, pi), &donor_k[..], "case {case}: canary not written");

        // either drop order must return every page and mapping
        if rng.below(2) == 0 {
            // the donor's CoW-replaced original frees with it; the pages
            // the follower still shares (plus its copy) stay resident
            drop(donor);
            assert_eq!(pool.pages_in_use(), pfx_pages, "case {case}: follower still maps");
            drop(follower);
        } else {
            drop(follower);
            assert_eq!(pool.pages_in_use(), pfx_pages, "case {case}: donor still maps");
            drop(donor);
        }
        assert_eq!(
            (pool.pages_in_use(), pool.logical_pages()),
            (0, 0),
            "case {case}: pool must drain to zero pages and zero mappings"
        );
    }
}

/// Satellite: a τ=1e30 threshold-armed coordinator serves bit-identical
/// token streams to the exact (τ=off) coordinator — every armed code
/// path runs (stamped pool, thresh prefill/decode kernels, skip
/// counters) yet nothing is skipped, so serving output cannot move.
#[test]
fn huge_tau_serving_is_bitwise_identical_to_exact() {
    let kv = KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true };
    let plan = fleet_plan(16);
    let mut streams: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for attn in [AttnOptions::default(), AttnOptions { threshold: Some(1e30) }] {
        let eng = engine_with_attn(kv, attn);
        let stats_handle = eng.clone();
        let mut coord = Coordinator::start(
            eng,
            BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
        );
        let d = serve_prompts_and_drain(&mut coord, &plan, None);
        assert!(!d.disconnected);
        let st = stats_handle.attn_stats();
        if attn.threshold.is_some() {
            assert!(st.rows > 0 && st.pages > 0, "armed paths must have counted: {st:?}");
            assert_eq!((st.rows_skipped, st.pages_skipped), (0, 0), "{st:?}");
            assert!(coord.metrics_summary().contains("attn_rows_skipped=0/"), "summary must surface the armed counters");
        } else {
            assert!(!st.engaged(), "exact engine must never count: {st:?}");
            assert!(!coord.metrics_summary().contains("attn_"), "τ=off summary must stay byte-identical");
        }
        let mut got: Vec<(u64, Vec<u32>)> = d
            .completions
            .into_iter()
            .map(|(id, (tokens, err))| {
                assert!(err.is_none(), "request {id}: {err:?}");
                (id, tokens)
            })
            .collect();
        got.sort_by_key(|(id, _)| *id);
        coord.stop();
        streams.push(got);
    }
    assert_eq!(streams[0], streams[1], "huge-τ streams must be bitwise identical to exact");
}

/// Satellite: the threshold-armed chaos mix. A finite τ under the fault
/// matrix keeps every liveness invariant — exactly one completion per
/// request, pool drained to zero — and the skip counters stay
/// consistent (engaged, and skipped never exceeds visited) across round
/// panics, retries, prefill failures and deadline retirements.
#[test]
fn threshold_armed_sessions_survive_chaos_with_consistent_counters() {
    let s = chaos_seed();
    let specs = [
        format!("decode_round_panic:0.15:{s}"),
        format!("prefill_error:0.25:{}", s + 2),
        format!(
            "decode_round_panic:0.05:{q},decode_round_error:0.1:{q},prefill_error:0.1:{q},\
             kv_pool_exhausted:0.05:{q},decode_stall_ms:0.1:{q}:5",
            q = s + 4
        ),
    ];
    for spec in &specs {
        for tau in [0.5f32, 4.0] {
            let eng = engine_with_attn(
                KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true },
                AttnOptions { threshold: Some(tau) },
            );
            let stats_handle = eng.clone();
            let pool = eng.kv_pool().clone();
            let mut coord = Coordinator::start_with_faults(
                eng,
                BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
                Faults::parse(spec).unwrap(),
            );
            let d = serve_and_drain(&mut coord, &std_plan(24), None);
            assert!(!d.disconnected, "{spec} tau={tau}: unexpected worker death");
            assert_eq!(d.completions.len(), 24, "{spec} tau={tau}: request lost");
            coord.stop();
            assert_eq!(pool.pages_in_use(), 0, "{spec} tau={tau}: KV pages leaked");
            let st = stats_handle.attn_stats();
            assert!(st.engaged(), "{spec} tau={tau}: armed engine never counted");
            assert!(
                st.rows_skipped <= st.rows
                    && st.tiles_skipped <= st.tiles
                    && st.pages_skipped <= st.pages,
                "{spec} tau={tau}: skip counters exceed visits: {st:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet tier: replicated serving under replica-level chaos
// ---------------------------------------------------------------------------

/// Shared-prefix request mix for the fleet matrix: every third request
/// reuses one 4-token prefix (failover replays then also cross the CoW
/// prefix cache), the rest are unrelated.
fn fleet_plan(n: u64) -> Vec<(u64, Vec<u32>, usize)> {
    (0..n)
        .map(|i| {
            let mut prompt: Vec<u32> = if i % 3 == 0 { vec![5, 9, 13, 17] } else { Vec::new() };
            prompt
                .extend((0..2 + (i as usize % 5)).map(|j| ((i as usize * 7 + j * 3) % 64) as u32));
            (i, prompt, 1 + (i as usize % 6))
        })
        .collect()
}

/// Expected token streams for `plan`: one clean pass through a bare
/// coordinator. Greedy decode is deterministic, so every healthy serving
/// path — and every failover replay — must reproduce these bitwise.
fn clean_streams(plan: &[(u64, Vec<u32>, usize)]) -> HashMap<u64, Vec<u32>> {
    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let mut coord = Coordinator::start(
        eng,
        BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
    );
    let d = serve_prompts_and_drain(&mut coord, plan, None);
    coord.stop();
    assert!(!d.disconnected);
    d.completions
        .into_iter()
        .map(|(id, (tokens, err))| {
            assert!(err.is_none(), "clean run failed request {id}: {err:?}");
            (id, tokens)
        })
        .collect()
}

/// Submit `plan` through a fleet and drain every completion, enforcing
/// exactly-once and the 30 s no-deadlock bound.
fn fleet_serve_and_drain(
    fleet: &Fleet,
    plan: &[(u64, Vec<u32>, usize)],
) -> HashMap<u64, (Vec<u32>, Option<String>)> {
    for (id, prompt, max_new) in plan {
        fleet
            .submit(Request {
                id: *id,
                prompt: prompt.clone(),
                max_new: *max_new,
                ..Default::default()
            })
            .expect("fleet front door must accept while running");
    }
    let mut completions = HashMap::new();
    while completions.len() < plan.len() {
        match fleet.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                assert!(
                    completions.insert(c.id, (c.tokens, c.error)).is_none(),
                    "duplicate completion for request {}",
                    c.id
                );
            }
            CompletionWait::Disconnected => panic!("fleet router died mid-load"),
            CompletionWait::TimedOut => panic!(
                "deadlock: {}/{} fleet completions after 30s",
                completions.len(),
                plan.len()
            ),
        }
    }
    completions
}

/// Satellite: `--replicas 1` equivalence. A one-replica fleet with no
/// fault plan is byte-identical to the bare coordinator — same greedy
/// streams, same invariant metrics digest, zero fleet-level events.
#[test]
fn single_replica_fleet_is_byte_identical_to_bare_coordinator() {
    let plan = fleet_plan(16);

    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let pool = eng.kv_pool().clone();
    let mut coord = Coordinator::start(
        eng,
        BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
    );
    let bare = serve_prompts_and_drain(&mut coord, &plan, None);
    assert!(!bare.disconnected);
    let bare_digest = coord.metrics_digest();
    coord.stop();
    assert_eq!(pool.pages_in_use(), 0);

    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let mut fleet = Fleet::start(
        &eng,
        FleetConfig {
            replicas: 1,
            batcher: BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
            // generous threshold: a false stall depose would change the
            // digest, and this test is about the quiet path
            stall_ms: 5_000,
            ..FleetConfig::default()
        },
    );
    let through_fleet = fleet_serve_and_drain(&fleet, &plan);
    assert_eq!(
        fleet.replica_digests(),
        vec![bare_digest],
        "one-replica fleet metrics must match the bare coordinator"
    );
    let m = fleet.metrics();
    assert_eq!(
        (m.failovers, m.restarts, m.deposed_stalls, m.replica_deaths, m.failed),
        (0, 0, 0, 0, 0),
        "a healthy one-replica fleet must see no fleet-level events"
    );
    let pools = fleet.pools();
    fleet.stop();
    assert_eq!(pools.len(), 1, "one replica, one incarnation, one pool");
    assert_eq!(pools[0].pages_in_use(), 0);

    for (id, (tokens, err)) in &through_fleet {
        assert!(err.is_none(), "request {id} failed through the fleet: {err:?}");
        assert_eq!(
            tokens,
            &bare.completions[id].0,
            "request {id}: fleet stream diverged from the bare coordinator"
        );
    }
}

/// Tentpole: replica-kill storm. All three replica-level fault sites
/// armed over a 3-replica fleet with a tight stall detector. Every
/// request is answered exactly once within the deadlock bound, every
/// *successful* stream is bitwise identical to the clean run (failover
/// replays are exact), and every incarnation's pool drains to zero.
#[test]
fn replica_kill_storm_serves_exactly_once_with_bitwise_failover() {
    let s = chaos_seed();
    let plan = fleet_plan(24);
    let expected = clean_streams(&plan);

    let spec =
        format!("replica_crash:0.02:{s},replica_stall_ms:0.05:{s}:60,heartbeat_drop:0.3:{s}");
    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let mut fleet = Fleet::start_with_faults(
        &eng,
        FleetConfig {
            replicas: 3,
            batcher: BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
            seed: s,
            // tight enough that the injected 60 ms freezes get deposed
            stall_ms: 45,
            ..FleetConfig::default()
        },
        Faults::parse(&spec).unwrap(),
    );
    let completions = fleet_serve_and_drain(&fleet, &plan);
    let m = fleet.metrics();
    let pools = fleet.pools();
    fleet.stop();

    let mut ok = 0usize;
    for (id, (tokens, err)) in &completions {
        if err.is_some() {
            // exhausted failovers / every replica lost: legal under a storm
            continue;
        }
        ok += 1;
        assert_eq!(
            tokens,
            &expected[id],
            "request {id}: failover replay diverged from the clean stream"
        );
    }
    assert!(ok > 0, "the storm must not fail every request: {}", m.summary());
    for (i, p) in pools.iter().enumerate() {
        assert_eq!(
            (p.pages_in_use(), p.logical_pages()),
            (0, 0),
            "incarnation pool {i}/{} still holds pages or mappings after the storm",
            pools.len()
        );
    }
}

/// Tentpole: zero-downtime rolling restart. Cycling every replica while a
/// load is in flight drops nothing — all requests succeed with clean-run
/// streams, each replica comes back Healthy, and both generations of
/// every pool drain.
#[test]
fn rolling_restart_under_load_drops_nothing() {
    let plan = fleet_plan(24);
    let expected = clean_streams(&plan);
    let (first, second) = plan.split_at(12);

    let eng = engine(KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true });
    let mut fleet = Fleet::start(
        &eng,
        FleetConfig {
            replicas: 3,
            batcher: BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
            seed: 9,
            stall_ms: 5_000,
            ..FleetConfig::default()
        },
    );
    for (id, prompt, max_new) in first {
        fleet
            .submit(Request {
                id: *id,
                prompt: prompt.clone(),
                max_new: *max_new,
                ..Default::default()
            })
            .unwrap();
    }
    // cycle every replica while the first half is still in flight: each
    // drains its own sessions before stopping, the others keep serving
    fleet.rolling_restart().unwrap();
    assert!(
        fleet.statuses().iter().all(|s| *s == ReplicaStatus::Healthy),
        "every replica must come back Healthy: {:?}",
        fleet.statuses()
    );
    for (id, prompt, max_new) in second {
        fleet
            .submit(Request {
                id: *id,
                prompt: prompt.clone(),
                max_new: *max_new,
                ..Default::default()
            })
            .unwrap();
    }
    let mut completions = HashMap::new();
    while completions.len() < plan.len() {
        match fleet.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                assert!(
                    completions.insert(c.id, (c.tokens, c.error)).is_none(),
                    "duplicate completion for request {}",
                    c.id
                );
            }
            CompletionWait::Disconnected => panic!("fleet died during rolling restart"),
            CompletionWait::TimedOut => panic!(
                "deadlock during rolling restart: {}/{} completions",
                completions.len(),
                plan.len()
            ),
        }
    }
    let m = fleet.metrics();
    let pools = fleet.pools();
    fleet.stop();

    assert_eq!(
        (m.planned_restarts, m.failed),
        (3, 0),
        "rolling restart must cycle all three replicas and drop nothing"
    );
    assert_eq!(pools.len(), 6, "three original + three cycled incarnation pools");
    for (i, p) in pools.iter().enumerate() {
        assert_eq!((p.pages_in_use(), p.logical_pages()), (0, 0), "incarnation pool {i} leaked");
    }
    for (id, (tokens, err)) in &completions {
        assert!(err.is_none(), "request {id} failed during rolling restart: {err:?}");
        assert_eq!(tokens, &expected[id], "request {id} diverged across the restart");
    }
}
