//! End-to-end serving integration: the coordinator over a real sparse
//! engine, exercising admission, continuous batching, KV sessions, and
//! the dense/sparse equivalence at the service boundary.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use blast::coordinator::{BatcherConfig, Coordinator, Request};
use blast::model::config::{ModelKind, NativeConfig};
use blast::model::engine::{Engine, MlpMode};
use blast::model::kv::{KvOptions, PrefixStats};
use blast::model::params::ParamStore;
use blast::sparse::BlockMask;
use blast::tensor::Tensor;
use blast::util::rng::Rng;

fn cfg() -> NativeConfig {
    NativeConfig {
        name: "serve-test".into(),
        kind: ModelKind::Llama,
        vocab: 64,
        emb: 32,
        ffn: 64,
        layers: 2,
        heads: 4,
        max_seq: 64,
        block: 8,
    }
}

fn params(cfg: &NativeConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut s = ParamStore::new();
    let e = cfg.emb;
    s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
    for i in 0..cfg.layers {
        let p = |n: &str| format!("layer{i}.{n}");
        s.insert(p("ln1"), Tensor::full(&[e], 1.0));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
        }
        s.insert(p("ln2"), Tensor::full(&[e], 1.0));
        for (n, r, c) in cfg.mlp_shapes() {
            s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
        }
    }
    s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
    s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
    s
}

fn masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
    let mut rng = Rng::new(seed);
    let mut m = BTreeMap::new();
    for i in 0..cfg.layers {
        for (n, r, c) in cfg.mlp_shapes() {
            m.insert(
                format!("layer{i}.{n}"),
                BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
            );
        }
    }
    m
}

#[test]
fn mixed_length_load_completes_with_correct_token_counts() {
    let c = cfg();
    let engine = Arc::new(
        Engine::new(c.clone(), &params(&c, 1), &masks(&c, 0.5, 2), MlpMode::Sparse).unwrap(),
    );
    let mut coord = Coordinator::start(
        engine,
        BatcherConfig {
            max_batch: 2,
            max_queue: 32,
            ..BatcherConfig::default()
        },
    );
    let plan: Vec<(u64, usize, usize)> = (0..10).map(|i| (i, 2 + (i as usize % 5), 1 + (i as usize % 7))).collect();
    for &(id, plen, max_new) in &plan {
        coord
            .submit(Request {
                id,
                prompt: (0..plen).map(|j| (j * 3 % 64) as u32).collect(),
                max_new,
                eos: None,
                ..Default::default()
            })
            .unwrap();
    }
    let mut seen = std::collections::HashMap::new();
    for _ in 0..plan.len() {
        let done = coord.next_completion(Duration::from_secs(60)).ready().unwrap();
        assert!(done.error.is_none());
        seen.insert(done.id, done.tokens.len());
    }
    for (id, _plen, max_new) in plan {
        assert_eq!(seen[&id], max_new, "request {id}");
    }
    assert!(coord.throughput() > 0.0);
    coord.stop();
}

#[test]
fn sparse_and_dense_serving_agree_token_for_token() {
    let c = cfg();
    let p = params(&c, 3);
    let m = masks(&c, 0.5, 4);
    let mut answers = Vec::new();
    for mode in [MlpMode::Dense, MlpMode::Sparse] {
        let engine = Arc::new(Engine::new(c.clone(), &p, &m, mode).unwrap());
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![5, 9, 13],
                max_new: 8,
                eos: None,
                ..Default::default()
            })
            .unwrap();
        let done = coord.next_completion(Duration::from_secs(60)).ready().unwrap();
        answers.push(done.tokens);
        coord.stop();
    }
    assert_eq!(
        answers[0], answers[1],
        "dense and sparse engines must serve identical greedy outputs"
    );
}

/// The serving-level guarantee of the batched decode path: the same mixed
/// load, batched vs sequential rounds, dense vs sparse MLP — all four
/// serve bit-identical greedy streams per request.
#[test]
fn batched_rounds_match_sequential_across_modes() {
    let c = cfg();
    let p = params(&c, 7);
    let m = masks(&c, 0.5, 8);
    let plan: Vec<(u64, usize, usize)> =
        (0..8).map(|i| (i, 2 + (i as usize % 4), 2 + (i as usize % 5))).collect();
    let mut answers: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for mode in [MlpMode::Dense, MlpMode::Sparse] {
        for batched in [true, false] {
            let engine = Arc::new(Engine::new(c.clone(), &p, &m, mode).unwrap());
            let mut coord = Coordinator::start(
                engine,
                BatcherConfig {
                    max_batch: 3,
                    max_queue: 32,
                    batched,
                    ..BatcherConfig::default()
                },
            );
            for &(id, plen, max_new) in &plan {
                coord
                    .submit(Request {
                        id,
                        prompt: (0..plen).map(|j| ((id as usize * 7 + j * 3) % 64) as u32).collect(),
                        max_new,
                        eos: None,
                    })
                    .unwrap();
            }
            let mut got = Vec::new();
            for _ in 0..plan.len() {
                let done = coord.next_completion(Duration::from_secs(60)).ready().unwrap();
                assert!(done.error.is_none(), "{:?}", done.error);
                got.push((done.id, done.tokens));
            }
            got.sort_by_key(|(id, _)| *id);
            // every round decodes at least one session; occupancy is
            // recorded either way
            assert!(coord.mean_round_batch() >= 1.0);
            coord.stop();
            answers.push(got);
        }
    }
    // batched == sequential within each mode, and dense == sparse greedy
    assert_eq!(answers[0], answers[1], "dense: batched vs sequential");
    assert_eq!(answers[2], answers[3], "sparse: batched vs sequential");
    assert_eq!(answers[0], answers[2], "dense vs sparse greedy streams");
}

/// Regression: stopping the coordinator with work still queued must answer
/// every request (error completions), never leave a client hanging on
/// `next_completion`.
#[test]
fn stop_answers_queued_requests() {
    let c = cfg();
    let engine = Arc::new(
        Engine::new(c.clone(), &params(&c, 9), &BTreeMap::new(), MlpMode::Sparse).unwrap(),
    );
    let n = 10u64;
    let mut coord = Coordinator::start(
        engine,
        BatcherConfig {
            max_batch: 1,
            max_queue: 16,
            ..BatcherConfig::default()
        },
    );
    for i in 0..n {
        coord
            .submit(Request {
                id: i,
                prompt: vec![1, 2, 3, 4],
                max_new: 6,
                eos: None,
                ..Default::default()
            })
            .unwrap();
    }
    coord.stop();
    let mut seen = std::collections::HashSet::new();
    while let Some(done) = coord.next_completion(Duration::from_millis(500)).ready() {
        assert!(seen.insert(done.id), "duplicate completion {}", done.id);
    }
    assert_eq!(seen.len() as u64, n, "every request must be answered on stop");
}

/// KV page size is a pure layout knob at the *service* level too: the
/// same mixed load served through a small-page engine and a flat
/// (page = max_seq) engine produces identical greedy streams.
#[test]
fn paged_and_flat_serving_agree_token_for_token() {
    let c = cfg();
    let p = params(&c, 11);
    let m = masks(&c, 0.5, 12);
    let mut answers: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for page in [3usize, c.max_seq] {
        let engine = Arc::new(
            Engine::new_with_kv(
                c.clone(),
                &p,
                &m,
                MlpMode::Sparse,
                KvOptions { page, pool_pages: None, prefix_cache: true },
            )
            .unwrap(),
        );
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 32,
                ..BatcherConfig::default()
            },
        );
        // prompt lengths 2..6 and budgets straddle the 3-position page
        let plan: Vec<(u64, usize, usize)> =
            (0..6).map(|i| (i, 2 + (i as usize % 5), 2 + (i as usize % 4))).collect();
        for &(id, plen, max_new) in &plan {
            coord
                .submit(Request {
                    id,
                    prompt: (0..plen).map(|j| ((id as usize * 7 + j * 3) % 64) as u32).collect(),
                    max_new,
                    eos: None,
                })
                .unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..plan.len() {
            let done = coord.next_completion(Duration::from_secs(60)).ready().unwrap();
            assert!(done.error.is_none(), "{:?}", done.error);
            got.push((done.id, done.tokens));
        }
        got.sort_by_key(|(id, _)| *id);
        coord.stop();
        answers.push(got);
    }
    assert_eq!(
        answers[0], answers[1],
        "paged and flat KV layouts must serve identical greedy streams"
    );
}

/// The `--prefix-cache` service-level guarantee: the same shared-prefix
/// load serves bitwise-identical token streams with sharing on and off.
/// On the sharing engine the prefix index must actually engage (≥ 1 hit);
/// on the off engine every sharing counter must stay zero — it *is* the
/// unshared pool, not a sharing pool that happens not to share.
#[test]
fn prefix_cache_on_and_off_serve_identical_streams() {
    let c = cfg();
    let p = params(&c, 21);
    let m = masks(&c, 0.5, 22);
    let prefix: Vec<u32> = (0..8).map(|j| ((j * 3 + 2) % 64) as u32).collect();
    let mut answers: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for prefix_cache in [true, false] {
        let engine = Arc::new(
            Engine::new_with_kv(
                c.clone(),
                &p,
                &m,
                MlpMode::Sparse,
                KvOptions { page: 4, pool_pages: None, prefix_cache },
            )
            .unwrap(),
        );
        let pool = engine.kv_pool().clone();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 32,
                ..BatcherConfig::default()
            },
        );
        for i in 0..6u64 {
            let mut prompt = prefix.clone();
            prompt.extend((0..i % 3).map(|j| ((i * 11 + j * 5 + 1) % 64) as u32));
            coord
                .submit(Request {
                    id: i,
                    prompt,
                    max_new: 2 + (i as usize % 4),
                    eos: None,
                    ..Default::default()
                })
                .unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..6 {
            let done = coord.next_completion(Duration::from_secs(60)).ready().unwrap();
            assert!(done.error.is_none(), "{:?}", done.error);
            got.push((done.id, done.tokens));
        }
        got.sort_by_key(|(id, _)| *id);
        coord.stop();
        let stats = pool.prefix_stats();
        if prefix_cache {
            assert!(stats.hits >= 1, "prefix sharing never engaged: {stats:?}");
        } else {
            assert_eq!(stats, PrefixStats::default(), "sharing-off pool must stay inert");
        }
        assert_eq!((pool.pages_in_use(), pool.logical_pages()), (0, 0));
        answers.push(got);
    }
    assert_eq!(
        answers[0], answers[1],
        "prefix sharing must not change a single served token"
    );
}

/// Sharing raises effective capacity: five sessions over one hot prefix
/// run through a pool that could never hold five *unshared* sessions
/// concurrently (5 × 4 pages = 20 > 10). With CoW sharing the prefix is
/// resident once (2 pages) and each session adds only its private tail,
/// so the whole load completes in full — and the prefix stats prove every
/// follower mapped the donor's pages instead of recomputing them.
#[test]
fn shared_prefix_load_outgrows_unshared_pool_capacity() {
    let c = cfg();
    let engine = Arc::new(
        Engine::new_with_kv(
            c.clone(),
            &params(&c, 31),
            &masks(&c, 0.5, 32),
            MlpMode::Sparse,
            KvOptions { page: 4, pool_pages: Some(10), prefix_cache: true },
        )
        .unwrap(),
    );
    let pool = engine.kv_pool().clone();
    let mut coord = Coordinator::start(
        engine,
        BatcherConfig {
            max_batch: 4,
            max_queue: 16,
            ..BatcherConfig::default()
        },
    );
    let prefix: Vec<u32> = (0..8).map(|j| ((j * 5 + 3) % 64) as u32).collect();
    let n = 5u64;
    for i in 0..n {
        let mut prompt = prefix.clone();
        prompt.extend([(20 + 2 * i) as u32, (21 + 2 * i) as u32]); // distinct 2-token tails
        coord
            .submit(Request {
                id: i,
                prompt,
                max_new: 4,
                eos: None,
                ..Default::default()
            })
            .unwrap();
    }
    let mut seen = std::collections::HashSet::new();
    for _ in 0..n {
        let done = coord.next_completion(Duration::from_secs(60)).ready().unwrap();
        assert!(done.error.is_none(), "request {}: {:?}", done.id, done.error);
        assert_eq!(done.tokens.len(), 4, "request {} was cut short", done.id);
        assert!(seen.insert(done.id));
    }
    coord.stop();
    let stats = pool.prefix_stats();
    assert!(
        stats.hits >= n - 1,
        "every follower must map the shared prefix: {stats:?}"
    );
    assert!(stats.pages_shared >= 2 * (n - 1), "{stats:?}");
    assert_eq!((pool.pages_in_use(), pool.logical_pages()), (0, 0));
}

/// A session whose pool runs dry mid-stream retires cleanly with the
/// tokens it already produced — the coordinator's error-isolation path,
/// not a panic and not a hang.
#[test]
fn mid_stream_pool_exhaustion_retires_with_partial_output() {
    let c = cfg();
    let engine = Arc::new(
        Engine::new_with_kv(
            c.clone(),
            &params(&c, 13),
            &BTreeMap::new(),
            MlpMode::Sparse,
            // 2 pages × 4 positions = 8 positions total; the admission
            // check (prompt 4 + 1 = 5 positions → 2 pages) passes, but the
            // 10-token decode budget cannot: the pool dries up at pos 8
            KvOptions { page: 4, pool_pages: Some(2), prefix_cache: true },
        )
        .unwrap(),
    );
    let mut coord = Coordinator::start(engine, BatcherConfig::default());
    coord
        .submit(Request {
            id: 0,
            prompt: vec![1, 2, 3, 4],
            max_new: 10,
            eos: None,
        })
        .unwrap();
    let done = coord.next_completion(Duration::from_secs(60)).ready().expect("completion");
    // prefill token + decodes at positions 4..=7 = 5 tokens, then pos 8
    // would need page 3 of 2 → the session retires with what it has
    assert!(done.error.is_none(), "{:?}", done.error);
    assert_eq!(done.tokens.len(), 5, "expected partial output at pool exhaustion");
    // the scheduler survives and keeps serving new (fitting) requests
    // once the retired session's pages are back in the pool
    coord
        .submit(Request {
            id: 1,
            prompt: vec![5, 6],
            max_new: 3,
            eos: None,
        })
        .unwrap();
    let done = coord.next_completion(Duration::from_secs(60)).ready().expect("completion");
    assert_eq!((done.id, done.tokens.len()), (1, 3));
    assert!(done.error.is_none());
    coord.stop();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let c = cfg();
    let engine = Arc::new(
        Engine::new(c.clone(), &params(&c, 5), &BTreeMap::new(), MlpMode::Dense).unwrap(),
    );
    let mut coord = Coordinator::start(
        engine,
        BatcherConfig {
            max_batch: 1,
            max_queue: 2,
            ..BatcherConfig::default()
        },
    );
    // flood: the sync channel holds max_queue, so eventually submit fails
    let mut rejected = 0;
    for i in 0..24 {
        if coord
            .submit(Request {
                id: i,
                prompt: vec![1, 2, 3, 4, 5, 6, 7, 8],
                max_new: 8,
                eos: None,
                ..Default::default()
            })
            .is_err()
        {
            rejected += 1;
        }
    }
    // drain whatever was accepted (short timeout once the queue is idle)
    while coord.next_completion(Duration::from_secs(2)).ready().is_some() {}
    assert!(rejected > 0, "expected backpressure rejections");
    coord.stop();
}
