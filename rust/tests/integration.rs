//! Integration tests over the real AOT artifacts (require `make artifacts`
//! and a build with `--features pjrt`).
//!
//! These are the cross-layer proofs:
//!  * L1→L3: the Pallas-lowered kernels execute through PJRT from Rust and
//!    match the native Rust kernels bit-for-tolerance.
//!  * L2→L3: `train_step` drives loss down; eval/perplexity works; the
//!    Pallas-MLP model variant agrees with the masked-dense variant.
//!  * native engine ↔ AOT graphs: identical weights + masks produce the
//!    same prefill logits in both stacks.
//!
//! When the runtime cannot open (default no-`pjrt` build, or artifacts not
//! generated) every test here *skips* instead of failing: the native-stack
//! guarantees are covered by the crate's unit tests and `serving_e2e.rs`.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use blast::kernels::bspmm::{bspmm, fused_mlp_sparse, FusedMlpWeights};
use blast::model::config::NativeConfig;
use blast::model::engine::{Engine, MlpMode};
use blast::model::params::ParamStore;
use blast::runtime::{HostValue, Runtime};
use blast::sparse::{Bcsc, BlockMask};
use blast::tensor::Tensor;
use blast::train::pretrain::{PretrainOptions, Trainer};
use blast::util::rng::Rng;

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("AOT runtime unavailable, skipping integration test: {e:#}");
            None
        }
    })
    .as_ref()
}

/// Evaluates to the runtime or returns early (skip) when it is unavailable.
macro_rules! require_runtime {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

// ---------------------------------------------------------------------------
// L1 → L3: Pallas kernel artifacts vs native kernels
// ---------------------------------------------------------------------------

#[test]
fn pallas_bspmm_artifact_matches_native_kernel() {
    let rt = require_runtime!();
    let info = rt.manifest().entry("bspmm_pallas").unwrap().clone();
    // shapes from the manifest: x (m,k), w (k,n), mask (k/b, n/b)
    let m = info.inputs[0].shape[0];
    let k = info.inputs[0].shape[1];
    let n = info.inputs[1].shape[1];
    let kb = info.inputs[2].shape[0];
    let nb = info.inputs[2].shape[1];
    let b = k / kb;
    assert_eq!(n / nb, b);

    let mut rng = Rng::new(11);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let w = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mask = BlockMask::random(kb, nb, 0.5, &mut rng);

    let out = rt
        .execute(
            "bspmm_pallas",
            &[
                HostValue::from_tensor(&x),
                HostValue::from_tensor(&w),
                HostValue::tensor(mask.to_tensor()),
            ],
        )
        .unwrap();
    let y_pallas = out[0].clone().into_tensor().unwrap();

    let y_native = bspmm(&x, &Bcsc::from_dense(&w, &mask, b));
    let diff = y_pallas.max_abs_diff(&y_native);
    assert!(diff < 1e-2, "pallas vs native bspmm diff {diff}");
}

#[test]
fn pallas_fused_mlp_artifact_matches_native_kernel() {
    let rt = require_runtime!();
    let info = rt.manifest().entry("fused_mlp_pallas").unwrap().clone();
    let m = info.inputs[0].shape[0];
    let k = info.inputs[0].shape[1];
    let f = info.inputs[1].shape[1];
    let kb = info.inputs[4].shape[0];
    let b = k / kb;

    let mut rng = Rng::new(12);
    let x = Tensor::randn(&[m, k], 0.5, &mut rng);
    let w1 = Tensor::randn(&[k, f], 0.05, &mut rng);
    let w2 = Tensor::randn(&[k, f], 0.05, &mut rng);
    let w3 = Tensor::randn(&[f, k], 0.05, &mut rng);
    let m1 = BlockMask::random(k / b, f / b, 0.4, &mut rng);
    let m2 = BlockMask::random(k / b, f / b, 0.4, &mut rng);
    let m3 = BlockMask::random(f / b, k / b, 0.4, &mut rng);

    let out = rt
        .execute(
            "fused_mlp_pallas",
            &[
                HostValue::from_tensor(&x),
                HostValue::from_tensor(&w1),
                HostValue::from_tensor(&w2),
                HostValue::from_tensor(&w3),
                HostValue::tensor(m1.to_tensor()),
                HostValue::tensor(m2.to_tensor()),
                HostValue::tensor(m3.to_tensor()),
            ],
        )
        .unwrap();
    let y_pallas = out[0].clone().into_tensor().unwrap();

    let y_native = fused_mlp_sparse(
        &x,
        &FusedMlpWeights {
            w1: &Bcsc::from_dense(&w1, &m1, b),
            w2: &Bcsc::from_dense(&w2, &m2, b),
            w3: &Bcsc::from_dense(&w3, &m3, b),
        },
    );
    let diff = y_pallas.max_abs_diff(&y_native);
    assert!(diff < 1e-2, "pallas vs native fused MLP diff {diff}");
}

// ---------------------------------------------------------------------------
// L2 → L3: training through PJRT
// ---------------------------------------------------------------------------

#[test]
fn micro_training_reduces_loss_and_applies_sparsity() {
    let rt = require_runtime!();
    let opts = PretrainOptions {
        total_iters: 25,
        s_max: 0.6,
        step_size: 5,
        ..Default::default()
    };
    let mut t = Trainer::new(rt, "micro", opts).unwrap();
    t.run(25).unwrap();
    let first = t.log[0].loss;
    let last = t.log.last().unwrap().loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // schedule reached a meaningful sparsity and masks follow it
    assert!(t.controller().mean_sparsity() > 0.3);
    // perplexity is finite and below vocab size (the model learned)
    let ppl = t.eval_perplexity(4).unwrap();
    assert!(ppl.is_finite() && ppl < 256.0, "ppl {ppl}");
}

#[test]
fn pallas_model_variant_matches_dense_variant_through_pjrt() {
    let rt = require_runtime!();
    let cfg = rt.manifest().config("micro-llama").unwrap().clone();
    let params = ParamStore::init(&cfg, 5);
    let mut rng = Rng::new(6);
    let mut inputs = Vec::new();
    for (_, t) in params.in_order() {
        inputs.push(HostValue::from_tensor(t));
    }
    for (name, shape) in &cfg.masks {
        let mask = BlockMask::random(shape[0], shape[1], 0.5, &mut rng);
        let _ = name;
        inputs.push(HostValue::tensor(mask.to_tensor()));
    }
    let toks: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| (i * 31 % cfg.vocab) as i32)
        .collect();
    let tgts: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| (i * 17 % cfg.vocab) as i32)
        .collect();
    inputs.push(HostValue::i32s(&[cfg.batch, cfg.seq], toks));
    inputs.push(HostValue::i32s(&[cfg.batch, cfg.seq], tgts));

    let dense = rt.execute("micro-llama_eval_loss", &inputs).unwrap()[0]
        .scalar()
        .unwrap();
    let pallas = rt.execute("micro-llama_eval_loss_pallas", &inputs).unwrap()[0]
        .scalar()
        .unwrap();
    assert!(
        (dense - pallas).abs() < 1e-3,
        "dense {dense} vs pallas {pallas}"
    );
}

// ---------------------------------------------------------------------------
// native engine ↔ AOT prefill agreement
// ---------------------------------------------------------------------------

#[test]
fn native_engine_matches_aot_prefill_logits() {
    let rt = require_runtime!();
    let cfg = rt.manifest().config("micro-llama").unwrap().clone();
    let params = ParamStore::init(&cfg, 9);
    let mut rng = Rng::new(10);
    let mut masks = BTreeMap::new();
    let mut inputs = Vec::new();
    for (_, t) in params.in_order() {
        inputs.push(HostValue::from_tensor(t));
    }
    for (name, shape) in &cfg.masks {
        let mask = BlockMask::random(shape[0], shape[1], 0.4, &mut rng);
        inputs.push(HostValue::tensor(mask.to_tensor()));
        masks.insert(name.clone(), mask);
    }
    let toks: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| (i * 13 % cfg.vocab) as i32)
        .collect();
    inputs.push(HostValue::i32s(&[cfg.batch, cfg.seq], toks.clone()));

    let out = rt.execute("micro-llama_prefill", &inputs).unwrap();
    let logits_aot = out[0].clone().into_tensor().unwrap(); // (batch, vocab)

    let native_cfg = NativeConfig::from_manifest(&cfg);
    let engine = Engine::new(native_cfg, &params, &masks, MlpMode::Sparse).unwrap();
    for row in 0..cfg.batch {
        let prompt: Vec<u32> = toks[row * cfg.seq..(row + 1) * cfg.seq]
            .iter()
            .map(|&t| t as u32)
            .collect();
        let mut cache = engine.new_cache();
        let logits_native = engine.prefill(&prompt, &mut cache).unwrap();
        for v in 0..cfg.vocab {
            let a = logits_aot.at2(row, v);
            let b = logits_native[v];
            assert!(
                (a - b).abs() < 2e-2,
                "row {row} vocab {v}: aot {a} vs native {b}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// decode path through PJRT
// ---------------------------------------------------------------------------

#[test]
fn aot_prefill_decode_consistent_with_full_prefill() {
    let rt = require_runtime!();
    let cfg = rt.manifest().config("micro-llama").unwrap().clone();
    let params = ParamStore::init(&cfg, 13);
    let mut base_inputs = Vec::new();
    for (_, t) in params.in_order() {
        base_inputs.push(HostValue::from_tensor(t));
    }
    for (_, shape) in &cfg.masks {
        base_inputs.push(HostValue::tensor(BlockMask::ones(shape[0], shape[1]).to_tensor()));
    }

    // full prompt prefill
    let toks: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| (i * 7 % cfg.vocab) as i32)
        .collect();
    let mut full_in = base_inputs.clone();
    full_in.push(HostValue::i32s(&[cfg.batch, cfg.seq], toks.clone()));
    let full_out = rt.execute("micro-llama_prefill", &full_in).unwrap();
    let logits_full = full_out[0].clone().into_tensor().unwrap();

    // prefix prefill (prompt padded — AOT shape is fixed, so we re-prefill
    // the full-but-one prompt and decode the final token)
    let mut prefix = toks.clone();
    // replace final position of each row with token 0 (it will be masked by
    // decode at pos = seq-1 anyway, but prefill reads it — so instead
    // prefill on a rolled prompt and check decode at the last position)
    for row in 0..cfg.batch {
        prefix[row * cfg.seq + cfg.seq - 1] = 0;
    }
    let mut pre_in = base_inputs.clone();
    pre_in.push(HostValue::i32s(&[cfg.batch, cfg.seq], prefix));
    let pre_out = rt.execute("micro-llama_prefill", &pre_in).unwrap();
    let kc = pre_out[1].clone();
    let vc = pre_out[2].clone();

    // decode the true final token at position seq-1
    let last_tokens: Vec<i32> = (0..cfg.batch)
        .map(|row| toks[row * cfg.seq + cfg.seq - 1])
        .collect();
    let mut dec_in = base_inputs.clone();
    dec_in.push(kc);
    dec_in.push(vc);
    dec_in.push(HostValue::i32s(&[cfg.batch], last_tokens));
    dec_in.push(HostValue::scalar_i32(cfg.seq as i32 - 1));
    let dec_out = rt.execute("micro-llama_decode_step", &dec_in).unwrap();
    let logits_dec = dec_out[0].clone().into_tensor().unwrap();

    // the decode logits must match the full prefill's last-position logits
    let diff = logits_dec.max_abs_diff(&logits_full);
    assert!(diff < 2e-2, "decode vs full prefill diff {diff}");
}
