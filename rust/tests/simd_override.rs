//! `BLAST_SIMD` environment override, exercised end-to-end in its own test
//! binary (integration tests run as separate processes, so this is the one
//! place the lazily-cached env read can be pinned before any kernel call).
//!
//! This file must stay a single test: the env var is read once at first
//! `dispatch()`, so another test in this binary touching the kernels first
//! would defeat the point.

use blast::kernels::simd::{self, Isa};
use blast::kernels::{gemm, ops, PackedB};
use blast::tensor::Tensor;
use blast::util::rng::Rng;

#[test]
fn env_off_forces_scalar_arm_end_to_end() {
    // Set before the first dispatch() in this process.
    std::env::set_var("BLAST_SIMD", "off");
    assert_eq!(simd::dispatch().isa, Isa::Scalar, "BLAST_SIMD=off must pin scalar");

    // A real kernel pass on the forced arm: packed GEMM + fused epilogue
    // against the unfused scalar oracle must now be *bitwise* identical,
    // because the scalar arm is the oracle.
    let mut rng = Rng::new(0x51D);
    let (m, k, n) = (19usize, 12usize, 23usize);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let packed = PackedB::pack(b.data(), k, n);
    let mut fused = Tensor::zeros(&[m, n]);
    gemm::gemm_packed_ep_into(
        a.data(),
        &packed,
        fused.data_mut(),
        m,
        blast::kernels::simd::Epilogue::Gelu,
    );
    let mut unfused = Tensor::zeros(&[m, n]);
    gemm::gemm_packed_into(a.data(), &packed, unfused.data_mut(), m);
    for v in unfused.data_mut().iter_mut() {
        *v = ops::gelu(*v);
    }
    assert_eq!(fused.data(), unfused.data(), "scalar arm must be bit-exact");

    // The programmatic override composes: turning SIMD back on cannot
    // un-force the env (env wins, by design — a CI lane sets it).
    simd::set_simd_enabled(true);
    assert_eq!(simd::dispatch().isa, Isa::Scalar);
}
