//! LM pretraining orchestrator.
//!
//! One `Trainer` owns the host-side training state (params, Adam moments,
//! masks) and repeatedly executes one [`TrainBackend`] step — the
//! **native** packed-kernel backend by default
//! ([`Trainer::new_native`], no artifacts needed), or the AOT PJRT
//! executable ([`Trainer::new`], `pjrt` feature). Every `step_size`
//! iterations it feeds the returned MLP gradients to the prune-and-grow
//! controller, refreshes the block masks, and zeroes the regrown blocks in
//! the dense weights — the Rust realization of the paper's Listing 1.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::corpus::{Corpus, LmBatch};
use crate::model::config::sim_config;
use crate::model::params::ParamStore;
use crate::runtime::{ConfigInfo, Runtime};
use crate::sparse::BlockMask;
use crate::sparsify::controller::{DensePolicy, PruneGrowConfig, PruneGrowController, WeightSpec};
use crate::sparsify::SparsitySchedule;
use crate::tensor::Tensor;
use crate::train::backend::{AotBackend, TrainBackend, TrainState};
use crate::train::guard::{
    global_grad_norm, scale_grads, GuardConfig, GuardPersist, StepGuard, Verdict,
};
use crate::train::native::NativeBackend;
use crate::util::faults::{FaultSite, Faults};
use crate::util::json::Json;

/// Seed of the re-forked corpus after `fork` divergence rollbacks: the
/// run must not replay into the same loss cliff, so each rollback draws a
/// fresh but deterministic data order. `fork = 0` is the original seed.
fn forked_corpus_seed(seed: u64, fork: u64) -> u64 {
    seed ^ fork.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Hyper-parameters of one pretraining run (Table 2's columns).
#[derive(Clone, Debug)]
pub struct PretrainOptions {
    pub total_iters: usize,
    pub s_init: f64,
    pub s_max: f64,
    /// Sparsity decay `d` (Table 6).
    pub decay: usize,
    /// Mask refresh interval (Table 5).
    pub step_size: usize,
    /// Dense layers kept on the right (`L` in Table 2 / Fig. 11).
    pub dense_right: usize,
    pub dense_left: usize,
    pub seed: u64,
    /// Corpus branching factor (entropy control).
    pub branching: usize,
    /// Effective sparse block = `block_mult × cfg.block` (Table 4's
    /// b ∈ {64, 128} points reuse the b=32 ABI via coarse grouping: the
    /// controller prunes on the coarse grid, masks are emitted fine).
    pub block_mult: usize,
}

impl Default for PretrainOptions {
    fn default() -> Self {
        PretrainOptions {
            total_iters: 200,
            s_init: 0.0,
            s_max: 0.8,
            decay: 0,
            step_size: 10,
            dense_right: 0,
            dense_left: 0,
            seed: 0xB1A57,
            branching: 8,
            block_mult: 1,
        }
    }
}

/// Parse the shared `--backend native|aot` CLI value and open the AOT
/// runtime when selected (`None` = native). Every surface that exposes
/// the flag — the binary, the experiment drivers, the benches, the
/// examples — goes through this one place, then hands the result to
/// [`Trainer::from_backend`], so the flag's semantics cannot drift.
pub fn open_backend_runtime(backend: &str) -> Result<Option<Runtime>> {
    match backend {
        "native" => Ok(None),
        "aot" => Ok(Some(Runtime::open_default()?)),
        other => bail!("--backend expects native|aot, got {other:?}"),
    }
}

/// Expand a coarse-grid mask to the fine ABI grid (each coarse block maps
/// to a `mult × mult` group of fine blocks).
pub fn expand_mask_grid(coarse: &BlockMask, mult: usize) -> BlockMask {
    if mult == 1 {
        return coarse.clone();
    }
    let mut fine = BlockMask::zeros(coarse.rb * mult, coarse.cb * mult);
    for r in 0..coarse.rb {
        for c in 0..coarse.cb {
            if coarse.get(r, c) {
                for i in 0..mult {
                    for j in 0..mult {
                        fine.set(r * mult + i, c * mult + j, true);
                    }
                }
            }
        }
    }
    fine
}

/// Per-iteration record (Fig. 8's series + Fig. 10's regrown ratio).
#[derive(Clone, Copy, Debug)]
pub struct IterLog {
    pub iter: usize,
    pub loss: f32,
    pub secs: f64,
    pub target_sparsity: f64,
    pub mean_mask_sparsity: f64,
    pub regrown_ratio: f64,
    /// Whether this iteration regenerated masks (the Fig. 8 spikes).
    pub mask_update: bool,
}

/// Backend-generic pretraining driver. `'rt` is the lifetime of the AOT
/// runtime when one is borrowed; native trainers are `Trainer<'static>`.
pub struct Trainer<'rt> {
    backend: Box<dyn TrainBackend + 'rt>,
    cfg: ConfigInfo,
    opts: PretrainOptions,
    state: TrainState,
    controller: PruneGrowController,
    corpus: Corpus,
    /// Iterations executed so far across the whole run — survives a
    /// checkpoint/resume round trip (unlike `log`, which is per-process
    /// diagnostics). [`Trainer::run`] continues from here. A divergence
    /// rollback rewinds this to the anchor's iteration, so after a
    /// rollback `log` can carry more than one entry per iteration.
    done_iters: usize,
    pub log: Vec<IterLog>,
    /// The anomaly guard; `None` (the default) leaves every code path
    /// bit-identical to the unguarded trainer.
    guard: Option<StepGuard>,
    /// Fault plan for the training-path sites (`grad_nan`, …) — consulted
    /// only on the guarded path.
    faults: Faults,
    /// Divergence rollbacks so far; keys [`forked_corpus_seed`].
    data_fork: u64,
    /// Last checkpoint known good — the rollback target. Advances only
    /// while the guard is healthy.
    rollback_anchor: Option<PathBuf>,
    /// Held-out probe batches for the mask guardrail (built lazily).
    probe: Option<Vec<LmBatch>>,
    /// Guard state carried by a resumed checkpoint, applied when
    /// [`Trainer::arm_guard`] runs.
    pending_guard_state: Option<GuardPersist>,
}

/// A block mask as a `[rb, cb]` 0/1 tensor (checkpoint representation).
fn mask_to_tensor(m: &BlockMask) -> Tensor {
    let mut data = vec![0.0f32; m.rb * m.cb];
    for r in 0..m.rb {
        for c in 0..m.cb {
            if m.get(r, c) {
                data[r * m.cb + c] = 1.0;
            }
        }
    }
    Tensor::new(&[m.rb, m.cb], data)
}

fn tensor_to_mask(t: &Tensor) -> BlockMask {
    let (rb, cb) = (t.shape()[0], t.shape()[1]);
    let mut m = BlockMask::zeros(rb, cb);
    for r in 0..rb {
        for c in 0..cb {
            if t.data()[r * cb + c] != 0.0 {
                m.set(r, c, true);
            }
        }
    }
    m
}

/// Split a checkpoint's flat tensor store into the four prefixed
/// sections (`param.` / `adam_m.` / `adam_v.` / `mask.`).
fn split_checkpoint_store(
    store: &ParamStore,
) -> (ParamStore, ParamStore, ParamStore, BTreeMap<String, BlockMask>) {
    let mut params = ParamStore::new();
    let mut adam_m = ParamStore::new();
    let mut adam_v = ParamStore::new();
    let mut masks: BTreeMap<String, BlockMask> = BTreeMap::new();
    for (n, t) in store.in_order() {
        if let Some(s) = n.strip_prefix("param.") {
            params.insert(s.to_string(), t.clone());
        } else if let Some(s) = n.strip_prefix("adam_m.") {
            adam_m.insert(s.to_string(), t.clone());
        } else if let Some(s) = n.strip_prefix("adam_v.") {
            adam_v.insert(s.to_string(), t.clone());
        } else if let Some(s) = n.strip_prefix("mask.") {
            masks.insert(s.to_string(), tensor_to_mask(t));
        }
    }
    (params, adam_m, adam_v, masks)
}

/// Guard trajectory from a checkpoint's meta block, when the checkpoint
/// was written by a guarded run (the f64 fields travel as IEEE-bit
/// strings so the round trip is exact).
fn guard_persist_from_meta(meta: &Json) -> Option<GuardPersist> {
    let ewma_bits: u64 = meta.str_or("guard_ewma_bits", "").parse().ok()?;
    let best_bits: u64 = meta.str_or("guard_best_bits", "").parse().ok()?;
    Some(GuardPersist {
        ewma_bits,
        best_bits,
        div_streak: meta.usize_or("guard_div_streak", 0),
        skip_streak: meta.usize_or("guard_skip_streak", 0),
        cooldown: meta.usize_or("guard_cooldown", 0),
        relaxed: meta.usize_or("guard_relaxed", 0) != 0,
        rollbacks: meta.usize_or("guard_rollbacks", 0) as u64,
        skips: meta.usize_or("guard_skips", 0) as u64,
        clips: meta.usize_or("guard_clips", 0) as u64,
        mask_reverts: meta.usize_or("guard_mask_reverts", 0) as u64,
        deferred: meta.usize_or("guard_deferred", 0) as u64,
    })
}

/// Newest-first retention sweep over `ckpt-*.blst` in `dir` (zero-padded
/// iteration numbers make lexicographic order chronological). Only
/// checkpoints that pass [`ParamStore::quick_verify`] count toward
/// `keep` — an unrestorable file must never crowd a good one out of the
/// retention window, so under injected `ckpt_torn_write` storms the
/// directory always holds at least `keep` valid checkpoints (as long as
/// that many were ever written). Invalid `.blst` files and stale
/// `.blst.tmp` debris abandoned by torn writers are swept as junk, and
/// any deletion is followed by a best-effort directory fsync so the
/// prune is durable no later than the rename that triggered it.
///
/// `pin` protects one path from the sweep regardless of age: the guarded
/// trainer's current rollback anchor must survive even when it has aged
/// out of the `keep` window, or a divergence would have nothing valid to
/// roll back to.
fn prune_checkpoints(dir: &Path, keep: usize, pin: Option<&Path>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut valid: Vec<std::path::PathBuf> = Vec::new();
    let mut junk: Vec<std::path::PathBuf> = Vec::new();
    for p in rd.filter_map(|e| e.ok()).map(|e| e.path()) {
        let Some(name) = p.file_name().and_then(|s| s.to_str()) else {
            continue;
        };
        if name.starts_with("ckpt-") && name.ends_with(".blst.tmp") {
            junk.push(p);
        } else if name.starts_with("ckpt-") && name.ends_with(".blst") {
            match ParamStore::quick_verify(&p) {
                Ok(()) => valid.push(p),
                Err(_) => junk.push(p),
            }
        }
    }
    valid.sort();
    let mut removed = false;
    let aged_out = valid.len().saturating_sub(keep.max(1));
    for p in valid.into_iter().take(aged_out) {
        if pin.is_some_and(|a| a == p.as_path()) {
            continue;
        }
        std::fs::remove_file(&p).ok();
        removed = true;
    }
    for p in junk {
        std::fs::remove_file(&p).ok();
        removed = true;
    }
    if removed {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
}

impl<'rt> Trainer<'rt> {
    /// AOT-backed trainer over a manifest config (requires the `pjrt`
    /// feature + artifacts to have *opened* `rt`).
    pub fn new(rt: &'rt Runtime, config: &str, opts: PretrainOptions) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let params = ParamStore::init(&cfg, opts.seed);
        Trainer::with_params(rt, config, opts, params)
    }

    /// AOT-backed trainer from existing weights (fine-tuning /
    /// post-training compression).
    pub fn with_params(
        rt: &'rt Runtime,
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let backend = Box::new(AotBackend::new(rt, cfg.clone()));
        Trainer::with_backend(backend, cfg, opts, params)
    }

    /// Native-backed trainer over a built-in twin
    /// ([`crate::model::config::sim_config`]) — the default path: runs in
    /// every build, no artifacts needed.
    pub fn new_native(config: &str, opts: PretrainOptions) -> Result<Trainer<'static>> {
        let cfg = sim_config(config).ok_or_else(|| {
            anyhow!(
                "no built-in native config {config:?} (have: {:?}); \
                 use --backend aot for manifest-only configs",
                crate::model::config::SIM_CONFIGS
            )
        })?;
        let params = ParamStore::init(&cfg, opts.seed);
        Trainer::new_native_with_params(config, opts, params)
    }

    /// Native-backed trainer from existing weights.
    pub fn new_native_with_params(
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'static>> {
        let cfg = sim_config(config)
            .ok_or_else(|| anyhow!("no built-in native config {config:?}"))?;
        let backend = Box::new(NativeBackend::new(&cfg)?);
        Trainer::with_backend(backend, cfg, opts, params)
    }

    /// The shared `--backend native|aot` dispatch: `Some(rt)` selects the
    /// AOT executables, `None` the native backend. One place for the CLI
    /// convention the binary, the experiment drivers, the benches and the
    /// examples all share.
    pub fn from_backend(
        rt: Option<&'rt Runtime>,
        config: &str,
        opts: PretrainOptions,
    ) -> Result<Trainer<'rt>> {
        match rt {
            Some(rt) => Trainer::new(rt, config, opts),
            None => Trainer::new_native(config, opts),
        }
    }

    /// Assemble a trainer around any backend (the seam the tests and the
    /// A/B harness use directly).
    pub fn with_backend(
        backend: Box<dyn TrainBackend + 'rt>,
        cfg: ConfigInfo,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let mult = opts.block_mult.max(1);
        let specs: Vec<WeightSpec> = cfg
            .masks
            .iter()
            .map(|(name, shape)| {
                assert!(
                    shape[0] % mult == 0 && shape[1] % mult == 0,
                    "mask grid {shape:?} not divisible by block_mult {mult}"
                );
                WeightSpec {
                    name: name.clone(),
                    layer: ConfigInfo::layer_of(name).unwrap_or(0),
                    rb: shape[0] / mult,
                    cb: shape[1] / mult,
                }
            })
            .collect();
        let controller = PruneGrowController::new(
            PruneGrowConfig {
                block: cfg.block * mult,
                schedule: SparsitySchedule::new(
                    opts.s_init,
                    opts.s_max,
                    opts.total_iters,
                    opts.decay.min(opts.total_iters.saturating_sub(1)),
                ),
                step_size: opts.step_size,
                dense_policy: DensePolicy {
                    left: opts.dense_left,
                    right: opts.dense_right,
                },
                n_layers: cfg.layers,
            },
            specs,
        );
        let corpus = Corpus::new(cfg.vocab, opts.branching, opts.seed);
        Ok(Trainer {
            backend,
            cfg,
            opts,
            state: TrainState::new(params),
            controller,
            corpus,
            done_iters: 0,
            log: Vec::new(),
            guard: None,
            faults: Faults::disabled(),
            data_fork: 0,
            rollback_anchor: None,
            probe: None,
            pending_guard_state: None,
        })
    }

    pub fn params(&self) -> &ParamStore {
        &self.state.params
    }

    /// Full training state (params + Adam moments + step) — the resume
    /// tests compare it bit-for-bit against an uninterrupted run.
    pub fn state(&self) -> &TrainState {
        &self.state
    }

    /// Iterations executed so far (survives checkpoint/resume).
    pub fn done_iters(&self) -> usize {
        self.done_iters
    }

    pub fn masks(&self) -> &BTreeMap<String, BlockMask> {
        self.controller.masks()
    }

    pub fn controller(&self) -> &PruneGrowController {
        &self.controller
    }

    pub fn config(&self) -> &ConfigInfo {
        &self.cfg
    }

    /// Which backend executes the steps (`"native"` / `"aot"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Thread a fault plan through to the guarded training path. Call
    /// *before* [`Trainer::arm_guard`] — the guard's backoff jitter
    /// stream is forked from this plan's spec.
    pub fn set_faults(&mut self, faults: Faults) {
        self.faults = faults;
    }

    /// Arm the anomaly guard. A checkpointed guard trajectory (from
    /// [`Trainer::resume_from`]) is applied here, so resume + arm
    /// continues the guarded run exactly where it left off.
    pub fn arm_guard(&mut self, cfg: GuardConfig) {
        let mut g = StepGuard::new(cfg, self.faults.fork_rng("train_guard"));
        if let Some(p) = self.pending_guard_state.take() {
            g.restore(&p);
        }
        self.guard = Some(g);
    }

    pub fn guard(&self) -> Option<&StepGuard> {
        self.guard.as_ref()
    }

    /// Divergence rollbacks so far (0 = the original data order).
    pub fn data_fork(&self) -> u64 {
        self.data_fork
    }

    /// The checkpoint a divergence would roll back to.
    pub fn rollback_anchor(&self) -> Option<&Path> {
        self.rollback_anchor.as_deref()
    }

    /// Rebuild the corpus stream for the current `data_fork` and
    /// fast-forward it to the batch iteration `done_iters` consumes next.
    fn rebuild_corpus(&mut self) {
        self.corpus = Corpus::new(
            self.cfg.vocab,
            self.opts.branching,
            forked_corpus_seed(self.opts.seed, self.data_fork),
        );
        for _ in 0..self.done_iters {
            self.corpus.batch(self.cfg.batch, self.cfg.seq);
        }
    }

    /// Masks expanded from the controller's (possibly coarse) grid to the
    /// fine ABI grid every backend consumes.
    fn fine_masks(&self) -> BTreeMap<String, BlockMask> {
        let mult = self.opts.block_mult.max(1);
        self.cfg
            .masks
            .iter()
            .map(|(name, _)| {
                (
                    name.clone(),
                    expand_mask_grid(&self.controller.masks()[name], mult),
                )
            })
            .collect()
    }

    /// Execute one training iteration (Listing 1 body). Returns the loss.
    ///
    /// With a guard armed ([`Trainer::arm_guard`]) the step runs split
    /// (gradients inspected before the optimizer) and may be skipped,
    /// clipped, or — on a divergence — answered with a rollback that
    /// *rewinds* [`Trainer::done_iters`] to the anchor's iteration.
    pub fn train_iteration(&mut self, iter: usize) -> Result<f32> {
        if self.guard.is_none() {
            self.train_iteration_unguarded(iter)
        } else {
            self.train_iteration_guarded(iter)
        }
    }

    /// The unguarded fused step — byte-for-byte the pre-guard trainer.
    fn train_iteration_unguarded(&mut self, iter: usize) -> Result<f32> {
        let t0 = Instant::now();
        let batch = self.corpus.batch(self.cfg.batch, self.cfg.seq);
        let fine = self.fine_masks();
        // prune-and-grow gate: only mask-update iterations need the MLP
        // gradient matrices shipped back
        let mask_update = self.controller.should_update(iter);
        let out = self
            .backend
            .train_step(&mut self.state, &fine, &batch, mask_update)?;
        let loss = out.loss;

        let mut regrown_ratio = 0.0;
        if mask_update {
            let mut weights = BTreeMap::new();
            for wname in &self.cfg.mlp_weights {
                weights.insert(wname.clone(), self.state.params.req(wname).clone());
            }
            let upd = self.controller.update(iter, &weights, &out.mlp_grads);
            regrown_ratio = upd.stats.regrown_ratio;
            self.zero_regrown(&upd.regrown);
        }

        self.push_iter_log(iter, loss, t0, regrown_ratio, mask_update);
        self.done_iters = self.done_iters.max(iter + 1);
        Ok(loss)
    }

    /// The guarded split step: grad fault sites → norm check → clip or
    /// skip-with-backoff → optimizer → EWMA divergence watch → probed
    /// mask update. Escalates to [`Trainer::rollback_to_anchor`] when the
    /// skip budget runs out or the EWMA diverges `div_steps` in a row.
    fn train_iteration_guarded(&mut self, iter: usize) -> Result<f32> {
        let t0 = Instant::now();
        let batch = self.corpus.batch(self.cfg.batch, self.cfg.seq);
        let fine = self.fine_masks();
        let mask_update = self.controller.should_update(iter);
        let (mut loss, mut grads) = self
            .backend
            .grad_step(&self.state, &fine, &batch)?
            .ok_or_else(|| {
                anyhow!(
                    "--guard-* needs a backend with a split step; the {:?} \
                     backend only offers the fused train_step",
                    self.backend.name()
                )
            })?;

        // deterministic training fault sites (armed storms only; the
        // unguarded path never consults them)
        if self.faults.fire(FaultSite::GradNan) {
            if let Some(name) = grads.names().first().cloned() {
                if let Some(x) = grads.get_mut(&name).unwrap().data_mut().first_mut() {
                    *x = f32::NAN;
                }
            }
        }
        if self.faults.fire(FaultSite::GradExplode) {
            scale_grads(&mut grads, self.faults.magnitude(FaultSite::GradExplode) as f32);
        }
        if self.faults.fire(FaultSite::LossSpikeMul) {
            loss *= self.faults.magnitude(FaultSite::LossSpikeMul) as f32;
        }

        let gnorm = global_grad_norm(&grads);
        let verdict = self.guard.as_mut().unwrap().check(loss, gnorm);
        match verdict {
            Verdict::Skip { reason, backoff } => {
                crate::log_warn!(
                    "train",
                    "iter {iter}: step skipped ({reason}, loss {loss:.4}, |g| {gnorm:.3e}); \
                     backing off {}ms",
                    backoff.as_millis()
                );
                std::thread::sleep(backoff);
                self.push_iter_log(iter, loss, t0, 0.0, false);
                self.done_iters = self.done_iters.max(iter + 1);
                if self.guard.as_ref().unwrap().skips_exhausted() {
                    self.rollback_to_anchor("consecutive-skip budget exhausted")?;
                }
                return Ok(loss);
            }
            Verdict::Accept { clip_scale } => {
                if let Some(s) = clip_scale {
                    scale_grads(&mut grads, s);
                }
                self.backend.apply_update(&mut self.state, &grads)?;
                let diverged = self.guard.as_mut().unwrap().observe_accepted(loss);
                let mut regrown_ratio = 0.0;
                if mask_update && !diverged {
                    let mut mlp_grads = BTreeMap::new();
                    for name in &self.cfg.mlp_weights {
                        mlp_grads.insert(name.clone(), grads.req(name).clone());
                    }
                    regrown_ratio = self.guarded_mask_update(iter, &mlp_grads)?;
                }
                self.push_iter_log(iter, loss, t0, regrown_ratio, mask_update);
                self.done_iters = self.done_iters.max(iter + 1);
                if diverged {
                    self.rollback_to_anchor("loss EWMA diverged beyond tolerance")?;
                }
                Ok(loss)
            }
        }
    }

    /// `prune_weights()`: zero newly-enabled blocks in the dense weights.
    fn zero_regrown(&mut self, regrown: &BTreeMap<String, BlockMask>) {
        let block = self.cfg.block * self.opts.block_mult.max(1);
        for (name, to_zero) in regrown {
            let w = self.state.params.get_mut(name).unwrap();
            let inverse = {
                // apply_to zeroes *pruned* blocks, so invert: we want to
                // zero exactly the to_zero set
                let mut inv = BlockMask::ones(to_zero.rb, to_zero.cb);
                for r in 0..to_zero.rb {
                    for c in 0..to_zero.cb {
                        if to_zero.get(r, c) {
                            inv.set(r, c, false);
                        }
                    }
                }
                inv
            };
            inverse.apply_to(w.data_mut(), block);
        }
    }

    fn push_iter_log(
        &mut self,
        iter: usize,
        loss: f32,
        t0: Instant,
        regrown_ratio: f64,
        mask_update: bool,
    ) {
        self.log.push(IterLog {
            iter,
            loss,
            secs: t0.elapsed().as_secs_f64(),
            target_sparsity: self.controller.target_sparsity(iter),
            mean_mask_sparsity: self.controller.mean_sparsity(),
            regrown_ratio,
            mask_update,
        });
    }

    /// Mean loss over the held-out probe batches (a corpus stream distinct
    /// from both training and [`Trainer::eval_perplexity`], so probing
    /// never perturbs the training data order).
    fn probe_loss(&mut self) -> Result<f32> {
        if self.probe.is_none() {
            let n = self.guard.as_ref().unwrap().config().probe_batches.max(1);
            self.probe = Some(Corpus::eval_batches(
                self.cfg.vocab,
                self.opts.branching,
                self.opts.seed ^ 0x9A7D_5EED,
                n,
                self.cfg.batch,
                self.cfg.seq,
            ));
        }
        let batches = self.probe.take().unwrap();
        let fine = self.fine_masks();
        let mut total = 0.0f64;
        for b in &batches {
            total += self.backend.eval_loss(&self.state, &fine, b)? as f64;
        }
        let n = batches.len();
        self.probe = Some(batches);
        Ok((total / n as f64) as f32)
    }

    /// One mask update under the guardrail: cooldown gate → (relaxed)
    /// target → probe before → update + zero regrown → probe after →
    /// revert with cooldown when the probe degrades beyond budget. The
    /// revert restores both the previous masks and the exact weight
    /// values the update zeroed, so a reverted update is a no-op on
    /// training state. Returns the regrown ratio (0 when deferred or
    /// reverted).
    fn guarded_mask_update(
        &mut self,
        iter: usize,
        mlp_grads: &BTreeMap<String, Tensor>,
    ) -> Result<f64> {
        if !self.guard.as_mut().unwrap().mask_update_allowed() {
            crate::log_warn!("train", "iter {iter}: mask update deferred (controller on cooldown)");
            return Ok(0.0);
        }
        let scheduled = self.controller.target_sparsity(iter);
        let current = self.controller.mean_sparsity();
        let target = self.guard.as_ref().unwrap().mask_target(scheduled, current);
        let probe_enabled = self.guard.as_ref().unwrap().config().mask_budget.is_finite();
        let before = if probe_enabled { Some(self.probe_loss()?) } else { None };
        let old_masks = self.controller.masks().clone();

        let mut weights = BTreeMap::new();
        for wname in &self.cfg.mlp_weights {
            weights.insert(wname.clone(), self.state.params.req(wname).clone());
        }
        let upd = self
            .controller
            .update_with_target(iter, target, &weights, mlp_grads);
        let regrown_ratio = upd.stats.regrown_ratio;
        // snapshot the exact values the zeroing is about to destroy
        let block = self.cfg.block * self.opts.block_mult.max(1);
        let snapshots: Vec<(String, Vec<f32>)> = if probe_enabled {
            upd.regrown
                .iter()
                .map(|(name, to_zero)| {
                    let w = self.state.params.req(name);
                    (name.clone(), to_zero.gather_blocks(w.data(), block))
                })
                .collect()
        } else {
            Vec::new()
        };
        self.zero_regrown(&upd.regrown);

        // catastrophic-update fault: the controller's fresh masks are
        // replaced wholesale with one-surviving-block grids — the probe
        // (or, with the probe disabled, divergence rollback) must catch it
        if self.faults.fire(FaultSite::MaskCorrupt) {
            let corrupt: BTreeMap<String, BlockMask> = self
                .controller
                .masks()
                .iter()
                .map(|(name, m)| {
                    let mut z = BlockMask::zeros(m.rb, m.cb);
                    z.set(0, 0, true);
                    (name.clone(), z)
                })
                .collect();
            self.controller.restore_masks(corrupt)?;
            crate::log_warn!("train", "iter {iter}: mask_corrupt fault fired");
        }

        if let Some(before) = before {
            let after = self.probe_loss()?;
            if !self.guard.as_ref().unwrap().mask_probe_ok(before, after) {
                for (name, vals) in &snapshots {
                    let w = self.state.params.get_mut(name).unwrap();
                    upd.regrown[name].scatter_blocks(vals, w.data_mut(), block);
                }
                self.controller.undo_last_update(old_masks)?;
                self.guard.as_mut().unwrap().note_mask_reverted();
                crate::log_warn!(
                    "train",
                    "iter {iter}: mask update reverted (probe {before:.4} → {after:.4} \
                     beyond budget); controller on cooldown"
                );
                return Ok(0.0);
            }
        }
        self.guard.as_mut().unwrap().note_mask_accepted();
        Ok(regrown_ratio)
    }

    /// Restore the last-good checkpoint in place and re-fork the data
    /// order. Monotone guard counters survive; the EWMA trajectory and
    /// cooldown state come back from the anchor. Without an anchor (plain
    /// [`Trainer::run`], no checkpoint dir) the streaks are cleared and
    /// the run limps on. Fails once the rollback budget is spent.
    fn rollback_to_anchor(&mut self, why: &str) -> Result<()> {
        if self.guard.as_ref().unwrap().rollbacks_exhausted() {
            bail!(
                "{why} and the rollback budget is exhausted \
                 ({} rollbacks); refusing to thrash",
                self.guard.as_ref().unwrap().stats().rollbacks
            );
        }
        let Some(anchor) = self.rollback_anchor.clone() else {
            crate::log_warn!(
                "train",
                "{why}, but no rollback anchor exists (run without --ckpt-dir); \
                 clearing anomaly streaks and continuing"
            );
            self.guard.as_mut().unwrap().rollback_restore(None);
            return Ok(());
        };
        let (store, meta) = ParamStore::load_with_meta(&anchor)
            .with_context(|| format!("loading rollback anchor {anchor:?}"))?;
        let (params, adam_m, adam_v, masks) = split_checkpoint_store(&store);
        self.state = TrainState {
            params,
            adam_m,
            adam_v,
            step: meta.usize_or("step", 0) as i32,
        };
        self.controller.restore_masks(masks)?;
        self.done_iters = meta.usize_or("iter", 0);
        self.guard
            .as_mut()
            .unwrap()
            .rollback_restore(guard_persist_from_meta(&meta).as_ref());
        self.data_fork += 1;
        self.rebuild_corpus();
        crate::log_warn!(
            "train",
            "{why}: rolled back to {anchor:?} (iter {}), data order re-forked (fork {})",
            self.done_iters,
            self.data_fork
        );
        Ok(())
    }

    /// Run `n` iterations continuing from [`Trainer::done_iters`] (0 for a
    /// fresh trainer, the checkpointed iteration after a resume). A
    /// divergence rollback rewinds `done_iters`, so the loop is a while
    /// over the target iteration, not a fixed count — identical to the
    /// old for-loop whenever no rollback fires.
    pub fn run(&mut self, n: usize) -> Result<()> {
        let start = self.done_iters;
        let end = start + n;
        while self.done_iters < end {
            let i = self.done_iters;
            let loss = self.train_iteration(i)?;
            if i % 20 == 0 || i + 1 == end {
                crate::log_info!(
                    "train",
                    "{} iter {i} loss {loss:.4} s={:.2}",
                    self.cfg.name,
                    self.controller.mean_sparsity()
                );
            }
        }
        Ok(())
    }

    /// Run `n` iterations with periodic crash-safe autosaves: every
    /// `every` iterations a checkpoint `ckpt-{iter:06}.blst` is written
    /// atomically into `dir`, retaining the newest `keep` files. A failed
    /// save (e.g. an injected `ckpt_torn_write`) is logged and training
    /// continues — the previous checkpoint on disk remains valid.
    pub fn run_with_autosave(
        &mut self,
        n: usize,
        dir: &Path,
        every: usize,
        keep: usize,
        faults: &Faults,
    ) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        let start = self.done_iters;
        let end = start + n;
        // a guarded run needs a rollback target before the first anomaly
        // can strike: anchor on the starting state
        if self.guard.is_some() && self.rollback_anchor.is_none() && every > 0 {
            let path = dir.join(format!("ckpt-{:06}.blst", start));
            match self.save_checkpoint_faulted(&path, faults) {
                Ok(()) => match ParamStore::quick_verify(&path) {
                    Ok(()) => self.rollback_anchor = Some(path),
                    Err(e) => crate::log_warn!(
                        "train",
                        "initial rollback anchor is not restorable ({e}); \
                         running without one until the first good autosave"
                    ),
                },
                Err(e) => crate::log_warn!(
                    "train",
                    "initial rollback anchor failed to save: {e}; \
                     running without one until the first good autosave"
                ),
            }
        }
        while self.done_iters < end {
            let i = self.done_iters;
            let loss = self.train_iteration(i)?;
            if self.done_iters != i + 1 {
                // the iteration answered with a rollback — no autosave on
                // this lap, the loop re-runs from the anchor's iteration
                continue;
            }
            if i % 20 == 0 || i + 1 == end {
                crate::log_info!(
                    "train",
                    "{} iter {i} loss {loss:.4} s={:.2}",
                    self.cfg.name,
                    self.controller.mean_sparsity()
                );
            }
            if every > 0 && (i + 1) % every == 0 {
                let path = dir.join(format!("ckpt-{:06}.blst", i + 1));
                match self.save_checkpoint_faulted(&path, faults) {
                    // retention may only run once the new checkpoint is
                    // provably on disk and restorable: a save that claimed
                    // success but left an invalid file must not trigger
                    // deletion of the older good checkpoints
                    Ok(()) => match ParamStore::quick_verify(&path) {
                        Ok(()) => {
                            // the anchor advances only while the guard sees
                            // a clean streak — an anomalous window must not
                            // overwrite the known-good rollback target
                            if self.guard.as_ref().is_some_and(|g| g.healthy()) {
                                self.rollback_anchor = Some(path);
                            }
                            prune_checkpoints(dir, keep, self.rollback_anchor.as_deref());
                        }
                        Err(e) => crate::log_warn!(
                            "train",
                            "autosave at iter {} is not restorable ({e}); \
                             retention sweep skipped",
                            i + 1
                        ),
                    },
                    Err(e) => crate::log_warn!(
                        "train",
                        "autosave at iter {} failed: {e}; continuing (previous checkpoint intact)",
                        i + 1
                    ),
                }
            }
        }
        Ok(())
    }

    /// Write a full training checkpoint: parameters, Adam moments, block
    /// masks and run metadata (config, iteration, step, hyper-parameters),
    /// atomically with per-tensor CRCs. [`Trainer::resume_from`] restores
    /// a run that continues bit-identically.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.save_checkpoint_faulted(path, &Faults::disabled())
    }

    /// [`Trainer::save_checkpoint`] with a fault plan threaded through to
    /// the writer (`ckpt_torn_write` chaos runs).
    pub fn save_checkpoint_faulted(&self, path: &Path, faults: &Faults) -> Result<()> {
        let mut store = ParamStore::new();
        for (n, t) in self.state.params.in_order() {
            store.insert(format!("param.{n}"), t.clone());
        }
        for (n, t) in self.state.adam_m.in_order() {
            store.insert(format!("adam_m.{n}"), t.clone());
        }
        for (n, t) in self.state.adam_v.in_order() {
            store.insert(format!("adam_v.{n}"), t.clone());
        }
        for (name, m) in self.controller.masks() {
            store.insert(format!("mask.{name}"), mask_to_tensor(m));
        }
        let o = &self.opts;
        let mut fields = vec![
            ("kind", Json::str("trainer")),
            ("config", Json::str(&self.cfg.name)),
            ("iter", Json::num(self.done_iters as f64)),
            ("step", Json::num(self.state.step as f64)),
            ("total_iters", Json::num(o.total_iters as f64)),
            ("s_init", Json::num(o.s_init)),
            ("s_max", Json::num(o.s_max)),
            ("decay", Json::num(o.decay as f64)),
            ("step_size", Json::num(o.step_size as f64)),
            ("dense_right", Json::num(o.dense_right as f64)),
            ("dense_left", Json::num(o.dense_left as f64)),
            // seeds are u64 — a string survives where f64 would round
            ("seed", Json::str(&o.seed.to_string())),
            ("branching", Json::num(o.branching as f64)),
            ("block_mult", Json::num(o.block_mult as f64)),
        ];
        // guard trajectory travels only in guarded runs, so guards-off
        // checkpoints stay byte-identical to the pre-guard format
        if let Some(g) = &self.guard {
            let p = g.persist();
            fields.push(("data_fork", Json::str(&self.data_fork.to_string())));
            fields.push(("guard_ewma_bits", Json::str(&p.ewma_bits.to_string())));
            fields.push(("guard_best_bits", Json::str(&p.best_bits.to_string())));
            fields.push(("guard_div_streak", Json::num(p.div_streak as f64)));
            fields.push(("guard_skip_streak", Json::num(p.skip_streak as f64)));
            fields.push(("guard_cooldown", Json::num(p.cooldown as f64)));
            fields.push(("guard_relaxed", Json::num(if p.relaxed { 1.0 } else { 0.0 })));
            fields.push(("guard_rollbacks", Json::num(p.rollbacks as f64)));
            fields.push(("guard_skips", Json::num(p.skips as f64)));
            fields.push(("guard_clips", Json::num(p.clips as f64)));
            fields.push(("guard_mask_reverts", Json::num(p.mask_reverts as f64)));
            fields.push(("guard_deferred", Json::num(p.deferred as f64)));
        }
        let meta = Json::obj(fields);
        store.save_with_meta(path, &meta, faults)
    }

    /// Rebuild a native trainer from a [`Trainer::save_checkpoint`] file
    /// and continue **bit-identically**: parameters, Adam moments, step
    /// counter and masks are restored exactly, the hyper-parameters come
    /// from the checkpoint's metadata, and the corpus stream is
    /// fast-forwarded to the batch the interrupted run would consume next
    /// (the corpus is a pure function of seed + batches drawn).
    pub fn resume_from(path: &Path) -> Result<Trainer<'static>> {
        let (store, meta) = ParamStore::load_with_meta(path)?;
        if meta.str_or("kind", "") != "trainer" {
            bail!(
                "{path:?} is not a trainer checkpoint (weights-only files \
                 carry no optimizer/mask state to resume from)"
            );
        }
        let config = meta.str_or("config", "");
        let seed: u64 = meta
            .str_or("seed", "0")
            .parse()
            .map_err(|_| anyhow!("{path:?}: bad seed in checkpoint meta"))?;
        let opts = PretrainOptions {
            total_iters: meta.usize_or("total_iters", 200),
            s_init: meta.f64_or("s_init", 0.0),
            s_max: meta.f64_or("s_max", 0.8),
            decay: meta.usize_or("decay", 0),
            step_size: meta.usize_or("step_size", 10),
            dense_right: meta.usize_or("dense_right", 0),
            dense_left: meta.usize_or("dense_left", 0),
            seed,
            branching: meta.usize_or("branching", 8),
            block_mult: meta.usize_or("block_mult", 1),
        };
        let iter = meta.usize_or("iter", 0);
        let step = meta.usize_or("step", 0) as i32;
        let (params, adam_m, adam_v, masks) = split_checkpoint_store(&store);
        let mut t = Trainer::new_native_with_params(&config, opts, params)?;
        t.state.adam_m = adam_m;
        t.state.adam_v = adam_v;
        t.state.step = step;
        t.controller.restore_masks(masks)?;
        t.done_iters = iter;
        // a guarded checkpoint carries the re-forked data order and the
        // guard trajectory (applied when the caller re-arms the guard)
        t.data_fork = meta.str_or("data_fork", "0").parse().unwrap_or(0);
        t.pending_guard_state = guard_persist_from_meta(&meta);
        t.rebuild_corpus();
        Ok(t)
    }

    /// Held-out loss → perplexity over `n` fixed eval batches.
    pub fn eval_perplexity(&mut self, n: usize) -> Result<f64> {
        let batches = Corpus::eval_batches(
            self.cfg.vocab,
            self.opts.branching,
            self.opts.seed,
            n,
            self.cfg.batch,
            self.cfg.seq,
        );
        let fine = self.fine_masks();
        let mut total = 0.0f64;
        for b in &batches {
            total += self.backend.eval_loss(&self.state, &fine, b)? as f64;
        }
        Ok((total / n as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sparse::Bcsc;
    use crate::testkit::prop;

    #[test]
    fn expand_mask_grid_identity_at_mult_1() {
        let mut rng = crate::util::rng::Rng::new(1);
        let m = BlockMask::random(4, 6, 0.5, &mut rng);
        assert_eq!(expand_mask_grid(&m, 1), m);
    }

    #[test]
    fn expand_mask_grid_properties() {
        prop::check_default("expand-mask-grid", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let mult = *prop::pick(rng, &[2usize, 3, 4]);
            let coarse = BlockMask::random(rb, cb, rng.f64(), rng);
            let fine = expand_mask_grid(&coarse, mult);
            prop_assert!(
                fine.rb == rb * mult && fine.cb == cb * mult,
                "shape {}x{}",
                fine.rb,
                fine.cb
            );
            // kept count scales by mult²
            prop_assert!(
                fine.nnzb() == coarse.nnzb() * mult * mult,
                "nnzb {} vs {}",
                fine.nnzb(),
                coarse.nnzb() * mult * mult
            );
            // every fine block agrees with its coarse parent
            for r in 0..fine.rb {
                for c in 0..fine.cb {
                    prop_assert!(
                        fine.get(r, c) == coarse.get(r / mult, c / mult),
                        "mismatch at ({r},{c})"
                    );
                }
            }
            // sparsity is preserved exactly
            prop_assert!(
                (fine.sparsity() - coarse.sparsity()).abs() < 1e-12,
                "sparsity changed"
            );
            Ok(())
        });
    }

    #[test]
    fn expanded_mask_matches_elementwise_expansion() {
        // expand_mask_grid(m, mult).expand(b) == m.expand(b * mult)
        let mut rng = crate::util::rng::Rng::new(2);
        let coarse = BlockMask::random(3, 2, 0.4, &mut rng);
        let fine = expand_mask_grid(&coarse, 2);
        let a = fine.expand(4);
        let b = coarse.expand(8);
        assert!(a.allclose(&b, 0.0));
    }

    /// End-to-end native pretraining on the micro twin: loss falls, the
    /// schedule is realized in the masks, perplexity is finite and below
    /// the vocab bound. This is the default-build replacement for the AOT
    /// integration test that can only run with `pjrt` + artifacts.
    #[test]
    fn native_micro_training_reduces_loss_and_applies_sparsity() {
        let opts = PretrainOptions {
            total_iters: 20,
            s_max: 0.6,
            step_size: 5,
            ..Default::default()
        };
        let mut t = Trainer::new_native("micro", opts).unwrap();
        assert_eq!(t.backend_name(), "native");
        t.run(20).unwrap();
        let first = t.log[0].loss;
        let last = t.log.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(t.controller().mean_sparsity() > 0.3);
        let ppl = t.eval_perplexity(2).unwrap();
        assert!(ppl.is_finite() && ppl < 256.0, "ppl {ppl}");
    }

    /// The acceptance-gate run: a full native prune-grow run reproduces
    /// the controller's scheduled sparsity history — every mask-update
    /// iteration logs the cubic-schedule target, realized mask sparsity
    /// tracks it from below (regrowth slack only), and non-update
    /// iterations leave masks untouched.
    #[test]
    fn native_prune_grow_run_reproduces_scheduled_sparsity_history() {
        let opts = PretrainOptions {
            total_iters: 16,
            s_max: 0.7,
            step_size: 4,
            seed: 9,
            ..Default::default()
        };
        let sched = SparsitySchedule::new(0.0, 0.7, 16, 0);
        let mut t = Trainer::new_native("micro", opts).unwrap();
        t.run(16).unwrap();
        assert_eq!(t.log.len(), 16);
        let updates: Vec<usize> = t
            .log
            .iter()
            .filter(|l| l.mask_update)
            .map(|l| l.iter)
            .collect();
        assert_eq!(updates, vec![0, 4, 8, 12]);
        for l in &t.log {
            let want = sched.sparsity_at(l.iter);
            assert!(
                (l.target_sparsity - want).abs() < 1e-12,
                "iter {}: target {} vs schedule {}",
                l.iter,
                l.target_sparsity,
                want
            );
            // realized mask sparsity never exceeds the last update's target
            assert!(l.mean_mask_sparsity <= l.target_sparsity + 1e-9);
        }
        // controller history carries one entry per update, in order
        let hist = t.controller().history();
        assert_eq!(hist.len(), updates.len());
        for (h, &it) in hist.iter().zip(&updates) {
            assert_eq!(h.iteration, it);
            assert!((h.target_sparsity - sched.sparsity_at(it)).abs() < 1e-12);
            assert!(h.stats.realized_sparsity <= h.target_sparsity + 1e-9);
        }
        // masks between updates are frozen: the last two non-update iters
        // report the same mean sparsity
        let tail: Vec<f64> = t
            .log
            .iter()
            .rev()
            .take(3)
            .map(|l| l.mean_mask_sparsity)
            .collect();
        assert!((tail[0] - tail[1]).abs() < 1e-12);
    }

    /// Two ParamStores are bit-identical: same names in order, same
    /// shapes, same bytes (allclose with tolerance 0).
    fn assert_stores_identical(a: &ParamStore, b: &ParamStore, what: &str) {
        let av: Vec<_> = a.in_order().collect();
        let bv: Vec<_> = b.in_order().collect();
        assert_eq!(
            av.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            bv.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            "{what}: tensor name sets differ"
        );
        for ((n, ta), (_, tb)) in av.iter().zip(&bv) {
            assert!(ta.allclose(tb, 0.0), "{what}: tensor {n} differs");
        }
    }

    fn small_opts(seed: u64) -> PretrainOptions {
        PretrainOptions {
            total_iters: 12,
            s_max: 0.6,
            step_size: 3,
            seed,
            ..Default::default()
        }
    }

    /// The acceptance criterion for crash safety: kill at iteration 5,
    /// resume from the checkpoint, run to iteration 12 — parameters, Adam
    /// moments, step counter and masks are **bit-identical** to a run that
    /// was never interrupted.
    #[test]
    fn kill_resume_roundtrip_is_bit_identical() {
        let p = std::env::temp_dir().join("blast_test_resume.blst");
        let mut uninterrupted = Trainer::new_native("micro", small_opts(42)).unwrap();
        uninterrupted.run(12).unwrap();

        let mut killed = Trainer::new_native("micro", small_opts(42)).unwrap();
        killed.run(5).unwrap();
        killed.save_checkpoint(&p).unwrap();
        drop(killed); // the "crash"

        let mut resumed = Trainer::resume_from(&p).unwrap();
        assert_eq!(resumed.done_iters(), 5);
        resumed.run(7).unwrap();

        assert_eq!(resumed.done_iters(), uninterrupted.done_iters());
        assert_eq!(resumed.state().step, uninterrupted.state().step);
        assert_stores_identical(
            &resumed.state().params,
            &uninterrupted.state().params,
            "params",
        );
        assert_stores_identical(
            &resumed.state().adam_m,
            &uninterrupted.state().adam_m,
            "adam_m",
        );
        assert_stores_identical(
            &resumed.state().adam_v,
            &uninterrupted.state().adam_v,
            "adam_v",
        );
        assert_eq!(resumed.masks(), uninterrupted.masks());
        std::fs::remove_file(&p).ok();
    }

    /// Autosave writes `ckpt-NNNNNN.blst` every `every` iterations and the
    /// retention sweep keeps only the newest `keep`; resuming from the
    /// newest matches the live trainer exactly.
    #[test]
    fn autosave_retention_keeps_newest() {
        let dir = std::env::temp_dir().join("blast_test_autosave");
        std::fs::remove_dir_all(&dir).ok();
        let mut t = Trainer::new_native("micro", small_opts(7)).unwrap();
        t.run_with_autosave(8, &dir, 2, 2, &Faults::disabled()).unwrap();
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(names, vec!["ckpt-000006.blst", "ckpt-000008.blst"]);

        let resumed = Trainer::resume_from(&dir.join("ckpt-000008.blst")).unwrap();
        assert_eq!(resumed.done_iters(), 8);
        assert_eq!(resumed.state().step, t.state().step);
        assert_stores_identical(&resumed.state().params, &t.state().params, "params");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Torn-write chaos: with `ckpt_torn_write` firing ~50% of the time,
    /// training still completes, failed saves never clobber the previous
    /// checkpoint, and every `.blst` file that survives on disk loads
    /// cleanly (the torn `.tmp` siblings are the only debris).
    #[test]
    fn autosave_survives_injected_torn_writes() {
        let dir = std::env::temp_dir().join("blast_test_autosave_torn");
        std::fs::remove_dir_all(&dir).ok();
        let faults = Faults::parse("ckpt_torn_write:0.5:99").unwrap();
        let mut t = Trainer::new_native("micro", small_opts(11)).unwrap();
        t.run_with_autosave(10, &dir, 2, 3, &faults).unwrap();
        assert_eq!(t.done_iters(), 10, "training must complete despite torn saves");
        let mut loaded = 0usize;
        for e in std::fs::read_dir(&dir).unwrap() {
            let p = e.unwrap().path();
            if p.extension().is_some_and(|x| x == "blst") {
                Trainer::resume_from(&p)
                    .unwrap_or_else(|e| panic!("{p:?} failed to load: {e}"));
                loaded += 1;
            }
        }
        // with prob 0.5 over 5 save points, at least one save succeeds for
        // this fixed seed (deterministic — the plan's RNG stream is seeded)
        assert!(loaded > 0, "no checkpoint survived");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Retention ordering under `ckpt_torn_write`: the sweep runs only
    /// after a new checkpoint is fully on disk and verifiable, counts
    /// only restorable files toward `keep`, and treats invalid `.blst`
    /// files and torn `.tmp` debris as junk — so no torn-write storm can
    /// ever leave the directory with fewer than `keep` valid checkpoints
    /// once `keep` saves have succeeded.
    #[test]
    fn torn_writes_never_shrink_the_valid_retention_window() {
        let dir = std::env::temp_dir().join("blast_test_autosave_keep");
        std::fs::remove_dir_all(&dir).ok();
        let keep = 2usize;
        let valid_names = |dir: &Path| -> Vec<String> {
            let mut v: Vec<String> = std::fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|s| s.to_str())
                        .is_some_and(|s| s.ends_with(".blst"))
                        && ParamStore::quick_verify(p).is_ok()
                })
                .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
                .collect();
            v.sort();
            v
        };
        let mut t = Trainer::new_native("micro", small_opts(13)).unwrap();
        // phase 1: clean saves establish a full retention window
        t.run_with_autosave(6, &dir, 2, keep, &Faults::disabled()).unwrap();
        assert_eq!(valid_names(&dir), vec!["ckpt-000004.blst", "ckpt-000006.blst"]);
        // phase 2: every save torn — the window must not shrink
        let torn = Faults::parse("ckpt_torn_write:1:3").unwrap();
        t.run_with_autosave(6, &dir, 2, keep, &torn).unwrap();
        let survivors = valid_names(&dir);
        assert_eq!(
            survivors,
            vec!["ckpt-000004.blst", "ckpt-000006.blst"],
            "a failed save must never cost a valid checkpoint"
        );
        // phase 3: a clean save advances the window and sweeps the torn
        // .tmp debris phase 2 left behind
        t.run_with_autosave(2, &dir, 2, keep, &Faults::disabled()).unwrap();
        assert_eq!(valid_names(&dir), vec!["ckpt-000006.blst", "ckpt-000014.blst"]);
        assert!(
            std::fs::read_dir(&dir).unwrap().all(|e| {
                !e.unwrap().file_name().to_string_lossy().ends_with(".tmp")
            }),
            "torn .tmp debris must be swept by the next successful prune"
        );
        // phase 4: a garbage .blst that sorts newest must not crowd a
        // valid checkpoint out of the window — it is junk, not retention
        std::fs::write(dir.join("ckpt-999998.blst"), b"NOT A CHECKPOINT").unwrap();
        t.run_with_autosave(2, &dir, 2, keep, &Faults::disabled()).unwrap();
        let mut all: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        all.sort();
        assert_eq!(all, vec!["ckpt-000014.blst", "ckpt-000016.blst"]);
        // the newest survivor actually restores
        Trainer::resume_from(&dir.join("ckpt-000016.blst")).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite regression: the rollback anchor is pinned through the
    /// retention sweep even at retention window 1 — the sweep may never
    /// delete the one checkpoint a divergence would restore.
    #[test]
    fn retention_pin_protects_rollback_anchor_at_window_1() {
        let dir = std::env::temp_dir().join("blast_test_retention_pin");
        std::fs::remove_dir_all(&dir).ok();
        let names = |dir: &Path| -> Vec<String> {
            let mut v: Vec<String> = std::fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
                .collect();
            v.sort();
            v
        };
        let mut t = Trainer::new_native("micro", small_opts(17)).unwrap();
        t.run_with_autosave(6, &dir, 2, 3, &Faults::disabled()).unwrap();
        assert_eq!(
            names(&dir),
            vec!["ckpt-000002.blst", "ckpt-000004.blst", "ckpt-000006.blst"]
        );
        // an anchor two windows old survives a keep=1 sweep...
        let pin = dir.join("ckpt-000002.blst");
        prune_checkpoints(&dir, 1, Some(&pin));
        assert_eq!(
            names(&dir),
            vec!["ckpt-000002.blst", "ckpt-000006.blst"],
            "the pinned anchor must survive outside the retention window"
        );
        // ...and the same sweep without the pin deletes it
        prune_checkpoints(&dir, 1, None);
        assert_eq!(names(&dir), vec!["ckpt-000006.blst"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite sweep: kill a *guarded* run at every autosave boundary,
    /// resume from each checkpoint with the same guard config, and land
    /// bit-identical to the never-killed run — params, Adam moments, step
    /// counter, masks, and the guard's EWMA trajectory. Extends the
    /// single-point `kill_resume_roundtrip` test to the guarded path
    /// (clipping active every step via a tiny clip norm).
    #[test]
    fn guarded_kill_at_every_autosave_boundary_resumes_bit_identically() {
        let dir = std::env::temp_dir().join("blast_test_guard_boundaries");
        std::fs::remove_dir_all(&dir).ok();
        let gcfg = GuardConfig {
            clip_norm: 0.05,
            ..GuardConfig::permissive()
        };
        let mut base = Trainer::new_native("micro", small_opts(21)).unwrap();
        base.arm_guard(gcfg);
        base.run_with_autosave(12, &dir, 3, 100, &Faults::disabled()).unwrap();
        assert!(
            base.guard().unwrap().stats().clips > 0,
            "clip threshold was never hit — the sweep is not exercising guard math"
        );
        for boundary in [0usize, 3, 6, 9, 12] {
            let p = dir.join(format!("ckpt-{boundary:06}.blst"));
            let mut r = Trainer::resume_from(&p)
                .unwrap_or_else(|e| panic!("resume from {p:?}: {e}"));
            assert_eq!(r.done_iters(), boundary);
            r.arm_guard(gcfg);
            r.run(12 - boundary).unwrap();
            assert_eq!(r.state().step, base.state().step, "boundary {boundary}");
            assert_stores_identical(&r.state().params, &base.state().params, "params");
            assert_stores_identical(&r.state().adam_m, &base.state().adam_m, "adam_m");
            assert_stores_identical(&r.state().adam_v, &base.state().adam_v, "adam_v");
            assert_eq!(r.masks(), base.masks(), "boundary {boundary}");
            let (a, b) = (
                r.guard().unwrap().persist(),
                base.guard().unwrap().persist(),
            );
            assert_eq!(a.ewma_bits, b.ewma_bits, "boundary {boundary}: EWMA diverged");
            assert_eq!(a.best_bits, b.best_bits, "boundary {boundary}");
            assert_eq!(a.clips, b.clips, "boundary {boundary}: clip count diverged");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The controller × expand_mask_grid seam at `block_mult > 1`: the
    /// coarse controller grid expands to a fine mask whose effective
    /// block structure matches the coarse block size, stays consistent
    /// with what the native backend consumes (BCSC at the fine block), and
    /// the regrown-block zeroing lands on whole coarse blocks.
    #[test]
    fn controller_and_expand_mask_grid_compose_at_block_mult_2() {
        let opts = PretrainOptions {
            total_iters: 8,
            s_max: 0.6,
            step_size: 2,
            block_mult: 2,
            seed: 3,
            ..Default::default()
        };
        let mut t = Trainer::new_native("micro", opts).unwrap();
        // micro: block 32, masks (2,4)/(4,2) → coarse grids (1,2)/(2,1)
        t.run(8).unwrap();
        assert!(t.controller().mean_sparsity() > 0.0, "nothing pruned");
        let fine = t.fine_masks();
        for (name, coarse) in t.masks() {
            let f = &fine[name];
            assert_eq!(f.rb, coarse.rb * 2);
            assert_eq!(f.cb, coarse.cb * 2);
            // every fine 2×2 group is uniform = the coarse bit
            for r in 0..f.rb {
                for c in 0..f.cb {
                    assert_eq!(f.get(r, c), coarse.get(r / 2, c / 2), "{name} ({r},{c})");
                }
            }
            // the fine mask slots straight into BCSC at the ABI block size
            let w = t.params().req(name);
            let bc = Bcsc::from_dense(w, f, t.config().block);
            assert_eq!(bc.nnzb(), f.nnzb());
            // pruned coarse blocks are zero in the dense master after the
            // controller's prune_weights application... only guaranteed for
            // *regrown-then-pruned* cycles; what must always hold is that
            // the masked weight reconstructs exactly:
            let mut masked = w.clone();
            f.apply_to(masked.data_mut(), t.config().block);
            assert!(bc.to_dense().allclose(&masked, 0.0), "{name}");
        }
    }
}
