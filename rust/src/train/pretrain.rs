//! LM pretraining orchestrator.
//!
//! One `Trainer` owns the host-side training state (params, Adam moments,
//! masks) and repeatedly executes one [`TrainBackend`] step — the
//! **native** packed-kernel backend by default
//! ([`Trainer::new_native`], no artifacts needed), or the AOT PJRT
//! executable ([`Trainer::new`], `pjrt` feature). Every `step_size`
//! iterations it feeds the returned MLP gradients to the prune-and-grow
//! controller, refreshes the block masks, and zeroes the regrown blocks in
//! the dense weights — the Rust realization of the paper's Listing 1.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::corpus::Corpus;
use crate::model::config::sim_config;
use crate::model::params::ParamStore;
use crate::runtime::{ConfigInfo, Runtime};
use crate::sparse::BlockMask;
use crate::sparsify::controller::{DensePolicy, PruneGrowConfig, PruneGrowController, WeightSpec};
use crate::sparsify::SparsitySchedule;
use crate::tensor::Tensor;
use crate::train::backend::{AotBackend, TrainBackend, TrainState};
use crate::train::native::NativeBackend;
use crate::util::faults::Faults;
use crate::util::json::Json;

/// Hyper-parameters of one pretraining run (Table 2's columns).
#[derive(Clone, Debug)]
pub struct PretrainOptions {
    pub total_iters: usize,
    pub s_init: f64,
    pub s_max: f64,
    /// Sparsity decay `d` (Table 6).
    pub decay: usize,
    /// Mask refresh interval (Table 5).
    pub step_size: usize,
    /// Dense layers kept on the right (`L` in Table 2 / Fig. 11).
    pub dense_right: usize,
    pub dense_left: usize,
    pub seed: u64,
    /// Corpus branching factor (entropy control).
    pub branching: usize,
    /// Effective sparse block = `block_mult × cfg.block` (Table 4's
    /// b ∈ {64, 128} points reuse the b=32 ABI via coarse grouping: the
    /// controller prunes on the coarse grid, masks are emitted fine).
    pub block_mult: usize,
}

impl Default for PretrainOptions {
    fn default() -> Self {
        PretrainOptions {
            total_iters: 200,
            s_init: 0.0,
            s_max: 0.8,
            decay: 0,
            step_size: 10,
            dense_right: 0,
            dense_left: 0,
            seed: 0xB1A57,
            branching: 8,
            block_mult: 1,
        }
    }
}

/// Parse the shared `--backend native|aot` CLI value and open the AOT
/// runtime when selected (`None` = native). Every surface that exposes
/// the flag — the binary, the experiment drivers, the benches, the
/// examples — goes through this one place, then hands the result to
/// [`Trainer::from_backend`], so the flag's semantics cannot drift.
pub fn open_backend_runtime(backend: &str) -> Result<Option<Runtime>> {
    match backend {
        "native" => Ok(None),
        "aot" => Ok(Some(Runtime::open_default()?)),
        other => bail!("--backend expects native|aot, got {other:?}"),
    }
}

/// Expand a coarse-grid mask to the fine ABI grid (each coarse block maps
/// to a `mult × mult` group of fine blocks).
pub fn expand_mask_grid(coarse: &BlockMask, mult: usize) -> BlockMask {
    if mult == 1 {
        return coarse.clone();
    }
    let mut fine = BlockMask::zeros(coarse.rb * mult, coarse.cb * mult);
    for r in 0..coarse.rb {
        for c in 0..coarse.cb {
            if coarse.get(r, c) {
                for i in 0..mult {
                    for j in 0..mult {
                        fine.set(r * mult + i, c * mult + j, true);
                    }
                }
            }
        }
    }
    fine
}

/// Per-iteration record (Fig. 8's series + Fig. 10's regrown ratio).
#[derive(Clone, Copy, Debug)]
pub struct IterLog {
    pub iter: usize,
    pub loss: f32,
    pub secs: f64,
    pub target_sparsity: f64,
    pub mean_mask_sparsity: f64,
    pub regrown_ratio: f64,
    /// Whether this iteration regenerated masks (the Fig. 8 spikes).
    pub mask_update: bool,
}

/// Backend-generic pretraining driver. `'rt` is the lifetime of the AOT
/// runtime when one is borrowed; native trainers are `Trainer<'static>`.
pub struct Trainer<'rt> {
    backend: Box<dyn TrainBackend + 'rt>,
    cfg: ConfigInfo,
    opts: PretrainOptions,
    state: TrainState,
    controller: PruneGrowController,
    corpus: Corpus,
    /// Iterations executed so far across the whole run — survives a
    /// checkpoint/resume round trip (unlike `log`, which is per-process
    /// diagnostics). [`Trainer::run`] continues from here.
    done_iters: usize,
    pub log: Vec<IterLog>,
}

/// A block mask as a `[rb, cb]` 0/1 tensor (checkpoint representation).
fn mask_to_tensor(m: &BlockMask) -> Tensor {
    let mut data = vec![0.0f32; m.rb * m.cb];
    for r in 0..m.rb {
        for c in 0..m.cb {
            if m.get(r, c) {
                data[r * m.cb + c] = 1.0;
            }
        }
    }
    Tensor::new(&[m.rb, m.cb], data)
}

fn tensor_to_mask(t: &Tensor) -> BlockMask {
    let (rb, cb) = (t.shape()[0], t.shape()[1]);
    let mut m = BlockMask::zeros(rb, cb);
    for r in 0..rb {
        for c in 0..cb {
            if t.data()[r * cb + c] != 0.0 {
                m.set(r, c, true);
            }
        }
    }
    m
}

/// Newest-first retention sweep over `ckpt-*.blst` in `dir` (zero-padded
/// iteration numbers make lexicographic order chronological). Only
/// checkpoints that pass [`ParamStore::quick_verify`] count toward
/// `keep` — an unrestorable file must never crowd a good one out of the
/// retention window, so under injected `ckpt_torn_write` storms the
/// directory always holds at least `keep` valid checkpoints (as long as
/// that many were ever written). Invalid `.blst` files and stale
/// `.blst.tmp` debris abandoned by torn writers are swept as junk, and
/// any deletion is followed by a best-effort directory fsync so the
/// prune is durable no later than the rename that triggered it.
fn prune_checkpoints(dir: &Path, keep: usize) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut valid: Vec<std::path::PathBuf> = Vec::new();
    let mut junk: Vec<std::path::PathBuf> = Vec::new();
    for p in rd.filter_map(|e| e.ok()).map(|e| e.path()) {
        let Some(name) = p.file_name().and_then(|s| s.to_str()) else {
            continue;
        };
        if name.starts_with("ckpt-") && name.ends_with(".blst.tmp") {
            junk.push(p);
        } else if name.starts_with("ckpt-") && name.ends_with(".blst") {
            match ParamStore::quick_verify(&p) {
                Ok(()) => valid.push(p),
                Err(_) => junk.push(p),
            }
        }
    }
    valid.sort();
    let mut removed = false;
    while valid.len() > keep.max(1) {
        std::fs::remove_file(valid.remove(0)).ok();
        removed = true;
    }
    for p in junk {
        std::fs::remove_file(&p).ok();
        removed = true;
    }
    if removed {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
}

impl<'rt> Trainer<'rt> {
    /// AOT-backed trainer over a manifest config (requires the `pjrt`
    /// feature + artifacts to have *opened* `rt`).
    pub fn new(rt: &'rt Runtime, config: &str, opts: PretrainOptions) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let params = ParamStore::init(&cfg, opts.seed);
        Trainer::with_params(rt, config, opts, params)
    }

    /// AOT-backed trainer from existing weights (fine-tuning /
    /// post-training compression).
    pub fn with_params(
        rt: &'rt Runtime,
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let backend = Box::new(AotBackend::new(rt, cfg.clone()));
        Trainer::with_backend(backend, cfg, opts, params)
    }

    /// Native-backed trainer over a built-in twin
    /// ([`crate::model::config::sim_config`]) — the default path: runs in
    /// every build, no artifacts needed.
    pub fn new_native(config: &str, opts: PretrainOptions) -> Result<Trainer<'static>> {
        let cfg = sim_config(config).ok_or_else(|| {
            anyhow!(
                "no built-in native config {config:?} (have: {:?}); \
                 use --backend aot for manifest-only configs",
                crate::model::config::SIM_CONFIGS
            )
        })?;
        let params = ParamStore::init(&cfg, opts.seed);
        Trainer::new_native_with_params(config, opts, params)
    }

    /// Native-backed trainer from existing weights.
    pub fn new_native_with_params(
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'static>> {
        let cfg = sim_config(config)
            .ok_or_else(|| anyhow!("no built-in native config {config:?}"))?;
        let backend = Box::new(NativeBackend::new(&cfg)?);
        Trainer::with_backend(backend, cfg, opts, params)
    }

    /// The shared `--backend native|aot` dispatch: `Some(rt)` selects the
    /// AOT executables, `None` the native backend. One place for the CLI
    /// convention the binary, the experiment drivers, the benches and the
    /// examples all share.
    pub fn from_backend(
        rt: Option<&'rt Runtime>,
        config: &str,
        opts: PretrainOptions,
    ) -> Result<Trainer<'rt>> {
        match rt {
            Some(rt) => Trainer::new(rt, config, opts),
            None => Trainer::new_native(config, opts),
        }
    }

    /// Assemble a trainer around any backend (the seam the tests and the
    /// A/B harness use directly).
    pub fn with_backend(
        backend: Box<dyn TrainBackend + 'rt>,
        cfg: ConfigInfo,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let mult = opts.block_mult.max(1);
        let specs: Vec<WeightSpec> = cfg
            .masks
            .iter()
            .map(|(name, shape)| {
                assert!(
                    shape[0] % mult == 0 && shape[1] % mult == 0,
                    "mask grid {shape:?} not divisible by block_mult {mult}"
                );
                WeightSpec {
                    name: name.clone(),
                    layer: ConfigInfo::layer_of(name).unwrap_or(0),
                    rb: shape[0] / mult,
                    cb: shape[1] / mult,
                }
            })
            .collect();
        let controller = PruneGrowController::new(
            PruneGrowConfig {
                block: cfg.block * mult,
                schedule: SparsitySchedule::new(
                    opts.s_init,
                    opts.s_max,
                    opts.total_iters,
                    opts.decay.min(opts.total_iters.saturating_sub(1)),
                ),
                step_size: opts.step_size,
                dense_policy: DensePolicy {
                    left: opts.dense_left,
                    right: opts.dense_right,
                },
                n_layers: cfg.layers,
            },
            specs,
        );
        let corpus = Corpus::new(cfg.vocab, opts.branching, opts.seed);
        Ok(Trainer {
            backend,
            cfg,
            opts,
            state: TrainState::new(params),
            controller,
            corpus,
            done_iters: 0,
            log: Vec::new(),
        })
    }

    pub fn params(&self) -> &ParamStore {
        &self.state.params
    }

    /// Full training state (params + Adam moments + step) — the resume
    /// tests compare it bit-for-bit against an uninterrupted run.
    pub fn state(&self) -> &TrainState {
        &self.state
    }

    /// Iterations executed so far (survives checkpoint/resume).
    pub fn done_iters(&self) -> usize {
        self.done_iters
    }

    pub fn masks(&self) -> &BTreeMap<String, BlockMask> {
        self.controller.masks()
    }

    pub fn controller(&self) -> &PruneGrowController {
        &self.controller
    }

    pub fn config(&self) -> &ConfigInfo {
        &self.cfg
    }

    /// Which backend executes the steps (`"native"` / `"aot"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Masks expanded from the controller's (possibly coarse) grid to the
    /// fine ABI grid every backend consumes.
    fn fine_masks(&self) -> BTreeMap<String, BlockMask> {
        let mult = self.opts.block_mult.max(1);
        self.cfg
            .masks
            .iter()
            .map(|(name, _)| {
                (
                    name.clone(),
                    expand_mask_grid(&self.controller.masks()[name], mult),
                )
            })
            .collect()
    }

    /// Execute one training iteration (Listing 1 body). Returns the loss.
    pub fn train_iteration(&mut self, iter: usize) -> Result<f32> {
        let t0 = Instant::now();
        let batch = self.corpus.batch(self.cfg.batch, self.cfg.seq);
        let fine = self.fine_masks();
        // prune-and-grow gate: only mask-update iterations need the MLP
        // gradient matrices shipped back
        let mask_update = self.controller.should_update(iter);
        let out = self
            .backend
            .train_step(&mut self.state, &fine, &batch, mask_update)?;
        let loss = out.loss;

        let mut regrown_ratio = 0.0;
        if mask_update {
            let mut weights = BTreeMap::new();
            for wname in &self.cfg.mlp_weights {
                weights.insert(wname.clone(), self.state.params.req(wname).clone());
            }
            let upd = self.controller.update(iter, &weights, &out.mlp_grads);
            regrown_ratio = upd.stats.regrown_ratio;
            // prune_weights(): zero newly-enabled blocks in the dense W
            for (name, to_zero) in &upd.regrown {
                let block = self.cfg.block * self.opts.block_mult.max(1);
                let w = self.state.params.get_mut(name).unwrap();
                let inverse = {
                    // apply_to zeroes *pruned* blocks, so invert: we want to
                    // zero exactly the to_zero set
                    let mut inv = BlockMask::ones(to_zero.rb, to_zero.cb);
                    for r in 0..to_zero.rb {
                        for c in 0..to_zero.cb {
                            if to_zero.get(r, c) {
                                inv.set(r, c, false);
                            }
                        }
                    }
                    inv
                };
                inverse.apply_to(w.data_mut(), block);
            }
        }

        self.log.push(IterLog {
            iter,
            loss,
            secs: t0.elapsed().as_secs_f64(),
            target_sparsity: self.controller.target_sparsity(iter),
            mean_mask_sparsity: self.controller.mean_sparsity(),
            regrown_ratio,
            mask_update,
        });
        self.done_iters = self.done_iters.max(iter + 1);
        Ok(loss)
    }

    /// Run `n` iterations continuing from [`Trainer::done_iters`] (0 for a
    /// fresh trainer, the checkpointed iteration after a resume).
    pub fn run(&mut self, n: usize) -> Result<()> {
        let start = self.done_iters;
        for i in start..start + n {
            let loss = self.train_iteration(i)?;
            if i % 20 == 0 || i + 1 == start + n {
                crate::log_info!(
                    "train",
                    "{} iter {i} loss {loss:.4} s={:.2}",
                    self.cfg.name,
                    self.controller.mean_sparsity()
                );
            }
        }
        Ok(())
    }

    /// Run `n` iterations with periodic crash-safe autosaves: every
    /// `every` iterations a checkpoint `ckpt-{iter:06}.blst` is written
    /// atomically into `dir`, retaining the newest `keep` files. A failed
    /// save (e.g. an injected `ckpt_torn_write`) is logged and training
    /// continues — the previous checkpoint on disk remains valid.
    pub fn run_with_autosave(
        &mut self,
        n: usize,
        dir: &Path,
        every: usize,
        keep: usize,
        faults: &Faults,
    ) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        let start = self.done_iters;
        for i in start..start + n {
            let loss = self.train_iteration(i)?;
            if i % 20 == 0 || i + 1 == start + n {
                crate::log_info!(
                    "train",
                    "{} iter {i} loss {loss:.4} s={:.2}",
                    self.cfg.name,
                    self.controller.mean_sparsity()
                );
            }
            if every > 0 && (i + 1) % every == 0 {
                let path = dir.join(format!("ckpt-{:06}.blst", i + 1));
                match self.save_checkpoint_faulted(&path, faults) {
                    // retention may only run once the new checkpoint is
                    // provably on disk and restorable: a save that claimed
                    // success but left an invalid file must not trigger
                    // deletion of the older good checkpoints
                    Ok(()) => match ParamStore::quick_verify(&path) {
                        Ok(()) => prune_checkpoints(dir, keep),
                        Err(e) => crate::log_warn!(
                            "train",
                            "autosave at iter {} is not restorable ({e}); \
                             retention sweep skipped",
                            i + 1
                        ),
                    },
                    Err(e) => crate::log_warn!(
                        "train",
                        "autosave at iter {} failed: {e}; continuing (previous checkpoint intact)",
                        i + 1
                    ),
                }
            }
        }
        Ok(())
    }

    /// Write a full training checkpoint: parameters, Adam moments, block
    /// masks and run metadata (config, iteration, step, hyper-parameters),
    /// atomically with per-tensor CRCs. [`Trainer::resume_from`] restores
    /// a run that continues bit-identically.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.save_checkpoint_faulted(path, &Faults::disabled())
    }

    /// [`Trainer::save_checkpoint`] with a fault plan threaded through to
    /// the writer (`ckpt_torn_write` chaos runs).
    pub fn save_checkpoint_faulted(&self, path: &Path, faults: &Faults) -> Result<()> {
        let mut store = ParamStore::new();
        for (n, t) in self.state.params.in_order() {
            store.insert(format!("param.{n}"), t.clone());
        }
        for (n, t) in self.state.adam_m.in_order() {
            store.insert(format!("adam_m.{n}"), t.clone());
        }
        for (n, t) in self.state.adam_v.in_order() {
            store.insert(format!("adam_v.{n}"), t.clone());
        }
        for (name, m) in self.controller.masks() {
            store.insert(format!("mask.{name}"), mask_to_tensor(m));
        }
        let o = &self.opts;
        let meta = Json::obj(vec![
            ("kind", Json::str("trainer")),
            ("config", Json::str(&self.cfg.name)),
            ("iter", Json::num(self.done_iters as f64)),
            ("step", Json::num(self.state.step as f64)),
            ("total_iters", Json::num(o.total_iters as f64)),
            ("s_init", Json::num(o.s_init)),
            ("s_max", Json::num(o.s_max)),
            ("decay", Json::num(o.decay as f64)),
            ("step_size", Json::num(o.step_size as f64)),
            ("dense_right", Json::num(o.dense_right as f64)),
            ("dense_left", Json::num(o.dense_left as f64)),
            // seeds are u64 — a string survives where f64 would round
            ("seed", Json::str(&o.seed.to_string())),
            ("branching", Json::num(o.branching as f64)),
            ("block_mult", Json::num(o.block_mult as f64)),
        ]);
        store.save_with_meta(path, &meta, faults)
    }

    /// Rebuild a native trainer from a [`Trainer::save_checkpoint`] file
    /// and continue **bit-identically**: parameters, Adam moments, step
    /// counter and masks are restored exactly, the hyper-parameters come
    /// from the checkpoint's metadata, and the corpus stream is
    /// fast-forwarded to the batch the interrupted run would consume next
    /// (the corpus is a pure function of seed + batches drawn).
    pub fn resume_from(path: &Path) -> Result<Trainer<'static>> {
        let (store, meta) = ParamStore::load_with_meta(path)?;
        if meta.str_or("kind", "") != "trainer" {
            bail!(
                "{path:?} is not a trainer checkpoint (weights-only files \
                 carry no optimizer/mask state to resume from)"
            );
        }
        let config = meta.str_or("config", "");
        let seed: u64 = meta
            .str_or("seed", "0")
            .parse()
            .map_err(|_| anyhow!("{path:?}: bad seed in checkpoint meta"))?;
        let opts = PretrainOptions {
            total_iters: meta.usize_or("total_iters", 200),
            s_init: meta.f64_or("s_init", 0.0),
            s_max: meta.f64_or("s_max", 0.8),
            decay: meta.usize_or("decay", 0),
            step_size: meta.usize_or("step_size", 10),
            dense_right: meta.usize_or("dense_right", 0),
            dense_left: meta.usize_or("dense_left", 0),
            seed,
            branching: meta.usize_or("branching", 8),
            block_mult: meta.usize_or("block_mult", 1),
        };
        let iter = meta.usize_or("iter", 0);
        let step = meta.usize_or("step", 0) as i32;
        let mut params = ParamStore::new();
        let mut adam_m = ParamStore::new();
        let mut adam_v = ParamStore::new();
        let mut masks: BTreeMap<String, BlockMask> = BTreeMap::new();
        for (n, t) in store.in_order() {
            if let Some(s) = n.strip_prefix("param.") {
                params.insert(s.to_string(), t.clone());
            } else if let Some(s) = n.strip_prefix("adam_m.") {
                adam_m.insert(s.to_string(), t.clone());
            } else if let Some(s) = n.strip_prefix("adam_v.") {
                adam_v.insert(s.to_string(), t.clone());
            } else if let Some(s) = n.strip_prefix("mask.") {
                masks.insert(s.to_string(), tensor_to_mask(t));
            }
        }
        let mut t = Trainer::new_native_with_params(&config, opts, params)?;
        t.state.adam_m = adam_m;
        t.state.adam_v = adam_v;
        t.state.step = step;
        t.controller.restore_masks(masks)?;
        for _ in 0..iter {
            t.corpus.batch(t.cfg.batch, t.cfg.seq);
        }
        t.done_iters = iter;
        Ok(t)
    }

    /// Held-out loss → perplexity over `n` fixed eval batches.
    pub fn eval_perplexity(&mut self, n: usize) -> Result<f64> {
        let batches = Corpus::eval_batches(
            self.cfg.vocab,
            self.opts.branching,
            self.opts.seed,
            n,
            self.cfg.batch,
            self.cfg.seq,
        );
        let fine = self.fine_masks();
        let mut total = 0.0f64;
        for b in &batches {
            total += self.backend.eval_loss(&self.state, &fine, b)? as f64;
        }
        Ok((total / n as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sparse::Bcsc;
    use crate::testkit::prop;

    #[test]
    fn expand_mask_grid_identity_at_mult_1() {
        let mut rng = crate::util::rng::Rng::new(1);
        let m = BlockMask::random(4, 6, 0.5, &mut rng);
        assert_eq!(expand_mask_grid(&m, 1), m);
    }

    #[test]
    fn expand_mask_grid_properties() {
        prop::check_default("expand-mask-grid", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let mult = *prop::pick(rng, &[2usize, 3, 4]);
            let coarse = BlockMask::random(rb, cb, rng.f64(), rng);
            let fine = expand_mask_grid(&coarse, mult);
            prop_assert!(
                fine.rb == rb * mult && fine.cb == cb * mult,
                "shape {}x{}",
                fine.rb,
                fine.cb
            );
            // kept count scales by mult²
            prop_assert!(
                fine.nnzb() == coarse.nnzb() * mult * mult,
                "nnzb {} vs {}",
                fine.nnzb(),
                coarse.nnzb() * mult * mult
            );
            // every fine block agrees with its coarse parent
            for r in 0..fine.rb {
                for c in 0..fine.cb {
                    prop_assert!(
                        fine.get(r, c) == coarse.get(r / mult, c / mult),
                        "mismatch at ({r},{c})"
                    );
                }
            }
            // sparsity is preserved exactly
            prop_assert!(
                (fine.sparsity() - coarse.sparsity()).abs() < 1e-12,
                "sparsity changed"
            );
            Ok(())
        });
    }

    #[test]
    fn expanded_mask_matches_elementwise_expansion() {
        // expand_mask_grid(m, mult).expand(b) == m.expand(b * mult)
        let mut rng = crate::util::rng::Rng::new(2);
        let coarse = BlockMask::random(3, 2, 0.4, &mut rng);
        let fine = expand_mask_grid(&coarse, 2);
        let a = fine.expand(4);
        let b = coarse.expand(8);
        assert!(a.allclose(&b, 0.0));
    }

    /// End-to-end native pretraining on the micro twin: loss falls, the
    /// schedule is realized in the masks, perplexity is finite and below
    /// the vocab bound. This is the default-build replacement for the AOT
    /// integration test that can only run with `pjrt` + artifacts.
    #[test]
    fn native_micro_training_reduces_loss_and_applies_sparsity() {
        let opts = PretrainOptions {
            total_iters: 20,
            s_max: 0.6,
            step_size: 5,
            ..Default::default()
        };
        let mut t = Trainer::new_native("micro", opts).unwrap();
        assert_eq!(t.backend_name(), "native");
        t.run(20).unwrap();
        let first = t.log[0].loss;
        let last = t.log.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(t.controller().mean_sparsity() > 0.3);
        let ppl = t.eval_perplexity(2).unwrap();
        assert!(ppl.is_finite() && ppl < 256.0, "ppl {ppl}");
    }

    /// The acceptance-gate run: a full native prune-grow run reproduces
    /// the controller's scheduled sparsity history — every mask-update
    /// iteration logs the cubic-schedule target, realized mask sparsity
    /// tracks it from below (regrowth slack only), and non-update
    /// iterations leave masks untouched.
    #[test]
    fn native_prune_grow_run_reproduces_scheduled_sparsity_history() {
        let opts = PretrainOptions {
            total_iters: 16,
            s_max: 0.7,
            step_size: 4,
            seed: 9,
            ..Default::default()
        };
        let sched = SparsitySchedule::new(0.0, 0.7, 16, 0);
        let mut t = Trainer::new_native("micro", opts).unwrap();
        t.run(16).unwrap();
        assert_eq!(t.log.len(), 16);
        let updates: Vec<usize> = t
            .log
            .iter()
            .filter(|l| l.mask_update)
            .map(|l| l.iter)
            .collect();
        assert_eq!(updates, vec![0, 4, 8, 12]);
        for l in &t.log {
            let want = sched.sparsity_at(l.iter);
            assert!(
                (l.target_sparsity - want).abs() < 1e-12,
                "iter {}: target {} vs schedule {}",
                l.iter,
                l.target_sparsity,
                want
            );
            // realized mask sparsity never exceeds the last update's target
            assert!(l.mean_mask_sparsity <= l.target_sparsity + 1e-9);
        }
        // controller history carries one entry per update, in order
        let hist = t.controller().history();
        assert_eq!(hist.len(), updates.len());
        for (h, &it) in hist.iter().zip(&updates) {
            assert_eq!(h.iteration, it);
            assert!((h.target_sparsity - sched.sparsity_at(it)).abs() < 1e-12);
            assert!(h.stats.realized_sparsity <= h.target_sparsity + 1e-9);
        }
        // masks between updates are frozen: the last two non-update iters
        // report the same mean sparsity
        let tail: Vec<f64> = t
            .log
            .iter()
            .rev()
            .take(3)
            .map(|l| l.mean_mask_sparsity)
            .collect();
        assert!((tail[0] - tail[1]).abs() < 1e-12);
    }

    /// Two ParamStores are bit-identical: same names in order, same
    /// shapes, same bytes (allclose with tolerance 0).
    fn assert_stores_identical(a: &ParamStore, b: &ParamStore, what: &str) {
        let av: Vec<_> = a.in_order().collect();
        let bv: Vec<_> = b.in_order().collect();
        assert_eq!(
            av.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            bv.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            "{what}: tensor name sets differ"
        );
        for ((n, ta), (_, tb)) in av.iter().zip(&bv) {
            assert!(ta.allclose(tb, 0.0), "{what}: tensor {n} differs");
        }
    }

    fn small_opts(seed: u64) -> PretrainOptions {
        PretrainOptions {
            total_iters: 12,
            s_max: 0.6,
            step_size: 3,
            seed,
            ..Default::default()
        }
    }

    /// The acceptance criterion for crash safety: kill at iteration 5,
    /// resume from the checkpoint, run to iteration 12 — parameters, Adam
    /// moments, step counter and masks are **bit-identical** to a run that
    /// was never interrupted.
    #[test]
    fn kill_resume_roundtrip_is_bit_identical() {
        let p = std::env::temp_dir().join("blast_test_resume.blst");
        let mut uninterrupted = Trainer::new_native("micro", small_opts(42)).unwrap();
        uninterrupted.run(12).unwrap();

        let mut killed = Trainer::new_native("micro", small_opts(42)).unwrap();
        killed.run(5).unwrap();
        killed.save_checkpoint(&p).unwrap();
        drop(killed); // the "crash"

        let mut resumed = Trainer::resume_from(&p).unwrap();
        assert_eq!(resumed.done_iters(), 5);
        resumed.run(7).unwrap();

        assert_eq!(resumed.done_iters(), uninterrupted.done_iters());
        assert_eq!(resumed.state().step, uninterrupted.state().step);
        assert_stores_identical(
            &resumed.state().params,
            &uninterrupted.state().params,
            "params",
        );
        assert_stores_identical(
            &resumed.state().adam_m,
            &uninterrupted.state().adam_m,
            "adam_m",
        );
        assert_stores_identical(
            &resumed.state().adam_v,
            &uninterrupted.state().adam_v,
            "adam_v",
        );
        assert_eq!(resumed.masks(), uninterrupted.masks());
        std::fs::remove_file(&p).ok();
    }

    /// Autosave writes `ckpt-NNNNNN.blst` every `every` iterations and the
    /// retention sweep keeps only the newest `keep`; resuming from the
    /// newest matches the live trainer exactly.
    #[test]
    fn autosave_retention_keeps_newest() {
        let dir = std::env::temp_dir().join("blast_test_autosave");
        std::fs::remove_dir_all(&dir).ok();
        let mut t = Trainer::new_native("micro", small_opts(7)).unwrap();
        t.run_with_autosave(8, &dir, 2, 2, &Faults::disabled()).unwrap();
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(names, vec!["ckpt-000006.blst", "ckpt-000008.blst"]);

        let resumed = Trainer::resume_from(&dir.join("ckpt-000008.blst")).unwrap();
        assert_eq!(resumed.done_iters(), 8);
        assert_eq!(resumed.state().step, t.state().step);
        assert_stores_identical(&resumed.state().params, &t.state().params, "params");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Torn-write chaos: with `ckpt_torn_write` firing ~50% of the time,
    /// training still completes, failed saves never clobber the previous
    /// checkpoint, and every `.blst` file that survives on disk loads
    /// cleanly (the torn `.tmp` siblings are the only debris).
    #[test]
    fn autosave_survives_injected_torn_writes() {
        let dir = std::env::temp_dir().join("blast_test_autosave_torn");
        std::fs::remove_dir_all(&dir).ok();
        let faults = Faults::parse("ckpt_torn_write:0.5:99").unwrap();
        let mut t = Trainer::new_native("micro", small_opts(11)).unwrap();
        t.run_with_autosave(10, &dir, 2, 3, &faults).unwrap();
        assert_eq!(t.done_iters(), 10, "training must complete despite torn saves");
        let mut loaded = 0usize;
        for e in std::fs::read_dir(&dir).unwrap() {
            let p = e.unwrap().path();
            if p.extension().is_some_and(|x| x == "blst") {
                Trainer::resume_from(&p)
                    .unwrap_or_else(|e| panic!("{p:?} failed to load: {e}"));
                loaded += 1;
            }
        }
        // with prob 0.5 over 5 save points, at least one save succeeds for
        // this fixed seed (deterministic — the plan's RNG stream is seeded)
        assert!(loaded > 0, "no checkpoint survived");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Retention ordering under `ckpt_torn_write`: the sweep runs only
    /// after a new checkpoint is fully on disk and verifiable, counts
    /// only restorable files toward `keep`, and treats invalid `.blst`
    /// files and torn `.tmp` debris as junk — so no torn-write storm can
    /// ever leave the directory with fewer than `keep` valid checkpoints
    /// once `keep` saves have succeeded.
    #[test]
    fn torn_writes_never_shrink_the_valid_retention_window() {
        let dir = std::env::temp_dir().join("blast_test_autosave_keep");
        std::fs::remove_dir_all(&dir).ok();
        let keep = 2usize;
        let valid_names = |dir: &Path| -> Vec<String> {
            let mut v: Vec<String> = std::fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|s| s.to_str())
                        .is_some_and(|s| s.ends_with(".blst"))
                        && ParamStore::quick_verify(p).is_ok()
                })
                .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
                .collect();
            v.sort();
            v
        };
        let mut t = Trainer::new_native("micro", small_opts(13)).unwrap();
        // phase 1: clean saves establish a full retention window
        t.run_with_autosave(6, &dir, 2, keep, &Faults::disabled()).unwrap();
        assert_eq!(valid_names(&dir), vec!["ckpt-000004.blst", "ckpt-000006.blst"]);
        // phase 2: every save torn — the window must not shrink
        let torn = Faults::parse("ckpt_torn_write:1:3").unwrap();
        t.run_with_autosave(6, &dir, 2, keep, &torn).unwrap();
        let survivors = valid_names(&dir);
        assert_eq!(
            survivors,
            vec!["ckpt-000004.blst", "ckpt-000006.blst"],
            "a failed save must never cost a valid checkpoint"
        );
        // phase 3: a clean save advances the window and sweeps the torn
        // .tmp debris phase 2 left behind
        t.run_with_autosave(2, &dir, 2, keep, &Faults::disabled()).unwrap();
        assert_eq!(valid_names(&dir), vec!["ckpt-000006.blst", "ckpt-000014.blst"]);
        assert!(
            std::fs::read_dir(&dir).unwrap().all(|e| {
                !e.unwrap().file_name().to_string_lossy().ends_with(".tmp")
            }),
            "torn .tmp debris must be swept by the next successful prune"
        );
        // phase 4: a garbage .blst that sorts newest must not crowd a
        // valid checkpoint out of the window — it is junk, not retention
        std::fs::write(dir.join("ckpt-999998.blst"), b"NOT A CHECKPOINT").unwrap();
        t.run_with_autosave(2, &dir, 2, keep, &Faults::disabled()).unwrap();
        let mut all: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        all.sort();
        assert_eq!(all, vec!["ckpt-000014.blst", "ckpt-000016.blst"]);
        // the newest survivor actually restores
        Trainer::resume_from(&dir.join("ckpt-000016.blst")).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The controller × expand_mask_grid seam at `block_mult > 1`: the
    /// coarse controller grid expands to a fine mask whose effective
    /// block structure matches the coarse block size, stays consistent
    /// with what the native backend consumes (BCSC at the fine block), and
    /// the regrown-block zeroing lands on whole coarse blocks.
    #[test]
    fn controller_and_expand_mask_grid_compose_at_block_mult_2() {
        let opts = PretrainOptions {
            total_iters: 8,
            s_max: 0.6,
            step_size: 2,
            block_mult: 2,
            seed: 3,
            ..Default::default()
        };
        let mut t = Trainer::new_native("micro", opts).unwrap();
        // micro: block 32, masks (2,4)/(4,2) → coarse grids (1,2)/(2,1)
        t.run(8).unwrap();
        assert!(t.controller().mean_sparsity() > 0.0, "nothing pruned");
        let fine = t.fine_masks();
        for (name, coarse) in t.masks() {
            let f = &fine[name];
            assert_eq!(f.rb, coarse.rb * 2);
            assert_eq!(f.cb, coarse.cb * 2);
            // every fine 2×2 group is uniform = the coarse bit
            for r in 0..f.rb {
                for c in 0..f.cb {
                    assert_eq!(f.get(r, c), coarse.get(r / 2, c / 2), "{name} ({r},{c})");
                }
            }
            // the fine mask slots straight into BCSC at the ABI block size
            let w = t.params().req(name);
            let bc = Bcsc::from_dense(w, f, t.config().block);
            assert_eq!(bc.nnzb(), f.nnzb());
            // pruned coarse blocks are zero in the dense master after the
            // controller's prune_weights application... only guaranteed for
            // *regrown-then-pruned* cycles; what must always hold is that
            // the masked weight reconstructs exactly:
            let mut masked = w.clone();
            f.apply_to(masked.data_mut(), t.config().block);
            assert!(bc.to_dense().allclose(&masked, 0.0), "{name}");
        }
    }
}
