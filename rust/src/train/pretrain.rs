//! LM pretraining orchestrator.
//!
//! One `Trainer` owns the host-side training state (params, Adam moments,
//! masks) and repeatedly executes one [`TrainBackend`] step — the
//! **native** packed-kernel backend by default
//! ([`Trainer::new_native`], no artifacts needed), or the AOT PJRT
//! executable ([`Trainer::new`], `pjrt` feature). Every `step_size`
//! iterations it feeds the returned MLP gradients to the prune-and-grow
//! controller, refreshes the block masks, and zeroes the regrown blocks in
//! the dense weights — the Rust realization of the paper's Listing 1.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::data::corpus::Corpus;
use crate::model::config::sim_config;
use crate::model::params::ParamStore;
use crate::runtime::{ConfigInfo, Runtime};
use crate::sparse::BlockMask;
use crate::sparsify::controller::{DensePolicy, PruneGrowConfig, PruneGrowController, WeightSpec};
use crate::sparsify::SparsitySchedule;
use crate::train::backend::{AotBackend, TrainBackend, TrainState};
use crate::train::native::NativeBackend;

/// Hyper-parameters of one pretraining run (Table 2's columns).
#[derive(Clone, Debug)]
pub struct PretrainOptions {
    pub total_iters: usize,
    pub s_init: f64,
    pub s_max: f64,
    /// Sparsity decay `d` (Table 6).
    pub decay: usize,
    /// Mask refresh interval (Table 5).
    pub step_size: usize,
    /// Dense layers kept on the right (`L` in Table 2 / Fig. 11).
    pub dense_right: usize,
    pub dense_left: usize,
    pub seed: u64,
    /// Corpus branching factor (entropy control).
    pub branching: usize,
    /// Effective sparse block = `block_mult × cfg.block` (Table 4's
    /// b ∈ {64, 128} points reuse the b=32 ABI via coarse grouping: the
    /// controller prunes on the coarse grid, masks are emitted fine).
    pub block_mult: usize,
}

impl Default for PretrainOptions {
    fn default() -> Self {
        PretrainOptions {
            total_iters: 200,
            s_init: 0.0,
            s_max: 0.8,
            decay: 0,
            step_size: 10,
            dense_right: 0,
            dense_left: 0,
            seed: 0xB1A57,
            branching: 8,
            block_mult: 1,
        }
    }
}

/// Parse the shared `--backend native|aot` CLI value and open the AOT
/// runtime when selected (`None` = native). Every surface that exposes
/// the flag — the binary, the experiment drivers, the benches, the
/// examples — goes through this one place, then hands the result to
/// [`Trainer::from_backend`], so the flag's semantics cannot drift.
pub fn open_backend_runtime(backend: &str) -> Result<Option<Runtime>> {
    match backend {
        "native" => Ok(None),
        "aot" => Ok(Some(Runtime::open_default()?)),
        other => bail!("--backend expects native|aot, got {other:?}"),
    }
}

/// Expand a coarse-grid mask to the fine ABI grid (each coarse block maps
/// to a `mult × mult` group of fine blocks).
pub fn expand_mask_grid(coarse: &BlockMask, mult: usize) -> BlockMask {
    if mult == 1 {
        return coarse.clone();
    }
    let mut fine = BlockMask::zeros(coarse.rb * mult, coarse.cb * mult);
    for r in 0..coarse.rb {
        for c in 0..coarse.cb {
            if coarse.get(r, c) {
                for i in 0..mult {
                    for j in 0..mult {
                        fine.set(r * mult + i, c * mult + j, true);
                    }
                }
            }
        }
    }
    fine
}

/// Per-iteration record (Fig. 8's series + Fig. 10's regrown ratio).
#[derive(Clone, Copy, Debug)]
pub struct IterLog {
    pub iter: usize,
    pub loss: f32,
    pub secs: f64,
    pub target_sparsity: f64,
    pub mean_mask_sparsity: f64,
    pub regrown_ratio: f64,
    /// Whether this iteration regenerated masks (the Fig. 8 spikes).
    pub mask_update: bool,
}

/// Backend-generic pretraining driver. `'rt` is the lifetime of the AOT
/// runtime when one is borrowed; native trainers are `Trainer<'static>`.
pub struct Trainer<'rt> {
    backend: Box<dyn TrainBackend + 'rt>,
    cfg: ConfigInfo,
    opts: PretrainOptions,
    state: TrainState,
    controller: PruneGrowController,
    corpus: Corpus,
    pub log: Vec<IterLog>,
}

impl<'rt> Trainer<'rt> {
    /// AOT-backed trainer over a manifest config (requires the `pjrt`
    /// feature + artifacts to have *opened* `rt`).
    pub fn new(rt: &'rt Runtime, config: &str, opts: PretrainOptions) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let params = ParamStore::init(&cfg, opts.seed);
        Trainer::with_params(rt, config, opts, params)
    }

    /// AOT-backed trainer from existing weights (fine-tuning /
    /// post-training compression).
    pub fn with_params(
        rt: &'rt Runtime,
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let backend = Box::new(AotBackend::new(rt, cfg.clone()));
        Trainer::with_backend(backend, cfg, opts, params)
    }

    /// Native-backed trainer over a built-in twin
    /// ([`crate::model::config::sim_config`]) — the default path: runs in
    /// every build, no artifacts needed.
    pub fn new_native(config: &str, opts: PretrainOptions) -> Result<Trainer<'static>> {
        let cfg = sim_config(config).ok_or_else(|| {
            anyhow!(
                "no built-in native config {config:?} (have: {:?}); \
                 use --backend aot for manifest-only configs",
                crate::model::config::SIM_CONFIGS
            )
        })?;
        let params = ParamStore::init(&cfg, opts.seed);
        Trainer::new_native_with_params(config, opts, params)
    }

    /// Native-backed trainer from existing weights.
    pub fn new_native_with_params(
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'static>> {
        let cfg = sim_config(config)
            .ok_or_else(|| anyhow!("no built-in native config {config:?}"))?;
        let backend = Box::new(NativeBackend::new(&cfg)?);
        Trainer::with_backend(backend, cfg, opts, params)
    }

    /// The shared `--backend native|aot` dispatch: `Some(rt)` selects the
    /// AOT executables, `None` the native backend. One place for the CLI
    /// convention the binary, the experiment drivers, the benches and the
    /// examples all share.
    pub fn from_backend(
        rt: Option<&'rt Runtime>,
        config: &str,
        opts: PretrainOptions,
    ) -> Result<Trainer<'rt>> {
        match rt {
            Some(rt) => Trainer::new(rt, config, opts),
            None => Trainer::new_native(config, opts),
        }
    }

    /// Assemble a trainer around any backend (the seam the tests and the
    /// A/B harness use directly).
    pub fn with_backend(
        backend: Box<dyn TrainBackend + 'rt>,
        cfg: ConfigInfo,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let mult = opts.block_mult.max(1);
        let specs: Vec<WeightSpec> = cfg
            .masks
            .iter()
            .map(|(name, shape)| {
                assert!(
                    shape[0] % mult == 0 && shape[1] % mult == 0,
                    "mask grid {shape:?} not divisible by block_mult {mult}"
                );
                WeightSpec {
                    name: name.clone(),
                    layer: ConfigInfo::layer_of(name).unwrap_or(0),
                    rb: shape[0] / mult,
                    cb: shape[1] / mult,
                }
            })
            .collect();
        let controller = PruneGrowController::new(
            PruneGrowConfig {
                block: cfg.block * mult,
                schedule: SparsitySchedule::new(
                    opts.s_init,
                    opts.s_max,
                    opts.total_iters,
                    opts.decay.min(opts.total_iters.saturating_sub(1)),
                ),
                step_size: opts.step_size,
                dense_policy: DensePolicy {
                    left: opts.dense_left,
                    right: opts.dense_right,
                },
                n_layers: cfg.layers,
            },
            specs,
        );
        let corpus = Corpus::new(cfg.vocab, opts.branching, opts.seed);
        Ok(Trainer {
            backend,
            cfg,
            opts,
            state: TrainState::new(params),
            controller,
            corpus,
            log: Vec::new(),
        })
    }

    pub fn params(&self) -> &ParamStore {
        &self.state.params
    }

    pub fn masks(&self) -> &BTreeMap<String, BlockMask> {
        self.controller.masks()
    }

    pub fn controller(&self) -> &PruneGrowController {
        &self.controller
    }

    pub fn config(&self) -> &ConfigInfo {
        &self.cfg
    }

    /// Which backend executes the steps (`"native"` / `"aot"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Masks expanded from the controller's (possibly coarse) grid to the
    /// fine ABI grid every backend consumes.
    fn fine_masks(&self) -> BTreeMap<String, BlockMask> {
        let mult = self.opts.block_mult.max(1);
        self.cfg
            .masks
            .iter()
            .map(|(name, _)| {
                (
                    name.clone(),
                    expand_mask_grid(&self.controller.masks()[name], mult),
                )
            })
            .collect()
    }

    /// Execute one training iteration (Listing 1 body). Returns the loss.
    pub fn train_iteration(&mut self, iter: usize) -> Result<f32> {
        let t0 = Instant::now();
        let batch = self.corpus.batch(self.cfg.batch, self.cfg.seq);
        let fine = self.fine_masks();
        // prune-and-grow gate: only mask-update iterations need the MLP
        // gradient matrices shipped back
        let mask_update = self.controller.should_update(iter);
        let out = self
            .backend
            .train_step(&mut self.state, &fine, &batch, mask_update)?;
        let loss = out.loss;

        let mut regrown_ratio = 0.0;
        if mask_update {
            let mut weights = BTreeMap::new();
            for wname in &self.cfg.mlp_weights {
                weights.insert(wname.clone(), self.state.params.req(wname).clone());
            }
            let upd = self.controller.update(iter, &weights, &out.mlp_grads);
            regrown_ratio = upd.stats.regrown_ratio;
            // prune_weights(): zero newly-enabled blocks in the dense W
            for (name, to_zero) in &upd.regrown {
                let block = self.cfg.block * self.opts.block_mult.max(1);
                let w = self.state.params.get_mut(name).unwrap();
                let inverse = {
                    // apply_to zeroes *pruned* blocks, so invert: we want to
                    // zero exactly the to_zero set
                    let mut inv = BlockMask::ones(to_zero.rb, to_zero.cb);
                    for r in 0..to_zero.rb {
                        for c in 0..to_zero.cb {
                            if to_zero.get(r, c) {
                                inv.set(r, c, false);
                            }
                        }
                    }
                    inv
                };
                inverse.apply_to(w.data_mut(), block);
            }
        }

        self.log.push(IterLog {
            iter,
            loss,
            secs: t0.elapsed().as_secs_f64(),
            target_sparsity: self.controller.target_sparsity(iter),
            mean_mask_sparsity: self.controller.mean_sparsity(),
            regrown_ratio,
            mask_update,
        });
        Ok(loss)
    }

    /// Run `n` iterations starting at the current log length.
    pub fn run(&mut self, n: usize) -> Result<()> {
        let start = self.log.len();
        for i in start..start + n {
            let loss = self.train_iteration(i)?;
            if i % 20 == 0 || i + 1 == start + n {
                crate::log_info!(
                    "train",
                    "{} iter {i} loss {loss:.4} s={:.2}",
                    self.cfg.name,
                    self.controller.mean_sparsity()
                );
            }
        }
        Ok(())
    }

    /// Held-out loss → perplexity over `n` fixed eval batches.
    pub fn eval_perplexity(&mut self, n: usize) -> Result<f64> {
        let batches = Corpus::eval_batches(
            self.cfg.vocab,
            self.opts.branching,
            self.opts.seed,
            n,
            self.cfg.batch,
            self.cfg.seq,
        );
        let fine = self.fine_masks();
        let mut total = 0.0f64;
        for b in &batches {
            total += self.backend.eval_loss(&self.state, &fine, b)? as f64;
        }
        Ok((total / n as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sparse::Bcsc;
    use crate::testkit::prop;

    #[test]
    fn expand_mask_grid_identity_at_mult_1() {
        let mut rng = crate::util::rng::Rng::new(1);
        let m = BlockMask::random(4, 6, 0.5, &mut rng);
        assert_eq!(expand_mask_grid(&m, 1), m);
    }

    #[test]
    fn expand_mask_grid_properties() {
        prop::check_default("expand-mask-grid", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let mult = *prop::pick(rng, &[2usize, 3, 4]);
            let coarse = BlockMask::random(rb, cb, rng.f64(), rng);
            let fine = expand_mask_grid(&coarse, mult);
            prop_assert!(
                fine.rb == rb * mult && fine.cb == cb * mult,
                "shape {}x{}",
                fine.rb,
                fine.cb
            );
            // kept count scales by mult²
            prop_assert!(
                fine.nnzb() == coarse.nnzb() * mult * mult,
                "nnzb {} vs {}",
                fine.nnzb(),
                coarse.nnzb() * mult * mult
            );
            // every fine block agrees with its coarse parent
            for r in 0..fine.rb {
                for c in 0..fine.cb {
                    prop_assert!(
                        fine.get(r, c) == coarse.get(r / mult, c / mult),
                        "mismatch at ({r},{c})"
                    );
                }
            }
            // sparsity is preserved exactly
            prop_assert!(
                (fine.sparsity() - coarse.sparsity()).abs() < 1e-12,
                "sparsity changed"
            );
            Ok(())
        });
    }

    #[test]
    fn expanded_mask_matches_elementwise_expansion() {
        // expand_mask_grid(m, mult).expand(b) == m.expand(b * mult)
        let mut rng = crate::util::rng::Rng::new(2);
        let coarse = BlockMask::random(3, 2, 0.4, &mut rng);
        let fine = expand_mask_grid(&coarse, 2);
        let a = fine.expand(4);
        let b = coarse.expand(8);
        assert!(a.allclose(&b, 0.0));
    }

    /// End-to-end native pretraining on the micro twin: loss falls, the
    /// schedule is realized in the masks, perplexity is finite and below
    /// the vocab bound. This is the default-build replacement for the AOT
    /// integration test that can only run with `pjrt` + artifacts.
    #[test]
    fn native_micro_training_reduces_loss_and_applies_sparsity() {
        let opts = PretrainOptions {
            total_iters: 20,
            s_max: 0.6,
            step_size: 5,
            ..Default::default()
        };
        let mut t = Trainer::new_native("micro", opts).unwrap();
        assert_eq!(t.backend_name(), "native");
        t.run(20).unwrap();
        let first = t.log[0].loss;
        let last = t.log.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(t.controller().mean_sparsity() > 0.3);
        let ppl = t.eval_perplexity(2).unwrap();
        assert!(ppl.is_finite() && ppl < 256.0, "ppl {ppl}");
    }

    /// The acceptance-gate run: a full native prune-grow run reproduces
    /// the controller's scheduled sparsity history — every mask-update
    /// iteration logs the cubic-schedule target, realized mask sparsity
    /// tracks it from below (regrowth slack only), and non-update
    /// iterations leave masks untouched.
    #[test]
    fn native_prune_grow_run_reproduces_scheduled_sparsity_history() {
        let opts = PretrainOptions {
            total_iters: 16,
            s_max: 0.7,
            step_size: 4,
            seed: 9,
            ..Default::default()
        };
        let sched = SparsitySchedule::new(0.0, 0.7, 16, 0);
        let mut t = Trainer::new_native("micro", opts).unwrap();
        t.run(16).unwrap();
        assert_eq!(t.log.len(), 16);
        let updates: Vec<usize> = t
            .log
            .iter()
            .filter(|l| l.mask_update)
            .map(|l| l.iter)
            .collect();
        assert_eq!(updates, vec![0, 4, 8, 12]);
        for l in &t.log {
            let want = sched.sparsity_at(l.iter);
            assert!(
                (l.target_sparsity - want).abs() < 1e-12,
                "iter {}: target {} vs schedule {}",
                l.iter,
                l.target_sparsity,
                want
            );
            // realized mask sparsity never exceeds the last update's target
            assert!(l.mean_mask_sparsity <= l.target_sparsity + 1e-9);
        }
        // controller history carries one entry per update, in order
        let hist = t.controller().history();
        assert_eq!(hist.len(), updates.len());
        for (h, &it) in hist.iter().zip(&updates) {
            assert_eq!(h.iteration, it);
            assert!((h.target_sparsity - sched.sparsity_at(it)).abs() < 1e-12);
            assert!(h.stats.realized_sparsity <= h.target_sparsity + 1e-9);
        }
        // masks between updates are frozen: the last two non-update iters
        // report the same mean sparsity
        let tail: Vec<f64> = t
            .log
            .iter()
            .rev()
            .take(3)
            .map(|l| l.mean_mask_sparsity)
            .collect();
        assert!((tail[0] - tail[1]).abs() < 1e-12);
    }

    /// The controller × expand_mask_grid seam at `block_mult > 1`: the
    /// coarse controller grid expands to a fine mask whose effective
    /// block structure matches the coarse block size, stays consistent
    /// with what the native backend consumes (BCSC at the fine block), and
    /// the regrown-block zeroing lands on whole coarse blocks.
    #[test]
    fn controller_and_expand_mask_grid_compose_at_block_mult_2() {
        let opts = PretrainOptions {
            total_iters: 8,
            s_max: 0.6,
            step_size: 2,
            block_mult: 2,
            seed: 3,
            ..Default::default()
        };
        let mut t = Trainer::new_native("micro", opts).unwrap();
        // micro: block 32, masks (2,4)/(4,2) → coarse grids (1,2)/(2,1)
        t.run(8).unwrap();
        assert!(t.controller().mean_sparsity() > 0.0, "nothing pruned");
        let fine = t.fine_masks();
        for (name, coarse) in t.masks() {
            let f = &fine[name];
            assert_eq!(f.rb, coarse.rb * 2);
            assert_eq!(f.cb, coarse.cb * 2);
            // every fine 2×2 group is uniform = the coarse bit
            for r in 0..f.rb {
                for c in 0..f.cb {
                    assert_eq!(f.get(r, c), coarse.get(r / 2, c / 2), "{name} ({r},{c})");
                }
            }
            // the fine mask slots straight into BCSC at the ABI block size
            let w = t.params().req(name);
            let bc = Bcsc::from_dense(w, f, t.config().block);
            assert_eq!(bc.nnzb(), f.nnzb());
            // pruned coarse blocks are zero in the dense master after the
            // controller's prune_weights application... only guaranteed for
            // *regrown-then-pruned* cycles; what must always hold is that
            // the masked weight reconstructs exactly:
            let mut masked = w.clone();
            f.apply_to(masked.data_mut(), t.config().block);
            assert!(bc.to_dense().allclose(&masked, 0.0), "{name}");
        }
    }
}
