//! LM pretraining orchestrator.
//!
//! One `Trainer` owns the host-side training state (params, Adam moments,
//! masks) and repeatedly executes the AOT `train_step` entry. Every
//! `step_size` iterations it feeds the returned MLP gradients to the
//! prune-and-grow controller, refreshes the block masks, and zeroes the
//! regrown blocks in the dense weights — the Rust realization of the
//! paper's Listing 1.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::corpus::{Corpus, LmBatch};
use crate::model::params::ParamStore;
use crate::runtime::{ConfigInfo, HostValue, Runtime};
use crate::sparse::BlockMask;
use crate::sparsify::controller::{DensePolicy, PruneGrowConfig, PruneGrowController, WeightSpec};
use crate::sparsify::SparsitySchedule;
use crate::tensor::Tensor;

/// Hyper-parameters of one pretraining run (Table 2's columns).
#[derive(Clone, Debug)]
pub struct PretrainOptions {
    pub total_iters: usize,
    pub s_init: f64,
    pub s_max: f64,
    /// Sparsity decay `d` (Table 6).
    pub decay: usize,
    /// Mask refresh interval (Table 5).
    pub step_size: usize,
    /// Dense layers kept on the right (`L` in Table 2 / Fig. 11).
    pub dense_right: usize,
    pub dense_left: usize,
    pub seed: u64,
    /// Corpus branching factor (entropy control).
    pub branching: usize,
    /// Effective sparse block = `block_mult × cfg.block` (Table 4's
    /// b ∈ {64, 128} points reuse the b=32 ABI via coarse grouping: the
    /// controller prunes on the coarse grid, masks are emitted fine).
    pub block_mult: usize,
}

impl Default for PretrainOptions {
    fn default() -> Self {
        PretrainOptions {
            total_iters: 200,
            s_init: 0.0,
            s_max: 0.8,
            decay: 0,
            step_size: 10,
            dense_right: 0,
            dense_left: 0,
            seed: 0xB1A57,
            branching: 8,
            block_mult: 1,
        }
    }
}

/// Expand a coarse-grid mask to the fine ABI grid (each coarse block maps
/// to a `mult × mult` group of fine blocks).
pub fn expand_mask_grid(coarse: &BlockMask, mult: usize) -> BlockMask {
    if mult == 1 {
        return coarse.clone();
    }
    let mut fine = BlockMask::zeros(coarse.rb * mult, coarse.cb * mult);
    for r in 0..coarse.rb {
        for c in 0..coarse.cb {
            if coarse.get(r, c) {
                for i in 0..mult {
                    for j in 0..mult {
                        fine.set(r * mult + i, c * mult + j, true);
                    }
                }
            }
        }
    }
    fine
}

/// Per-iteration record (Fig. 8's series + Fig. 10's regrown ratio).
#[derive(Clone, Copy, Debug)]
pub struct IterLog {
    pub iter: usize,
    pub loss: f32,
    pub secs: f64,
    pub target_sparsity: f64,
    pub mean_mask_sparsity: f64,
    pub regrown_ratio: f64,
    /// Whether this iteration regenerated masks (the Fig. 8 spikes).
    pub mask_update: bool,
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    cfg: ConfigInfo,
    opts: PretrainOptions,
    params: ParamStore,
    adam_m: ParamStore,
    adam_v: ParamStore,
    step: i32,
    controller: PruneGrowController,
    corpus: Corpus,
    pub log: Vec<IterLog>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str, opts: PretrainOptions) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let params = ParamStore::init(&cfg, opts.seed);
        Self::with_params(rt, config, opts, params)
    }

    /// Start from existing weights (fine-tuning / post-training compression).
    pub fn with_params(
        rt: &'rt Runtime,
        config: &str,
        opts: PretrainOptions,
        params: ParamStore,
    ) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest().config(config)?.clone();
        let mut adam_m = ParamStore::new();
        let mut adam_v = ParamStore::new();
        for (name, t) in params.in_order() {
            adam_m.insert(name.clone(), Tensor::zeros(t.shape()));
            adam_v.insert(name.clone(), Tensor::zeros(t.shape()));
        }
        let mult = opts.block_mult.max(1);
        let specs: Vec<WeightSpec> = cfg
            .masks
            .iter()
            .map(|(name, shape)| {
                assert!(
                    shape[0] % mult == 0 && shape[1] % mult == 0,
                    "mask grid {shape:?} not divisible by block_mult {mult}"
                );
                WeightSpec {
                    name: name.clone(),
                    layer: ConfigInfo::layer_of(name).unwrap_or(0),
                    rb: shape[0] / mult,
                    cb: shape[1] / mult,
                }
            })
            .collect();
        let controller = PruneGrowController::new(
            PruneGrowConfig {
                block: cfg.block * mult,
                schedule: SparsitySchedule::new(
                    opts.s_init,
                    opts.s_max,
                    opts.total_iters,
                    opts.decay.min(opts.total_iters.saturating_sub(1)),
                ),
                step_size: opts.step_size,
                dense_policy: DensePolicy {
                    left: opts.dense_left,
                    right: opts.dense_right,
                },
                n_layers: cfg.layers,
            },
            specs,
        );
        let corpus = Corpus::new(cfg.vocab, opts.branching, opts.seed);
        Ok(Trainer {
            rt,
            cfg,
            opts,
            params,
            adam_m,
            adam_v,
            step: 0,
            controller,
            corpus,
            log: Vec::new(),
        })
    }

    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    pub fn masks(&self) -> &BTreeMap<String, BlockMask> {
        self.controller.masks()
    }

    pub fn controller(&self) -> &PruneGrowController {
        &self.controller
    }

    pub fn config(&self) -> &ConfigInfo {
        &self.cfg
    }

    fn train_entry(&self) -> String {
        format!("{}_train_step", self.cfg.name)
    }

    fn eval_entry(&self) -> String {
        format!("{}_eval_loss", self.cfg.name)
    }

    /// Assemble the flat positional input list for `train_step`.
    fn build_inputs(&self, batch: &LmBatch) -> Vec<HostValue> {
        let mut inputs = Vec::with_capacity(3 * self.params.len() + self.cfg.masks.len() + 3);
        for (_, t) in self.params.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        for (_, t) in self.adam_m.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        for (_, t) in self.adam_v.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        inputs.push(HostValue::scalar_i32(self.step));
        let mult = self.opts.block_mult.max(1);
        for (name, _) in &self.cfg.masks {
            let fine = expand_mask_grid(&self.controller.masks()[name], mult);
            inputs.push(HostValue::tensor(fine.to_tensor()));
        }
        inputs.push(HostValue::i32s(
            &[batch.batch, batch.seq],
            batch.tokens.clone(),
        ));
        inputs.push(HostValue::i32s(
            &[batch.batch, batch.seq],
            batch.targets.clone(),
        ));
        inputs
    }

    /// Execute one training iteration (Listing 1 body). Returns the loss.
    pub fn train_iteration(&mut self, iter: usize) -> Result<f32> {
        let t0 = Instant::now();
        let batch = self.corpus.batch(self.cfg.batch, self.cfg.seq);
        let inputs = self.build_inputs(&batch);
        let entry = self.train_entry();
        let out = self.rt.execute(&entry, &inputs)?;

        // unpack: P params, P m, P v, step, loss, G grads
        let p = self.params.len();
        let names: Vec<String> = self.params.names().to_vec();
        for (i, name) in names.iter().enumerate() {
            self.params
                .insert(name.clone(), out[i].clone().into_tensor()?);
            self.adam_m
                .insert(name.clone(), out[p + i].clone().into_tensor()?);
            self.adam_v
                .insert(name.clone(), out[2 * p + i].clone().into_tensor()?);
        }
        self.step = out[3 * p].as_i32().context("step")?[0];
        let loss = out[3 * p + 1].scalar()?;

        // prune-and-grow gate
        let mask_update = self.controller.should_update(iter);
        let mut regrown_ratio = 0.0;
        if mask_update {
            let mut weights = BTreeMap::new();
            let mut grads = BTreeMap::new();
            for (gi, wname) in self.cfg.mlp_weights.iter().enumerate() {
                weights.insert(wname.clone(), self.params.req(wname).clone());
                grads.insert(
                    wname.clone(),
                    out[3 * p + 2 + gi].clone().into_tensor()?,
                );
            }
            let upd = self.controller.update(iter, &weights, &grads);
            regrown_ratio = upd.stats.regrown_ratio;
            // prune_weights(): zero newly-enabled blocks in the dense W
            for (name, to_zero) in &upd.regrown {
                let block = self.cfg.block * self.opts.block_mult.max(1);
                let w = self.params.get_mut(name).unwrap();
                let inverse = {
                    // apply_to zeroes *pruned* blocks, so invert: we want to
                    // zero exactly the to_zero set
                    let mut inv = BlockMask::ones(to_zero.rb, to_zero.cb);
                    for r in 0..to_zero.rb {
                        for c in 0..to_zero.cb {
                            if to_zero.get(r, c) {
                                inv.set(r, c, false);
                            }
                        }
                    }
                    inv
                };
                inverse.apply_to(w.data_mut(), block);
            }
        }

        self.log.push(IterLog {
            iter,
            loss,
            secs: t0.elapsed().as_secs_f64(),
            target_sparsity: self.controller.target_sparsity(iter),
            mean_mask_sparsity: self.controller.mean_sparsity(),
            regrown_ratio,
            mask_update,
        });
        Ok(loss)
    }

    /// Run `n` iterations starting at the current log length.
    pub fn run(&mut self, n: usize) -> Result<()> {
        let start = self.log.len();
        for i in start..start + n {
            let loss = self.train_iteration(i)?;
            if i % 20 == 0 || i + 1 == start + n {
                crate::log_info!(
                    "train",
                    "{} iter {i} loss {loss:.4} s={:.2}",
                    self.cfg.name,
                    self.controller.mean_sparsity()
                );
            }
        }
        Ok(())
    }

    /// Held-out loss → perplexity over `n` fixed eval batches.
    pub fn eval_perplexity(&self, n: usize) -> Result<f64> {
        let batches = Corpus::eval_batches(
            self.cfg.vocab,
            self.opts.branching,
            self.opts.seed,
            n,
            self.cfg.batch,
            self.cfg.seq,
        );
        let entry = self.eval_entry();
        let mut total = 0.0f64;
        for b in &batches {
            let mut inputs = Vec::with_capacity(self.params.len() + self.cfg.masks.len() + 2);
            for (_, t) in self.params.in_order() {
                inputs.push(HostValue::from_tensor(t));
            }
            for (name, _) in &self.cfg.masks {
                let fine =
                    expand_mask_grid(&self.controller.masks()[name], self.opts.block_mult.max(1));
                inputs.push(HostValue::tensor(fine.to_tensor()));
            }
            inputs.push(HostValue::i32s(&[b.batch, b.seq], b.tokens.clone()));
            inputs.push(HostValue::i32s(&[b.batch, b.seq], b.targets.clone()));
            let out = self.rt.execute(&entry, &inputs)?;
            total += out[0].scalar()? as f64;
        }
        Ok((total / n as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;

    #[test]
    fn expand_mask_grid_identity_at_mult_1() {
        let mut rng = crate::util::rng::Rng::new(1);
        let m = BlockMask::random(4, 6, 0.5, &mut rng);
        assert_eq!(expand_mask_grid(&m, 1), m);
    }

    #[test]
    fn expand_mask_grid_properties() {
        prop::check_default("expand-mask-grid", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let mult = *prop::pick(rng, &[2usize, 3, 4]);
            let coarse = BlockMask::random(rb, cb, rng.f64(), rng);
            let fine = expand_mask_grid(&coarse, mult);
            prop_assert!(
                fine.rb == rb * mult && fine.cb == cb * mult,
                "shape {}x{}",
                fine.rb,
                fine.cb
            );
            // kept count scales by mult²
            prop_assert!(
                fine.nnzb() == coarse.nnzb() * mult * mult,
                "nnzb {} vs {}",
                fine.nnzb(),
                coarse.nnzb() * mult * mult
            );
            // every fine block agrees with its coarse parent
            for r in 0..fine.rb {
                for c in 0..fine.cb {
                    prop_assert!(
                        fine.get(r, c) == coarse.get(r / mult, c / mult),
                        "mismatch at ({r},{c})"
                    );
                }
            }
            // sparsity is preserved exactly
            prop_assert!(
                (fine.sparsity() - coarse.sparsity()).abs() < 1e-12,
                "sparsity changed"
            );
            Ok(())
        });
    }

    #[test]
    fn expanded_mask_matches_elementwise_expansion() {
        // expand_mask_grid(m, mult).expand(b) == m.expand(b * mult)
        let mut rng = crate::util::rng::Rng::new(2);
        let coarse = BlockMask::random(3, 2, 0.4, &mut rng);
        let fine = expand_mask_grid(&coarse, 2);
        let a = fine.expand(4);
        let b = coarse.expand(8);
        assert!(a.allclose(&b, 0.0));
    }
}
