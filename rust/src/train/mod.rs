//! Training orchestration (L3): drive the AOT `train_step` executables,
//! interleave the blocked prune-and-grow controller per the paper's
//! Listing 1, and log the per-iteration series behind Tables 2/4/5/6 and
//! Figs. 8/10.
//!
//! * [`pretrain`] — LM pretraining on the synthetic corpus.
//! * [`classify`] — classification (ViT / GLUE twins) training +
//!   fine-tuning, including the dense-checkpoint → sparsify-and-recover
//!   pipeline of Table 1 / §5.2.

pub mod classify;
pub mod pretrain;

pub use classify::{ClassifyTrainer, EvalScores};
pub use pretrain::{IterLog, PretrainOptions, Trainer};
