//! Training orchestration (L3): drive one [`backend::TrainBackend`] per
//! step, interleave the blocked prune-and-grow controller per the paper's
//! Listing 1, and log the per-iteration series behind Tables 2/4/5/6 and
//! Figs. 8/10.
//!
//! * [`backend`] — the trainer ↔ executor seam: [`backend::TrainState`],
//!   [`backend::StepOutput`], the AOT/PJRT executor.
//! * [`native`] — the default executor: forward + backward + AdamW on the
//!   packed kernel stack, block sparsity accelerating both directions of
//!   the MLP (no artifacts, runs in every build).
//! * [`guard`] — the self-healing ladder around the step: anomaly
//!   skip/clip, divergence rollback, mask-update probe + revert.
//! * [`pretrain`] — LM pretraining on the synthetic corpus
//!   (backend-generic; `Trainer::new_native` / `Trainer::new`).
//! * [`classify`] — classification (ViT / GLUE twins) training +
//!   fine-tuning, including the dense-checkpoint → sparsify-and-recover
//!   pipeline of Table 1 / §5.2 (AOT-only: the classifier entry points
//!   exist only as HLO artifacts).

pub mod backend;
pub mod classify;
pub mod guard;
pub mod native;
pub mod pretrain;

pub use backend::{AotBackend, StepOutput, TrainBackend, TrainState};
pub use classify::{ClassifyTrainer, EvalScores};
pub use guard::{GuardConfig, GuardPersist, GuardStats, StepGuard, Verdict};
pub use native::{MlpExec, NativeBackend, RepackStats};
pub use pretrain::{open_backend_runtime, IterLog, PretrainOptions, Trainer};
