//! Classification training/fine-tuning (ViT twin for Table 3 / Fig. 9,
//! GLUE twin for Table 1).
//!
//! Shares the Listing-1 structure with [`super::pretrain`], but over
//! `(features, label)` batches, and scores accuracy / Matthews correlation
//! / F1 on a fixed held-out set — the metrics of Table 1.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::model::params::ParamStore;
use crate::runtime::{ConfigInfo, HostValue, Runtime};
use crate::sparse::BlockMask;
use crate::sparsify::controller::{DensePolicy, PruneGrowConfig, PruneGrowController, WeightSpec};
use crate::sparsify::SparsitySchedule;
use crate::tensor::Tensor;
use crate::train::pretrain::{expand_mask_grid, IterLog, PretrainOptions};
use crate::util::stats;

/// One labeled batch in the classifier ABI.
#[derive(Clone, Debug)]
pub struct ClsBatch {
    /// (batch * seq * feat) features.
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
}

/// Table 1-style metrics on a held-out set.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalScores {
    pub loss: f64,
    pub accuracy: f64,
    pub matthews: f64,
    pub f1: f64,
}

pub struct ClassifyTrainer<'rt> {
    rt: &'rt Runtime,
    cfg: ConfigInfo,
    params: ParamStore,
    adam_m: ParamStore,
    adam_v: ParamStore,
    step: i32,
    controller: PruneGrowController,
    block_mult: usize,
    pub log: Vec<IterLog>,
}

impl<'rt> ClassifyTrainer<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str, opts: &PretrainOptions) -> Result<Self> {
        let cfg = rt.manifest().config(config)?.clone();
        let params = ParamStore::init(&cfg, opts.seed);
        Self::with_params(rt, config, opts, params)
    }

    /// Fine-tune from a dense checkpoint (the Table 1 protocol).
    pub fn with_params(
        rt: &'rt Runtime,
        config: &str,
        opts: &PretrainOptions,
        params: ParamStore,
    ) -> Result<Self> {
        let cfg = rt.manifest().config(config)?.clone();
        let mut adam_m = ParamStore::new();
        let mut adam_v = ParamStore::new();
        for (name, t) in params.in_order() {
            adam_m.insert(name.clone(), Tensor::zeros(t.shape()));
            adam_v.insert(name.clone(), Tensor::zeros(t.shape()));
        }
        let mult = opts.block_mult.max(1);
        let specs: Vec<WeightSpec> = cfg
            .masks
            .iter()
            .map(|(name, shape)| WeightSpec {
                name: name.clone(),
                layer: ConfigInfo::layer_of(name).unwrap_or(0),
                rb: shape[0] / mult,
                cb: shape[1] / mult,
            })
            .collect();
        let controller = PruneGrowController::new(
            PruneGrowConfig {
                block: cfg.block * mult,
                schedule: SparsitySchedule::new(
                    opts.s_init,
                    opts.s_max,
                    opts.total_iters,
                    opts.decay.min(opts.total_iters.saturating_sub(1)),
                ),
                step_size: opts.step_size,
                dense_policy: DensePolicy {
                    left: opts.dense_left,
                    right: opts.dense_right,
                },
                n_layers: cfg.layers,
            },
            specs,
        );
        Ok(ClassifyTrainer {
            rt,
            cfg,
            params,
            adam_m,
            adam_v,
            step: 0,
            controller,
            block_mult: mult,
            log: Vec::new(),
        })
    }

    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    pub fn masks(&self) -> &BTreeMap<String, BlockMask> {
        self.controller.masks()
    }

    pub fn config(&self) -> &ConfigInfo {
        &self.cfg
    }

    pub fn mean_sparsity(&self) -> f64 {
        self.controller.mean_sparsity()
    }

    fn feat_shape(&self) -> [usize; 3] {
        [self.cfg.batch, self.cfg.seq - 1, self.cfg.patch_dim]
    }

    /// One Listing-1 iteration over a labeled batch.
    pub fn train_iteration(&mut self, iter: usize, batch: &ClsBatch) -> Result<f32> {
        let t0 = Instant::now();
        let mut inputs = Vec::with_capacity(3 * self.params.len() + self.cfg.masks.len() + 3);
        for (_, t) in self.params.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        for (_, t) in self.adam_m.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        for (_, t) in self.adam_v.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        inputs.push(HostValue::scalar_i32(self.step));
        for (name, _) in &self.cfg.masks {
            let fine = expand_mask_grid(&self.controller.masks()[name], self.block_mult);
            inputs.push(HostValue::tensor(fine.to_tensor()));
        }
        let fs = self.feat_shape();
        inputs.push(HostValue::F32 {
            shape: fs.to_vec(),
            data: batch.features.clone(),
        });
        inputs.push(HostValue::i32s(&[self.cfg.batch], batch.labels.clone()));

        let entry = format!("{}_train_step", self.cfg.name);
        let out = self.rt.execute(&entry, &inputs)?;
        let p = self.params.len();
        let names: Vec<String> = self.params.names().to_vec();
        for (i, name) in names.iter().enumerate() {
            self.params.insert(name.clone(), out[i].clone().into_tensor()?);
            self.adam_m
                .insert(name.clone(), out[p + i].clone().into_tensor()?);
            self.adam_v
                .insert(name.clone(), out[2 * p + i].clone().into_tensor()?);
        }
        self.step = out[3 * p].as_i32().context("step")?[0];
        let loss = out[3 * p + 1].scalar()?;

        let mask_update = self.controller.should_update(iter);
        let mut regrown_ratio = 0.0;
        if mask_update {
            let mut weights = BTreeMap::new();
            let mut grads = BTreeMap::new();
            for (gi, wname) in self.cfg.mlp_weights.iter().enumerate() {
                weights.insert(wname.clone(), self.params.req(wname).clone());
                grads.insert(wname.clone(), out[3 * p + 2 + gi].clone().into_tensor()?);
            }
            let upd = self.controller.update(iter, &weights, &grads);
            regrown_ratio = upd.stats.regrown_ratio;
            for (name, to_zero) in &upd.regrown {
                let block = self.cfg.block * self.block_mult;
                let w = self.params.get_mut(name).unwrap();
                let mut inv = BlockMask::ones(to_zero.rb, to_zero.cb);
                for r in 0..to_zero.rb {
                    for c in 0..to_zero.cb {
                        if to_zero.get(r, c) {
                            inv.set(r, c, false);
                        }
                    }
                }
                inv.apply_to(w.data_mut(), block);
            }
        }

        self.log.push(IterLog {
            iter,
            loss,
            secs: t0.elapsed().as_secs_f64(),
            target_sparsity: self.controller.target_sparsity(iter),
            mean_mask_sparsity: self.controller.mean_sparsity(),
            regrown_ratio,
            mask_update,
        });
        Ok(loss)
    }

    /// Score a held-out set: loss, accuracy, Matthews correlation (binary),
    /// F1 (binary, positive class = 1).
    pub fn eval(&self, batches: &[ClsBatch]) -> Result<EvalScores> {
        let entry = format!("{}_eval_loss", self.cfg.name);
        let mut losses = Vec::new();
        let (mut tp, mut tn, mut fp, mut fn_) = (0u64, 0u64, 0u64, 0u64);
        let mut correct = 0u64;
        let mut total = 0u64;
        for b in batches {
            let mut inputs = Vec::with_capacity(self.params.len() + self.cfg.masks.len() + 2);
            for (_, t) in self.params.in_order() {
                inputs.push(HostValue::from_tensor(t));
            }
            for (name, _) in &self.cfg.masks {
                let fine = expand_mask_grid(&self.controller.masks()[name], self.block_mult);
                inputs.push(HostValue::tensor(fine.to_tensor()));
            }
            let fs = self.feat_shape();
            inputs.push(HostValue::F32 {
                shape: fs.to_vec(),
                data: b.features.clone(),
            });
            inputs.push(HostValue::i32s(&[self.cfg.batch], b.labels.clone()));
            let out = self.rt.execute(&entry, &inputs)?;
            losses.push(out[0].scalar()? as f64);
            let logits = out[1].as_f32()?;
            let nc = self.cfg.num_classes;
            for (row, &label) in b.labels.iter().enumerate() {
                let slice = &logits[row * nc..(row + 1) * nc];
                let pred = slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                total += 1;
                if pred == label {
                    correct += 1;
                }
                match (pred, label) {
                    (1, 1) => tp += 1,
                    (0, 0) => tn += 1,
                    (1, 0) => fp += 1,
                    (0, 1) => fn_ += 1,
                    _ => {}
                }
            }
        }
        Ok(EvalScores {
            loss: stats::mean(&losses),
            accuracy: correct as f64 / total.max(1) as f64,
            matthews: stats::matthews_corr(tp, tn, fp, fn_),
            f1: stats::f1(tp, fp, fn_),
        })
    }
}
