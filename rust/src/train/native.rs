//! Native block-sparse training backend — forward + backward + AdamW
//! entirely on the packed kernel stack, no AOT artifacts required.
//!
//! This is the piece that makes the paper's *pretraining* half real in
//! default builds: the same block masks that accelerate inference (PRs
//! 1–3) accelerate the training step here, in **both** directions of the
//! MLP:
//!
//! * forward `H = X·W₁m`, `Y = A·W₃m` run as BSpMM over the resident
//!   BCSC blocks ([`crate::kernels::bspmm::bspmm_into`]);
//! * backward data gradients `dX = dY·Wᵀ` run as the *same* BSpMM against
//!   a transposed BCSC ([`crate::sparse::Bcsc::transpose`]) — pruned
//!   blocks cost nothing going backward either;
//! * backward weight gradients `dW = Xᵀ·dY` run through the block-masked
//!   accumulator ([`crate::kernels::bspmm::bspmm_dw_masked_into`]), which
//!   touches only resident blocks and leaves the rest **exactly zero** —
//!   which is the true gradient of `W ⊙ expand(M)`, and exactly the `G_i`
//!   matrices the prune-and-grow controller feeds to `S(G_i)`.
//!
//! Dense projections (`Wq/Wk/Wv/Wo`, LM head) use the packed micro-GEMMs,
//! including the two backward forms added for this backend
//! ([`crate::kernels::gemm::gemm_nt_into`] /
//! [`crate::kernels::gemm::gemm_tn_into`]). Attention backward recomputes
//! the softmax probabilities per `(sample, head)` from the saved post-RoPE
//! Q/K (memory ∝ `seq·hd`, not `seq²`) and chains
//! `dS = P ∘ (dP − rowsum(dP ∘ P))` with single-threaded axpy kernels
//! inside thread-pool items — no nested pool calls.
//!
//! **Incremental re-packing:** the backend caches one BCSC pair (forward +
//! transposed) per MLP weight. Between mask updates only the *values*
//! refresh in place ([`crate::sparse::Bcsc::refresh_from_dense`] — the
//! optimizer changed the numbers, not the structure); a weight's structure
//! rebuilds only when *its* mask actually changed. [`RepackStats`] counts
//! both so tests can pin the behavior.
//!
//! Semantics mirror `python/compile/model.py` exactly: pre-norm blocks
//! (LayerNorm for GPT-2, RMSNorm for Llama), RoPE on the Llama twins, mean
//! cross-entropy over all positions, and `adam_update` with
//! `b1=0.9, b2=0.95, eps=1e-8, wd=0.01` bias-corrected at `t = step+1`.
//! The finite-difference tests below hold the analytic gradient to the
//! numeric one within 1e-3 relative error.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::data::corpus::LmBatch;
use crate::kernels::attention::causal_attention;
use crate::kernels::bspmm::{bspmm_dw_masked_into, bspmm_into};
use crate::kernels::gemm::{axpy, gemm_into, gemm_nt_into, gemm_tn_into};
use crate::kernels::ops;
use crate::model::config::ModelKind;
use crate::model::params::ParamStore;
use crate::runtime::ConfigInfo;
use crate::sparse::{Bcsc, BlockMask};
use crate::tensor::Tensor;
use crate::train::backend::{StepOutput, TrainBackend, TrainState};
use crate::util::threadpool;

/// Adam moments decay / epsilon — the values `python/compile/model.py`
/// bakes into every AOT `train_step` (and the manifest records).
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.95;
pub const ADAM_EPS: f32 = 1e-8;
/// AdamW weight decay (`make_train_step`'s default).
pub const WEIGHT_DECAY: f32 = 0.01;
const NORM_EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 10000.0;
/// Mean mask sparsity at which [`MlpExec::Auto`] switches the MLP from
/// masked-dense GEMM to BSpMM — the paper's ~60% runtime crossover.
pub const SPARSE_SWITCH: f64 = 0.6;

/// How the masked MLP contractions execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlpExec {
    /// Masked-dense GEMM below [`SPARSE_SWITCH`] mean sparsity (or for
    /// blocks too small for the BCSC kernels), BSpMM above — the default.
    Auto,
    /// Always masked-dense GEMM (the A/B baseline arm).
    Dense,
    /// Always BSpMM over resident blocks.
    Sparse,
}

/// Counters for the incremental re-pack behavior (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepackStats {
    /// Full structure builds (`from_dense` + transpose): initial packs and
    /// mask changes only.
    pub rebuilds: usize,
    /// In-place value refreshes (structure reused between mask updates).
    pub refreshes: usize,
}

struct SparseSlot {
    mask: BlockMask,
    fwd: Bcsc,
    bwd: Bcsc,
}

/// Per-layer view of the masked MLP weights for one step.
enum LayerMlp<'a> {
    Sparse {
        w1: &'a SparseSlot,
        w2: Option<&'a SparseSlot>,
        w3: &'a SparseSlot,
    },
    Dense {
        w1: &'a Tensor,
        w2: Option<&'a Tensor>,
        w3: &'a Tensor,
    },
}

/// The native training backend (see module docs).
pub struct NativeBackend {
    cfg: ConfigInfo,
    kind: ModelKind,
    wd: f32,
    exec: MlpExec,
    slots: BTreeMap<String, SparseSlot>,
    stats: RepackStats,
}

/// Saved activations of one layer (everything backward needs).
struct LayerActs {
    x_in: Vec<f32>,  // (m, e) residual stream entering the layer
    n1: Vec<f32>,    // (m, e)
    qh: Vec<f32>,    // (B, h, S, hd) post-RoPE
    kh: Vec<f32>,    // (B, h, S, hd) post-RoPE
    vh: Vec<f32>,    // (B, h, S, hd)
    att: Vec<f32>,   // (m, e) merged attention output (pre-Wo)
    x_mid: Vec<f32>, // (m, e) after the attention residual
    n2: Vec<f32>,    // (m, e)
    h1: Vec<f32>,    // (m, f) pre-activation hidden
    h2: Vec<f32>,    // (m, f) llama up-projection; empty for gpt2
    act: Vec<f32>,   // (m, f) activated hidden
}

struct Fwd {
    layers: Vec<LayerActs>,
    x_final: Vec<f32>, // (m, e) residual stream after the last layer
    xf: Vec<f32>,      // (m, e) final-normed
    logits: Vec<f32>,  // (m, v)
    loss: f64,
}

impl NativeBackend {
    /// Backend over an LM twin geometry with [`MlpExec::Auto`].
    pub fn new(cfg: &ConfigInfo) -> Result<NativeBackend> {
        NativeBackend::with_exec(cfg, MlpExec::Auto)
    }

    /// Backend with an explicit MLP execution policy (the A/B harness
    /// forces each arm).
    pub fn with_exec(cfg: &ConfigInfo, exec: MlpExec) -> Result<NativeBackend> {
        let kind = match cfg.kind.as_str() {
            "gpt2" => ModelKind::Gpt2,
            "llama" => ModelKind::Llama,
            other => bail!("native training backend serves LM configs (gpt2/llama), not {other:?}"),
        };
        ensure!(cfg.heads > 0 && cfg.emb % cfg.heads == 0, "emb {} % heads {}", cfg.emb, cfg.heads);
        if kind == ModelKind::Llama {
            ensure!((cfg.emb / cfg.heads) % 2 == 0, "RoPE needs an even head_dim");
        }
        ensure!(cfg.block >= 1, "block size must be >= 1");
        Ok(NativeBackend {
            cfg: cfg.clone(),
            kind,
            wd: WEIGHT_DECAY,
            exec,
            slots: BTreeMap::new(),
            stats: RepackStats::default(),
        })
    }

    /// Incremental re-pack counters (see [`RepackStats`]).
    pub fn repack_stats(&self) -> RepackStats {
        self.stats
    }

    /// The geometry this backend runs.
    pub fn config(&self) -> &ConfigInfo {
        &self.cfg
    }

    fn use_sparse(&self, masks: &BTreeMap<String, BlockMask>) -> bool {
        match self.exec {
            MlpExec::Dense => false,
            MlpExec::Sparse => true,
            MlpExec::Auto => {
                // the BCSC kernels want blocks wide enough for the
                // micro-kernel's vector chunks; b=1 twins stay dense
                if self.cfg.block < 8 {
                    return false;
                }
                let names = &self.cfg.mlp_weights;
                let mean: f64 = names.iter().map(|n| masks[n].sparsity()).sum::<f64>()
                    / names.len().max(1) as f64;
                mean >= SPARSE_SWITCH
            }
        }
    }

    /// Refresh the cached BCSC pair of every MLP weight: values in place
    /// when the mask is unchanged, full rebuild only on a mask change.
    /// Forward-only passes (eval) skip the transposed refresh — `bwd` is
    /// only read by `backward`, and the next `train_step` refreshes it
    /// before use.
    fn refresh_slots(
        &mut self,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        with_bwd: bool,
    ) {
        let b = self.cfg.block;
        for name in &self.cfg.mlp_weights {
            let mask = &masks[name];
            let w = params.req(name);
            let refreshed = match self.slots.get_mut(name) {
                Some(slot) if slot.mask == *mask => {
                    slot.fwd.refresh_from_dense(w);
                    if with_bwd {
                        slot.bwd.refresh_from_dense_transposed(w);
                    }
                    true
                }
                _ => false,
            };
            if refreshed {
                self.stats.refreshes += 1;
            } else {
                let fwd = Bcsc::from_dense(w, mask, b);
                let bwd = fwd.transpose();
                self.slots.insert(
                    name.clone(),
                    SparseSlot {
                        mask: mask.clone(),
                        fwd,
                        bwd,
                    },
                );
                self.stats.rebuilds += 1;
            }
        }
    }

    fn masked_dense(
        &self,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
    ) -> BTreeMap<String, Tensor> {
        self.cfg
            .mlp_weights
            .iter()
            .map(|name| {
                let mut t = params.req(name).clone();
                masks[name].apply_to(t.data_mut(), self.cfg.block);
                (name.clone(), t)
            })
            .collect()
    }

    /// Pick the execution mode and ready the weights for one step.
    /// `with_bwd` declares whether a backward pass will follow (eval
    /// passes skip readying the transposed structures).
    fn prepare(
        &mut self,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        with_bwd: bool,
    ) -> Result<Option<BTreeMap<String, Tensor>>> {
        for name in &self.cfg.mlp_weights {
            ensure!(masks.contains_key(name), "missing mask for {name}");
        }
        if self.use_sparse(masks) {
            self.refresh_slots(params, masks, with_bwd);
            Ok(None)
        } else {
            Ok(Some(self.masked_dense(params, masks)))
        }
    }

    fn layer_mlps<'a>(&'a self, dense: Option<&'a BTreeMap<String, Tensor>>) -> Vec<LayerMlp<'a>> {
        (0..self.cfg.layers)
            .map(|i| {
                let n1 = format!("layer{i}.mlp.w1");
                let n2 = format!("layer{i}.mlp.w2");
                let n3 = format!("layer{i}.mlp.w3");
                let llama = self.kind == ModelKind::Llama;
                match dense {
                    Some(d) => LayerMlp::Dense {
                        w1: &d[&n1],
                        w2: if llama { Some(&d[&n2]) } else { None },
                        w3: &d[&n3],
                    },
                    None => LayerMlp::Sparse {
                        w1: &self.slots[&n1],
                        w2: if llama { Some(&self.slots[&n2]) } else { None },
                        w3: &self.slots[&n3],
                    },
                }
            })
            .collect()
    }

    fn norm(&self, x: &[f32], g: &[f32], out: &mut [f32]) {
        match self.kind {
            ModelKind::Llama => ops::rmsnorm(x, g, out, NORM_EPS),
            _ => ops::layernorm(x, g, out, NORM_EPS),
        }
    }

    fn norm_bwd(&self, x: &[f32], g: &[f32], dy: &[f32], dx: &mut [f32], dg: &mut [f32]) {
        match self.kind {
            ModelKind::Llama => ops::rmsnorm_bwd(x, g, dy, dx, dg, NORM_EPS),
            _ => ops::layernorm_bwd(x, g, dy, dx, dg, NORM_EPS),
        }
    }

    /// `dW += Xᵀ·dY` restricted to resident blocks — exact for
    /// `W ⊙ expand(M)` forward. Blocks below the micro-kernel's useful
    /// width fall back to the dense TN GEMM plus a mask sweep (same
    /// exactly-zero guarantee).
    #[allow(clippy::too_many_arguments)] // a GEMM-shaped ABI
    fn masked_dw(
        &self,
        x: &[f32],
        dy: &[f32],
        mask: &BlockMask,
        dw: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        let b = self.cfg.block;
        if b >= 8 {
            bspmm_dw_masked_into(x, dy, mask, b, dw, m);
        } else {
            gemm_tn_into(x, dy, dw, m, k, n);
            mask.apply_to(dw, b);
        }
    }

    // -----------------------------------------------------------------
    // forward
    // -----------------------------------------------------------------

    fn forward(&self, params: &ParamStore, mlps: &[LayerMlp], batch: &LmBatch) -> Result<Fwd> {
        let (bsz, seq) = (batch.batch, batch.seq);
        ensure!(bsz > 0 && seq > 0, "empty batch");
        ensure!(seq <= self.cfg.seq, "batch seq {seq} > config seq {}", self.cfg.seq);
        let m = bsz * seq;
        ensure!(batch.tokens.len() == m && batch.targets.len() == m, "batch layout");
        let (e, f, h, v) = (self.cfg.emb, self.cfg.ffn, self.cfg.heads, self.cfg.vocab);
        let hd = e / h;

        // embed
        let tok_emb = params.req("tok_emb");
        let pos_emb = params.get("pos_emb");
        let mut x = vec![0.0f32; m * e];
        for b in 0..bsz {
            for s in 0..seq {
                let i = b * seq + s;
                let t = batch.tokens[i];
                ensure!(t >= 0 && (t as usize) < v, "token {t} out of vocab {v}");
                let row = &mut x[i * e..(i + 1) * e];
                row.copy_from_slice(tok_emb.row(t as usize));
                if let Some(pe) = pos_emb {
                    for (a, &p) in row.iter_mut().zip(pe.row(s)) {
                        *a += p;
                    }
                }
            }
        }

        let mut layers = Vec::with_capacity(self.cfg.layers);
        for li in 0..self.cfg.layers {
            let p = |s: &str| format!("layer{li}.{s}");
            let x_in = x.clone();
            // pre-norm
            let ln1 = params.req(&p("ln1")).data();
            let mut n1 = vec![0.0f32; m * e];
            for i in 0..m {
                self.norm(&x_in[i * e..(i + 1) * e], ln1, &mut n1[i * e..(i + 1) * e]);
            }
            // projections (one batched GEMM each)
            let mut q = vec![0.0f32; m * e];
            let mut k = vec![0.0f32; m * e];
            let mut vv = vec![0.0f32; m * e];
            gemm_into(&n1, params.req(&p("attn.wq")).data(), &mut q, m, e, e);
            gemm_into(&n1, params.req(&p("attn.wk")).data(), &mut k, m, e, e);
            gemm_into(&n1, params.req(&p("attn.wv")).data(), &mut vv, m, e, e);
            // head split to (B, h, S, hd) + RoPE
            let mut qh = vec![0.0f32; m * e];
            let mut kh = vec![0.0f32; m * e];
            let mut vh = vec![0.0f32; m * e];
            for b in 0..bsz {
                for s in 0..seq {
                    for hh in 0..h {
                        let src = (b * seq + s) * e + hh * hd;
                        let dst = ((b * h + hh) * seq + s) * hd;
                        qh[dst..dst + hd].copy_from_slice(&q[src..src + hd]);
                        kh[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                        vh[dst..dst + hd].copy_from_slice(&vv[src..src + hd]);
                    }
                }
            }
            if self.kind == ModelKind::Llama {
                for bh in 0..bsz * h {
                    for s in 0..seq {
                        let o = (bh * seq + s) * hd;
                        ops::rope_inplace(&mut qh[o..o + hd], s, ROPE_THETA);
                        ops::rope_inplace(&mut kh[o..o + hd], s, ROPE_THETA);
                    }
                }
            }
            // attention per sample (the tiled kernel parallelizes inside)
            let mut att = vec![0.0f32; m * e];
            for b in 0..bsz {
                let sl = b * h * seq * hd..(b + 1) * h * seq * hd;
                let o = causal_attention(&qh[sl.clone()], &kh[sl.clone()], &vh[sl], h, seq, hd);
                att[b * seq * e..(b + 1) * seq * e].copy_from_slice(&o);
            }
            let mut proj = vec![0.0f32; m * e];
            gemm_into(&att, params.req(&p("attn.wo")).data(), &mut proj, m, e, e);
            for (a, &pp) in x.iter_mut().zip(&proj) {
                *a += pp;
            }
            let x_mid = x.clone();
            // MLP
            let ln2 = params.req(&p("ln2")).data();
            let mut n2 = vec![0.0f32; m * e];
            for i in 0..m {
                self.norm(&x_mid[i * e..(i + 1) * e], ln2, &mut n2[i * e..(i + 1) * e]);
            }
            let mut h1 = vec![0.0f32; m * f];
            let mut h2 = Vec::new();
            match &mlps[li] {
                LayerMlp::Sparse { w1, w2, .. } => {
                    bspmm_into(&n2, &w1.fwd, &mut h1, m);
                    if let Some(w2) = w2 {
                        h2 = vec![0.0f32; m * f];
                        bspmm_into(&n2, &w2.fwd, &mut h2, m);
                    }
                }
                LayerMlp::Dense { w1, w2, .. } => {
                    gemm_into(&n2, w1.data(), &mut h1, m, e, f);
                    if let Some(w2) = w2 {
                        h2 = vec![0.0f32; m * f];
                        gemm_into(&n2, w2.data(), &mut h2, m, e, f);
                    }
                }
            }
            // h1 stays pre-activation (the backward pass needs it); the
            // activation runs on the dispatched SIMD lanes
            let mut act = h1.clone();
            match self.kind {
                ModelKind::Llama => ops::silu_gate_slice(&mut act, &h2),
                _ => ops::gelu_slice(&mut act),
            }
            let mut y = vec![0.0f32; m * e];
            match &mlps[li] {
                LayerMlp::Sparse { w3, .. } => bspmm_into(&act, &w3.fwd, &mut y, m),
                LayerMlp::Dense { w3, .. } => gemm_into(&act, w3.data(), &mut y, m, f, e),
            }
            for (a, &yy) in x.iter_mut().zip(&y) {
                *a += yy;
            }
            layers.push(LayerActs {
                x_in,
                n1,
                qh,
                kh,
                vh,
                att,
                x_mid,
                n2,
                h1,
                h2,
                act,
            });
        }

        // final norm + LM head
        let x_final = x;
        let fnorm = params.req("final_norm").data();
        let mut xf = vec![0.0f32; m * e];
        for i in 0..m {
            self.norm(&x_final[i * e..(i + 1) * e], fnorm, &mut xf[i * e..(i + 1) * e]);
        }
        let mut logits = vec![0.0f32; m * v];
        gemm_into(&xf, params.req("lm_head").data(), &mut logits, m, e, v);

        // mean cross-entropy, accumulated in f64
        let mut loss = 0.0f64;
        for i in 0..m {
            let t = batch.targets[i];
            ensure!(t >= 0 && (t as usize) < v, "target {t} out of vocab {v}");
            let row = &logits[i * v..(i + 1) * v];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let sumexp: f64 = row.iter().map(|&l| ((l - max) as f64).exp()).sum();
            loss -= (row[t as usize] - max) as f64 - sumexp.ln();
        }
        loss /= m as f64;
        Ok(Fwd {
            layers,
            x_final,
            xf,
            logits,
            loss,
        })
    }

    // -----------------------------------------------------------------
    // backward
    // -----------------------------------------------------------------

    fn backward(
        &self,
        params: &ParamStore,
        mlps: &[LayerMlp],
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
        fwd: &Fwd,
    ) -> ParamStore {
        let (bsz, seq) = (batch.batch, batch.seq);
        let m = bsz * seq;
        let (e, f, h, v) = (self.cfg.emb, self.cfg.ffn, self.cfg.heads, self.cfg.vocab);
        let hd = e / h;
        let mut grads = ParamStore::new();
        for (name, t) in params.in_order() {
            grads.insert(name.clone(), Tensor::zeros(t.shape()));
        }

        // dlogits = (softmax(logits) − onehot(target)) / m
        let mut dlog = vec![0.0f32; m * v];
        let inv_m = 1.0 / m as f32;
        for i in 0..m {
            let row = &fwd.logits[i * v..(i + 1) * v];
            let drow = &mut dlog[i * v..(i + 1) * v];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0f32;
            for (d, &l) in drow.iter_mut().zip(row) {
                *d = (l - max).exp();
                sum += *d;
            }
            let inv = inv_m / sum;
            for d in drow.iter_mut() {
                *d *= inv;
            }
            drow[batch.targets[i] as usize] -= inv_m;
        }

        // LM head + final norm
        gemm_tn_into(
            &fwd.xf,
            &dlog,
            grads.get_mut("lm_head").unwrap().data_mut(),
            m,
            e,
            v,
        );
        let mut dxf = vec![0.0f32; m * e];
        gemm_nt_into(&dlog, params.req("lm_head").data(), &mut dxf, m, v, e);
        let mut dx = vec![0.0f32; m * e];
        {
            let fnorm = params.req("final_norm").data();
            let dg = grads.get_mut("final_norm").unwrap().data_mut();
            for i in 0..m {
                self.norm_bwd(
                    &fwd.x_final[i * e..(i + 1) * e],
                    fnorm,
                    &dxf[i * e..(i + 1) * e],
                    &mut dx[i * e..(i + 1) * e],
                    dg,
                );
            }
        }

        for li in (0..self.cfg.layers).rev() {
            let a = &fwd.layers[li];
            let p = |s: &str| format!("layer{li}.{s}");
            let (w1n, w2n, w3n) = (p("mlp.w1"), p("mlp.w2"), p("mlp.w3"));

            // ---- MLP backward (dx = grad of the layer's output stream) ----
            let mut d_act = vec![0.0f32; m * f];
            match &mlps[li] {
                LayerMlp::Sparse { w3, .. } => bspmm_into(&dx, &w3.bwd, &mut d_act, m),
                LayerMlp::Dense { w3, .. } => gemm_nt_into(&dx, w3.data(), &mut d_act, m, e, f),
            }
            self.masked_dw(
                &a.act,
                &dx,
                &masks[&w3n],
                grads.get_mut(&w3n).unwrap().data_mut(),
                m,
                f,
                e,
            );
            // activation backward
            let (dh1, dh2) = match self.kind {
                ModelKind::Llama => {
                    let mut dh1 = vec![0.0f32; m * f];
                    let mut dh2 = vec![0.0f32; m * f];
                    // dispatched SwiGLU backward lane
                    ops::swiglu_bwd_slice(&a.h1, &a.h2, &d_act, &mut dh1, &mut dh2);
                    (dh1, Some(dh2))
                }
                _ => {
                    let mut dh1 = d_act;
                    ops::gelu_bwd_inplace(&a.h1, &mut dh1);
                    (dh1, None)
                }
            };
            self.masked_dw(
                &a.n2,
                &dh1,
                &masks[&w1n],
                grads.get_mut(&w1n).unwrap().data_mut(),
                m,
                e,
                f,
            );
            let mut d_n2 = vec![0.0f32; m * e];
            match &mlps[li] {
                LayerMlp::Sparse { w1, .. } => bspmm_into(&dh1, &w1.bwd, &mut d_n2, m),
                LayerMlp::Dense { w1, .. } => gemm_nt_into(&dh1, w1.data(), &mut d_n2, m, f, e),
            }
            if let Some(dh2) = &dh2 {
                self.masked_dw(
                    &a.n2,
                    dh2,
                    &masks[&w2n],
                    grads.get_mut(&w2n).unwrap().data_mut(),
                    m,
                    e,
                    f,
                );
                match &mlps[li] {
                    LayerMlp::Sparse { w2, .. } => {
                        bspmm_into(dh2, &w2.unwrap().bwd, &mut d_n2, m)
                    }
                    LayerMlp::Dense { w2, .. } => {
                        gemm_nt_into(dh2, w2.unwrap().data(), &mut d_n2, m, f, e)
                    }
                }
            }
            // ln2 backward, residual passthrough
            let mut d_x_mid = dx;
            {
                let ln2 = params.req(&p("ln2")).data();
                let dg = grads.get_mut(&p("ln2")).unwrap().data_mut();
                for i in 0..m {
                    self.norm_bwd(
                        &a.x_mid[i * e..(i + 1) * e],
                        ln2,
                        &d_n2[i * e..(i + 1) * e],
                        &mut d_x_mid[i * e..(i + 1) * e],
                        dg,
                    );
                }
            }

            // ---- attention backward ----
            let mut d_att = vec![0.0f32; m * e];
            gemm_nt_into(&d_x_mid, params.req(&p("attn.wo")).data(), &mut d_att, m, e, e);
            gemm_tn_into(
                &a.att,
                &d_x_mid,
                grads.get_mut(&p("attn.wo")).unwrap().data_mut(),
                m,
                e,
                e,
            );
            // merged (m, e) → head-major (B, h, S, hd)
            let mut d_out_h = vec![0.0f32; m * e];
            for b in 0..bsz {
                for s in 0..seq {
                    for hh in 0..h {
                        let src = (b * seq + s) * e + hh * hd;
                        let dst = ((b * h + hh) * seq + s) * hd;
                        d_out_h[dst..dst + hd].copy_from_slice(&d_att[src..src + hd]);
                    }
                }
            }
            let mut dqh = vec![0.0f32; m * e];
            let mut dkh = vec![0.0f32; m * e];
            let mut dvh = vec![0.0f32; m * e];
            {
                let qh_ref: &[f32] = &a.qh;
                let kh_ref: &[f32] = &a.kh;
                let vh_ref: &[f32] = &a.vh;
                let dout_ref: &[f32] = &d_out_h;
                let dq_base = dqh.as_mut_ptr() as usize;
                let dk_base = dkh.as_mut_ptr() as usize;
                let dv_base = dvh.as_mut_ptr() as usize;
                threadpool::parallel_for(bsz * h, |t| {
                    let off = t * seq * hd;
                    let len = seq * hd;
                    // SAFETY: each (sample, head) item owns the disjoint
                    // span [off, off+len) of dqh/dkh/dvh; parallel_for
                    // blocks until every item finishes.
                    let dq = unsafe {
                        std::slice::from_raw_parts_mut((dq_base as *mut f32).add(off), len)
                    };
                    let dk = unsafe {
                        std::slice::from_raw_parts_mut((dk_base as *mut f32).add(off), len)
                    };
                    let dv = unsafe {
                        std::slice::from_raw_parts_mut((dv_base as *mut f32).add(off), len)
                    };
                    attn_bwd_head(
                        &qh_ref[off..off + len],
                        &kh_ref[off..off + len],
                        &vh_ref[off..off + len],
                        &dout_ref[off..off + len],
                        seq,
                        hd,
                        dq,
                        dk,
                        dv,
                    );
                });
            }
            if self.kind == ModelKind::Llama {
                for bh in 0..bsz * h {
                    for s in 0..seq {
                        let o = (bh * seq + s) * hd;
                        ops::rope_bwd_inplace(&mut dqh[o..o + hd], s, ROPE_THETA);
                        ops::rope_bwd_inplace(&mut dkh[o..o + hd], s, ROPE_THETA);
                    }
                }
            }
            // merge heads back to (m, e)
            let mut dq = vec![0.0f32; m * e];
            let mut dk = vec![0.0f32; m * e];
            let mut dv = vec![0.0f32; m * e];
            for b in 0..bsz {
                for s in 0..seq {
                    for hh in 0..h {
                        let dst = (b * seq + s) * e + hh * hd;
                        let src = ((b * h + hh) * seq + s) * hd;
                        dq[dst..dst + hd].copy_from_slice(&dqh[src..src + hd]);
                        dk[dst..dst + hd].copy_from_slice(&dkh[src..src + hd]);
                        dv[dst..dst + hd].copy_from_slice(&dvh[src..src + hd]);
                    }
                }
            }
            let mut d_n1 = vec![0.0f32; m * e];
            gemm_nt_into(&dq, params.req(&p("attn.wq")).data(), &mut d_n1, m, e, e);
            gemm_nt_into(&dk, params.req(&p("attn.wk")).data(), &mut d_n1, m, e, e);
            gemm_nt_into(&dv, params.req(&p("attn.wv")).data(), &mut d_n1, m, e, e);
            gemm_tn_into(&a.n1, &dq, grads.get_mut(&p("attn.wq")).unwrap().data_mut(), m, e, e);
            gemm_tn_into(&a.n1, &dk, grads.get_mut(&p("attn.wk")).unwrap().data_mut(), m, e, e);
            gemm_tn_into(&a.n1, &dv, grads.get_mut(&p("attn.wv")).unwrap().data_mut(), m, e, e);
            // ln1 backward, residual passthrough
            let mut d_x_in = d_x_mid;
            {
                let ln1 = params.req(&p("ln1")).data();
                let dg = grads.get_mut(&p("ln1")).unwrap().data_mut();
                for i in 0..m {
                    self.norm_bwd(
                        &a.x_in[i * e..(i + 1) * e],
                        ln1,
                        &d_n1[i * e..(i + 1) * e],
                        &mut d_x_in[i * e..(i + 1) * e],
                        dg,
                    );
                }
            }
            dx = d_x_in;
        }

        // embeddings
        {
            let dtok = grads.get_mut("tok_emb").unwrap();
            for i in 0..m {
                let t = batch.tokens[i] as usize;
                let row = dtok.row_mut(t);
                for (a, &b) in row.iter_mut().zip(&dx[i * e..(i + 1) * e]) {
                    *a += b;
                }
            }
        }
        if self.kind == ModelKind::Gpt2 {
            let dpos = grads.get_mut("pos_emb").unwrap();
            for b in 0..bsz {
                for s in 0..seq {
                    let i = b * seq + s;
                    let row = dpos.row_mut(s);
                    for (a, &v2) in row.iter_mut().zip(&dx[i * e..(i + 1) * e]) {
                        *a += v2;
                    }
                }
            }
        }
        grads
    }

    /// Forward + backward without the optimizer update — the hook the
    /// finite-difference tests and the A/B harness's parity check use.
    /// Returns `(loss, grads)` with grads in parameter-ABI order; MLP
    /// weight gradients are masked (exactly zero outside resident blocks).
    pub fn loss_and_grads(
        &mut self,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
    ) -> Result<(f32, ParamStore)> {
        let dense = self.prepare(params, masks, true)?;
        let mlps = self.layer_mlps(dense.as_ref());
        let fwd = self.forward(params, &mlps, batch)?;
        let grads = self.backward(params, &mlps, masks, batch, &fwd);
        Ok((fwd.loss as f32, grads))
    }

    /// Forward-only loss (the eval path, also used by the fd tests).
    pub fn loss_only(
        &mut self,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
    ) -> Result<f32> {
        let dense = self.prepare(params, masks, false)?;
        let mlps = self.layer_mlps(dense.as_ref());
        let fwd = self.forward(params, &mlps, batch)?;
        Ok(fwd.loss as f32)
    }

    /// Bias-corrected AdamW, elementwise over every parameter — the exact
    /// update `python/compile/model.py::adam_update` fuses into the AOT
    /// step (`t = step + 1`; decoupled weight decay inside the lr factor).
    fn adam(&self, state: &mut TrainState, grads: &ParamStore) {
        let lr = self.cfg.lr as f32;
        let t = state.step + 1;
        let c1 = 1.0 - ADAM_B1.powi(t);
        let c2 = 1.0 - ADAM_B2.powi(t);
        let TrainState {
            params,
            adam_m,
            adam_v,
            ..
        } = state;
        for name in grads.names() {
            let g = grads.req(name).data();
            let p = params.get_mut(name).unwrap().data_mut();
            let mm = adam_m.get_mut(name).unwrap().data_mut();
            let vv = adam_v.get_mut(name).unwrap().data_mut();
            for i in 0..g.len() {
                let gi = g[i];
                mm[i] = ADAM_B1 * mm[i] + (1.0 - ADAM_B1) * gi;
                vv[i] = ADAM_B2 * vv[i] + (1.0 - ADAM_B2) * gi * gi;
                let upd = (mm[i] / c1) / ((vv[i] / c2).sqrt() + ADAM_EPS);
                p[i] -= lr * (upd + self.wd * p[i]);
            }
        }
    }
}

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn train_step(
        &mut self,
        state: &mut TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
        want_mlp_grads: bool,
    ) -> Result<StepOutput> {
        let (loss, grads) = self.loss_and_grads(&state.params, masks, batch)?;
        self.adam(state, &grads);
        state.step += 1;
        let mut mlp_grads = BTreeMap::new();
        if want_mlp_grads {
            for name in &self.cfg.mlp_weights {
                mlp_grads.insert(name.clone(), grads.req(name).clone());
            }
        }
        Ok(StepOutput { loss, mlp_grads })
    }

    fn eval_loss(
        &mut self,
        state: &TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
    ) -> Result<f32> {
        self.loss_only(&state.params, masks, batch)
    }

    fn grad_step(
        &mut self,
        state: &TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
    ) -> Result<Option<(f32, ParamStore)>> {
        self.loss_and_grads(&state.params, masks, batch).map(Some)
    }

    fn apply_update(&mut self, state: &mut TrainState, grads: &ParamStore) -> Result<()> {
        self.adam(state, grads);
        state.step += 1;
        Ok(())
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Single-threaded attention backward for one `(sample, head)`:
/// recompute the causal softmax `P` from the saved (post-RoPE) `Q`/`K`
/// (O(seq·hd) memory per item, no saved `seq²` tensor), then chain
/// `dV = Pᵀ·dO`, `dP = dO·Vᵀ`, `dS = P ∘ (dP − rowsum(dP ∘ P))`,
/// `dQ = scale·dS·K`, `dK = scale·dSᵀ·Q`. Accumulates into `dq/dk/dv`
/// (callers pass zeroed spans). Runs inside thread-pool items, so it must
/// not re-enter the pool — the inner loops are plain axpy/dot.
#[allow(clippy::too_many_arguments)] // mirrors the forward kernel ABI
fn attn_bwd_head(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    seq: usize,
    hd: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let scale = 1.0 / (hd as f32).sqrt();
    // recompute P row by row (causal: row i attends to 0..=i); both
    // seq² tiles come from the thread-local scratch arena — this runs
    // inside pool items on the training hot path, so per-item heap
    // allocations would put the allocator lock back on it
    let mut p = crate::util::scratch::take_zeroed(seq * seq);
    for i in 0..seq {
        let qi = &q[i * hd..(i + 1) * hd];
        for j in 0..=i {
            p[i * seq + j] = scale * dot(qi, &k[j * hd..(j + 1) * hd]);
        }
        ops::softmax_row(&mut p[i * seq..i * seq + i + 1]);
    }
    // dV[j,:] += Σ_i P[i,j]·dO[i,:]
    for i in 0..seq {
        let doi = &dout[i * hd..(i + 1) * hd];
        for j in 0..=i {
            let w = p[i * seq + j];
            if w != 0.0 {
                axpy(w, doi, &mut dv[j * hd..(j + 1) * hd]);
            }
        }
    }
    // dS = P ∘ (dP − rowsum(dP ∘ P)), scale folded in
    let mut ds = crate::util::scratch::take_zeroed(seq * seq);
    for i in 0..seq {
        let doi = &dout[i * hd..(i + 1) * hd];
        let mut rowdot = 0.0f32;
        for j in 0..=i {
            let dp = dot(doi, &v[j * hd..(j + 1) * hd]);
            ds[i * seq + j] = dp;
            rowdot += dp * p[i * seq + j];
        }
        for j in 0..=i {
            ds[i * seq + j] = p[i * seq + j] * (ds[i * seq + j] - rowdot) * scale;
        }
    }
    // dQ[i,:] += Σ_j dS[i,j]·K[j,:] ; dK[j,:] += Σ_i dS[i,j]·Q[i,:]
    for i in 0..seq {
        for j in 0..=i {
            let w = ds[i * seq + j];
            if w != 0.0 {
                axpy(w, &k[j * hd..(j + 1) * hd], &mut dq[i * hd..(i + 1) * hd]);
                axpy(w, &q[i * hd..(i + 1) * hd], &mut dk[j * hd..(j + 1) * hd]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::lm_config_info;
    use crate::util::rng::Rng;

    fn tiny_cfg(kind: &str) -> ConfigInfo {
        // small enough for finite differences, big enough to cross every
        // tile/panel boundary at least once (m=12, e=16, f=32, b=8)
        lm_config_info("tiny", kind, 24, 16, 32, 2, 2, 6, 2, 8, 1e-3, "test")
    }

    fn rand_batch(cfg: &ConfigInfo, rng: &mut Rng) -> LmBatch {
        let m = cfg.batch * cfg.seq;
        LmBatch {
            tokens: (0..m).map(|_| rng.below(cfg.vocab) as i32).collect(),
            targets: (0..m).map(|_| rng.below(cfg.vocab) as i32).collect(),
            batch: cfg.batch,
            seq: cfg.seq,
        }
    }

    fn rand_masks(cfg: &ConfigInfo, s: f64, rng: &mut Rng) -> BTreeMap<String, BlockMask> {
        cfg.masks
            .iter()
            .map(|(n, sh)| (n.clone(), BlockMask::random(sh[0], sh[1], s, rng)))
            .collect()
    }

    /// The acceptance-gate gradient check: the analytic gradient's norm
    /// must match the central finite difference of the loss along the
    /// gradient direction within 1e-3 relative error (both model kinds,
    /// sparse execution, masked MLP weights). Per-tensor directional
    /// checks run at a looser bound to localize any failure.
    #[test]
    fn gradients_match_finite_differences() {
        for kind in ["gpt2", "llama"] {
            let cfg = tiny_cfg(kind);
            let mut rng = Rng::new(42);
            let params = ParamStore::init(&cfg, 7);
            let masks = rand_masks(&cfg, 0.4, &mut rng);
            let batch = rand_batch(&cfg, &mut rng);
            let mut be = NativeBackend::with_exec(&cfg, MlpExec::Sparse).unwrap();
            let (loss, grads) = be.loss_and_grads(&params, &masks, &batch).unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{kind}: loss {loss}");

            // ---- global directional check (the 1e-3 gate) ----
            let gnorm2: f64 = grads
                .in_order()
                .map(|(_, g)| g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
                .sum();
            let gnorm = gnorm2.sqrt();
            assert!(gnorm > 1e-4, "{kind}: vanishing gradient {gnorm}");
            // ε chosen from a curvature sweep (error scales with ε², f32
            // noise is negligible down to ε = 2e-3): at 5e-3 the numpy
            // twin of this test measures rel ≈ 1.1–1.6e-4 — 6× under gate
            let eps = 5e-3f32;
            let scale = eps / gnorm as f32;
            let mut pp = params.clone();
            let mut pm = params.clone();
            for name in grads.names() {
                let g = grads.req(name).data();
                let wp = pp.get_mut(name).unwrap().data_mut();
                let wm = pm.get_mut(name).unwrap().data_mut();
                for i in 0..g.len() {
                    wp[i] += scale * g[i];
                    wm[i] -= scale * g[i];
                }
            }
            let lp = be.loss_only(&pp, &masks, &batch).unwrap() as f64;
            let lm = be.loss_only(&pm, &masks, &batch).unwrap() as f64;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let rel = (fd - gnorm).abs() / gnorm;
            assert!(
                rel <= 1e-3,
                "{kind}: directional fd {fd} vs |g| {gnorm} (rel {rel:.2e})"
            );

            // ---- per-tensor directional checks (localize failures) ----
            for name in grads.names() {
                let g = grads.req(name).data();
                let tnorm = g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
                if tnorm < 1e-4 {
                    continue;
                }
                let ts = eps / tnorm as f32;
                let mut pp = params.clone();
                let mut pm = params.clone();
                for i in 0..g.len() {
                    pp.get_mut(name).unwrap().data_mut()[i] += ts * g[i];
                    pm.get_mut(name).unwrap().data_mut()[i] -= ts * g[i];
                }
                let lp = be.loss_only(&pp, &masks, &batch).unwrap() as f64;
                let lm = be.loss_only(&pm, &masks, &batch).unwrap() as f64;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let rel = (fd - tnorm).abs() / tnorm;
                assert!(
                    rel <= 2e-2,
                    "{kind}/{name}: fd {fd} vs |g| {tnorm} (rel {rel:.2e})"
                );
            }
        }
    }

    /// Acceptance-gate invariant: MLP weight gradients are exactly zero
    /// outside resident blocks, in both execution modes, and carry real
    /// signal inside them.
    #[test]
    fn mlp_grads_exactly_zero_outside_resident_blocks() {
        for kind in ["gpt2", "llama"] {
            for exec in [MlpExec::Sparse, MlpExec::Dense] {
                let cfg = tiny_cfg(kind);
                let mut rng = Rng::new(5);
                let params = ParamStore::init(&cfg, 6);
                let masks = rand_masks(&cfg, 0.5, &mut rng);
                let batch = rand_batch(&cfg, &mut rng);
                let mut be = NativeBackend::with_exec(&cfg, exec).unwrap();
                let (_, grads) = be.loss_and_grads(&params, &masks, &batch).unwrap();
                let b = cfg.block;
                for name in &cfg.mlp_weights {
                    let g = grads.req(name);
                    let mask = &masks[name];
                    let mut resident_nonzero = false;
                    for br in 0..mask.rb {
                        for bc in 0..mask.cb {
                            for i in 0..b {
                                for j in 0..b {
                                    let val = g.at2(br * b + i, bc * b + j);
                                    if mask.get(br, bc) {
                                        resident_nonzero |= val != 0.0;
                                    } else {
                                        assert!(
                                            val == 0.0,
                                            "{kind}/{exec:?}/{name}: grad outside resident \
                                             block ({br},{bc})"
                                        );
                                    }
                                }
                            }
                        }
                    }
                    assert!(
                        resident_nonzero || mask.nnzb() == 0,
                        "{kind}/{exec:?}/{name}: no gradient signal in resident blocks"
                    );
                }
            }
        }
    }

    /// BSpMM execution and masked-dense execution are the same math.
    #[test]
    fn sparse_and_dense_exec_agree() {
        for kind in ["gpt2", "llama"] {
            let cfg = tiny_cfg(kind);
            let mut rng = Rng::new(21);
            let params = ParamStore::init(&cfg, 22);
            let masks = rand_masks(&cfg, 0.5, &mut rng);
            let batch = rand_batch(&cfg, &mut rng);
            let mut dense = NativeBackend::with_exec(&cfg, MlpExec::Dense).unwrap();
            let mut sparse = NativeBackend::with_exec(&cfg, MlpExec::Sparse).unwrap();
            let (ld, gd) = dense.loss_and_grads(&params, &masks, &batch).unwrap();
            let (ls, gs) = sparse.loss_and_grads(&params, &masks, &batch).unwrap();
            assert!((ld - ls).abs() < 1e-4, "{kind}: loss {ld} vs {ls}");
            for (name, g) in gd.in_order() {
                let diff = g.max_abs_diff(gs.req(name));
                assert!(diff < 1e-3, "{kind}/{name}: grad diff {diff}");
            }
        }
    }

    /// The incremental re-pack contract: first step builds structure, later
    /// steps only refresh values, a mask change rebuilds exactly the
    /// weights whose masks changed.
    #[test]
    fn incremental_repack_refreshes_until_mask_changes() {
        let cfg = tiny_cfg("gpt2");
        let n_w = cfg.mlp_weights.len();
        let mut rng = Rng::new(31);
        let masks = rand_masks(&cfg, 0.5, &mut rng);
        let batch = rand_batch(&cfg, &mut rng);
        let mut be = NativeBackend::with_exec(&cfg, MlpExec::Sparse).unwrap();
        let mut state = TrainState::new(ParamStore::init(&cfg, 32));
        be.train_step(&mut state, &masks, &batch, false).unwrap();
        let s1 = be.repack_stats();
        assert_eq!(s1, RepackStats { rebuilds: n_w, refreshes: 0 });
        // Adam moved every weight — values refresh, structure survives
        be.train_step(&mut state, &masks, &batch, false).unwrap();
        let s2 = be.repack_stats();
        assert_eq!(s2, RepackStats { rebuilds: n_w, refreshes: n_w });
        // flip one block of one mask — exactly one rebuild, rest refresh
        let mut masks2 = masks.clone();
        let first = cfg.mlp_weights[0].clone();
        {
            let m0 = masks2.get_mut(&first).unwrap();
            let flip = !m0.get(0, 0);
            m0.set(0, 0, flip);
        }
        be.train_step(&mut state, &masks2, &batch, false).unwrap();
        let s3 = be.repack_stats();
        assert_eq!(
            s3,
            RepackStats { rebuilds: n_w + 1, refreshes: 2 * n_w - 1 }
        );
        // the step output carries the requested masked grads
        let out = be.train_step(&mut state, &masks2, &batch, true).unwrap();
        assert_eq!(out.mlp_grads.len(), n_w);
        assert!(out.loss.is_finite());
    }

    /// Auto mode: dense below the switch, sparse above, dense for b=1.
    #[test]
    fn auto_exec_switches_on_sparsity_and_block() {
        let cfg = tiny_cfg("gpt2");
        let be = NativeBackend::new(&cfg).unwrap();
        let mut rng = Rng::new(41);
        let low = rand_masks(&cfg, 0.3, &mut rng);
        let high = rand_masks(&cfg, 0.8, &mut rng);
        assert!(!be.use_sparse(&low));
        assert!(be.use_sparse(&high));
        let cfg1 = lm_config_info("tiny-b1", "gpt2", 24, 16, 32, 1, 2, 6, 2, 1, 1e-3, "test");
        let be1 = NativeBackend::new(&cfg1).unwrap();
        let mut rng1 = Rng::new(43);
        let high1 = rand_masks(&cfg1, 0.9, &mut rng1);
        assert!(!be1.use_sparse(&high1));
    }

    /// ViT configs are rejected up front (the classifier path stays AOT).
    #[test]
    fn rejects_non_lm_kinds() {
        let mut cfg = tiny_cfg("gpt2");
        cfg.kind = "vit".into();
        assert!(NativeBackend::new(&cfg).is_err());
    }

    /// A few AdamW steps on a fixed batch drive the loss down and the
    /// update matches the reference formula on a hand-checked scalar.
    #[test]
    fn adam_steps_reduce_loss_on_fixed_batch() {
        let cfg = tiny_cfg("llama");
        let mut rng = Rng::new(51);
        let masks = rand_masks(&cfg, 0.4, &mut rng);
        let batch = rand_batch(&cfg, &mut rng);
        let mut be = NativeBackend::new(&cfg).unwrap();
        let mut state = TrainState::new(ParamStore::init(&cfg, 52));
        let mut losses = Vec::new();
        for _ in 0..8 {
            let out = be.train_step(&mut state, &masks, &batch, false).unwrap();
            losses.push(out.loss);
        }
        assert_eq!(state.step, 8);
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "loss did not decrease on a fixed batch: {losses:?}"
        );
    }

    /// The split step (`grad_step` + `apply_update`) is bit-identical to
    /// the fused `train_step` — the invariant the guarded trainer's
    /// bit-identity guarantee rests on.
    #[test]
    fn split_step_is_bit_identical_to_fused_step() {
        let cfg = tiny_cfg("gpt2");
        let mut rng = Rng::new(61);
        let masks = rand_masks(&cfg, 0.5, &mut rng);
        let mut be_fused = NativeBackend::new(&cfg).unwrap();
        let mut be_split = NativeBackend::new(&cfg).unwrap();
        let mut fused = TrainState::new(ParamStore::init(&cfg, 62));
        let mut split = TrainState::new(ParamStore::init(&cfg, 62));
        for _ in 0..4 {
            let batch = rand_batch(&cfg, &mut rng);
            let out = be_fused.train_step(&mut fused, &masks, &batch, false).unwrap();
            let (loss, grads) = be_split.grad_step(&split, &masks, &batch).unwrap().unwrap();
            be_split.apply_update(&mut split, &grads).unwrap();
            assert_eq!(out.loss.to_bits(), loss.to_bits());
        }
        assert_eq!(fused.step, split.step);
        for store in [
            (&fused.params, &split.params),
            (&fused.adam_m, &split.adam_m),
            (&fused.adam_v, &split.adam_v),
        ] {
            for ((na, ta), (nb, tb)) in store.0.in_order().zip(store.1.in_order()) {
                assert_eq!(na, nb);
                assert!(
                    ta.data().iter().zip(tb.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{na}: split step diverged from fused step"
                );
            }
        }
    }
}
