//! Per-step training guard — the training-side mirror of the serving
//! HealthState ladder (PR 6/8). Where serving retries a round, retires a
//! session, and finally fails a replica over, training:
//!
//! ```text
//! L1  clip     gradient norm > clip_norm        → scale grads to clip_norm
//! L2  skip     NaN/Inf loss or grads, norm >    → drop the update, jittered
//!              explode_norm, loss > EWMA·spike    bounded backoff, retry on
//!                                                 the next batch
//! L3  revert   mask update degrades the held-   → restore previous mask +
//!              out probe beyond mask_budget       zeroed blocks, cooldown,
//!                                                 retry at lower aggression
//! L4  rollback loss EWMA > best·(1+div_tol)     → restore last-good
//!              for div_steps consecutive          checkpoint, re-fork the
//!              accepted steps, or max_skips       data order
//!              consecutive skips
//! ```
//!
//! The guard is pure bookkeeping over `(loss, grad_norm)` pairs — all
//! decisions are deterministic functions of the observation stream, the
//! config, and one `fork_rng`-seeded jitter stream, so the whole ladder
//! is transliterated and pinned by `python/tests/train_guard_check.py`.
//! Guards-off runs never construct a `StepGuard` and are bit-identical
//! to the unguarded trainer.

use std::time::Duration;

use crate::model::params::ParamStore;
use crate::util::rng::Rng;

/// Thresholds and budgets for the guard ladder. Defaults are deliberately
/// loose — they catch catastrophic anomalies (NaN, 100× spikes), not
/// ordinary loss noise.
#[derive(Clone, Copy, Debug)]
pub struct GuardConfig {
    /// Global-norm clip: gradients scaled so their norm never exceeds this.
    pub clip_norm: f64,
    /// Gradient norm above this is an anomaly (skip, don't clip).
    pub explode_norm: f64,
    /// Loss above `EWMA · spike_mul` is an anomaly.
    pub spike_mul: f64,
    /// EWMA smoothing weight of the newest accepted loss.
    pub ewma_alpha: f64,
    /// Divergence tolerance: EWMA above `best · (1 + div_tol)` counts
    /// toward the rollback streak.
    pub div_tol: f64,
    /// Consecutive diverged steps that trigger a rollback.
    pub div_steps: usize,
    /// Consecutive skipped steps that escalate to a rollback.
    pub max_skips: usize,
    /// Base backoff after a skipped step (doubles per consecutive skip,
    /// capped at 16×, plus `below(base)` ms of jitter — the
    /// `restart_backoff_ms` idiom from the fleet).
    pub backoff_ms: u64,
    /// Rollbacks allowed before the run fails loudly.
    pub max_rollbacks: usize,
    /// Mask probe budget: post-update probe loss above
    /// `pre · (1 + mask_budget)` reverts the update. `INFINITY` disables
    /// the probe entirely.
    pub mask_budget: f64,
    /// Mask updates to defer after a revert.
    pub cooldown_updates: usize,
    /// Held-out batches per mask probe.
    pub probe_batches: usize,
}

impl Default for GuardConfig {
    fn default() -> GuardConfig {
        GuardConfig {
            clip_norm: 10.0,
            explode_norm: 1e3,
            spike_mul: 3.0,
            ewma_alpha: 0.3,
            div_tol: 0.2,
            div_steps: 5,
            max_skips: 8,
            backoff_ms: 5,
            max_rollbacks: 8,
            mask_budget: 0.25,
            cooldown_updates: 2,
            probe_batches: 1,
        }
    }
}

impl GuardConfig {
    /// Every threshold at infinity: the guard observes but can never
    /// clip, skip, revert, or roll back. A permissive guard's run must be
    /// bit-identical to guards-off (asserted in `chaos_training.rs`).
    pub fn permissive() -> GuardConfig {
        GuardConfig {
            clip_norm: f64::INFINITY,
            explode_norm: f64::INFINITY,
            spike_mul: f64::INFINITY,
            div_tol: f64::INFINITY,
            mask_budget: f64::INFINITY,
            ..GuardConfig::default()
        }
    }
}

/// Counters the guard accumulates over a run (monotone across rollbacks).
#[derive(Clone, Copy, Debug, Default)]
pub struct GuardStats {
    pub steps_accepted: u64,
    pub skips: u64,
    pub clips: u64,
    pub rollbacks: u64,
    pub mask_reverts: u64,
    pub mask_updates_deferred: u64,
    pub last_anomaly: Option<&'static str>,
}

/// The guard's verdict on one `(loss, grad_norm)` observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Verdict {
    /// Apply the optimizer update, scaling gradients by `clip_scale`
    /// first when present.
    Accept { clip_scale: Option<f32> },
    /// Drop the update (gradients discarded, step counter untouched) and
    /// sleep `backoff` before the next batch.
    Skip {
        reason: &'static str,
        backoff: Duration,
    },
}

/// Guard state in checkpoint-portable form: f64s as IEEE bit patterns
/// (`NAN` bits = uninitialized EWMA) so a save/restore round-trip is
/// bit-exact.
#[derive(Clone, Copy, Debug, Default)]
pub struct GuardPersist {
    pub ewma_bits: u64,
    pub best_bits: u64,
    pub div_streak: usize,
    pub skip_streak: usize,
    pub cooldown: usize,
    pub relaxed: bool,
    pub rollbacks: u64,
    pub skips: u64,
    pub clips: u64,
    pub mask_reverts: u64,
    pub deferred: u64,
}

/// The per-step anomaly guard. One instance lives on a guarded
/// [`Trainer`](crate::train::Trainer); all methods are deterministic.
pub struct StepGuard {
    cfg: GuardConfig,
    /// Backoff jitter stream, forked from the fault plan so armed storms
    /// replay bit-for-bit (`faults.fork_rng("train_guard")`).
    rng: Rng,
    /// EWMA of *accepted* losses; `None` until the first accepted step.
    ewma: Option<f64>,
    /// Best (lowest) EWMA seen — the divergence reference level.
    best: f64,
    div_streak: usize,
    skip_streak: usize,
    /// Mask updates still to defer after a revert.
    cooldown: usize,
    /// After a revert, the next attempted update halves its sparsity
    /// increment; cleared when an update passes the probe.
    relaxed: bool,
    stats: GuardStats,
}

impl StepGuard {
    pub fn new(cfg: GuardConfig, rng: Rng) -> StepGuard {
        StepGuard {
            cfg,
            rng,
            ewma: None,
            best: f64::INFINITY,
            div_streak: 0,
            skip_streak: 0,
            cooldown: 0,
            relaxed: false,
            stats: GuardStats::default(),
        }
    }

    pub fn config(&self) -> &GuardConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &GuardStats {
        &self.stats
    }

    /// No live anomaly streak — the condition for advancing the rollback
    /// anchor to a fresh checkpoint.
    pub fn healthy(&self) -> bool {
        self.div_streak == 0 && self.skip_streak == 0
    }

    /// Judge one step's `(loss, grad_norm)` *before* the optimizer runs.
    /// Counters and the skip streak advance here; the EWMA only advances
    /// in [`observe_accepted`](Self::observe_accepted) once the update is
    /// actually applied.
    pub fn check(&mut self, loss: f32, grad_norm: f64) -> Verdict {
        let reason = if !loss.is_finite() {
            Some("loss_nonfinite")
        } else if !grad_norm.is_finite() {
            Some("grad_nonfinite")
        } else if grad_norm > self.cfg.explode_norm {
            Some("grad_explode")
        } else if self
            .ewma
            .is_some_and(|e| loss as f64 > e * self.cfg.spike_mul)
        {
            Some("loss_spike")
        } else {
            None
        };
        match reason {
            Some(reason) => {
                self.skip_streak += 1;
                self.stats.skips += 1;
                self.stats.last_anomaly = Some(reason);
                let ms = guard_backoff_ms(self.cfg.backoff_ms, self.skip_streak, &mut self.rng);
                Verdict::Skip {
                    reason,
                    backoff: Duration::from_millis(ms),
                }
            }
            None => {
                self.skip_streak = 0;
                self.stats.steps_accepted += 1;
                let clip_scale = if grad_norm > self.cfg.clip_norm {
                    self.stats.clips += 1;
                    Some((self.cfg.clip_norm / grad_norm) as f32)
                } else {
                    None
                };
                Verdict::Accept { clip_scale }
            }
        }
    }

    /// Fold an accepted step's loss into the EWMA and advance the
    /// divergence streak. Returns `true` when the streak has reached
    /// `div_steps` — the trainer must roll back to the last-good anchor.
    pub fn observe_accepted(&mut self, loss: f32) -> bool {
        let l = loss as f64;
        let e = match self.ewma {
            None => l,
            Some(e) => self.cfg.ewma_alpha * l + (1.0 - self.cfg.ewma_alpha) * e,
        };
        self.ewma = Some(e);
        if e > self.best * (1.0 + self.cfg.div_tol) {
            self.div_streak += 1;
        } else {
            self.div_streak = 0;
        }
        if e < self.best {
            self.best = e;
        }
        self.div_streak >= self.cfg.div_steps
    }

    /// Has the consecutive-skip budget run out? (Escalates to rollback.)
    pub fn skips_exhausted(&self) -> bool {
        self.skip_streak >= self.cfg.max_skips
    }

    /// Account a rollback and restore the anchor's guard trajectory
    /// (EWMA/best/cooldown/relaxed) while keeping the monotone counters —
    /// the rolled-back run remembers how much trouble it has been in.
    /// `None` anchor (plain `run()` with no checkpoint dir) just clears
    /// the streaks so the run can limp on.
    pub fn rollback_restore(&mut self, anchor: Option<&GuardPersist>) {
        self.stats.rollbacks += 1;
        if let Some(a) = anchor {
            let e = f64::from_bits(a.ewma_bits);
            self.ewma = if e.is_nan() { None } else { Some(e) };
            self.best = f64::from_bits(a.best_bits);
            self.cooldown = a.cooldown;
            self.relaxed = a.relaxed;
        }
        self.div_streak = 0;
        self.skip_streak = 0;
    }

    /// True when the rollback budget is spent — the trainer fails the run
    /// loudly instead of thrashing.
    pub fn rollbacks_exhausted(&self) -> bool {
        self.stats.rollbacks >= self.cfg.max_rollbacks as u64
    }

    // ---- mask-update guardrail ----

    /// Gate one scheduled mask update. A cooldown from a recent revert
    /// consumes the update instead (counted as deferred).
    pub fn mask_update_allowed(&mut self) -> bool {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            self.stats.mask_updates_deferred += 1;
            return false;
        }
        true
    }

    /// Target sparsity for the next update: the schedule's value, or —
    /// right after a revert — only half the remaining climb from the
    /// current level (retry at lower aggression).
    pub fn mask_target(&self, scheduled: f64, current: f64) -> f64 {
        if self.relaxed && scheduled > current {
            current + (scheduled - current) * 0.5
        } else {
            scheduled
        }
    }

    /// Did the post-update probe stay inside the budget?
    pub fn mask_probe_ok(&self, before: f32, after: f32) -> bool {
        after.is_finite() && (after as f64) <= (before as f64) * (1.0 + self.cfg.mask_budget)
    }

    /// Account a reverted mask update: controller on cooldown, next
    /// attempt relaxed.
    pub fn note_mask_reverted(&mut self) {
        self.stats.mask_reverts += 1;
        self.cooldown = self.cfg.cooldown_updates;
        self.relaxed = true;
    }

    /// Account an accepted mask update (probe passed or probe disabled).
    pub fn note_mask_accepted(&mut self) {
        self.relaxed = false;
    }

    // ---- persistence ----

    /// Snapshot for the checkpoint meta block.
    pub fn persist(&self) -> GuardPersist {
        GuardPersist {
            ewma_bits: self.ewma.unwrap_or(f64::NAN).to_bits(),
            best_bits: self.best.to_bits(),
            div_streak: self.div_streak,
            skip_streak: self.skip_streak,
            cooldown: self.cooldown,
            relaxed: self.relaxed,
            rollbacks: self.stats.rollbacks,
            skips: self.stats.skips,
            clips: self.stats.clips,
            mask_reverts: self.stats.mask_reverts,
            deferred: self.stats.mask_updates_deferred,
        }
    }

    /// Restore from a checkpoint meta block (the resume path) — the
    /// inverse of [`persist`](Self::persist), bit-exact.
    pub fn restore(&mut self, p: &GuardPersist) {
        let e = f64::from_bits(p.ewma_bits);
        self.ewma = if e.is_nan() { None } else { Some(e) };
        self.best = f64::from_bits(p.best_bits);
        self.div_streak = p.div_streak;
        self.skip_streak = p.skip_streak;
        self.cooldown = p.cooldown;
        self.relaxed = p.relaxed;
        self.stats.rollbacks = p.rollbacks;
        self.stats.skips = p.skips;
        self.stats.clips = p.clips;
        self.stats.mask_reverts = p.mask_reverts;
        self.stats.mask_updates_deferred = p.deferred;
    }

    /// One-line counter summary for the CLI exit report.
    pub fn summary(&self) -> String {
        let s = &self.stats;
        let mut out = format!(
            "accepted={} skips={} clips={} rollbacks={} mask_reverts={} deferred={}",
            s.steps_accepted, s.skips, s.clips, s.rollbacks, s.mask_reverts, s.mask_updates_deferred
        );
        if let Some(a) = s.last_anomaly {
            out.push_str(&format!(" last_anomaly={a}"));
        }
        out
    }
}

/// Jittered bounded backoff after the `streak`-th consecutive skip
/// (1-based): `base · 2^min(streak−1, 4)` plus `below(base)` ms of
/// spec-seeded jitter — the same shape as the fleet's
/// `restart_backoff_ms`, so storms desynchronize instead of thundering.
pub fn guard_backoff_ms(base_ms: u64, streak: usize, rng: &mut Rng) -> u64 {
    let base = base_ms.max(1);
    (base << streak.saturating_sub(1).min(4)) + rng.below(base as usize) as u64
}

/// Global L2 norm over every tensor in `grads`, accumulated in f64 (the
/// clip decision must not itself overflow on exploded f32 gradients).
pub fn global_grad_norm(grads: &ParamStore) -> f64 {
    let mut acc = 0.0f64;
    for (_, t) in grads.in_order() {
        for &x in t.data() {
            acc += (x as f64) * (x as f64);
        }
    }
    acc.sqrt()
}

/// Scale every gradient tensor in place (the clip application).
pub fn scale_grads(grads: &mut ParamStore, scale: f32) {
    let names: Vec<String> = grads.names().to_vec();
    for name in &names {
        for x in grads.get_mut(name).unwrap().data_mut() {
            *x *= scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn guard(cfg: GuardConfig) -> StepGuard {
        StepGuard::new(cfg, Rng::new(7))
    }

    #[test]
    fn ewma_matches_closed_form_recurrence() {
        let mut g = guard(GuardConfig::permissive());
        let losses = [4.0f32, 3.5, 3.8, 3.2, 3.0];
        let mut expect: Option<f64> = None;
        for &l in &losses {
            assert_eq!(g.check(l, 1.0), Verdict::Accept { clip_scale: None });
            g.observe_accepted(l);
            expect = Some(match expect {
                None => l as f64,
                Some(e) => 0.3 * l as f64 + 0.7 * e,
            });
            assert_eq!(g.persist().ewma_bits, expect.unwrap().to_bits());
        }
    }

    #[test]
    fn clip_scale_kicks_in_above_threshold_only() {
        let mut g = guard(GuardConfig::default());
        assert_eq!(g.check(2.0, 9.99), Verdict::Accept { clip_scale: None });
        match g.check(2.0, 40.0) {
            Verdict::Accept {
                clip_scale: Some(s),
            } => assert_eq!(s.to_bits(), ((10.0f64 / 40.0) as f32).to_bits()),
            v => panic!("expected clipped accept, got {v:?}"),
        }
        assert_eq!(g.stats().clips, 1);
        assert_eq!(g.stats().steps_accepted, 2);
    }

    #[test]
    fn nonfinite_and_exploded_observations_skip() {
        let mut g = guard(GuardConfig::default());
        for (loss, norm, want) in [
            (f32::NAN, 1.0, "loss_nonfinite"),
            (2.0, f64::INFINITY, "grad_nonfinite"),
            (2.0, 1e4, "grad_explode"),
        ] {
            match g.check(loss, norm) {
                Verdict::Skip { reason, .. } => assert_eq!(reason, want),
                v => panic!("expected skip, got {v:?}"),
            }
        }
        assert_eq!(g.stats().skips, 3);
        assert!(!g.skips_exhausted());
        // accepting resets the streak
        g.check(2.0, 1.0);
        assert!(g.healthy());
    }

    #[test]
    fn loss_spike_needs_an_initialized_ewma() {
        let mut g = guard(GuardConfig::default());
        // first-ever loss can't spike — there is no baseline yet
        assert!(matches!(g.check(1e6, 1.0), Verdict::Accept { .. }));
        g.observe_accepted(2.0); // pretend the accepted loss was sane
        match g.check(100.0, 1.0) {
            Verdict::Skip { reason, .. } => assert_eq!(reason, "loss_spike"),
            v => panic!("expected spike skip, got {v:?}"),
        }
    }

    #[test]
    fn backoff_schedule_is_bounded_and_grows() {
        let mut rng = Rng::new(1);
        for streak in 1..=12usize {
            let ms = guard_backoff_ms(5, streak, &mut rng);
            let det = 5u64 << (streak - 1).min(4);
            assert!(ms >= det && ms < det + 5, "streak {streak}: {ms}");
        }
        // zero base is clamped to 1 (never a divide/modulo-by-zero)
        let ms = guard_backoff_ms(0, 1, &mut rng);
        assert!(ms >= 1 && ms < 2);
    }

    #[test]
    fn divergence_streak_triggers_rollback_after_div_steps() {
        let cfg = GuardConfig {
            div_steps: 3,
            ..GuardConfig::default()
        };
        let mut g = guard(cfg);
        // establish a good baseline
        for _ in 0..8 {
            g.check(1.0, 1.0);
            assert!(!g.observe_accepted(1.0));
        }
        // regress > 20% above best: streak builds, fires on the 3rd
        g.check(2.0, 1.0);
        assert!(!g.observe_accepted(2.0));
        g.check(2.0, 1.0);
        assert!(!g.observe_accepted(2.0));
        g.check(2.0, 1.0);
        assert!(g.observe_accepted(2.0));
        // one good-enough step anywhere resets the streak
        let mut h = guard(cfg);
        for _ in 0..8 {
            h.check(1.0, 1.0);
            h.observe_accepted(1.0);
        }
        h.check(2.0, 1.0);
        assert!(!h.observe_accepted(2.0));
        // EWMA decays back under best·1.2 if the loss recovers
        for _ in 0..12 {
            h.check(1.0, 1.0);
            assert!(!h.observe_accepted(1.0));
        }
        assert!(h.healthy());
    }

    #[test]
    fn rollback_restore_keeps_monotone_counters() {
        let mut g = guard(GuardConfig::default());
        g.check(1.0, 1.0);
        g.observe_accepted(1.0);
        let anchor = g.persist();
        // trouble after the anchor: skips accumulate
        g.check(f32::NAN, 1.0);
        g.check(f32::NAN, 1.0);
        g.rollback_restore(Some(&anchor));
        assert_eq!(g.stats().rollbacks, 1);
        assert_eq!(g.stats().skips, 2, "skip counter must survive rollback");
        assert!(g.healthy());
        assert_eq!(g.persist().ewma_bits, anchor.ewma_bits);
        assert!(!g.rollbacks_exhausted());
    }

    #[test]
    fn persist_restore_roundtrip_is_bit_exact() {
        let mut g = guard(GuardConfig::default());
        // uninitialized EWMA survives the NaN sentinel
        let p0 = g.persist();
        let mut h = guard(GuardConfig::default());
        h.restore(&p0);
        assert_eq!(h.persist().ewma_bits, p0.ewma_bits);
        // initialized state roundtrips every field
        g.check(3.0, 20.0);
        g.observe_accepted(3.0);
        g.check(f32::NAN, 1.0);
        g.note_mask_reverted();
        let p = g.persist();
        let mut k = guard(GuardConfig::default());
        k.restore(&p);
        let q = k.persist();
        assert_eq!(p.ewma_bits, q.ewma_bits);
        assert_eq!(p.best_bits, q.best_bits);
        assert_eq!(p.skip_streak, q.skip_streak);
        assert_eq!(p.cooldown, q.cooldown);
        assert_eq!(p.relaxed, q.relaxed);
        assert_eq!(p.skips, q.skips);
        assert_eq!(p.clips, q.clips);
        assert_eq!(p.mask_reverts, q.mask_reverts);
    }

    #[test]
    fn mask_guardrail_cooldown_and_relaxed_target() {
        let mut g = guard(GuardConfig::default());
        assert!(g.mask_update_allowed());
        assert_eq!(g.mask_target(0.6, 0.2), 0.6, "not relaxed: schedule wins");
        assert!(g.mask_probe_ok(2.0, 2.4));
        assert!(!g.mask_probe_ok(2.0, 2.6));
        assert!(!g.mask_probe_ok(2.0, f32::NAN));
        g.note_mask_reverted();
        // cooldown_updates=2 deferred updates, then allowed again
        assert!(!g.mask_update_allowed());
        assert!(!g.mask_update_allowed());
        assert!(g.mask_update_allowed());
        assert_eq!(g.stats().mask_updates_deferred, 2);
        // relaxed halves the remaining climb, never lowers below current
        assert_eq!(g.mask_target(0.6, 0.2), 0.4);
        assert_eq!(g.mask_target(0.1, 0.2), 0.1, "descending schedule passes through");
        g.note_mask_accepted();
        assert_eq!(g.mask_target(0.6, 0.2), 0.6);
    }

    #[test]
    fn permissive_guard_never_intervenes() {
        let mut g = guard(GuardConfig::permissive());
        for i in 0..100 {
            let loss = 1.0 + (i % 7) as f32 * 100.0; // wild swings
            assert_eq!(g.check(loss, 1e9), Verdict::Accept { clip_scale: None });
            assert!(!g.observe_accepted(loss));
        }
        assert_eq!(g.stats().skips, 0);
        assert_eq!(g.stats().clips, 0);
        assert!(g.healthy());
    }
}
