//! The training-backend seam: one trait, two executors.
//!
//! [`Trainer`](crate::train::Trainer) owns the *algorithm* of the paper's
//! Listing 1 — corpus batches, the prune-and-grow controller, mask
//! bookkeeping, logging — and delegates the numerical step
//! (forward + backward + Adam) to a [`TrainBackend`]:
//!
//! * [`NativeBackend`](crate::train::native::NativeBackend) — the default:
//!   the full step on the packed micro-kernel stack (PR 1/PR 3 machinery),
//!   with block-sparsity accelerating the backward pass too. Runs in every
//!   build, no artifacts needed.
//! * [`AotBackend`] — the original PJRT path: one fused `train_step` HLO
//!   executable per config. Only *opens* with the `pjrt` cargo feature +
//!   `make artifacts`; in default builds `Runtime::open` reports why.
//!
//! The ABI between trainer and backend is deliberately small: dense
//! parameter/optimizer state in a [`TrainState`], fine-grid (ABI-block)
//! masks, one corpus batch, and back come the loss and — when the
//! controller is about to run — the masked MLP weight gradients `G_i`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::data::corpus::LmBatch;
use crate::model::params::ParamStore;
use crate::runtime::{ConfigInfo, HostValue, Runtime};
use crate::sparse::BlockMask;
use crate::tensor::Tensor;

/// Dense host-side training state: parameters plus Adam first/second
/// moments (all in manifest ABI order) and the shared step counter.
pub struct TrainState {
    pub params: ParamStore,
    pub adam_m: ParamStore,
    pub adam_v: ParamStore,
    pub step: i32,
}

impl TrainState {
    /// Fresh optimizer state (zero moments, step 0) around `params`.
    pub fn new(params: ParamStore) -> TrainState {
        let mut adam_m = ParamStore::new();
        let mut adam_v = ParamStore::new();
        for (name, t) in params.in_order() {
            adam_m.insert(name.clone(), Tensor::zeros(t.shape()));
            adam_v.insert(name.clone(), Tensor::zeros(t.shape()));
        }
        TrainState {
            params,
            adam_m,
            adam_v,
            step: 0,
        }
    }
}

/// What one training step hands back to the trainer.
pub struct StepOutput {
    pub loss: f32,
    /// Masked MLP weight gradients (`G_i`, zero outside resident blocks),
    /// keyed by weight name. Populated only when the trainer requested
    /// them (`want_mlp_grads` — i.e. on mask-update iterations).
    pub mlp_grads: BTreeMap<String, Tensor>,
}

/// One executor of the fused train/eval step. Masks arrive on the fine
/// (ABI-block) grid — the trainer expands coarse `block_mult` grids before
/// calling — keyed by MLP weight name.
pub trait TrainBackend {
    /// Short tag for logs/CLI (`"native"` / `"aot"`).
    fn name(&self) -> &'static str;

    /// One fused step: forward + backward + Adam update, in place on
    /// `state`. Returns the loss and, when `want_mlp_grads`, the masked
    /// MLP gradients the prune-and-grow controller consumes.
    fn train_step(
        &mut self,
        state: &mut TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
        want_mlp_grads: bool,
    ) -> Result<StepOutput>;

    /// Held-out loss of one batch (no state mutation beyond internal
    /// caches).
    fn eval_loss(
        &mut self,
        state: &TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
    ) -> Result<f32>;

    /// First half of a *split* step: forward + backward only, no state
    /// mutation. Returns `Some((loss, grads))` when the backend can
    /// separate gradient computation from the optimizer update — the
    /// guarded trainer needs this window to inspect/clip/reject gradients
    /// before they reach Adam. `None` (the default) means the backend only
    /// offers the fused [`train_step`](Self::train_step); guards cannot be
    /// armed on it.
    fn grad_step(
        &mut self,
        _state: &TrainState,
        _masks: &BTreeMap<String, BlockMask>,
        _batch: &LmBatch,
    ) -> Result<Option<(f32, ParamStore)>> {
        Ok(None)
    }

    /// Second half of a split step: apply `grads` to `state` via the
    /// optimizer and advance the step counter — exactly what
    /// [`train_step`](Self::train_step) does after its backward pass, so a
    /// `grad_step` + `apply_update` pair is bit-identical to one fused
    /// step. Backends without a split step reject the call.
    fn apply_update(&mut self, _state: &mut TrainState, _grads: &ParamStore) -> Result<()> {
        bail!("backend has no split-step path (grad_step returned None)")
    }
}

/// The PJRT/AOT executor: drives the `<config>_train_step` /
/// `<config>_eval_loss` HLO entries with the flat positional ABI the
/// manifest records.
pub struct AotBackend<'rt> {
    rt: &'rt Runtime,
    cfg: ConfigInfo,
}

impl<'rt> AotBackend<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: ConfigInfo) -> AotBackend<'rt> {
        AotBackend { rt, cfg }
    }

    fn push_masks(&self, inputs: &mut Vec<HostValue>, masks: &BTreeMap<String, BlockMask>) {
        for (name, _) in &self.cfg.masks {
            inputs.push(HostValue::tensor(masks[name].to_tensor()));
        }
    }
}

impl TrainBackend for AotBackend<'_> {
    fn name(&self) -> &'static str {
        "aot"
    }

    fn train_step(
        &mut self,
        state: &mut TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
        want_mlp_grads: bool,
    ) -> Result<StepOutput> {
        let mut inputs =
            Vec::with_capacity(3 * state.params.len() + self.cfg.masks.len() + 3);
        for (_, t) in state.params.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        for (_, t) in state.adam_m.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        for (_, t) in state.adam_v.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        inputs.push(HostValue::scalar_i32(state.step));
        self.push_masks(&mut inputs, masks);
        inputs.push(HostValue::i32s(
            &[batch.batch, batch.seq],
            batch.tokens.clone(),
        ));
        inputs.push(HostValue::i32s(
            &[batch.batch, batch.seq],
            batch.targets.clone(),
        ));

        let entry = format!("{}_train_step", self.cfg.name);
        let out = self.rt.execute(&entry, &inputs)?;

        // unpack: P params, P m, P v, step, loss, G grads
        let p = state.params.len();
        let names: Vec<String> = state.params.names().to_vec();
        for (i, name) in names.iter().enumerate() {
            state
                .params
                .insert(name.clone(), out[i].clone().into_tensor()?);
            state
                .adam_m
                .insert(name.clone(), out[p + i].clone().into_tensor()?);
            state
                .adam_v
                .insert(name.clone(), out[2 * p + i].clone().into_tensor()?);
        }
        state.step = out[3 * p].as_i32().context("step")?[0];
        let loss = out[3 * p + 1].scalar()?;
        let mut mlp_grads = BTreeMap::new();
        if want_mlp_grads {
            for (gi, wname) in self.cfg.mlp_weights.iter().enumerate() {
                mlp_grads.insert(wname.clone(), out[3 * p + 2 + gi].clone().into_tensor()?);
            }
        }
        Ok(StepOutput { loss, mlp_grads })
    }

    fn eval_loss(
        &mut self,
        state: &TrainState,
        masks: &BTreeMap<String, BlockMask>,
        batch: &LmBatch,
    ) -> Result<f32> {
        let mut inputs = Vec::with_capacity(state.params.len() + self.cfg.masks.len() + 2);
        for (_, t) in state.params.in_order() {
            inputs.push(HostValue::from_tensor(t));
        }
        self.push_masks(&mut inputs, masks);
        inputs.push(HostValue::i32s(
            &[batch.batch, batch.seq],
            batch.tokens.clone(),
        ));
        inputs.push(HostValue::i32s(
            &[batch.batch, batch.seq],
            batch.targets.clone(),
        ));
        let entry = format!("{}_eval_loss", self.cfg.name);
        let out = self.rt.execute(&entry, &inputs)?;
        out[0].scalar()
    }
}
