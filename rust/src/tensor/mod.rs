//! Minimal dense tensor (row-major, f32) used by the native kernel stack
//! and the PJRT interchange layer.
//!
//! Deliberately small: contiguous storage, shape/stride math, elementwise
//! helpers. All heavy compute lives in [`crate::kernels`], which operates on
//! raw slices so the same micro-kernels serve both `Tensor` and the sparse
//! formats.

use crate::util::rng::Rng;

/// Row-major, contiguous, f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    /// N(0, scale²) entries.
    pub fn randn(shape: &[usize], scale: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(n, scale),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows/cols for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reinterpret the shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose (copy).
    pub fn transpose2(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(&[c, r], out)
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for x in &mut self.data {
            *x = f(*x);
        }
        self
    }

    pub fn add_inplace(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let tt = t.transpose2().transpose2();
        assert!(t.allclose(&tt, 0.0));
    }

    #[test]
    fn map_and_add() {
        let t = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).map(|x| x * 2.0);
        let mut u = Tensor::zeros(&[2, 2]);
        u.add_inplace(&t);
        assert_eq!(u.data(), &[2., 4., 6., 8.]);
    }

    #[test]
    fn fro_norm() {
        let t = Tensor::new(&[1, 2], vec![3., 4.]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-6);
    }
}
