//! Self-built substrates for the offline environment.
//!
//! Only the `xla` crate's dependency closure exists in the vendored
//! registry, so the usual ecosystem crates are re-implemented here at the
//! scale this project needs: a scoped thread pool (rayon stand-in) with
//! cost-aware scheduling, a thread-local scratch arena for kernel tile
//! buffers, a JSON parser/serializer (serde stand-in), a declarative CLI
//! parser (clap stand-in), a deterministic PRNG with the samplers the data
//! generators need, and timing/statistics helpers.

pub mod cli;
pub mod crc;
pub mod faults;
pub mod json;
pub mod logging;
pub mod rng;
pub mod scratch;
pub mod stats;
pub mod threadpool;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn ceil_div(n: usize, m: usize) -> usize {
    n.div_ceil(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }
}
