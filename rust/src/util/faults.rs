//! Deterministic, seed-driven fault injection.
//!
//! The supervised serving/training runtime is only trustworthy if its
//! failure paths are exercised on every CI run, not once a quarter in an
//! outage. This module provides the lever: a [`FaultPlan`] parsed from
//! `BLAST_FAULTS=site:prob:seed[,site:prob:seed...]` (or the `--faults`
//! flag, same grammar) arms named fault sites threaded through the hot
//! paths — each site draws from its *own* seeded [`Rng`] stream, so a
//! chaos run is reproducible from the spec string alone.
//!
//! Sites (see ARCHITECTURE.md "Failure domains & recovery"):
//!
//! | site                | effect at the injection point                   |
//! |---------------------|-------------------------------------------------|
//! | `decode_round_panic`| panic inside a batched decode round / a session's sequential fallback |
//! | `decode_round_error`| batched round returns a *transient* error (exercises bounded retry) |
//! | `prefill_error`     | `Engine::prefill` result replaced with an error |
//! | `kv_pool_exhausted` | batched round fails as if the KV pool ran dry   |
//! | `decode_stall_ms`   | decode round sleeps `value` ms (deadline tests) |
//! | `ckpt_torn_write`   | checkpoint write stops mid-payload (simulated crash) |
//! | `scheduler_panic`   | scheduler thread dies *outside* round isolation (watchdog tests) |
//!
//! An optional fourth field sets a per-site magnitude
//! (`decode_stall_ms:1:7:40` = 40 ms stalls); other sites ignore it.
//!
//! **Zero overhead when disabled**: [`Faults`] is an `Option<Arc<..>>`;
//! with no plan armed every [`Faults::fire`] call is a single pointer
//! null-check — no lock, no RNG draw, no counter traffic — so the
//! serving/training hot paths compile to the existing code. The no-faults
//! parity test in `tests/chaos_serving.rs` pins bit-identical outputs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// A named injection point. Keep [`FaultSite::ALL`] in sync.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Panic inside the batched decode round (and, redrawn per session,
    /// inside the sequential fallback — a "session panic").
    DecodeRoundPanic,
    /// The batched round returns a transient error — the one failure class
    /// the coordinator answers with retry-plus-jittered-backoff rather
    /// than an immediate sequential fallback.
    DecodeRoundError,
    /// Prefill returns an injected error instead of running.
    PrefillError,
    /// The batched round fails with a pool-exhausted error (classified
    /// non-transient: no retry, straight to the sequential fallback).
    KvPoolExhausted,
    /// The decode round stalls for `value` milliseconds.
    DecodeStallMs,
    /// A checkpoint write stops after half the payload (crash simulation);
    /// the atomic tmp+rename protocol must leave the old file intact.
    CkptTornWrite,
    /// The scheduler thread panics outside per-round isolation; the
    /// watchdog must fail pending requests instead of hanging clients.
    SchedulerPanic,
}

impl FaultSite {
    pub const ALL: [FaultSite; 7] = [
        FaultSite::DecodeRoundPanic,
        FaultSite::DecodeRoundError,
        FaultSite::PrefillError,
        FaultSite::KvPoolExhausted,
        FaultSite::DecodeStallMs,
        FaultSite::CkptTornWrite,
        FaultSite::SchedulerPanic,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::DecodeRoundPanic => "decode_round_panic",
            FaultSite::DecodeRoundError => "decode_round_error",
            FaultSite::PrefillError => "prefill_error",
            FaultSite::KvPoolExhausted => "kv_pool_exhausted",
            FaultSite::DecodeStallMs => "decode_stall_ms",
            FaultSite::CkptTornWrite => "ckpt_torn_write",
            FaultSite::SchedulerPanic => "scheduler_panic",
        }
    }

    fn from_name(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Default magnitude when the spec omits the fourth field.
    fn default_value(self) -> u64 {
        match self {
            FaultSite::DecodeStallMs => 25,
            _ => 0,
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

struct SiteState {
    prob: f64,
    value: u64,
    rng: Mutex<Rng>,
    checked: AtomicU64,
    fired: AtomicU64,
}

/// The armed plan: per-site probability, magnitude and RNG stream.
pub struct FaultPlan {
    sites: [Option<SiteState>; 7],
    spec: String,
}

/// Cheap cloneable handle to an optional [`FaultPlan`].
///
/// `Faults::disabled()` (the default) is a `None` — every query is one
/// branch. All clones share the same per-site RNG streams and counters,
/// so the fire sequence is globally deterministic for a given spec.
#[derive(Clone, Default)]
pub struct Faults(Option<Arc<FaultPlan>>);

impl std::fmt::Debug for Faults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "Faults(disabled)"),
            Some(p) => write!(f, "Faults({:?})", p.spec),
        }
    }
}

impl Faults {
    /// No faults: every site is a no-op null-check.
    pub fn disabled() -> Faults {
        Faults(None)
    }

    /// Parse a `site:prob:seed[:value][,...]` spec. Empty/whitespace input
    /// yields a disabled handle. Probabilities are clamped to `[0, 1]`.
    pub fn parse(spec: &str) -> Result<Faults> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(Faults::disabled());
        }
        let mut sites: [Option<SiteState>; 7] = Default::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                bail!("fault spec {part:?}: want site:prob:seed[:value]");
            }
            let site = FaultSite::from_name(fields[0]).ok_or_else(|| {
                let names: Vec<&str> = FaultSite::ALL.iter().map(|f| f.name()).collect();
                anyhow::anyhow!("unknown fault site {:?}; known sites: {names:?}", fields[0])
            })?;
            let prob: f64 = fields[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec {part:?}: bad probability {:?}", fields[1]))?;
            let seed: u64 = fields[2]
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec {part:?}: bad seed {:?}", fields[2]))?;
            let value: u64 = match fields.get(3) {
                Some(v) => v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault spec {part:?}: bad value {v:?}"))?,
                None => site.default_value(),
            };
            sites[site.index()] = Some(SiteState {
                prob: prob.clamp(0.0, 1.0),
                value,
                // fork per site from the site name so two sites with the
                // same seed still draw independent streams
                rng: Mutex::new(Rng::new(seed ^ crate::util::crc::crc32(site.name().as_bytes()) as u64)),
                checked: AtomicU64::new(0),
                fired: AtomicU64::new(0),
            });
        }
        Ok(Faults(Some(Arc::new(FaultPlan {
            sites,
            spec: spec.to_string(),
        }))))
    }

    /// Arm from the `BLAST_FAULTS` environment variable. A malformed spec
    /// is a configuration error worth failing loudly on — chaos runs must
    /// not silently become no-fault runs.
    pub fn from_env() -> Result<Faults> {
        match std::env::var("BLAST_FAULTS") {
            Ok(v) => Faults::parse(&v),
            Err(_) => Ok(Faults::disabled()),
        }
    }

    /// `true` when a plan is armed (any site).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// The spec string the plan was parsed from (empty when disabled).
    pub fn spec(&self) -> &str {
        self.0.as_ref().map(|p| p.spec.as_str()).unwrap_or("")
    }

    /// Should `site` fire now? One deterministic draw from the site's
    /// stream; always `false` (and free) when disabled or the site is
    /// not armed.
    #[inline]
    pub fn fire(&self, site: FaultSite) -> bool {
        let Some(plan) = &self.0 else { return false };
        plan.fire(site)
    }

    /// [`Faults::fire`] for `decode_stall_ms`-style sites: the stall
    /// duration when the site fires.
    pub fn stall(&self, site: FaultSite) -> Option<Duration> {
        let plan = self.0.as_ref()?;
        if plan.fire(site) {
            let ms = plan.sites[site.index()].as_ref().map(|s| s.value).unwrap_or(0);
            Some(Duration::from_millis(ms))
        } else {
            None
        }
    }

    /// Times `site` has fired so far.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.0
            .as_ref()
            .and_then(|p| p.sites[site.index()].as_ref())
            .map(|s| s.fired.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Total injections across all sites.
    pub fn total_fired(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.fired(s)).sum()
    }

    /// One-line `site=fired/checked` digest for logs and the chaos driver.
    pub fn summary(&self) -> String {
        let Some(plan) = &self.0 else {
            return "faults disabled".into();
        };
        let mut parts = Vec::new();
        for site in FaultSite::ALL {
            if let Some(s) = &plan.sites[site.index()] {
                parts.push(format!(
                    "{}={}/{}",
                    site.name(),
                    s.fired.load(Ordering::Relaxed),
                    s.checked.load(Ordering::Relaxed)
                ));
            }
        }
        parts.join(" ")
    }
}

impl FaultPlan {
    fn fire(&self, site: FaultSite) -> bool {
        let Some(s) = &self.sites[site.index()] else {
            return false;
        };
        s.checked.fetch_add(1, Ordering::Relaxed);
        if s.prob <= 0.0 {
            return false;
        }
        let hit = s.prob >= 1.0 || s.rng.lock().unwrap().f64() < s.prob;
        if hit {
            s.fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_disabled_and_free() {
        let f = Faults::parse("").unwrap();
        assert!(!f.enabled());
        for site in FaultSite::ALL {
            assert!(!f.fire(site));
        }
        assert_eq!(f.total_fired(), 0);
        assert_eq!(Faults::parse("   ").unwrap().enabled(), false);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Faults::parse("bogus_site:1:2").is_err());
        assert!(Faults::parse("prefill_error:x:2").is_err());
        assert!(Faults::parse("prefill_error:0.5").is_err());
        assert!(Faults::parse("prefill_error:0.5:1:2:3").is_err());
    }

    #[test]
    fn deterministic_fire_sequence() {
        let spec = "decode_round_panic:0.3:42,prefill_error:0.7:7";
        let a = Faults::parse(spec).unwrap();
        let b = Faults::parse(spec).unwrap();
        for _ in 0..200 {
            assert_eq!(
                a.fire(FaultSite::DecodeRoundPanic),
                b.fire(FaultSite::DecodeRoundPanic)
            );
            assert_eq!(a.fire(FaultSite::PrefillError), b.fire(FaultSite::PrefillError));
        }
        assert_eq!(
            a.fired(FaultSite::DecodeRoundPanic),
            b.fired(FaultSite::DecodeRoundPanic)
        );
        assert!(a.total_fired() > 0);
    }

    #[test]
    fn probability_extremes() {
        let f = Faults::parse("prefill_error:1:1,kv_pool_exhausted:0:1").unwrap();
        for _ in 0..50 {
            assert!(f.fire(FaultSite::PrefillError));
            assert!(!f.fire(FaultSite::KvPoolExhausted));
        }
        // unarmed site never fires even with a plan present
        assert!(!f.fire(FaultSite::DecodeRoundPanic));
    }

    #[test]
    fn site_streams_are_independent() {
        // same seed, different sites → different draw sequences
        let f = Faults::parse("decode_round_panic:0.5:9,prefill_error:0.5:9").unwrap();
        let a: Vec<bool> = (0..64).map(|_| f.fire(FaultSite::DecodeRoundPanic)).collect();
        let b: Vec<bool> = (0..64).map(|_| f.fire(FaultSite::PrefillError)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn stall_returns_configured_duration() {
        let f = Faults::parse("decode_stall_ms:1:3:40").unwrap();
        assert_eq!(f.stall(FaultSite::DecodeStallMs), Some(Duration::from_millis(40)));
        // default value when the field is omitted
        let g = Faults::parse("decode_stall_ms:1:3").unwrap();
        assert_eq!(g.stall(FaultSite::DecodeStallMs), Some(Duration::from_millis(25)));
        // disabled → None, and no counter movement
        assert_eq!(Faults::disabled().stall(FaultSite::DecodeStallMs), None);
    }

    #[test]
    fn summary_reports_counters() {
        let f = Faults::parse("prefill_error:1:1").unwrap();
        f.fire(FaultSite::PrefillError);
        f.fire(FaultSite::PrefillError);
        assert_eq!(f.summary(), "prefill_error=2/2");
        assert_eq!(Faults::disabled().summary(), "faults disabled");
    }
}
