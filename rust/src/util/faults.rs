//! Deterministic, seed-driven fault injection.
//!
//! The supervised serving/training runtime is only trustworthy if its
//! failure paths are exercised on every CI run, not once a quarter in an
//! outage. This module provides the lever: a [`FaultPlan`] parsed from
//! `BLAST_FAULTS=site:prob:seed[,site:prob:seed...]` (or the `--faults`
//! flag, same grammar) arms named fault sites threaded through the hot
//! paths — each site draws from its *own* seeded [`Rng`] stream, so a
//! chaos run is reproducible from the spec string alone.
//!
//! Sites (see ARCHITECTURE.md "Failure domains & recovery"):
//!
//! | site                | effect at the injection point                   |
//! |---------------------|-------------------------------------------------|
//! | `decode_round_panic`| panic inside a batched decode round / a session's sequential fallback |
//! | `decode_round_error`| batched round returns a *transient* error (exercises bounded retry) |
//! | `prefill_error`     | `Engine::prefill` result replaced with an error |
//! | `kv_pool_exhausted` | batched round fails as if the KV pool ran dry   |
//! | `decode_stall_ms`   | decode round sleeps `value` ms (deadline tests) |
//! | `ckpt_torn_write`   | checkpoint write stops mid-payload (simulated crash) |
//! | `scheduler_panic`   | scheduler thread dies *outside* round isolation (watchdog tests) |
//! | `replica_crash`     | a fleet replica's scheduler dies (fleet restart + session failover) |
//! | `replica_stall_ms`  | a replica's scheduler loop freezes `value` ms (heartbeat stall detection) |
//! | `heartbeat_drop`    | a replica skips one heartbeat bump (stall-detector noise immunity) |
//! | `grad_nan`          | one gradient element becomes NaN before the optimizer (guarded training) |
//! | `grad_explode`      | all gradients scaled by `value` (default 10⁶) — clip/skip ladder |
//! | `loss_spike_mul`    | the observed loss multiplied by `value` (default 100) — EWMA spike detector |
//! | `mask_corrupt`      | a prune-and-grow mask update replaced with a catastrophic mask (probe/revert path) |
//!
//! An optional fourth field sets a per-site magnitude
//! (`decode_stall_ms:1:7:40` = 40 ms stalls); other sites ignore it.
//! The four training sites inject on the **guarded** training path
//! (`StepGuard` armed) — they exist to prove the guard ladder catches
//! them, and the unguarded fused step never consults them.
//!
//! Multi-replica runs fork one armed plan per replica with
//! [`Faults::fork`]: each replica re-derives every site's RNG stream from
//! `(seed, site, salt)`, so per-replica fault schedules are deterministic
//! regardless of how the replicas' threads interleave — a shared plan
//! would make the draw order (and thus the whole chaos run) racy.
//!
//! **Zero overhead when disabled**: [`Faults`] is an `Option<Arc<..>>`;
//! with no plan armed every [`Faults::fire`] call is a single pointer
//! null-check — no lock, no RNG draw, no counter traffic — so the
//! serving/training hot paths compile to the existing code. The no-faults
//! parity test in `tests/chaos_serving.rs` pins bit-identical outputs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// A named injection point. Keep [`FaultSite::ALL`] in sync.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Panic inside the batched decode round (and, redrawn per session,
    /// inside the sequential fallback — a "session panic").
    DecodeRoundPanic,
    /// The batched round returns a transient error — the one failure class
    /// the coordinator answers with retry-plus-jittered-backoff rather
    /// than an immediate sequential fallback.
    DecodeRoundError,
    /// Prefill returns an injected error instead of running.
    PrefillError,
    /// The batched round fails with a pool-exhausted error (classified
    /// non-transient: no retry, straight to the sequential fallback).
    KvPoolExhausted,
    /// The decode round stalls for `value` milliseconds.
    DecodeStallMs,
    /// A checkpoint write stops after half the payload (crash simulation);
    /// the atomic tmp+rename protocol must leave the old file intact.
    CkptTornWrite,
    /// The scheduler thread panics outside per-round isolation; the
    /// watchdog must fail pending requests instead of hanging clients.
    SchedulerPanic,
    /// A fleet replica's scheduler dies wholesale (same mechanics as
    /// `scheduler_panic`, armed per replica via [`Faults::fork`]): the
    /// fleet must detect the death, fail sessions over to survivors and
    /// restart the replica with bounded backoff.
    ReplicaCrash,
    /// A replica's scheduler loop freezes for `value` milliseconds without
    /// dying — the straggler case heartbeat stall detection exists for.
    ReplicaStallMs,
    /// One heartbeat bump is skipped (lossy heartbeat channel); the stall
    /// detector must tolerate isolated drops without deposing the replica.
    HeartbeatDrop,
    /// One gradient element turns NaN after the backward pass — the
    /// guarded trainer must skip the optimizer update instead of letting
    /// Adam propagate the NaN into every parameter.
    GradNan,
    /// Every gradient scaled by `value` (default 10⁶): below the guard's
    /// explode threshold this exercises global-norm clipping, above it
    /// the skip-with-backoff path.
    GradExplode,
    /// The observed loss multiplied by `value` (default 100) — gradients
    /// stay healthy, so this isolates the EWMA spike detector (a false
    /// positive the run must survive by skipping one clean batch).
    LossSpikeMul,
    /// A prune-and-grow mask update replaced with a catastrophic mask
    /// (one surviving block per weight) — the held-out probe must catch
    /// the degradation and revert, or divergence rollback must recover.
    MaskCorrupt,
}

impl FaultSite {
    pub const ALL: [FaultSite; 14] = [
        FaultSite::DecodeRoundPanic,
        FaultSite::DecodeRoundError,
        FaultSite::PrefillError,
        FaultSite::KvPoolExhausted,
        FaultSite::DecodeStallMs,
        FaultSite::CkptTornWrite,
        FaultSite::SchedulerPanic,
        FaultSite::ReplicaCrash,
        FaultSite::ReplicaStallMs,
        FaultSite::HeartbeatDrop,
        FaultSite::GradNan,
        FaultSite::GradExplode,
        FaultSite::LossSpikeMul,
        FaultSite::MaskCorrupt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::DecodeRoundPanic => "decode_round_panic",
            FaultSite::DecodeRoundError => "decode_round_error",
            FaultSite::PrefillError => "prefill_error",
            FaultSite::KvPoolExhausted => "kv_pool_exhausted",
            FaultSite::DecodeStallMs => "decode_stall_ms",
            FaultSite::CkptTornWrite => "ckpt_torn_write",
            FaultSite::SchedulerPanic => "scheduler_panic",
            FaultSite::ReplicaCrash => "replica_crash",
            FaultSite::ReplicaStallMs => "replica_stall_ms",
            FaultSite::HeartbeatDrop => "heartbeat_drop",
            FaultSite::GradNan => "grad_nan",
            FaultSite::GradExplode => "grad_explode",
            FaultSite::LossSpikeMul => "loss_spike_mul",
            FaultSite::MaskCorrupt => "mask_corrupt",
        }
    }

    fn from_name(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Default magnitude when the spec omits the fourth field.
    fn default_value(self) -> u64 {
        match self {
            FaultSite::DecodeStallMs => 25,
            // long enough for a fleet stall detector with a sub-100ms
            // threshold to notice, short enough that joining the deposed
            // thread at shutdown stays cheap
            FaultSite::ReplicaStallMs => 150,
            // far above any sane explode threshold, so the default storm
            // exercises the skip ladder rather than silent clipping
            FaultSite::GradExplode => 1_000_000,
            // two orders of magnitude over a healthy LM loss: trips any
            // reasonable EWMA spike multiplier
            FaultSite::LossSpikeMul => 100,
            _ => 0,
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

struct SiteState {
    prob: f64,
    value: u64,
    /// The spec seed, kept so [`Faults::fork`] can re-derive the stream
    /// with a per-replica salt instead of splitting the live RNG (which
    /// would make forked streams depend on how many draws happened first).
    seed: u64,
    rng: Mutex<Rng>,
    checked: AtomicU64,
    fired: AtomicU64,
}

impl SiteState {
    /// Per-site stream seed: the spec seed forked by the site name (so two
    /// sites with the same seed draw independently) and by an optional
    /// salt (so each fleet replica draws independently of its peers).
    /// Salt 0 reproduces the unforked plan bit-for-bit.
    fn stream_seed(seed: u64, site: FaultSite, salt: u64) -> u64 {
        seed ^ crate::util::crc::crc32(site.name().as_bytes()) as u64
            ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }
}

const N_SITES: usize = FaultSite::ALL.len();

/// The armed plan: per-site probability, magnitude and RNG stream.
pub struct FaultPlan {
    sites: [Option<SiteState>; N_SITES],
    spec: String,
    /// Replica salt this plan was forked with (0 = the root plan).
    salt: u64,
}

/// Cheap cloneable handle to an optional [`FaultPlan`].
///
/// `Faults::disabled()` (the default) is a `None` — every query is one
/// branch. All clones share the same per-site RNG streams and counters,
/// so the fire sequence is globally deterministic for a given spec.
#[derive(Clone, Default)]
pub struct Faults(Option<Arc<FaultPlan>>);

impl std::fmt::Debug for Faults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "Faults(disabled)"),
            Some(p) => write!(f, "Faults({:?})", p.spec),
        }
    }
}

impl Faults {
    /// No faults: every site is a no-op null-check.
    pub fn disabled() -> Faults {
        Faults(None)
    }

    /// Parse a `site:prob:seed[:value][,...]` spec. Empty/whitespace input
    /// yields a disabled handle. Probabilities are clamped to `[0, 1]`.
    pub fn parse(spec: &str) -> Result<Faults> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(Faults::disabled());
        }
        let mut sites: [Option<SiteState>; N_SITES] = Default::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                bail!("fault spec {part:?}: want site:prob:seed[:value]");
            }
            let site = FaultSite::from_name(fields[0]).ok_or_else(|| {
                let names: Vec<&str> = FaultSite::ALL.iter().map(|f| f.name()).collect();
                anyhow::anyhow!("unknown fault site {:?}; known sites: {names:?}", fields[0])
            })?;
            let prob: f64 = fields[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec {part:?}: bad probability {:?}", fields[1]))?;
            let seed: u64 = fields[2]
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec {part:?}: bad seed {:?}", fields[2]))?;
            let value: u64 = match fields.get(3) {
                Some(v) => v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault spec {part:?}: bad value {v:?}"))?,
                None => site.default_value(),
            };
            sites[site.index()] = Some(SiteState {
                prob: prob.clamp(0.0, 1.0),
                value,
                seed,
                rng: Mutex::new(Rng::new(SiteState::stream_seed(seed, site, 0))),
                checked: AtomicU64::new(0),
                fired: AtomicU64::new(0),
            });
        }
        Ok(Faults(Some(Arc::new(FaultPlan {
            sites,
            spec: spec.to_string(),
            salt: 0,
        }))))
    }

    /// Fork a per-replica plan: same sites, probabilities and magnitudes,
    /// but every site's RNG stream re-derived from `(seed, site, salt)`
    /// with fresh fired/checked counters. Forking a disabled handle stays
    /// disabled; salt 0 reproduces the root plan's streams bit-for-bit.
    pub fn fork(&self, salt: u64) -> Faults {
        let Some(plan) = &self.0 else {
            return Faults::disabled();
        };
        let mut sites: [Option<SiteState>; N_SITES] = Default::default();
        for site in FaultSite::ALL {
            if let Some(s) = &plan.sites[site.index()] {
                sites[site.index()] = Some(SiteState {
                    prob: s.prob,
                    value: s.value,
                    seed: s.seed,
                    rng: Mutex::new(Rng::new(SiteState::stream_seed(s.seed, site, salt))),
                    checked: AtomicU64::new(0),
                    fired: AtomicU64::new(0),
                });
            }
        }
        Faults(Some(Arc::new(FaultPlan {
            sites,
            spec: plan.spec.clone(),
            salt,
        })))
    }

    /// A deterministic jitter stream tied to this plan: seeded from
    /// `(crc32(spec), crc32(label), salt)` when armed, from `label` alone
    /// when disabled. Backoff schedules (round retries, replica restarts)
    /// draw from this instead of ad-hoc constants so a chaos run's timing
    /// jitter replays bit-for-bit from the spec string.
    pub fn fork_rng(&self, label: &str) -> Rng {
        let l = crate::util::crc::crc32(label.as_bytes()) as u64;
        match &self.0 {
            None => Rng::new(0xB0FF ^ l),
            Some(plan) => Rng::new(
                ((crate::util::crc::crc32(plan.spec.as_bytes()) as u64) << 32)
                    ^ l
                    ^ plan.salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ 0xB0FF,
            ),
        }
    }

    /// Arm from the `BLAST_FAULTS` environment variable. A malformed spec
    /// is a configuration error worth failing loudly on — chaos runs must
    /// not silently become no-fault runs.
    pub fn from_env() -> Result<Faults> {
        match std::env::var("BLAST_FAULTS") {
            Ok(v) => Faults::parse(&v),
            Err(_) => Ok(Faults::disabled()),
        }
    }

    /// `true` when a plan is armed (any site).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// The spec string the plan was parsed from (empty when disabled).
    pub fn spec(&self) -> &str {
        self.0.as_ref().map(|p| p.spec.as_str()).unwrap_or("")
    }

    /// Should `site` fire now? One deterministic draw from the site's
    /// stream; always `false` (and free) when disabled or the site is
    /// not armed.
    #[inline]
    pub fn fire(&self, site: FaultSite) -> bool {
        let Some(plan) = &self.0 else { return false };
        plan.fire(site)
    }

    /// [`Faults::fire`] for `decode_stall_ms`-style sites: the stall
    /// duration when the site fires.
    pub fn stall(&self, site: FaultSite) -> Option<Duration> {
        let plan = self.0.as_ref()?;
        if plan.fire(site) {
            let ms = plan.sites[site.index()].as_ref().map(|s| s.value).unwrap_or(0);
            Some(Duration::from_millis(ms))
        } else {
            None
        }
    }

    /// The armed magnitude of `site` (the optional fourth spec field),
    /// falling back to the site's default when unarmed — the guarded
    /// trainer uses this for `grad_explode` / `loss_spike_mul` scaling.
    pub fn magnitude(&self, site: FaultSite) -> u64 {
        self.0
            .as_ref()
            .and_then(|p| p.sites[site.index()].as_ref())
            .map(|s| s.value)
            .unwrap_or_else(|| site.default_value())
    }

    /// Times `site` has fired so far.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.0
            .as_ref()
            .and_then(|p| p.sites[site.index()].as_ref())
            .map(|s| s.fired.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Total injections across all sites.
    pub fn total_fired(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.fired(s)).sum()
    }

    /// One-line `site=fired/checked` digest for logs and the chaos driver.
    pub fn summary(&self) -> String {
        let Some(plan) = &self.0 else {
            return "faults disabled".into();
        };
        let mut parts = Vec::new();
        for site in FaultSite::ALL {
            if let Some(s) = &plan.sites[site.index()] {
                parts.push(format!(
                    "{}={}/{}",
                    site.name(),
                    s.fired.load(Ordering::Relaxed),
                    s.checked.load(Ordering::Relaxed)
                ));
            }
        }
        parts.join(" ")
    }
}

impl FaultPlan {
    fn fire(&self, site: FaultSite) -> bool {
        let Some(s) = &self.sites[site.index()] else {
            return false;
        };
        s.checked.fetch_add(1, Ordering::Relaxed);
        if s.prob <= 0.0 {
            return false;
        }
        let hit = s.prob >= 1.0 || s.rng.lock().unwrap().f64() < s.prob;
        if hit {
            s.fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_disabled_and_free() {
        let f = Faults::parse("").unwrap();
        assert!(!f.enabled());
        for site in FaultSite::ALL {
            assert!(!f.fire(site));
        }
        assert_eq!(f.total_fired(), 0);
        assert_eq!(Faults::parse("   ").unwrap().enabled(), false);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Faults::parse("bogus_site:1:2").is_err());
        assert!(Faults::parse("prefill_error:x:2").is_err());
        assert!(Faults::parse("prefill_error:0.5").is_err());
        assert!(Faults::parse("prefill_error:0.5:1:2:3").is_err());
    }

    #[test]
    fn deterministic_fire_sequence() {
        let spec = "decode_round_panic:0.3:42,prefill_error:0.7:7";
        let a = Faults::parse(spec).unwrap();
        let b = Faults::parse(spec).unwrap();
        for _ in 0..200 {
            assert_eq!(
                a.fire(FaultSite::DecodeRoundPanic),
                b.fire(FaultSite::DecodeRoundPanic)
            );
            assert_eq!(a.fire(FaultSite::PrefillError), b.fire(FaultSite::PrefillError));
        }
        assert_eq!(
            a.fired(FaultSite::DecodeRoundPanic),
            b.fired(FaultSite::DecodeRoundPanic)
        );
        assert!(a.total_fired() > 0);
    }

    #[test]
    fn probability_extremes() {
        let f = Faults::parse("prefill_error:1:1,kv_pool_exhausted:0:1").unwrap();
        for _ in 0..50 {
            assert!(f.fire(FaultSite::PrefillError));
            assert!(!f.fire(FaultSite::KvPoolExhausted));
        }
        // unarmed site never fires even with a plan present
        assert!(!f.fire(FaultSite::DecodeRoundPanic));
    }

    #[test]
    fn site_streams_are_independent() {
        // same seed, different sites → different draw sequences
        let f = Faults::parse("decode_round_panic:0.5:9,prefill_error:0.5:9").unwrap();
        let a: Vec<bool> = (0..64).map(|_| f.fire(FaultSite::DecodeRoundPanic)).collect();
        let b: Vec<bool> = (0..64).map(|_| f.fire(FaultSite::PrefillError)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn stall_returns_configured_duration() {
        let f = Faults::parse("decode_stall_ms:1:3:40").unwrap();
        assert_eq!(f.stall(FaultSite::DecodeStallMs), Some(Duration::from_millis(40)));
        // default value when the field is omitted
        let g = Faults::parse("decode_stall_ms:1:3").unwrap();
        assert_eq!(g.stall(FaultSite::DecodeStallMs), Some(Duration::from_millis(25)));
        // disabled → None, and no counter movement
        assert_eq!(Faults::disabled().stall(FaultSite::DecodeStallMs), None);
    }

    #[test]
    fn fork_streams_are_deterministic_and_replica_independent() {
        let spec = "replica_crash:0.5:3,heartbeat_drop:0.5:3";
        let root = Faults::parse(spec).unwrap();
        // salt 0 reproduces the root plan's streams bit-for-bit
        let zero = root.fork(0);
        let again = Faults::parse(spec).unwrap();
        let draws = |f: &Faults| -> Vec<bool> {
            (0..64).map(|_| f.fire(FaultSite::ReplicaCrash)).collect()
        };
        assert_eq!(draws(&zero), draws(&again));
        // distinct salts → distinct streams; same salt → identical stream
        let a = Faults::parse(spec).unwrap().fork(1);
        let b = Faults::parse(spec).unwrap().fork(2);
        let a2 = Faults::parse(spec).unwrap().fork(1);
        let (da, db, da2) = (draws(&a), draws(&b), draws(&a2));
        assert_eq!(da, da2);
        assert_ne!(da, db);
        // counters are per-fork, not shared with the root
        assert_eq!(root.fired(FaultSite::ReplicaCrash), 0);
        // forking a disabled handle stays disabled (and free)
        assert!(!Faults::disabled().fork(7).enabled());
    }

    #[test]
    fn replica_stall_uses_default_value() {
        let f = Faults::parse("replica_stall_ms:1:5").unwrap();
        assert_eq!(
            f.stall(FaultSite::ReplicaStallMs),
            Some(Duration::from_millis(150))
        );
        let g = Faults::parse("replica_stall_ms:1:5:60").unwrap();
        assert_eq!(
            g.stall(FaultSite::ReplicaStallMs),
            Some(Duration::from_millis(60))
        );
    }

    #[test]
    fn fork_rng_is_a_pure_function_of_spec_label_and_salt() {
        let spec = "decode_round_error:0.3:9";
        let seq = |r: &mut crate::util::rng::Rng| -> Vec<usize> {
            (0..16).map(|_| r.below(1000)).collect()
        };
        let mut a = Faults::parse(spec).unwrap().fork_rng("round_retry");
        let mut b = Faults::parse(spec).unwrap().fork_rng("round_retry");
        assert_eq!(seq(&mut a), seq(&mut b), "same spec+label must replay");
        let mut c = Faults::parse(spec).unwrap().fork_rng("replica_restart");
        assert_ne!(seq(&mut a), seq(&mut c), "labels draw distinct streams");
        let mut d = Faults::parse("decode_round_error:0.3:10").unwrap().fork_rng("round_retry");
        assert_ne!(seq(&mut b), seq(&mut d), "specs draw distinct streams");
        // per-replica forks jitter independently but deterministically
        let mut e = Faults::parse(spec).unwrap().fork(3).fork_rng("round_retry");
        let mut e2 = Faults::parse(spec).unwrap().fork(3).fork_rng("round_retry");
        assert_eq!(seq(&mut e), seq(&mut e2));
        assert_ne!(seq(&mut b), seq(&mut e));
        // disabled handles still get a fixed, label-keyed stream
        let mut f = Faults::disabled().fork_rng("round_retry");
        let mut g = Faults::disabled().fork_rng("round_retry");
        assert_eq!(seq(&mut f), seq(&mut g));
    }

    #[test]
    fn training_sites_parse_fire_and_report_magnitude() {
        let f = Faults::parse("grad_nan:1:1,grad_explode:1:1,loss_spike_mul:1:1:7,mask_corrupt:0:1")
            .unwrap();
        assert!(f.fire(FaultSite::GradNan));
        assert!(f.fire(FaultSite::GradExplode));
        assert!(f.fire(FaultSite::LossSpikeMul));
        assert!(!f.fire(FaultSite::MaskCorrupt));
        // armed value wins; unarmed/absent sites fall back to the default
        assert_eq!(f.magnitude(FaultSite::LossSpikeMul), 7);
        assert_eq!(f.magnitude(FaultSite::GradExplode), 1_000_000);
        assert_eq!(f.magnitude(FaultSite::GradNan), 0);
        assert_eq!(Faults::disabled().magnitude(FaultSite::LossSpikeMul), 100);
        // round-trip through from_name like the spec parser does
        for name in ["grad_nan", "grad_explode", "loss_spike_mul", "mask_corrupt"] {
            let site = FaultSite::from_name(name).unwrap();
            assert_eq!(site.name(), name);
        }
    }

    #[test]
    fn summary_reports_counters() {
        let f = Faults::parse("prefill_error:1:1").unwrap();
        f.fire(FaultSite::PrefillError);
        f.fire(FaultSite::PrefillError);
        assert_eq!(f.summary(), "prefill_error=2/2");
        assert_eq!(Faults::disabled().summary(), "faults disabled");
    }
}
