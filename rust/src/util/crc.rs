//! CRC32 (IEEE 802.3, polynomial 0xEDB88320) — the checksum guarding
//! checkpoint tensors against torn or corrupt writes.
//!
//! Implemented as the classic reflected table-driven byte loop so the
//! value matches every mainstream implementation (zlib's `crc32`,
//! Python's `zlib.crc32`, the `crc32fast` crate): initial value
//! `0xFFFF_FFFF`, final XOR `0xFFFF_FFFF`. The Python transliteration
//! check (`python/tests/ckpt_format_check.py`) pins this equivalence so
//! the checkpoint format stays verifiable without a Rust toolchain.

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Streaming CRC32 state — feed bytes incrementally, then [`Crc32::value`].
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in bytes {
            c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// The digest of everything fed so far (does not reset the state).
    pub fn value(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical check value of CRC-32/ISO-HDLC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(37) {
            c.update(chunk);
        }
        assert_eq!(c.value(), crc32(&data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xA5u8; 4096];
        let clean = crc32(&data);
        data[2048] ^= 0x10;
        assert_ne!(crc32(&data), clean);
    }
}
