//! Persistent scoped thread pool (rayon stand-in).
//!
//! The pool keeps `ncpu` parked workers and exposes a blocking
//! `parallel_for(n, f)` that splits `0..n` into per-worker index grabs via a
//! shared atomic counter. The caller blocks until every index is processed,
//! so borrowed data in `f` is safe to reference — the closure's lifetime is
//! erased internally but provably outlives its use (the completion barrier
//! fires before `parallel_for` returns).
//!
//! This matters for the kernel hot paths: decode-time GEMMs run every few
//! hundred microseconds, and re-spawning OS threads per call (the
//! `std::thread::scope` pattern) costs more than some of the GEMMs
//! themselves.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Arc<JobInner>;
type PanicPayload = Box<dyn std::any::Any + Send>;

struct JobInner {
    // type-erased `&(dyn Fn(usize) + Sync)` valid until `done` is signaled
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    n: usize,
    chunk: usize,
    pending: AtomicUsize,
    /// Set when a chunk panicked: remaining chunks are skipped (claimed and
    /// accounted, not executed) so the completion barrier still opens.
    aborted: AtomicBool,
    /// First panic payload, re-thrown on the calling thread. Without this
    /// a worker panic would leave `pending` above zero forever and park
    /// `parallel_for` in the barrier — a deadlock, not a crash.
    panic: Mutex<Option<PanicPayload>>,
}

unsafe impl Send for JobInner {}
unsafe impl Sync for JobInner {}

struct Shared {
    queue: Mutex<Vec<Job>>,
    cv: Condvar,
}

/// A fixed-size pool of parked worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: usize,
    done: Mutex<()>,
    done_cv: Condvar,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Arc<Self> {
        let workers = workers.max(1);
        let pool = Arc::new(ThreadPool {
            shared: Arc::new(Shared {
                queue: Mutex::new(Vec::new()),
                cv: Condvar::new(),
            }),
            workers,
            done: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        for _ in 0..workers {
            let shared = pool.shared.clone();
            let pool2 = Arc::downgrade(&pool);
            std::thread::spawn(move || loop {
                let job = {
                    let mut q = shared.queue.lock().unwrap();
                    loop {
                        if let Some(j) = q.pop() {
                            break j;
                        }
                        q = shared.cv.wait(q).unwrap();
                    }
                };
                run_job(&job);
                if let Some(p) = pool2.upgrade() {
                    if job.pending.load(Ordering::Acquire) == 0 {
                        let _g = p.done.lock().unwrap();
                        p.done_cv.notify_all();
                    }
                }
            });
        }
        pool
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(i)` for every `i in 0..n`, blocking until all complete.
    /// Indices are handed out in chunks to amortize the atomic traffic.
    pub fn parallel_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if n == 1 || self.workers == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let chunk = (n / (self.workers * 4)).max(1);
        // SAFETY: `job` is only executed by worker threads between now and
        // the `pending == 0` wait below; `f` outlives this function call.
        let f_erased: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
        let job: Job = Arc::new(JobInner {
            f: f_erased,
            next: AtomicUsize::new(0),
            n,
            chunk,
            pending: AtomicUsize::new(n),
            aborted: AtomicBool::new(false),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            // enqueue one handle per worker so all of them participate
            for _ in 0..self.workers {
                q.push(job.clone());
            }
        }
        self.shared.cv.notify_all();
        // the calling thread helps too
        run_job(&job);
        if job.pending.load(Ordering::Acquire) != 0 {
            let mut g = self.done.lock().unwrap();
            while job.pending.load(Ordering::Acquire) != 0 {
                let (g2, _timeout) = self
                    .done_cv
                    .wait_timeout(g, std::time::Duration::from_millis(1))
                    .unwrap();
                g = g2;
            }
        }
        // Re-throw a worker panic on the caller — only after the barrier,
        // so no thread still holds the type-erased `f` when we unwind.
        let payload = job
            .panic
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

fn run_job(job: &JobInner) {
    // SAFETY: see `parallel_for` — the reference is valid while pending > 0.
    let f = unsafe { &*job.f };
    loop {
        let start = job.next.fetch_add(job.chunk, Ordering::Relaxed);
        if start >= job.n {
            break;
        }
        let end = (start + job.chunk).min(job.n);
        if !job.aborted.load(Ordering::Acquire) {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for i in start..end {
                    f(i);
                }
            }));
            if let Err(p) = r {
                job.aborted.store(true, Ordering::Release);
                let mut slot = job.panic.lock().unwrap_or_else(|e| e.into_inner());
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        }
        // claimed indices are ALWAYS accounted — panicked or skipped — so
        // the barrier opens and the pool stays usable for the next call
        job.pending.fetch_sub(end - start, Ordering::AcqRel);
    }
}

static GLOBAL: OnceLock<Arc<ThreadPool>> = OnceLock::new();

/// The process-wide pool (ncpu workers, lazily created).
pub fn global() -> &'static Arc<ThreadPool> {
    GLOBAL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ThreadPool::new(n)
    })
}

/// Convenience: run `f(i)` for `i in 0..n` on the global pool.
pub fn parallel_for(n: usize, f: impl Fn(usize) + Sync) {
    global().parallel_for(n, &f);
}

/// Cost-aware variant: run `f(i)` for `i in 0..n` where `weight(i)` is an
/// estimate of item `i`'s cost (any unit). Items are grouped into
/// *contiguous* index ranges of approximately equal total weight and the
/// ranges are scheduled on the pool, so a few heavy items (e.g. dense
/// block columns of a mostly-pruned BSpMM) cannot serialize the whole
/// call the way uniform index chunking does.
///
/// Weights are supplied as a function, not a slice, so callers with
/// structured costs (BSpMM: per-column block counts repeated per row
/// tile) don't materialize an O(n) vector per call. Zero-weight items
/// ride along with their neighbors for free; contiguity preserves
/// whatever cache locality the item order encodes.
pub fn parallel_for_weighted(
    n: usize,
    weight: impl Fn(usize) -> usize,
    f: impl Fn(usize) + Sync,
) {
    if n == 0 {
        return;
    }
    let workers = global().workers();
    let total: usize = (0..n).map(&weight).sum();
    if n == 1 || workers == 1 || total == 0 {
        parallel_for(n, f);
        return;
    }
    // ~4 ranges per worker: enough slack for work stealing via the shared
    // counter without paying per-item dispatch.
    let target = total.div_ceil(workers * 4).max(1);
    let mut bounds = Vec::with_capacity(workers * 4 + 2);
    bounds.push(0usize);
    let mut acc = 0usize;
    for i in 0..n {
        acc += weight(i);
        if acc >= target && i + 1 < n {
            bounds.push(i + 1);
            acc = 0;
        }
    }
    bounds.push(n);
    let f_ref = &f;
    let bounds_ref = &bounds;
    global().parallel_for(bounds.len() - 1, &move |ci| {
        for i in bounds_ref[ci]..bounds_ref[ci + 1] {
            f_ref(i);
        }
    });
}

/// Split `data` into `n_chunks` contiguous mutable chunks and process each on
/// the pool. `f(chunk_index, chunk)`.
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let base = data.as_mut_ptr() as usize;
    let total = data.len();
    parallel_for(n_chunks, |ci| {
        let start = ci * chunk_len;
        let end = (start + chunk_len).min(total);
        // SAFETY: chunks are disjoint; `data` is borrowed mutably for the
        // duration of the (blocking) parallel_for.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start) };
        f(ci, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reentrant_calls_sequential() {
        for _ in 0..50 {
            let sum = AtomicU64::new(0);
            parallel_for(64, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 64 * 63 / 2);
        }
    }

    #[test]
    fn chunks_mut_writes_disjoint() {
        let mut v = vec![0u32; 257];
        parallel_chunks_mut(&mut v, 32, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        assert_eq!(v[0], 1);
        assert_eq!(v[256], 9);
    }

    #[test]
    fn zero_and_one_items() {
        parallel_for(0, |_| panic!("should not run"));
        let ran = AtomicU64::new(0);
        parallel_for(1, |_| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn weighted_covers_all_indices_once() {
        // skewed weights incl. zeros — the BSpMM block-column profile
        let weight = |i: usize| if i % 7 == 0 { 0 } else { (i * 37) % 23 };
        let hits: Vec<AtomicU64> = (0..4096).map(|_| AtomicU64::new(0)).collect();
        parallel_for_weighted(4096, weight, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn weighted_extreme_profiles() {
        // all-zero weights fall back to uniform chunking
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        parallel_for_weighted(100, |_| 0, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // one heavy item among zeros must not lose the light ones
        let hits: Vec<AtomicU64> = (0..513).map(|_| AtomicU64::new(0)).collect();
        parallel_for_weighted(513, |i| if i == 200 { 1_000_000 } else { 0 }, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // empty + singleton
        parallel_for_weighted(0, |_| 1, |_| panic!("should not run"));
        let ran = AtomicU64::new(0);
        parallel_for_weighted(1, |_| 42, |_| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic(expected = "boom at 97")]
    fn panics_propagate_to_caller() {
        parallel_for(256, |i| {
            if i == 97 {
                panic!("boom at 97");
            }
        });
    }

    #[test]
    fn pool_survives_panics_and_stays_correct() {
        for round in 0..10 {
            let r = std::panic::catch_unwind(|| {
                parallel_for(512, |i| {
                    if i % 100 == 3 {
                        panic!("injected worker panic (round {round})");
                    }
                });
            });
            assert!(r.is_err(), "panic must reach the caller");
            // the pool is immediately reusable and exact
            let sum = AtomicU64::new(0);
            parallel_for(128, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 128 * 127 / 2, "round {round}");
        }
    }

    #[test]
    fn weighted_panics_propagate_too() {
        let r = std::panic::catch_unwind(|| {
            parallel_for_weighted(300, |i| i % 5, |i| {
                if i == 250 {
                    panic!("weighted boom");
                }
            });
        });
        let p = r.expect_err("panic must propagate through the weighted wrapper");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("weighted boom"), "payload was {msg:?}");
    }

    #[test]
    fn many_small_jobs_stress() {
        // nested-free storm of tiny jobs: the decode-projection pattern.
        // Guards the scheduler against lost wakeups / double dispatch.
        for round in 0..300 {
            let n = 1 + (round % 19);
            let sum = AtomicU64::new(0);
            if round % 2 == 0 {
                parallel_for(n, |i| {
                    sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
                });
            } else {
                parallel_for_weighted(n, |i| i % 3, |i| {
                    sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
                });
            }
            let expect = (n as u64) * (n as u64 + 1) / 2;
            assert_eq!(sum.load(Ordering::Relaxed), expect, "round {round}");
        }
    }
}
