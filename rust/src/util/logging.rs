//! Minimal leveled logger (tracing stand-in).
//!
//! Controlled by `BLAST_LOG` (error|warn|info|debug|trace, default info).
//! Timestamps are milliseconds since process start — enough to correlate
//! with the per-iteration training logs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("BLAST_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!(
        "[{:>9.3}s {:5} {}] {}",
        t.as_secs_f64(),
        format!("{l:?}").to_uppercase(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
