//! Deterministic PRNG + samplers (offline stand-in for `rand`).
//!
//! Core generator is xoshiro256**, seeded through SplitMix64 — the same
//! construction rand's `Xoshiro256StarStar` uses. All data generation in
//! the repo flows through this so every experiment is reproducible from a
//! single `u64` seed recorded in EXPERIMENTS.md.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (n << 2^64 in all our uses).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Vector of standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher-Yates over an index vec; fine at our scales
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Zipf-distributed sampler over [0, n) with exponent `s` — used by the
/// synthetic-corpus generator to mimic natural-language token frequency.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 40);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let z = Zipf::new(64, 1.1);
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 64];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[40]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
