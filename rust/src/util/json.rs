//! Minimal JSON parser/serializer (serde_json stand-in).
//!
//! Parses the AOT manifest, experiment configs and the serving protocol.
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); numbers are held as f64 which is lossless for
//! every integer the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?} in {self:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    // ---- construction ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs: best-effort (manifest is ASCII)
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"name":"t","shape":[2,3],"f":1.5}],"n":42,"s":"q\"uote"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn integers_preserved() {
        let j = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(j.as_f64(), Some(9007199254740992.0));
        assert_eq!(Json::Num(123456789.0).dump(), "123456789");
    }
}
