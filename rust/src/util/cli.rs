//! Tiny declarative CLI parsing (clap stand-in).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults keep call sites compact:
//!
//! ```no_run
//! # use blast::util::cli::Args;
//! let a = Args::parse_from(vec!["exp".into(), "tab4".into(), "--steps".into(), "200".into()]);
//! assert_eq!(a.pos(0), Some("exp"));
//! assert_eq!(a.get_usize("steps", 100), 200);
//! ```

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from process args (skipping argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1).collect())
    }

    pub fn parse_from(argv: Vec<String>) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Boolean with an explicit default — for on-by-default switches like
    /// `--batched`: absent keys return `default`; `--key` alone means true;
    /// `--key false` / `--key=false` (also `0`, `no`) turn it off. Any
    /// other value panics (like the numeric getters), so a typo can't
    /// silently select the wrong mode.
    pub fn get_bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some(v) => match v {
                "true" | "1" | "yes" => true,
                "false" | "0" | "no" => false,
                other => panic!("--{key} expects true/false, got {other:?}"),
            },
        }
    }

    /// Optional non-negative finite f32, e.g. `--attn-threshold 8.0`.
    /// Absent → `None`. NaN, ±inf, negatives and non-numbers panic with a
    /// clean message instead of silently arming a garbage threshold (NaN
    /// compares false in the skip test; a negative τ would skip tiles
    /// *above* the running row max).
    pub fn get_threshold(&self, key: &str) -> Option<f32> {
        let v = self.get(key)?;
        let t: f32 = v
            .parse()
            .unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}"));
        if !t.is_finite() || t < 0.0 {
            panic!("--{key} expects a finite value >= 0, got {v:?}");
        }
        Some(t)
    }

    /// Comma-separated list of usize, e.g. `--blocks 32,64,128`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad int {s:?}")))
                .collect(),
        }
    }

    /// Comma-separated list of f64, e.g. `--sparsities 0.7,0.9,0.95`.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad num {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        // boolean flags must use `=` or come after positionals (documented
        // limitation of arity-free parsing)
        let a = Args::parse_from(argv("run pos2 --steps 10 --lr=0.5 --verbose"));
        assert_eq!(a.pos(0), Some("run"));
        assert_eq!(a.pos(1), Some("pos2"));
        assert_eq!(a.get_usize("steps", 0), 10);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn lists() {
        let a = Args::parse_from(argv("--blocks 32,64 --sp 0.5,0.95"));
        assert_eq!(a.get_usize_list("blocks", &[]), vec![32, 64]);
        assert_eq!(a.get_f64_list("sp", &[]), vec![0.5, 0.95]);
        assert_eq!(a.get_usize_list("missing", &[1]), vec![1]);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse_from(argv("--flag"));
        assert!(a.get_bool("flag"));
    }

    #[test]
    fn threshold_parses_and_is_optional() {
        let a = Args::parse_from(argv("--attn-threshold 8.5"));
        assert_eq!(a.get_threshold("attn-threshold"), Some(8.5));
        let a = Args::parse_from(argv("--attn-threshold=0"));
        assert_eq!(a.get_threshold("attn-threshold"), Some(0.0));
        let a = Args::parse_from(argv("serve"));
        assert_eq!(a.get_threshold("attn-threshold"), None);
    }

    #[test]
    #[should_panic(expected = "--attn-threshold expects a finite value >= 0")]
    fn threshold_rejects_nan() {
        let a = Args::parse_from(argv("--attn-threshold NaN"));
        a.get_threshold("attn-threshold");
    }

    #[test]
    #[should_panic(expected = "--attn-threshold expects a finite value >= 0")]
    fn threshold_rejects_negative() {
        let a = Args::parse_from(argv("--attn-threshold=-2.0"));
        a.get_threshold("attn-threshold");
    }

    #[test]
    #[should_panic(expected = "--attn-threshold expects a finite value >= 0")]
    fn threshold_rejects_infinity() {
        let a = Args::parse_from(argv("--attn-threshold inf"));
        a.get_threshold("attn-threshold");
    }

    #[test]
    #[should_panic(expected = "--attn-threshold expects a number")]
    fn threshold_rejects_garbage() {
        let a = Args::parse_from(argv("--attn-threshold high"));
        a.get_threshold("attn-threshold");
    }

    #[test]
    fn bool_with_default() {
        let a = Args::parse_from(argv("--on --off false --also=no"));
        assert!(a.get_bool_or("on", false));
        assert!(!a.get_bool_or("off", true));
        assert!(!a.get_bool_or("also", true));
        assert!(a.get_bool_or("absent", true));
        assert!(!a.get_bool_or("absent2", false));
    }
}
