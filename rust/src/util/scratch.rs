//! Thread-local scratch arena for kernel tile buffers.
//!
//! The fused-MLP and packed-GEMM hot paths need short-lived `mr×f` tile
//! buffers *per task*. Allocating them with `vec![0.0; ..]` puts the
//! allocator on the decode critical path (and its lock under the thread
//! pool); this arena instead recycles buffers per worker thread, so after
//! warmup the kernels run allocation-free.
//!
//! Usage: [`take_zeroed`] / [`take_uninit`] return a [`Scratch`] guard that
//! derefs to `[f32]` and returns its backing `Vec` to the calling thread's
//! pool on drop. Buffers taken on a pool worker stay cached on that worker,
//! which is exactly the reuse pattern `threadpool::parallel_for` produces.
//!
//! Since PR 5 the handed-out slice is **64-byte aligned**: the guard
//! over-allocates by up to 15 floats and derefs to an aligned window, so
//! packed panels built in scratch start on a cache-line/vector boundary
//! and the SIMD arms' (unaligned-encoded) loads run at aligned speed.
//! Alignment is a performance guarantee only — the SIMD lanes never
//! require it for soundness (see `kernels/simd.rs`).

use std::cell::RefCell;

/// Max buffers cached per thread. The tiled attention kernel holds 7 live
/// at once per (head, q-tile) item (Q/K/P packs, score tile, accumulator,
/// running max/sum); the fused MLP needs 4; the remaining headroom covers
/// nested dense-MLP + projection usage without evicting warm buffers.
const POOL_CAP: usize = 12;

/// Buffers whose capacity exceeds this many floats (16 MiB) are freed on
/// drop instead of pooled: one giant prefill must not pin its tile buffers
/// in every worker thread for the lifetime of a serving process.
const MAX_POOLED_LEN: usize = 1 << 22;

/// Alignment of the handed-out window, in bytes (one cache line; covers
/// AVX-512-width loads too).
const ALIGN: usize = 64;

/// Worst-case f32 padding needed to reach [`ALIGN`] from a 4-byte-aligned
/// `Vec` allocation.
const ALIGN_PAD: usize = ALIGN / 4 - 1;

thread_local! {
    static POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// An arena-backed f32 buffer; derefs to a 64-byte-aligned window and
/// returns its backing `Vec` to the thread's pool on drop.
pub struct Scratch {
    buf: Vec<f32>,
    off: usize,
    len: usize,
}

impl std::ops::Deref for Scratch {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.buf[self.off..self.off + self.len]
    }
}

impl std::ops::DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf[self.off..self.off + self.len]
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() > MAX_POOLED_LEN {
            return; // free oversized buffers instead of pinning them
        }
        POOL.with(|p| {
            let mut p = p.borrow_mut();
            if p.len() < POOL_CAP {
                p.push(buf);
            }
        });
    }
}

fn take_raw(len: usize) -> Vec<f32> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        // prefer the buffer with the largest capacity to minimize regrowth
        let best = p
            .iter()
            .enumerate()
            .max_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        match best {
            Some(i) => p.swap_remove(i),
            None => Vec::new(),
        }
    })
}

/// Build the guard: size the backing store for `len` plus alignment slack
/// and compute the aligned window offset. `align_offset` is in elements
/// (f32 size divides [`ALIGN`], so it is always reachable and ≤
/// [`ALIGN_PAD`]); a defensive clamp keeps a pathological allocator
/// answer from walking past the slack.
fn window(buf: Vec<f32>, len: usize) -> Scratch {
    let off = buf.as_ptr().align_offset(ALIGN).min(ALIGN_PAD);
    debug_assert!(off + len <= buf.len());
    Scratch { buf, off, len }
}

/// A length-`len` buffer with every element set to 0.0.
pub fn take_zeroed(len: usize) -> Scratch {
    let mut buf = take_raw(len);
    buf.clear();
    buf.resize(len + ALIGN_PAD, 0.0);
    window(buf, len)
}

/// A length-`len` buffer with unspecified contents (recycled values); use
/// when every element is overwritten before being read (e.g. pack targets).
pub fn take_uninit(len: usize) -> Scratch {
    let mut buf = take_raw(len);
    buf.resize(len + ALIGN_PAD, 0.0);
    window(buf, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_really_zeroes_recycled_buffers() {
        {
            let mut a = take_uninit(64);
            for v in a.iter_mut() {
                *v = 7.0;
            }
        } // returns the dirty buffer to the pool
        let b = take_zeroed(64);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lengths_are_exact() {
        assert_eq!(take_zeroed(0).len(), 0);
        assert_eq!(take_zeroed(13).len(), 13);
        {
            let _big = take_zeroed(1000);
        }
        // shrinking reuse must not keep the old length
        assert_eq!(take_uninit(3).len(), 3);
    }

    #[test]
    fn windows_are_64_byte_aligned() {
        for len in [1usize, 7, 16, 64, 1000] {
            let s = take_zeroed(len);
            assert_eq!(s.as_ptr() as usize % ALIGN, 0, "len={len}");
            let s = take_uninit(len);
            assert_eq!(s.as_ptr() as usize % ALIGN, 0, "len={len}");
        }
    }

    #[test]
    fn capacity_is_recycled() {
        let cap = {
            let s = take_zeroed(512);
            s.buf.capacity()
        };
        // drop pushed it back; a smaller request should reuse that backing
        let s = take_uninit(16);
        assert!(s.buf.capacity() >= 16);
        let _ = cap; // capacity reuse is best-effort; assert no panic only
    }

    #[test]
    fn many_guards_alive_at_once() {
        let a = take_zeroed(8);
        let b = take_zeroed(8);
        let c = take_zeroed(8);
        let d = take_zeroed(8);
        assert_eq!(a.len() + b.len() + c.len() + d.len(), 32);
    }
}
