//! Summary statistics for benchmarks, latency tracking and experiment
//! reporting (Hoefler & Belli-style: medians + spread, not bare means).

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Online Welford accumulator for streaming latency metrics.
#[derive(Default, Debug, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Matthews correlation coefficient for binary classification (Table 1's
/// CoLA metric).
pub fn matthews_corr(tp: u64, tn: u64, fp: u64, fn_: u64) -> f64 {
    let (tp, tn, fp, fn_) = (tp as f64, tn as f64, fp as f64, fn_ as f64);
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fn_) / denom
    }
}

/// F1 score (Table 1's MRPC metric).
pub fn f1(tp: u64, fp: u64, fn_: u64) -> f64 {
    let denom = 2.0 * tp as f64 + fp as f64 + fn_ as f64;
    if denom == 0.0 {
        0.0
    } else {
        2.0 * tp as f64 / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.var().sqrt() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(w.min(), 0.0);
    }

    #[test]
    fn mcc_perfect_and_random() {
        assert!((matthews_corr(50, 50, 0, 0) - 1.0).abs() < 1e-12);
        assert!(matthews_corr(25, 25, 25, 25).abs() < 1e-12);
    }

    #[test]
    fn f1_basics() {
        assert!((f1(10, 0, 0) - 1.0).abs() < 1e-12);
        assert!((f1(0, 5, 5) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }
}
