//! Block-sparse formats (paper §3.2/§3.3).
//!
//! * [`BlockMask`] — the boolean block grid the prune-and-grow controller
//!   manipulates (one bit per `b×b` block of a weight matrix).
//! * [`Bcsc`] — blocked Compressed Sparse Column, the storage format of the
//!   paper's BSpMM kernel for the `Y = XW` (multiply-from-the-left) case:
//!   surviving blocks are streamed column-block by column-block, each block
//!   stored densely so the per-block micro-GEMM runs at dense speed.
//! * [`Csr`] — element-wise CSR, the *unstructured* sparsity baseline the
//!   paper argues cannot convert FLOP savings into wall-clock savings.

pub mod bcsc;
pub mod csr;
pub mod mask;

pub use bcsc::Bcsc;
pub use csr::Csr;
pub use mask::BlockMask;
