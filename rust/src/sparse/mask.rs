//! Boolean block masks — the unit of bookkeeping for blocked prune-and-grow.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One bit per `b×b` block of a `(rb*b, cb*b)` weight matrix.
/// `true` = block kept, `false` = block pruned.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockMask {
    pub rb: usize,
    pub cb: usize,
    bits: Vec<bool>,
}

impl BlockMask {
    pub fn ones(rb: usize, cb: usize) -> BlockMask {
        BlockMask {
            rb,
            cb,
            bits: vec![true; rb * cb],
        }
    }

    pub fn zeros(rb: usize, cb: usize) -> BlockMask {
        BlockMask {
            rb,
            cb,
            bits: vec![false; rb * cb],
        }
    }

    pub fn from_bits(rb: usize, cb: usize, bits: Vec<bool>) -> BlockMask {
        assert_eq!(bits.len(), rb * cb);
        BlockMask { rb, cb, bits }
    }

    /// Random mask with exactly `round(sparsity * rb*cb)` pruned blocks.
    pub fn random(rb: usize, cb: usize, sparsity: f64, rng: &mut Rng) -> BlockMask {
        let total = rb * cb;
        let n_zero = ((sparsity * total as f64).round() as usize).min(total);
        let mut m = BlockMask::ones(rb, cb);
        for i in rng.sample_indices(total, n_zero) {
            m.bits[i] = false;
        }
        m
    }

    pub fn get(&self, r: usize, c: usize) -> bool {
        self.bits[r * self.cb + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        self.bits[r * self.cb + c] = v;
    }

    pub fn bits(&self) -> &[bool] {
        &self.bits
    }

    pub fn total_blocks(&self) -> usize {
        self.rb * self.cb
    }

    /// Number of *kept* blocks.
    pub fn nnzb(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Fraction of *pruned* blocks.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnzb() as f64 / self.total_blocks() as f64
    }

    /// Linear indices (r * cb + c) of kept blocks, ascending.
    pub fn kept_indices(&self) -> Vec<usize> {
        (0..self.bits.len()).filter(|&i| self.bits[i]).collect()
    }

    /// Set union (kept if kept in either).
    pub fn union(&self, other: &BlockMask) -> BlockMask {
        assert_eq!((self.rb, self.cb), (other.rb, other.cb));
        BlockMask {
            rb: self.rb,
            cb: self.cb,
            bits: self
                .bits
                .iter()
                .zip(&other.bits)
                .map(|(a, b)| *a || *b)
                .collect(),
        }
    }

    /// Set difference: kept in `self` but not in `other`.
    pub fn difference(&self, other: &BlockMask) -> BlockMask {
        assert_eq!((self.rb, self.cb), (other.rb, other.cb));
        BlockMask {
            rb: self.rb,
            cb: self.cb,
            bits: self
                .bits
                .iter()
                .zip(&other.bits)
                .map(|(a, b)| *a && !*b)
                .collect(),
        }
    }

    /// Expand to an elementwise 0/1 tensor of shape `(rb*b, cb*b)` — the
    /// layout the AOT graphs consume.
    pub fn expand(&self, block: usize) -> Tensor {
        let (r, c) = (self.rb * block, self.cb * block);
        let mut out = vec![0.0f32; r * c];
        for br in 0..self.rb {
            for bc in 0..self.cb {
                if self.get(br, bc) {
                    for i in 0..block {
                        let row = (br * block + i) * c + bc * block;
                        out[row..row + block].fill(1.0);
                    }
                }
            }
        }
        Tensor::new(&[r, c], out)
    }

    /// The f32 block-grid tensor (shape `(rb, cb)`) passed to HLO entries.
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(
            &[self.rb, self.cb],
            self.bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
        )
    }

    pub fn from_tensor(t: &Tensor) -> BlockMask {
        assert_eq!(t.shape().len(), 2);
        BlockMask {
            rb: t.shape()[0],
            cb: t.shape()[1],
            bits: t.data().iter().map(|&x| x != 0.0).collect(),
        }
    }

    /// Zero out pruned blocks of a dense `(rb*b, cb*b)` matrix in place.
    pub fn apply_to(&self, w: &mut [f32], block: usize) {
        let c = self.cb * block;
        assert_eq!(w.len(), self.rb * block * c);
        for br in 0..self.rb {
            for bc in 0..self.cb {
                if !self.get(br, bc) {
                    for i in 0..block {
                        let row = (br * block + i) * c + bc * block;
                        w[row..row + block].fill(0.0);
                    }
                }
            }
        }
    }

    /// Pack the values of every *kept* block of a dense `(rb*b, cb*b)`
    /// matrix into a contiguous vector: blocks in row-major grid order,
    /// each block row-major. With `scatter_blocks` this gives a cheap
    /// undo buffer for a mask update — snapshot the blocks about to be
    /// zeroed, and restore them if the update is reverted.
    pub fn gather_blocks(&self, w: &[f32], block: usize) -> Vec<f32> {
        let c = self.cb * block;
        assert_eq!(w.len(), self.rb * block * c);
        let mut out = Vec::with_capacity(self.nnzb() * block * block);
        for br in 0..self.rb {
            for bc in 0..self.cb {
                if self.get(br, bc) {
                    for i in 0..block {
                        let row = (br * block + i) * c + bc * block;
                        out.extend_from_slice(&w[row..row + block]);
                    }
                }
            }
        }
        out
    }

    /// Inverse of [`gather_blocks`](Self::gather_blocks): write `vals`
    /// back into the kept blocks of `w`, same traversal order. Pruned
    /// blocks are left untouched.
    pub fn scatter_blocks(&self, vals: &[f32], w: &mut [f32], block: usize) {
        let c = self.cb * block;
        assert_eq!(w.len(), self.rb * block * c);
        assert_eq!(vals.len(), self.nnzb() * block * block);
        let mut at = 0;
        for br in 0..self.rb {
            for bc in 0..self.cb {
                if self.get(br, bc) {
                    for i in 0..block {
                        let row = (br * block + i) * c + bc * block;
                        w[row..row + block].copy_from_slice(&vals[at..at + block]);
                        at += block;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::prop_assert;

    #[test]
    fn counting() {
        let mut m = BlockMask::ones(2, 3);
        assert_eq!(m.nnzb(), 6);
        m.set(1, 2, false);
        assert_eq!(m.nnzb(), 5);
        assert!((m.sparsity() - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn random_mask_exact_sparsity() {
        let mut rng = Rng::new(0);
        let m = BlockMask::random(8, 8, 0.75, &mut rng);
        assert_eq!(m.nnzb(), 16);
    }

    #[test]
    fn expand_layout() {
        let mut m = BlockMask::zeros(2, 2);
        m.set(0, 1, true);
        let e = m.expand(2);
        assert_eq!(e.shape(), &[4, 4]);
        assert_eq!(e.at2(0, 2), 1.0);
        assert_eq!(e.at2(1, 3), 1.0);
        assert_eq!(e.at2(0, 0), 0.0);
        assert_eq!(e.at2(3, 3), 0.0);
    }

    #[test]
    fn set_algebra_properties() {
        prop::check_default("mask-set-algebra", |rng| {
            let rb = prop::usize_in(rng, 1, 6);
            let cb = prop::usize_in(rng, 1, 6);
            let a = BlockMask::random(rb, cb, rng.f64(), rng);
            let b = BlockMask::random(rb, cb, rng.f64(), rng);
            let u = a.union(&b);
            let d = a.difference(&b);
            prop_assert!(
                u.nnzb() >= a.nnzb().max(b.nnzb()),
                "union smaller than operand"
            );
            // |A \ B| = |A| - |A ∩ B|; check via u = b ∪ (a\b)
            let rebuilt = b.union(&d);
            prop_assert!(rebuilt == u, "b ∪ (a\\b) != a ∪ b");
            prop_assert!(d.difference(&a).nnzb() == 0, "(a\\b)\\a nonempty");
            Ok(())
        });
    }

    #[test]
    fn apply_to_zeroes_only_pruned() {
        let mut m = BlockMask::ones(2, 2);
        m.set(0, 0, false);
        let mut w: Vec<f32> = (0..16).map(|x| x as f32 + 1.0).collect();
        m.apply_to(&mut w, 2);
        // block (0,0) covers elements (0,0),(0,1),(1,0),(1,1) of a 4x4
        assert_eq!(w[0], 0.0);
        assert_eq!(w[1], 0.0);
        assert_eq!(w[4], 0.0);
        assert_eq!(w[5], 0.0);
        assert_eq!(w[2], 3.0); // block (0,1) intact
    }

    #[test]
    fn gather_scatter_roundtrip_restores_zeroed_blocks() {
        prop::check_default("mask-gather-scatter", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let block = prop::usize_in(rng, 1, 4);
            let m = BlockMask::random(rb, cb, rng.f64(), rng);
            let w0: Vec<f32> = (0..rb * cb * block * block)
                .map(|_| rng.f64() as f32 - 0.5)
                .collect();
            let saved = m.gather_blocks(&w0, block);
            prop_assert!(
                saved.len() == m.nnzb() * block * block,
                "gather size mismatch"
            );
            // zero the kept blocks (what a prune step does to regrown
            // blocks), then scatter the snapshot back
            let mut w = w0.clone();
            let inverse = BlockMask::from_bits(rb, cb, m.bits().iter().map(|b| !b).collect());
            inverse.apply_to(&mut w, block);
            m.scatter_blocks(&saved, &mut w, block);
            prop_assert!(
                w.iter().zip(&w0).all(|(a, b)| a.to_bits() == b.to_bits()),
                "gather→zero→scatter not bit-identical"
            );
            Ok(())
        });
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Rng::new(3);
        let m = BlockMask::random(5, 7, 0.4, &mut rng);
        assert_eq!(BlockMask::from_tensor(&m.to_tensor()), m);
    }
}
