//! Blocked Compressed Sparse Column storage (paper §3.2, Figure 3).
//!
//! For the left-multiply `Y = X @ W`, surviving `b×b` blocks of `W (k×n)`
//! are grouped by *block column* so the kernel can stream the blocks that
//! contribute to one `n`-tile of the output while reusing the loaded `X`
//! row-panel — the access pattern of the paper's Listing 2.

use crate::sparse::mask::BlockMask;
use crate::tensor::Tensor;

/// Blocked CSC matrix: values of kept blocks only, each block stored densely
/// row-major, blocks ordered column-block-major (then by block row).
#[derive(Clone, Debug)]
pub struct Bcsc {
    /// Sparse block edge length (paper's `b` / `blk_N`).
    pub block: usize,
    /// Block-grid rows (`k / b`).
    pub rb: usize,
    /// Block-grid cols (`n / b`).
    pub cb: usize,
    /// `cb + 1` offsets into `row_idx`/blocks per block column.
    pub col_ptr: Vec<usize>,
    /// Block-row index of each stored block.
    pub row_idx: Vec<usize>,
    /// Dense block payloads, `nnzb * block * block`, blocks in col_ptr order.
    pub vals: Vec<f32>,
}

impl Bcsc {
    /// Build from a dense `(k, n)` matrix and a block mask. Pruned blocks'
    /// values are dropped regardless of their dense contents.
    pub fn from_dense(w: &Tensor, mask: &BlockMask, block: usize) -> Bcsc {
        let (k, n) = (w.rows(), w.cols());
        assert_eq!(k, mask.rb * block, "rows {k} != {} * {block}", mask.rb);
        assert_eq!(n, mask.cb * block);
        let bb = block * block;
        let mut col_ptr = Vec::with_capacity(mask.cb + 1);
        let mut row_idx = Vec::new();
        let mut vals = Vec::with_capacity(mask.nnzb() * bb);
        col_ptr.push(0);
        for bc in 0..mask.cb {
            for br in 0..mask.rb {
                if mask.get(br, bc) {
                    row_idx.push(br);
                    // copy the b×b block, row-major
                    for i in 0..block {
                        let src = (br * block + i) * n + bc * block;
                        vals.extend_from_slice(&w.data()[src..src + block]);
                    }
                }
            }
            col_ptr.push(row_idx.len());
        }
        Bcsc {
            block,
            rb: mask.rb,
            cb: mask.cb,
            col_ptr,
            row_idx,
            vals,
        }
    }

    pub fn nnzb(&self) -> usize {
        self.row_idx.len()
    }

    /// Fraction of pruned blocks.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnzb() as f64 / (self.rb * self.cb) as f64
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rb * self.block, self.cb * self.block)
    }

    /// Bytes of payload + index structure (the inference-memory model input).
    pub fn bytes(&self) -> usize {
        self.vals.len() * 4 + self.row_idx.len() * 8 + self.col_ptr.len() * 8
    }

    /// Payload slice of block `idx` (in storage order).
    #[inline]
    pub fn block_vals(&self, idx: usize) -> &[f32] {
        let bb = self.block * self.block;
        &self.vals[idx * bb..(idx + 1) * bb]
    }

    /// Reconstruct the dense matrix (pruned blocks = 0).
    pub fn to_dense(&self) -> Tensor {
        let (k, n) = self.shape();
        let mut out = vec![0.0f32; k * n];
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                let br = self.row_idx[idx];
                let blk = self.block_vals(idx);
                for i in 0..self.block {
                    let dst = (br * self.block + i) * n + bc * self.block;
                    out[dst..dst + self.block]
                        .copy_from_slice(&blk[i * self.block..(i + 1) * self.block]);
                }
            }
        }
        Tensor::new(&[k, n], out)
    }

    /// The BCSC of `Wᵀ`: resident block `(br, bc)` of `W` becomes
    /// `(bc, br)` with its payload transposed. The native training backend
    /// runs its backward data-gradient BSpMM (`dX = dY · Wᵀ`) as a
    /// *forward* BSpMM against this structure, so pruned blocks cost
    /// nothing in the backward pass either.
    pub fn transpose(&self) -> Bcsc {
        let b = self.block;
        let bb = b * b;
        // counting sort by source block-row (= destination block-column)
        let mut col_ptr = vec![0usize; self.rb + 1];
        for &br in &self.row_idx {
            col_ptr[br + 1] += 1;
        }
        for i in 0..self.rb {
            col_ptr[i + 1] += col_ptr[i];
        }
        let mut row_idx = vec![0usize; self.nnzb()];
        let mut vals = vec![0.0f32; self.vals.len()];
        let mut cursor = col_ptr.clone();
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                let br = self.row_idx[idx];
                let dst = cursor[br];
                cursor[br] += 1;
                // bc ascending within each destination column keeps the
                // row indices sorted, matching from_dense's invariant
                row_idx[dst] = bc;
                let src = &self.vals[idx * bb..(idx + 1) * bb];
                let dvals = &mut vals[dst * bb..(dst + 1) * bb];
                for i in 0..b {
                    for j in 0..b {
                        dvals[j * b + i] = src[i * b + j];
                    }
                }
            }
        }
        Bcsc {
            block: b,
            rb: self.cb,
            cb: self.rb,
            col_ptr,
            row_idx,
            vals,
        }
    }

    /// Refresh resident payloads from a dense `W` **without touching the
    /// index structure** — the incremental re-pack the native trainer runs
    /// between mask updates: the optimizer changed the values, the mask did
    /// not, so only `nnzb · b²` floats move (no allocation, no re-index).
    /// Pruned regions of `w` are ignored, so the dense master weights need
    /// no masking sweep first.
    pub fn refresh_from_dense(&mut self, w: &Tensor) {
        let (k, n) = self.shape();
        assert_eq!((w.rows(), w.cols()), (k, n), "refresh: shape mismatch");
        let b = self.block;
        let bb = b * b;
        let data = w.data();
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                let br = self.row_idx[idx];
                let dst = &mut self.vals[idx * bb..(idx + 1) * bb];
                for i in 0..b {
                    let src = (br * b + i) * n + bc * b;
                    dst[i * b..(i + 1) * b].copy_from_slice(&data[src..src + b]);
                }
            }
        }
    }

    /// [`Bcsc::refresh_from_dense`] for a matrix that stores `Wᵀ` (built by
    /// [`Bcsc::transpose`]): refresh the transposed payloads straight from
    /// the **un-transposed** dense `W`, again structure-preserving.
    pub fn refresh_from_dense_transposed(&mut self, w: &Tensor) {
        let (kt, nt) = self.shape();
        assert_eq!(
            (w.rows(), w.cols()),
            (nt, kt),
            "refresh_transposed: shape mismatch"
        );
        let b = self.block;
        let bb = b * b;
        let n = w.cols();
        let data = w.data();
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                let br = self.row_idx[idx];
                // self block (br, bc) holds Wᵀ[br*b+i, bc*b+j] = W[bc*b+j, br*b+i]
                let dst = &mut self.vals[idx * bb..(idx + 1) * bb];
                for j in 0..b {
                    let src = (bc * b + j) * n + br * b;
                    for i in 0..b {
                        dst[i * b + j] = data[src + i];
                    }
                }
            }
        }
    }

    /// The mask this matrix realizes.
    pub fn mask(&self) -> BlockMask {
        let mut m = BlockMask::zeros(self.rb, self.cb);
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                m.set(self.row_idx[idx], bc, true);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::prop_assert;
    use crate::util::rng::Rng;

    #[test]
    fn dense_roundtrip_exact() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[8, 12], 1.0, &mut rng);
        let mask = BlockMask::random(2, 3, 0.3, &mut rng);
        let b = Bcsc::from_dense(&w, &mask, 4);
        let d = b.to_dense();
        // kept blocks must match w exactly; pruned blocks must be zero
        for br in 0..2 {
            for bc in 0..3 {
                for i in 0..4 {
                    for j in 0..4 {
                        let (r, c) = (br * 4 + i, bc * 4 + j);
                        let want = if mask.get(br, bc) { w.at2(r, c) } else { 0.0 };
                        assert_eq!(d.at2(r, c), want, "at ({r},{c})");
                    }
                }
            }
        }
    }

    #[test]
    fn mask_roundtrip_property() {
        prop::check_default("bcsc-mask-roundtrip", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let block = *prop::pick(rng, &[2, 4, 8]);
            let w = Tensor::randn(&[rb * block, cb * block], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let b = Bcsc::from_dense(&w, &mask, block);
            prop_assert!(b.mask() == mask, "mask not preserved");
            prop_assert!(b.nnzb() == mask.nnzb(), "nnzb mismatch");
            let d = b.to_dense();
            let mut w2 = w.clone();
            mask.apply_to(w2.data_mut(), block);
            prop_assert!(
                d.allclose(&w2, 0.0),
                "to_dense != masked dense (diff {})",
                d.max_abs_diff(&w2)
            );
            Ok(())
        });
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        prop::check_default("bcsc-transpose", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let block = *prop::pick(rng, &[2, 4, 8]);
            let w = Tensor::randn(&[rb * block, cb * block], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let b = Bcsc::from_dense(&w, &mask, block);
            let t = b.transpose();
            prop_assert!(t.shape() == (cb * block, rb * block), "shape");
            prop_assert!(t.nnzb() == b.nnzb(), "nnzb");
            // structural invariant from_dense guarantees: sorted row ids
            for bc in 0..t.cb {
                let ids = &t.row_idx[t.col_ptr[bc]..t.col_ptr[bc + 1]];
                prop_assert!(ids.windows(2).all(|w| w[0] < w[1]), "unsorted col {bc}");
            }
            let want = b.to_dense().transpose2();
            prop_assert!(
                t.to_dense().allclose(&want, 0.0),
                "transpose payload mismatch"
            );
            // double transpose is the identity (same storage order too)
            let tt = t.transpose();
            prop_assert!(tt.col_ptr == b.col_ptr && tt.row_idx == b.row_idx, "index");
            prop_assert!(tt.vals == b.vals, "vals");
            Ok(())
        });
    }

    #[test]
    fn refresh_tracks_dense_values_without_reindexing() {
        let mut rng = Rng::new(7);
        let w0 = Tensor::randn(&[16, 24], 1.0, &mut rng);
        let mask = BlockMask::random(2, 3, 0.4, &mut rng);
        let mut b = Bcsc::from_dense(&w0, &mask, 8);
        let mut t = b.transpose();
        // an "optimizer step": all values change, structure does not
        let w1 = w0.clone().map(|x| 1.5 * x - 0.25);
        let (cp, ri) = (b.col_ptr.clone(), b.row_idx.clone());
        b.refresh_from_dense(&w1);
        t.refresh_from_dense_transposed(&w1);
        assert_eq!(b.col_ptr, cp);
        assert_eq!(b.row_idx, ri);
        let fresh = Bcsc::from_dense(&w1, &mask, 8);
        assert!(b.to_dense().allclose(&fresh.to_dense(), 0.0));
        assert!(t.to_dense().allclose(&fresh.to_dense().transpose2(), 0.0));
        // pruned regions of the dense master are ignored by the refresh
        let mut dirty = w1.clone();
        for br in 0..2 {
            for bc in 0..3 {
                if !mask.get(br, bc) {
                    for i in 0..8 {
                        for j in 0..8 {
                            dirty.set2(br * 8 + i, bc * 8 + j, 999.0);
                        }
                    }
                }
            }
        }
        b.refresh_from_dense(&dirty);
        assert!(b.to_dense().allclose(&fresh.to_dense(), 0.0));
    }

    #[test]
    fn bytes_shrink_with_sparsity() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let dense = Bcsc::from_dense(&w, &BlockMask::ones(4, 4), 16);
        let sparse = Bcsc::from_dense(&w, &BlockMask::random(4, 4, 0.75, &mut rng), 16);
        assert!(sparse.bytes() < dense.bytes() / 2);
    }

    #[test]
    fn empty_mask_is_all_zero() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let b = Bcsc::from_dense(&w, &BlockMask::zeros(2, 2), 4);
        assert_eq!(b.nnzb(), 0);
        assert!(b.to_dense().allclose(&Tensor::zeros(&[8, 8]), 0.0));
    }
}
