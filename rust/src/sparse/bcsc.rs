//! Blocked Compressed Sparse Column storage (paper §3.2, Figure 3).
//!
//! For the left-multiply `Y = X @ W`, surviving `b×b` blocks of `W (k×n)`
//! are grouped by *block column* so the kernel can stream the blocks that
//! contribute to one `n`-tile of the output while reusing the loaded `X`
//! row-panel — the access pattern of the paper's Listing 2.

use crate::sparse::mask::BlockMask;
use crate::tensor::Tensor;

/// Blocked CSC matrix: values of kept blocks only, each block stored densely
/// row-major, blocks ordered column-block-major (then by block row).
#[derive(Clone, Debug)]
pub struct Bcsc {
    /// Sparse block edge length (paper's `b` / `blk_N`).
    pub block: usize,
    /// Block-grid rows (`k / b`).
    pub rb: usize,
    /// Block-grid cols (`n / b`).
    pub cb: usize,
    /// `cb + 1` offsets into `row_idx`/blocks per block column.
    pub col_ptr: Vec<usize>,
    /// Block-row index of each stored block.
    pub row_idx: Vec<usize>,
    /// Dense block payloads, `nnzb * block * block`, blocks in col_ptr order.
    pub vals: Vec<f32>,
}

impl Bcsc {
    /// Build from a dense `(k, n)` matrix and a block mask. Pruned blocks'
    /// values are dropped regardless of their dense contents.
    pub fn from_dense(w: &Tensor, mask: &BlockMask, block: usize) -> Bcsc {
        let (k, n) = (w.rows(), w.cols());
        assert_eq!(k, mask.rb * block, "rows {k} != {} * {block}", mask.rb);
        assert_eq!(n, mask.cb * block);
        let bb = block * block;
        let mut col_ptr = Vec::with_capacity(mask.cb + 1);
        let mut row_idx = Vec::new();
        let mut vals = Vec::with_capacity(mask.nnzb() * bb);
        col_ptr.push(0);
        for bc in 0..mask.cb {
            for br in 0..mask.rb {
                if mask.get(br, bc) {
                    row_idx.push(br);
                    // copy the b×b block, row-major
                    for i in 0..block {
                        let src = (br * block + i) * n + bc * block;
                        vals.extend_from_slice(&w.data()[src..src + block]);
                    }
                }
            }
            col_ptr.push(row_idx.len());
        }
        Bcsc {
            block,
            rb: mask.rb,
            cb: mask.cb,
            col_ptr,
            row_idx,
            vals,
        }
    }

    pub fn nnzb(&self) -> usize {
        self.row_idx.len()
    }

    /// Fraction of pruned blocks.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnzb() as f64 / (self.rb * self.cb) as f64
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rb * self.block, self.cb * self.block)
    }

    /// Bytes of payload + index structure (the inference-memory model input).
    pub fn bytes(&self) -> usize {
        self.vals.len() * 4 + self.row_idx.len() * 8 + self.col_ptr.len() * 8
    }

    /// Payload slice of block `idx` (in storage order).
    #[inline]
    pub fn block_vals(&self, idx: usize) -> &[f32] {
        let bb = self.block * self.block;
        &self.vals[idx * bb..(idx + 1) * bb]
    }

    /// Reconstruct the dense matrix (pruned blocks = 0).
    pub fn to_dense(&self) -> Tensor {
        let (k, n) = self.shape();
        let mut out = vec![0.0f32; k * n];
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                let br = self.row_idx[idx];
                let blk = self.block_vals(idx);
                for i in 0..self.block {
                    let dst = (br * self.block + i) * n + bc * self.block;
                    out[dst..dst + self.block]
                        .copy_from_slice(&blk[i * self.block..(i + 1) * self.block]);
                }
            }
        }
        Tensor::new(&[k, n], out)
    }

    /// The mask this matrix realizes.
    pub fn mask(&self) -> BlockMask {
        let mut m = BlockMask::zeros(self.rb, self.cb);
        for bc in 0..self.cb {
            for idx in self.col_ptr[bc]..self.col_ptr[bc + 1] {
                m.set(self.row_idx[idx], bc, true);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::prop_assert;
    use crate::util::rng::Rng;

    #[test]
    fn dense_roundtrip_exact() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[8, 12], 1.0, &mut rng);
        let mask = BlockMask::random(2, 3, 0.3, &mut rng);
        let b = Bcsc::from_dense(&w, &mask, 4);
        let d = b.to_dense();
        // kept blocks must match w exactly; pruned blocks must be zero
        for br in 0..2 {
            for bc in 0..3 {
                for i in 0..4 {
                    for j in 0..4 {
                        let (r, c) = (br * 4 + i, bc * 4 + j);
                        let want = if mask.get(br, bc) { w.at2(r, c) } else { 0.0 };
                        assert_eq!(d.at2(r, c), want, "at ({r},{c})");
                    }
                }
            }
        }
    }

    #[test]
    fn mask_roundtrip_property() {
        prop::check_default("bcsc-mask-roundtrip", |rng| {
            let rb = prop::usize_in(rng, 1, 5);
            let cb = prop::usize_in(rng, 1, 5);
            let block = *prop::pick(rng, &[2, 4, 8]);
            let w = Tensor::randn(&[rb * block, cb * block], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let b = Bcsc::from_dense(&w, &mask, block);
            prop_assert!(b.mask() == mask, "mask not preserved");
            prop_assert!(b.nnzb() == mask.nnzb(), "nnzb mismatch");
            let d = b.to_dense();
            let mut w2 = w.clone();
            mask.apply_to(w2.data_mut(), block);
            prop_assert!(
                d.allclose(&w2, 0.0),
                "to_dense != masked dense (diff {})",
                d.max_abs_diff(&w2)
            );
            Ok(())
        });
    }

    #[test]
    fn bytes_shrink_with_sparsity() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let dense = Bcsc::from_dense(&w, &BlockMask::ones(4, 4), 16);
        let sparse = Bcsc::from_dense(&w, &BlockMask::random(4, 4, 0.75, &mut rng), 16);
        assert!(sparse.bytes() < dense.bytes() / 2);
    }

    #[test]
    fn empty_mask_is_all_zero() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let b = Bcsc::from_dense(&w, &BlockMask::zeros(2, 2), 4);
        assert_eq!(b.nnzb(), 0);
        assert!(b.to_dense().allclose(&Tensor::zeros(&[8, 8]), 0.0));
    }
}
