//! Element-wise CSR — the *unstructured* sparsity baseline.
//!
//! The paper's argument (§1, §3.2) is that unstructured pruning saves FLOPs
//! but not wall-clock on real hardware because scalar gathers defeat the
//! memory pipeline. We implement the format + SpMM honestly (it gets the
//! same multithreading as the block kernel) so the Fig. 4-style benches can
//! show the same crossover: CSR only wins at extreme sparsity, BCSC wins
//! from ~50-60% on.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// CSR over elements of a `(k, n)` matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Keep entries where `keep(value)`; typically `|v| v != 0.0`.
    pub fn from_dense(w: &Tensor, keep: impl Fn(f32) -> bool) -> Csr {
        let (k, n) = (w.rows(), w.cols());
        let mut row_ptr = Vec::with_capacity(k + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..k {
            for j in 0..n {
                let v = w.at2(i, j);
                if keep(v) {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr {
            rows: k,
            cols: n,
            row_ptr,
            col_idx,
            vals,
        }
    }

    /// Random unstructured matrix with element sparsity `s`.
    pub fn random(rows: usize, cols: usize, sparsity: f64, rng: &mut Rng) -> Csr {
        let mut dense = Tensor::randn(&[rows, cols], 1.0, rng);
        for v in dense.data_mut() {
            if rng.f64() < sparsity {
                *v = 0.0;
            }
        }
        Csr::from_dense(&dense, |v| v != 0.0)
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn bytes(&self) -> usize {
        self.vals.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 8
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.rows {
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                out[i * self.cols + self.col_idx[idx] as usize] = self.vals[idx];
            }
        }
        Tensor::new(&[self.rows, self.cols], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::prop_assert;

    #[test]
    fn roundtrip() {
        prop::check_default("csr-roundtrip", |rng| {
            let r = prop::usize_in(rng, 1, 12);
            let c = prop::usize_in(rng, 1, 12);
            let mut w = Tensor::randn(&[r, c], 1.0, rng);
            for v in w.data_mut() {
                if rng.f64() < 0.6 {
                    *v = 0.0;
                }
            }
            let csr = Csr::from_dense(&w, |v| v != 0.0);
            prop_assert!(csr.to_dense().allclose(&w, 0.0), "roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn sparsity_accounting() {
        let mut rng = Rng::new(7);
        let csr = Csr::random(64, 64, 0.9, &mut rng);
        assert!((csr.sparsity() - 0.9).abs() < 0.05);
        assert_eq!(csr.nnz(), csr.vals.len());
    }
}
