//! Dense GEMM baseline (cuBLAS/CUTLASS stand-in).
//!
//! `C = A @ B`, row-major f32. Blocking scheme (COSMA-style, sized for
//! typical x86 cache hierarchy):
//!
//! * parallel over `MR`-row tiles of `C` (threads never share output rows);
//! * inside a tile, loop `n` in `NC` column panels so the `MR×NC` output
//!   subtile stays L1/L2-resident;
//! * innermost `k` loop broadcasts `A[i,k]` and FMAs the `B[k, jc..jc+NC]`
//!   panel row — this axpy form autovectorizes to AVX FMA and reuses each
//!   loaded `B` row `MR` times.
//!
//! The speedups in Figs. 4–6 are reported against *this* kernel, the same
//! way the paper reports against `min(cuBLAS, CUTLASS)`.

use crate::tensor::Tensor;
use crate::util::threadpool;

/// Rows of C per task (amortizes B-panel loads).
const MR: usize = 8;
/// Columns per inner panel (NC * 4B * MR ≈ 16 KiB of C in L1).
const NC: usize = 512;

/// `C = A @ B`; allocates the output.
pub fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C += A @ B` over raw row-major slices (C must be zeroed by the caller
/// if plain assignment is wanted). This is the shared entry for the dense
/// baseline and the engine's projection layers.
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_tiles = m.div_ceil(MR);
    let c_base = c.as_mut_ptr() as usize;
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        // SAFETY: tiles own disjoint row ranges of C; parallel_for blocks
        // until all tasks finish, so the borrow outlives the tasks.
        let c_tile = unsafe {
            std::slice::from_raw_parts_mut((c_base as *mut f32).add(i0 * n), (i1 - i0) * n)
        };
        gemm_tile(&a[i0 * k..i1 * k], b, c_tile, i1 - i0, k, n);
    });
}

/// Single-threaded tile kernel: C_tile (mr×n) += A_tile (mr×k) @ B (k×n).
#[inline]
fn gemm_tile(a: &[f32], b: &[f32], c: &mut [f32], mr: usize, k: usize, n: usize) {
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        for kk in 0..k {
            let brow = &b[kk * n + jc..kk * n + jc + nc];
            for i in 0..mr {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n + jc..i * n + jc + nc];
                axpy(aik, brow, crow);
            }
        }
        jc += nc;
    }
}

/// `y += a * x` — the vectorized inner loop shared with the sparse kernels.
#[inline(always)]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // chunks of 8 encourage AVX codegen without arch-specific intrinsics
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let xi = &x[c * 8..c * 8 + 8];
        let yi = &mut y[c * 8..c * 8 + 8];
        for l in 0..8 {
            yi[l] += a * xi[l];
        }
    }
    for l in chunks * 8..x.len() {
        y[l] += a * x[l];
    }
}

/// Naive triple loop — the oracle the fast kernels are tested against.
pub fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(k, b.rows());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for kk in 0..k {
            let aik = a.at2(i, kk);
            for j in 0..n {
                let v = c.at2(i, j) + aik * b.at2(kk, j);
                c.set2(i, j, v);
            }
        }
    }
    c
}

/// FLOP count of one `m×k×n` GEMM (mul+add).
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::prop_assert;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_property() {
        prop::check_default("gemm-vs-naive", |rng| {
            let m = prop::usize_in(rng, 1, 40);
            let k = prop::usize_in(rng, 1, 40);
            let n = prop::usize_in(rng, 1, 600); // crosses the NC boundary
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let fast = gemm(&a, &b);
            let slow = gemm_naive(&a, &b);
            let diff = fast.max_abs_diff(&slow);
            prop_assert!(diff < 1e-3, "diff {diff} at m={m} k={k} n={n}");
            Ok(())
        });
    }

    #[test]
    fn identity() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[16, 16]);
        for i in 0..16 {
            eye.set2(i, i, 1.0);
        }
        assert!(gemm(&a, &eye).allclose(&a, 1e-6));
    }

    #[test]
    fn accumulates_into_existing_c() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut c = Tensor::full(&[4, 4], 1.0);
        gemm_into(a.data(), b.data(), c.data_mut(), 4, 4, 4);
        let mut want = gemm_naive(&a, &b);
        want.add_inplace(&Tensor::full(&[4, 4], 1.0));
        assert!(c.allclose(&want, 1e-4));
    }

    #[test]
    fn axpy_tail_handling() {
        let x: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let mut y = vec![1.0f32; 13];
        axpy(2.0, &x, &mut y);
        for i in 0..13 {
            assert_eq!(y[i], 1.0 + 2.0 * i as f32);
        }
    }
}
