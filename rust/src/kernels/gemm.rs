//! Dense GEMM baseline (cuBLAS/CUTLASS stand-in), rebuilt on the packed
//! register-blocked micro-kernel.
//!
//! `C = A @ B`, row-major f32. BLIS/COSMA-style decomposition:
//!
//! * [`PackedB`] panels: `B` is repacked once into `NR`-wide k-major
//!   column panels (weights: once per model load, via
//!   [`gemm_packed_into`]; ad-hoc calls: once per multiply inside
//!   [`gemm_into`]);
//! * threads own disjoint `MR`-row tiles of `C`; each task transposes its
//!   `A` tile into a k-major panel (scratch-arena backed, allocation-free
//!   after warmup) and walks the B panels;
//! * [`crate::kernels::microkernel`] runs 4×NR register tiles over the two
//!   packed panels — contiguous loads only, accumulators in registers, `C`
//!   written once per tile.
//!
//! The seed kernel (scalar axpy over strided operands) is retained as
//! [`gemm_into_ref`]: it is the baseline the `BENCH_kernels.json` A/B
//! harness measures against, and the better choice for very small `m`
//! where packing `B` cannot amortize.
//!
//! The speedups in Figs. 4–6 are reported against *this* kernel, the same
//! way the paper reports against `min(cuBLAS, CUTLASS)`.

use crate::kernels::microkernel::microkernel_d;
use crate::kernels::pack::{pack_a_panel, PackedB};
use crate::kernels::simd::{self, Epilogue};
use crate::tensor::Tensor;
use crate::util::{scratch, threadpool};

/// Rows of C per parallel task in the packed path (each task streams every
/// B panel once, so taller tiles amortize B traffic).
const MR: usize = 16;

/// Below this row count the panel-packing overhead (O(k·n) moves) is not
/// amortized and the reference kernel wins; decode-time GEMV (m = 1) and
/// small prefill batches take this branch unless B is prepacked.
const PACK_MIN_M: usize = 16;

/// Rows per task of the reference kernel.
const REF_MR: usize = 8;
/// Columns per inner panel of the reference kernel (L1-resident C subtile).
const NC: usize = 512;

/// `C = A @ B`; allocates the output.
pub fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C += A @ B` over raw row-major slices (C must be zeroed by the caller
/// if plain assignment is wanted). This is the shared entry for the dense
/// baseline; it packs `B` on the fly when `m` is large enough to amortize
/// the packing sweep and otherwise falls back to [`gemm_into_ref`].
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if m < PACK_MIN_M {
        gemm_into_ref(a, b, c, m, k, n);
        return;
    }
    let packed = PackedB::pack(b, k, n);
    gemm_packed_into(a, &packed, c, m);
}

/// `C += A @ Bᵖ` against a prepacked right operand — the engine's
/// projection path (weights packed once at model load, reused every
/// prefill/decode step).
pub fn gemm_packed_into(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize) {
    gemm_packed_ep_into(a, bp, c, m, Epilogue::None);
}

/// [`gemm_packed_into`] with a fused [`Epilogue`] applied during each
/// panel's C write-back (each panel runs the full depth `k` in one
/// micro-kernel call, so the write-back *is* the final accumulation —
/// exactly the epilogue contract). `ep` operands are relative to the full
/// `m × n` output: a bias covers all `n` columns, a `SiluGate` gate is a
/// congruent `m × n` matrix. This is how the dense fused MLPs apply
/// bias/GeLU/SiLU/SwiGLU without a second pass over the hidden tensor.
pub fn gemm_packed_ep_into(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, ep: Epilogue<'_>) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    ep.check_operands(m, n);
    let d = simd::dispatch();
    if k == 0 {
        // nothing to accumulate, but a non-zero-preserving epilogue (bias)
        // must still reach every element
        if !matches!(ep, Epilogue::None) {
            d.apply_epilogue_region(c, n, m, n, ep);
        }
        return;
    }
    let n_tiles = m.div_ceil(MR);
    let c_base = c.as_mut_ptr() as usize;
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        let mr = i1 - i0;
        // k-major A tile (allocation-free after warmup)
        let mut ap = scratch::take_uninit(mr * k);
        pack_a_panel(&a[i0 * k..i1 * k], k, mr, k, &mut ap);
        // SAFETY: tiles own disjoint row ranges of C; parallel_for blocks
        // until all tasks finish, so the borrow outlives the tasks.
        let c_tile = unsafe {
            std::slice::from_raw_parts_mut((c_base as *mut f32).add(i0 * n), mr * n)
        };
        let ep_tile = ep.shift(i0, 0);
        for p in 0..bp.panels() {
            let cols = bp.panel_cols(p);
            microkernel_d(
                d,
                &ap,
                mr,
                mr,
                bp.panel(p),
                bp.nr,
                cols,
                k,
                &mut c_tile[p * bp.nr..],
                n,
                ep_tile.shift(0, p * bp.nr),
            );
        }
    });
}

/// `C += A · Bᵀ` over raw row-major slices — the backward-pass
/// *data-gradient* GEMM (`dX = dY · Wᵀ` with `W` stored un-transposed).
/// `a` is `(m × k)`, `b` is `(n × k)` row-major, `c` is `(m × n)`.
///
/// `B` is packed straight from its transposed layout
/// ([`PackedB::pack_transposed`]: a blocked-transpose sweep, no
/// materialized `Bᵀ`), then the packed micro-kernel path runs unchanged.
pub fn gemm_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let packed = PackedB::pack_transposed(b, n, k);
    gemm_packed_into(a, &packed, c, m);
}

/// `C += Aᵀ · B` over raw row-major slices — the backward-pass
/// *weight-gradient* GEMM (`dW = Xᵀ · dY`). `a` is `(m × k)` (its
/// transpose `(k × m)` is the left operand), `b` is `(m × n)`, `c` is
/// `(k × n)`.
///
/// The trick that keeps this on the packed micro-kernel without a strided
/// gather: a k-major panel of `Aᵀ` rows `i0..i1` is
/// `ap[d*mr + r] = a[d*k + i0 + r]` — for each depth step `d` that is one
/// **contiguous** slice of row `d` of `A`, so the pack is a clean blocked
/// copy. `B` (depth `m`) packs once per call and is streamed by every row
/// tile.
pub fn gemm_tn_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let packed = PackedB::pack(b, m, n);
    let n_tiles = k.div_ceil(MR);
    let c_base = c.as_mut_ptr() as usize;
    let disp = simd::dispatch();
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(k);
        let mr = i1 - i0;
        // k-major Aᵀ tile: contiguous reads per depth step (see above)
        let mut ap = scratch::take_uninit(mr * m);
        for d in 0..m {
            ap[d * mr..(d + 1) * mr].copy_from_slice(&a[d * k + i0..d * k + i1]);
        }
        // SAFETY: tiles own disjoint row ranges of C; parallel_for blocks
        // until all tasks finish, so the borrow outlives the tasks.
        let c_tile = unsafe {
            std::slice::from_raw_parts_mut((c_base as *mut f32).add(i0 * n), mr * n)
        };
        for p in 0..packed.panels() {
            let cols = packed.panel_cols(p);
            microkernel_d(
                disp,
                &ap,
                mr,
                mr,
                packed.panel(p),
                packed.nr,
                cols,
                m,
                &mut c_tile[p * packed.nr..],
                n,
                Epilogue::None,
            );
        }
    });
}

/// The seed kernel: parallel row tiles, `NC`-column C panels, scalar-axpy
/// inner loop over strided operands. Kept as the A/B baseline for
/// `BENCH_kernels.json` and as the small-`m` fallback.
pub fn gemm_into_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_tiles = m.div_ceil(REF_MR);
    let c_base = c.as_mut_ptr() as usize;
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * REF_MR;
        let i1 = (i0 + REF_MR).min(m);
        // SAFETY: tiles own disjoint row ranges of C; parallel_for blocks
        // until all tasks finish, so the borrow outlives the tasks.
        let c_tile = unsafe {
            std::slice::from_raw_parts_mut((c_base as *mut f32).add(i0 * n), (i1 - i0) * n)
        };
        gemm_tile_ref(&a[i0 * k..i1 * k], b, c_tile, i1 - i0, k, n);
    });
}

/// Single-threaded reference tile: C_tile (mr×n) += A_tile (mr×k) @ B (k×n).
#[inline]
fn gemm_tile_ref(a: &[f32], b: &[f32], c: &mut [f32], mr: usize, k: usize, n: usize) {
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        for kk in 0..k {
            let brow = &b[kk * n + jc..kk * n + jc + nc];
            for i in 0..mr {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n + jc..i * n + jc + nc];
                axpy(aik, brow, crow);
            }
        }
        jc += nc;
    }
}

/// `y += a * x` — the vectorized inner loop of the reference kernels.
#[inline(always)]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // chunks of 8 encourage AVX codegen without arch-specific intrinsics
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let xi = &x[c * 8..c * 8 + 8];
        let yi = &mut y[c * 8..c * 8 + 8];
        for l in 0..8 {
            yi[l] += a * xi[l];
        }
    }
    for l in chunks * 8..x.len() {
        y[l] += a * x[l];
    }
}

/// Naive triple loop — the oracle the fast kernels are tested against.
pub fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(k, b.rows());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for kk in 0..k {
            let aik = a.at2(i, kk);
            for j in 0..n {
                let v = c.at2(i, j) + aik * b.at2(kk, j);
                c.set2(i, j, v);
            }
        }
    }
    c
}

/// FLOP count of one `m×k×n` GEMM (mul+add).
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_property() {
        prop::check_default("gemm-vs-naive", |rng| {
            let m = prop::usize_in(rng, 1, 40); // crosses the PACK_MIN_M dispatch
            let k = prop::usize_in(rng, 1, 40);
            let n = prop::usize_in(rng, 1, 600); // crosses the NC boundary
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let fast = gemm(&a, &b);
            let slow = gemm_naive(&a, &b);
            let diff = fast.max_abs_diff(&slow);
            prop_assert!(diff < 1e-3, "diff {diff} at m={m} k={k} n={n}");
            Ok(())
        });
    }

    #[test]
    fn packed_matches_naive_property() {
        prop::check_default("gemm-packed-vs-naive", |rng| {
            // force the packed path regardless of the dispatch threshold,
            // including m = 1 (decode) and ragged tile/panel tails
            let m = *prop::pick(rng, &[1, 2, 15, 16, 17, 33]);
            let k = prop::usize_in(rng, 1, 48);
            let n = prop::usize_in(rng, 1, 70);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let packed = PackedB::pack(b.data(), k, n);
            let mut c = Tensor::zeros(&[m, n]);
            gemm_packed_into(a.data(), &packed, c.data_mut(), m);
            let slow = gemm_naive(&a, &b);
            let diff = c.max_abs_diff(&slow);
            prop_assert!(diff < 1e-3, "diff {diff} at m={m} k={k} n={n}");
            Ok(())
        });
    }

    #[test]
    fn nt_matches_naive_on_explicit_transpose() {
        prop::check_default("gemm-nt-vs-naive", |rng| {
            let m = *prop::pick(rng, &[1, 2, 15, 16, 17, 33]);
            let k = prop::usize_in(rng, 1, 40);
            let n = prop::usize_in(rng, 1, 40);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[n, k], 1.0, rng);
            let mut c = Tensor::zeros(&[m, n]);
            gemm_nt_into(a.data(), b.data(), c.data_mut(), m, k, n);
            let want = gemm_naive(&a, &b.transpose2());
            let diff = c.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} at m={m} k={k} n={n}");
            Ok(())
        });
    }

    #[test]
    fn tn_matches_naive_on_explicit_transpose() {
        prop::check_default("gemm-tn-vs-naive", |rng| {
            // m is the contraction depth here; k crosses the MR tiling
            let m = prop::usize_in(rng, 1, 40);
            let k = *prop::pick(rng, &[1, 2, 15, 16, 17, 33]);
            let n = prop::usize_in(rng, 1, 40);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[m, n], 1.0, rng);
            let mut c = Tensor::zeros(&[k, n]);
            gemm_tn_into(a.data(), b.data(), c.data_mut(), m, k, n);
            let want = gemm_naive(&a.transpose2(), &b);
            let diff = c.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} at m={m} k={k} n={n}");
            Ok(())
        });
    }

    #[test]
    fn nt_tn_accumulate_and_empty_dims() {
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let mut c = Tensor::full(&[6, 5], 1.0);
        gemm_nt_into(a.data(), b.data(), c.data_mut(), 6, 4, 5);
        let mut want = gemm_naive(&a, &b.transpose2());
        want.add_inplace(&Tensor::full(&[6, 5], 1.0));
        assert!(c.allclose(&want, 1e-4));
        let b2 = Tensor::randn(&[6, 3], 1.0, &mut rng);
        let mut c2 = Tensor::full(&[4, 3], 2.0);
        gemm_tn_into(a.data(), b2.data(), c2.data_mut(), 6, 4, 3);
        let mut want2 = gemm_naive(&a.transpose2(), &b2);
        want2.add_inplace(&Tensor::full(&[4, 3], 2.0));
        assert!(c2.allclose(&want2, 1e-4));
        // empty dims are no-ops
        gemm_nt_into(&[], &[], &mut [], 0, 0, 0);
        gemm_tn_into(&[], &[], &mut [], 0, 0, 0);
        let mut c3 = Tensor::full(&[2, 3], 5.0);
        gemm_tn_into(&[], &[], c3.data_mut(), 0, 2, 3);
        assert!(c3.allclose(&Tensor::full(&[2, 3], 5.0), 0.0));
    }

    #[test]
    fn ref_and_packed_agree() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (37, 29, 83);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c_ref = Tensor::zeros(&[m, n]);
        gemm_into_ref(a.data(), b.data(), c_ref.data_mut(), m, k, n);
        let packed = PackedB::pack(b.data(), k, n);
        let mut c_new = Tensor::zeros(&[m, n]);
        gemm_packed_into(a.data(), &packed, c_new.data_mut(), m);
        assert!(
            c_new.allclose(&c_ref, 1e-3),
            "diff {}",
            c_new.max_abs_diff(&c_ref)
        );
    }

    #[test]
    fn identity() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[16, 16]);
        for i in 0..16 {
            eye.set2(i, i, 1.0);
        }
        assert!(gemm(&a, &eye).allclose(&a, 1e-6));
    }

    #[test]
    fn accumulates_into_existing_c() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut c = Tensor::full(&[4, 4], 1.0);
        gemm_into(a.data(), b.data(), c.data_mut(), 4, 4, 4);
        let mut want = gemm_naive(&a, &b);
        want.add_inplace(&Tensor::full(&[4, 4], 1.0));
        assert!(c.allclose(&want, 1e-4));
    }

    #[test]
    fn packed_accumulates_into_existing_c() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[20, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 24], 1.0, &mut rng);
        let packed = PackedB::pack(b.data(), 8, 24);
        let mut c = Tensor::full(&[20, 24], 2.0);
        gemm_packed_into(a.data(), &packed, c.data_mut(), 20);
        let mut want = gemm_naive(&a, &b);
        want.add_inplace(&Tensor::full(&[20, 24], 2.0));
        assert!(c.allclose(&want, 1e-4));
    }

    /// The dense fused-MLP path: epilogues applied during the panel
    /// write-back must equal GEMM + a separate elementwise pass.
    #[test]
    fn packed_epilogue_matches_unfused() {
        use crate::kernels::ops;
        prop::check_default("gemm-packed-epilogue", |rng| {
            let m = *prop::pick(rng, &[1, 2, 15, 16, 17, 33]);
            let k = prop::usize_in(rng, 1, 24);
            let n = prop::usize_in(rng, 1, 40);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let gate = Tensor::randn(&[m, n], 1.0, rng);
            let bias = prop::normal_vec(rng, n);
            let packed = PackedB::pack(b.data(), k, n);
            let base = gemm_naive(&a, &b);
            let cases: [(Epilogue<'_>, usize); 4] = [
                (Epilogue::Gelu, 0),
                (Epilogue::Silu, 1),
                (Epilogue::SiluGate { g: gate.data(), ldg: n }, 2),
                (Epilogue::BiasGelu(&bias), 3),
            ];
            for (ep, kind) in cases {
                let mut c = Tensor::zeros(&[m, n]);
                gemm_packed_ep_into(a.data(), &packed, c.data_mut(), m, ep);
                for i in 0..m {
                    for j in 0..n {
                        let v = base.at2(i, j);
                        let want = match kind {
                            0 => ops::gelu(v),
                            1 => ops::silu(v),
                            2 => ops::silu(v) * gate.at2(i, j),
                            _ => ops::gelu(v + bias[j]),
                        };
                        let got = c.at2(i, j);
                        prop_assert!(
                            (got - want).abs() <= 1e-3 + 1e-4 * want.abs(),
                            "kind {kind} ({i},{j}): {got} vs {want} (m={m} k={k} n={n})"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_dims_are_noops() {
        gemm_into(&[], &[], &mut [], 0, 0, 0);
        let packed = PackedB::pack(&[], 0, 0);
        gemm_packed_into(&[], &packed, &mut [], 0);
        // k == 0 with nonzero m,n must leave C unchanged
        let mut c = Tensor::full(&[2, 3], 3.0);
        gemm_into(&[], &[], c.data_mut(), 2, 0, 3);
        assert!(c.allclose(&Tensor::full(&[2, 3], 3.0), 0.0));
    }

    #[test]
    fn axpy_tail_handling() {
        let x: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let mut y = vec![1.0f32; 13];
        axpy(2.0, &x, &mut y);
        for i in 0..13 {
            assert_eq!(y[i], 1.0 + 2.0 * i as f32);
        }
    }
}
