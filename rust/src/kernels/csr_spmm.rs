//! Unstructured CSR SpMM baseline (cuSPARSE role in Fig. 4).
//!
//! `Y = X @ W` with element-wise sparse `W`. Written as well as the format
//! allows — same thread pool, row-tiled X, W traversed once per tile — but
//! the scalar scatter into `Y` columns is exactly the memory-pipeline
//! defeat the paper describes: FLOP savings without block structure do not
//! become time savings until sparsity is extreme.

use crate::sparse::Csr;
use crate::tensor::Tensor;
use crate::util::threadpool;

const MR: usize = 8;

/// `Y = X @ W_csr`.
pub fn csr_spmm(x: &Tensor, w: &Csr) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    assert_eq!(k, w.rows);
    let n = w.cols;
    let mut y = Tensor::zeros(&[m, n]);
    let n_tiles = m.div_ceil(MR);
    let y_base = y.data_mut().as_mut_ptr() as usize;
    let xd = x.data();
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        // SAFETY: row tiles of Y are disjoint; parallel_for blocks.
        let yt = unsafe {
            std::slice::from_raw_parts_mut((y_base as *mut f32).add(i0 * n), (i1 - i0) * n)
        };
        for kk in 0..k {
            let lo = w.row_ptr[kk];
            let hi = w.row_ptr[kk + 1];
            if lo == hi {
                continue;
            }
            for i in i0..i1 {
                let xv = xd[i * k + kk];
                if xv == 0.0 {
                    continue;
                }
                let yrow = &mut yt[(i - i0) * n..(i - i0 + 1) * n];
                for idx in lo..hi {
                    yrow[w.col_idx[idx] as usize] += xv * w.vals[idx];
                }
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::gemm_naive;
    use crate::testkit::prop;
    use crate::prop_assert;

    #[test]
    fn matches_dense_property() {
        prop::check_default("csr-spmm-vs-dense", |rng| {
            let m = prop::usize_in(rng, 1, 20);
            let k = prop::usize_in(rng, 1, 24);
            let n = prop::usize_in(rng, 1, 24);
            let x = Tensor::randn(&[m, k], 1.0, rng);
            let mut wd = Tensor::randn(&[k, n], 1.0, rng);
            for v in wd.data_mut() {
                if rng.f64() < 0.7 {
                    *v = 0.0;
                }
            }
            let w = Csr::from_dense(&wd, |v| v != 0.0);
            let got = csr_spmm(&x, &w);
            let want = gemm_naive(&x, &wd);
            let diff = got.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff}");
            Ok(())
        });
    }
}
