//! Dense attention for the native inference engine.
//!
//! The paper leaves attention dense (its contribution is MLP sparsity), so
//! this module provides exactly what the engine needs: a causal prefill
//! pass over a whole prompt, and a single-position decode pass against a KV
//! cache. Layout is `(heads, seq, head_dim)` per layer, contiguous.

use crate::kernels::ops::softmax_row;
use crate::util::{scratch, threadpool};

/// Causal self-attention over a full sequence (prefill / training-eval).
///
/// `q,k,v`: `(heads, seq, hd)` flattened; returns `(seq, heads*hd)` merged.
pub fn causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    seq: usize,
    hd: usize,
) -> Vec<f32> {
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; seq * heads * hd];
    let out_base = out.as_mut_ptr() as usize;
    threadpool::parallel_for(heads, |h| {
        let qh = &q[h * seq * hd..(h + 1) * seq * hd];
        let kh = &k[h * seq * hd..(h + 1) * seq * hd];
        let vh = &v[h * seq * hd..(h + 1) * seq * hd];
        let mut scores = vec![0.0f32; seq];
        for i in 0..seq {
            let qi = &qh[i * hd..(i + 1) * hd];
            for (j, s) in scores.iter_mut().enumerate().take(i + 1) {
                let kj = &kh[j * hd..(j + 1) * hd];
                *s = dot(qi, kj) * scale;
            }
            softmax_row(&mut scores[..i + 1]);
            // out[i, h*hd..] = sum_j scores[j] * v[j]
            // SAFETY: each head writes a disjoint column stripe.
            let orow = unsafe {
                std::slice::from_raw_parts_mut(
                    (out_base as *mut f32).add(i * heads * hd + h * hd),
                    hd,
                )
            };
            orow.fill(0.0);
            for (j, &w) in scores.iter().enumerate().take(i + 1) {
                let vj = &vh[j * hd..(j + 1) * hd];
                for d in 0..hd {
                    orow[d] += w * vj[d];
                }
            }
        }
    });
    out
}

/// Decode attention for one new position against a KV cache.
///
/// `q`: `(heads, hd)` for the new token. `kcache`/`vcache`:
/// `(heads, max_seq, hd)`; positions `0..=pos` are valid. Returns
/// `(heads*hd,)` merged.
pub fn decode_attention(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    heads: usize,
    max_seq: usize,
    hd: usize,
    pos: usize,
) -> Vec<f32> {
    assert!(pos < max_seq);
    let mut out = vec![0.0f32; heads * hd];
    let out_base = out.as_mut_ptr() as usize;
    threadpool::parallel_for(heads, |h| {
        // SAFETY: each head writes a disjoint `hd`-wide stripe of `out`, and
        // parallel_for blocks until every head is done.
        let orow = unsafe {
            std::slice::from_raw_parts_mut((out_base as *mut f32).add(h * hd), hd)
        };
        decode_head_into(
            &q[h * hd..(h + 1) * hd],
            &kcache[h * max_seq * hd..],
            &vcache[h * max_seq * hd..],
            hd,
            pos,
            orow,
        );
    });
    out
}

/// One head of decode attention, single-threaded: softmax(q·Kᵀ)·V over
/// positions `0..=pos`, written into `out` (length `hd`, overwritten).
///
/// `kh`/`vh` point at the head's stripe of the KV cache (`max_seq × hd`
/// row-major, only `0..=pos` read). This is the shared inner body of
/// [`decode_attention`] and of the engine's batched decode, which schedules
/// `(session, head)` items on the thread pool directly — same arithmetic,
/// same summation order, so batched and sequential decode produce
/// bit-identical outputs.
pub fn decode_head_into(q: &[f32], kh: &[f32], vh: &[f32], hd: usize, pos: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), hd);
    debug_assert_eq!(out.len(), hd);
    let scale = 1.0 / (hd as f32).sqrt();
    // scratch-arena scores: every element is written below before softmax
    // reads it, and the buffer recycles per pool worker — the decode hot
    // path stays allocation-free after warmup
    let mut scores = scratch::take_uninit(pos + 1);
    for (j, s) in scores.iter_mut().enumerate() {
        *s = dot(q, &kh[j * hd..(j + 1) * hd]) * scale;
    }
    softmax_row(&mut scores);
    out.fill(0.0);
    for (j, &w) in scores.iter().enumerate() {
        let vj = &vh[j * hd..(j + 1) * hd];
        for d in 0..hd {
            out[d] += w * vj[d];
        }
    }
}

#[inline(always)]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive single-threaded oracle.
    fn causal_naive(q: &[f32], k: &[f32], v: &[f32], h: usize, s: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; s * h * d];
        for hh in 0..h {
            for i in 0..s {
                let qi = &q[hh * s * d + i * d..hh * s * d + (i + 1) * d];
                let mut sc: Vec<f32> = (0..=i)
                    .map(|j| {
                        dot(qi, &k[hh * s * d + j * d..hh * s * d + (j + 1) * d])
                            / (d as f32).sqrt()
                    })
                    .collect();
                softmax_row(&mut sc);
                for (j, &w) in sc.iter().enumerate() {
                    for dd in 0..d {
                        out[i * h * d + hh * d + dd] += w * v[hh * s * d + j * d + dd];
                    }
                }
            }
        }
        out
    }

    #[test]
    fn causal_matches_naive() {
        let (h, s, d) = (3, 7, 4);
        let mut rng = Rng::new(1);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let got = causal_attention(&q, &k, &v, h, s, d);
        let want = causal_naive(&q, &k, &v, h, s, d);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn decode_matches_last_row_of_causal() {
        let (h, s, d) = (2, 6, 4);
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let full = causal_attention(&q, &k, &v, h, s, d);
        // decode for position s-1 using q's last row per head
        let mut qlast = vec![0.0f32; h * d];
        for hh in 0..h {
            qlast[hh * d..(hh + 1) * d]
                .copy_from_slice(&q[hh * s * d + (s - 1) * d..hh * s * d + s * d]);
        }
        let got = decode_attention(&qlast, &k, &v, h, s, d, s - 1);
        for hh in 0..h {
            for dd in 0..d {
                let want = full[(s - 1) * h * d + hh * d + dd];
                assert!((got[hh * d + dd] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn decode_head_matches_full_decode_bitwise() {
        let (h, s, d) = (3, 5, 4);
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(h * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let full = decode_attention(&q, &k, &v, h, s, d, s - 1);
        for hh in 0..h {
            let mut out = vec![7.0f32; d]; // dirty buffer: must be overwritten
            decode_head_into(
                &q[hh * d..(hh + 1) * d],
                &k[hh * s * d..],
                &v[hh * s * d..],
                d,
                s - 1,
                &mut out,
            );
            assert_eq!(out, full[hh * d..(hh + 1) * d].to_vec(), "head {hh}");
        }
    }

    #[test]
    fn first_position_attends_only_to_itself() {
        let (h, s, d) = (1, 3, 2);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let out = causal_attention(&q, &k, &v, h, s, d);
        assert!((out[0] - v[0]).abs() < 1e-5);
        assert!((out[1] - v[1]).abs() < 1e-5);
    }
}
