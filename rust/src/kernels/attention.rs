//! Attention kernels for the native inference engine: tiled prefill and
//! paged decode.
//!
//! The paper leaves attention dense (its contribution is MLP sparsity),
//! but PR 1/PR 2 made every projection and MLP a packed GEMM/BSpMM, so
//! the scalar per-row attention of the seed became the remaining hot
//! path. This module rebuilds it around position *blocks*:
//!
//! * [`causal_attention`] — prefill over a whole prompt as a q-tile ×
//!   k-tile blocked kernel. Each tile pair runs **two small packed GEMMs**
//!   through [`crate::kernels::microkernel`] (scores `Q·Kᵀ`, then
//!   `P·V`), with online streaming-softmax rescaling across k-tiles
//!   (the FlashAttention recurrence), so scores never materialize beyond
//!   one `TQ × TK` tile and every buffer comes from the scratch arena.
//! * [`decode_head_paged_into`] — one head of single-position decode
//!   that walks fixed-size KV *pages* (see [`crate::model::kv`]) with an
//!   unrolled multi-accumulator dot lane. Page size never changes the
//!   position order or per-position arithmetic, so outputs are
//!   **bit-identical across page sizes** (the flat cache is just
//!   `page = max_seq`).
//!
//! The seed kernels survive as [`causal_attention_ref`] /
//! [`decode_attention_ref`] / [`decode_head_into`]: they are the oracles
//! the tiled/paged kernels are tolerance-gated against (≤ 1e-5 abs) and
//! the baselines `blast exp attention` measures (`BENCH_attention.json`).
//!
//! Layout: `(heads, seq, hd)` per layer for prefill operands; merged
//! `(seq, heads*hd)` outputs.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernels::microkernel::microkernel_d;
use crate::kernels::ops::{softmax_row, softmax_row_scalar};
use crate::kernels::pack::pack_kt_panel;
use crate::kernels::simd::{self, Epilogue, KernelDispatch};
use crate::util::{scratch, threadpool};

/// Query rows per prefill tile (output rows of the per-tile GEMMs).
pub const TQ: usize = 32;

/// Key positions per prefill tile (score columns per streaming step).
pub const TK: usize = 64;

// ---------------------------------------------------------------------
// BLASST dynamic blocked attention sparsity
// ---------------------------------------------------------------------
//
// The streaming-softmax recurrence already tracks the exact statistic
// BLASST ("Dynamic BLocked Attention Sparsity via Softmax Thresholding")
// thresholds on: the per-row running score max `m`. When a k-tile row's
// score max falls more than τ below `m`, every one of its post-softmax
// weights is < e^(−τ) relative to the *final* max (the running max only
// grows, so `max < m_now − τ` implies `max < m_final − τ`), and the
// row's whole contribution from that tile carries post-softmax mass
// ≤ TK·e^(−τ). Skipping the shifted-exp, the `P` column build and the
// `P·V` accumulation for that row leaves the `m`/`l`/`acc` recurrence
// untouched and well-defined — the tile simply contributes nothing,
// exactly like a causally-masked tile.
//
// τ is a per-engine knob (`AttnOptions { threshold }`): `None` (the
// default) takes the exact code path below, bit-for-bit the PR-8
// kernels; `Some(τ)` arms the skip test, which costs one extra
// `tile_max` reduction per k-tile row (its own dispatch lane).

/// Cumulative dynamic-sparsity counters, shared by every prefill/decode
/// call of one engine (replicas get their own). Only armed (`τ = Some`)
/// kernel paths ever increment, so an exact engine's counters stay
/// zero and `ServeMetrics` can print them conditionally without
/// disturbing byte-identical summaries.
#[derive(Debug, Default)]
pub struct AttnCounters {
    tiles: AtomicU64,
    tiles_skipped: AtomicU64,
    rows: AtomicU64,
    rows_skipped: AtomicU64,
    pages: AtomicU64,
    pages_skipped: AtomicU64,
}

impl AttnCounters {
    /// Fresh all-zero counters.
    pub fn new() -> AttnCounters {
        AttnCounters::default()
    }

    /// One self-consistent-enough snapshot (relaxed loads: counters are
    /// monotone and only read for reporting).
    pub fn snapshot(&self) -> AttnStats {
        AttnStats {
            tiles: self.tiles.load(Ordering::Relaxed),
            tiles_skipped: self.tiles_skipped.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            rows_skipped: self.rows_skipped.load(Ordering::Relaxed),
            pages: self.pages.load(Ordering::Relaxed),
            pages_skipped: self.pages_skipped.load(Ordering::Relaxed),
        }
    }

    /// Accumulate one prefill item's locally-counted tile/row totals
    /// (one relaxed add per field per `(head, q-tile)` item, not per
    /// tile — the hot loop touches only locals).
    fn add_prefill(&self, tiles: u64, tiles_skipped: u64, rows: u64, rows_skipped: u64) {
        self.tiles.fetch_add(tiles, Ordering::Relaxed);
        self.tiles_skipped.fetch_add(tiles_skipped, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.rows_skipped.fetch_add(rows_skipped, Ordering::Relaxed);
    }

    /// Accumulate one paged-decode head call's page totals.
    fn add_decode(&self, pages: u64, pages_skipped: u64) {
        self.pages.fetch_add(pages, Ordering::Relaxed);
        self.pages_skipped.fetch_add(pages_skipped, Ordering::Relaxed);
    }
}

/// Plain-value snapshot of [`AttnCounters`] — what `ServeMetrics`, the
/// fleet aggregation and the eval harnesses report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AttnStats {
    /// k-tile × row-group visits while armed (prefill skip-test
    /// denominators).
    pub tiles: u64,
    /// k-tiles whose `P·V` micro-GEMM was skipped outright (every
    /// causally-live row thresholded out).
    pub tiles_skipped: u64,
    /// Per-row k-tile visits while armed (causally live rows only).
    pub rows: u64,
    /// Rows whose shifted-exp + `P` column were skipped by the
    /// threshold.
    pub rows_skipped: u64,
    /// KV pages visited by armed paged decode.
    pub pages: u64,
    /// Pages skipped whole by the norm-bound test.
    pub pages_skipped: u64,
}

impl AttnStats {
    /// Whether any armed kernel ran (exact engines stay `false`).
    pub fn engaged(&self) -> bool {
        self.tiles != 0 || self.pages != 0
    }

    /// Fraction of row-level tile work skipped in prefill (0.0 when
    /// nothing ran).
    pub fn row_skip_frac(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.rows_skipped as f64 / self.rows as f64
        }
    }

    /// Fraction of whole k-tiles whose `P·V` GEMM was skipped.
    pub fn tile_skip_frac(&self) -> f64 {
        if self.tiles == 0 {
            0.0
        } else {
            self.tiles_skipped as f64 / self.tiles as f64
        }
    }

    /// Fraction of decode pages skipped whole.
    pub fn page_skip_frac(&self) -> f64 {
        if self.pages == 0 {
            0.0
        } else {
            self.pages_skipped as f64 / self.pages as f64
        }
    }

    /// Counter-wise sum — the fleet aggregation.
    pub fn merge(&mut self, o: &AttnStats) {
        self.tiles += o.tiles;
        self.tiles_skipped += o.tiles_skipped;
        self.rows += o.rows;
        self.rows_skipped += o.rows_skipped;
        self.pages += o.pages;
        self.pages_skipped += o.pages_skipped;
    }
}

/// An armed threshold: τ plus the counters the kernels report into.
/// `Copy` so it threads through the thread-pool closures by value.
#[derive(Clone, Copy)]
pub struct AttnThreshold<'a> {
    /// Skip a k-tile row when its score max falls more than this far
    /// below the running row max (post-softmax mass of everything
    /// skipped is ≤ count·e^(−τ)). Must be finite and ≥ 0 — the engine
    /// validates at build time.
    pub tau: f32,
    /// Where skip/visit counts accumulate.
    pub counters: &'a AttnCounters,
}

/// Causal self-attention over a full sequence (prefill / training-eval),
/// tiled with streaming softmax.
///
/// `q,k,v`: `(heads, seq, hd)` flattened; returns `(seq, heads*hd)`
/// merged. Matches [`causal_attention_ref`] within ~1e-6 (the online
/// rescaling reorders the reductions; tests gate at 1e-5 abs).
///
/// This is exactly [`causal_attention_offset`] with every key position
/// also a query position (`q_rows == kv_len`); the delegation keeps one
/// code path, and the offset kernel's extra masking branch is provably
/// dead at offset 0 (k-tiles start at multiples of [`TK`], q-tiles at
/// multiples of [`TQ`], so a tile's first key never exceeds its first
/// query) — same loop, same bits.
pub fn causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    seq: usize,
    hd: usize,
) -> Vec<f32> {
    causal_attention_thresh(q, k, v, heads, seq, hd, None)
}

/// [`causal_attention`] with an optional BLASST skip threshold. `None`
/// is *the* exact path (the plain entry points delegate here), so
/// τ=off stays bit-identical by construction.
pub fn causal_attention_thresh(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    seq: usize,
    hd: usize,
    th: Option<AttnThreshold<'_>>,
) -> Vec<f32> {
    causal_attention_offset_thresh(q, k, v, heads, seq, seq, hd, th)
}

/// Causal self-attention for the **last `q_rows` positions** of a
/// `kv_len`-position sequence — the resume-prefill kernel behind KV
/// prefix sharing: when the leading pages of a prompt are mapped from the
/// prefix cache, only the unshared tail's queries need computing, against
/// the *full* key/value sequence.
///
/// `q`: `(heads, q_rows, hd)` — queries for global positions
/// `offset..kv_len` where `offset = kv_len − q_rows`. `k,v`:
/// `(heads, kv_len, hd)` — the whole sequence (shared prefix gathered
/// from cache pages + freshly computed tail). Returns
/// `(q_rows, heads*hd)` merged, row `i` being global position
/// `offset + i`.
///
/// **Bit-identity:** row `offset + i` here is bitwise identical to row
/// `offset + i` of [`causal_attention`] over the full sequence. Every
/// per-row quantity is preserved exactly: scores come one element at a
/// time from the micro-kernel (serial over `hd` regardless of tile
/// shape), k-tile boundaries are absolute multiples of [`TK`] in both
/// tilings, so each row sees the same score slices, the same running
/// max/sum chain, and the same `P·V` accumulation order. k-tiles lying
/// wholly beyond a row's causal limit (reachable only when `offset > 0`)
/// contribute a zeroed `P` column — nothing — to that row.
///
/// Work is scheduled as `(head, q-tile)` items, cost-weighted by how many
/// key positions each tile attends to (later q-tiles see more keys — the
/// causal triangle — so uniform chunking would serialize on the tail).
pub fn causal_attention_offset(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    q_rows: usize,
    kv_len: usize,
    hd: usize,
) -> Vec<f32> {
    causal_attention_offset_thresh(q, k, v, heads, q_rows, kv_len, hd, None)
}

/// [`causal_attention_offset`] with an optional BLASST skip threshold
/// (see [`AttnThreshold`]); `None` is the exact path.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_offset_thresh(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    q_rows: usize,
    kv_len: usize,
    hd: usize,
    th: Option<AttnThreshold<'_>>,
) -> Vec<f32> {
    assert!(q_rows <= kv_len, "more query rows than key positions");
    let mut out = vec![0.0f32; q_rows * heads * hd];
    if q_rows == 0 || heads == 0 || hd == 0 {
        return out;
    }
    let offset = kv_len - q_rows;
    let n_qt = q_rows.div_ceil(TQ);
    let out_base = out.as_mut_ptr() as usize;
    let d = simd::dispatch();
    threadpool::parallel_for_weighted(
        heads * n_qt,
        |t| offset + ((t % n_qt) + 1) * TQ,
        |t| {
            let (h, qt) = (t / n_qt, t % n_qt);
            let qh = &q[h * q_rows * hd..(h + 1) * q_rows * hd];
            let kh = &k[h * kv_len * hd..(h + 1) * kv_len * hd];
            let vh = &v[h * kv_len * hd..(h + 1) * kv_len * hd];
            causal_tile(d, qh, kh, vh, offset, q_rows, hd, heads, h, qt, out_base, th);
        },
    );
    out
}

/// One `(head, q-tile)` item of the tiled prefill: stream k-tiles with
/// online softmax, two packed micro-GEMMs per tile pair. Query row `i` of
/// this head attends global positions `0..=offset + i` (`offset = 0` is
/// full prefill; `offset > 0` is prefix-sharing resume). `out_base` is
/// the merged `(q_rows, heads*hd)` output buffer's base address; this
/// item writes only rows `qt*TQ..` of column stripe `h*hd..(h+1)*hd`. The
/// score scale+mask-max, shifted-exp+sum and streaming-rescale row passes
/// all run on the dispatched SIMD lanes (`d` resolved once per prefill).
///
/// With `th` armed, each causally-live row first takes the BLASST skip
/// test: one `tile_max` reduction over its unscaled scores (max commutes
/// with the positive scale, so `scale·max` *is* the scaled row max). A
/// row whose scaled max falls below `m[i] − τ` contributes post-softmax
/// mass < TK·e^(−τ) no matter what later tiles do (the running max only
/// grows), so its exp/`P`-build is skipped and its `P` column zeroed;
/// when every live row of the tile skips, the `P·V` micro-GEMM is
/// skipped whole. Surviving rows run the *identical* instruction
/// sequence as the exact path.
#[allow(clippy::too_many_arguments)]
fn causal_tile(
    d: &KernelDispatch,
    qh: &[f32],
    kh: &[f32],
    vh: &[f32],
    offset: usize,
    q_rows: usize,
    hd: usize,
    heads: usize,
    h: usize,
    qt: usize,
    out_base: usize,
    th: Option<AttnThreshold<'_>>,
) {
    let i0 = qt * TQ;
    let i1 = (i0 + TQ).min(q_rows);
    let tq = i1 - i0;
    let scale = 1.0 / (hd as f32).sqrt();
    // scratch-arena tile state — allocation-free after warmup
    let mut qp = scratch::take_uninit(tq * hd); // Q tile, k-major
    let mut kb = scratch::take_uninit(TK * hd); // K tile, k-major (= Kᵀ panel)
    let mut s = scratch::take_uninit(tq * TK); // scores tile, row-major
    let mut pp = scratch::take_uninit(tq * TK); // exp-scores, k-major
    let mut acc = scratch::take_zeroed(tq * hd); // streaming O accumulator
    let mut m = scratch::take_uninit(tq); // running row max
    let mut l = scratch::take_uninit(tq); // running row sum
    m.fill(f32::NEG_INFINITY);
    l.fill(0.0);
    pack_kt_panel(&qh[i0 * hd..i1 * hd], tq, hd, &mut qp);
    // per-item skip accounting (armed only): one atomic add at the end
    let (mut c_tiles, mut c_tiles_skipped, mut c_rows, mut c_rows_skipped) = (0u64, 0u64, 0u64, 0u64);
    // k-tiles stream over the full key range this tile's rows attend to;
    // tile boundaries are absolute multiples of TK, independent of offset
    let kend = offset + i1;
    let mut k0 = 0;
    while k0 < kend {
        let k1 = (k0 + TK).min(kend);
        let tk = k1 - k0;
        pack_kt_panel(&kh[k0 * hd..k1 * hd], tk, hd, &mut kb);
        // scores tile: S[tq × tk] = Qᵖ · (Kᵀ)ᵖ (microkernel accumulates,
        // so zero the region first). The score GEMM always runs — it
        // produces the very statistic the BLASST skip test thresholds.
        s[..tq * tk].fill(0.0);
        microkernel_d(d, &qp, tq, tq, &kb, tk, tk, hd, &mut s[..tq * tk], tk, Epilogue::None);
        // online softmax update per row: scale, causal mask, rescale the
        // running accumulator, and build the packed P tile — the three row
        // passes run on the dispatched lanes
        let mut live = 0usize; // rows that survived into the P tile
        let mut thresh_skips = 0usize; // rows the threshold (not causality) skipped
        for i in 0..tq {
            let gi = offset + i0 + i;
            // columns this row may attend to within the tile
            let valid = (gi + 1).saturating_sub(k0).min(tk);
            if valid == 0 {
                // the whole k-tile is beyond this row's causal limit
                // (only reachable when offset > 0: an aligned full
                // prefill never visits such a tile) — zero its P column
                // so the P·V micro-GEMM adds nothing, and leave the
                // running max/sum untouched
                for j in 0..tk {
                    pp[j * tq + i] = 0.0;
                }
                continue;
            }
            let srow = &mut s[i * tk..i * tk + tk];
            if let Some(t) = th {
                c_rows += 1;
                // the skip test: scale·tile_max is the scaled row max
                // (multiplication by a positive scale is monotone), and
                // `m[i]` starts at −inf so a row's first contributing
                // tile can never skip — `x < −inf − τ` is always false.
                // NaN scores also compare false, falling through to the
                // exact path.
                if (d.tile_max)(&srow[..valid]) * scale < m[i] - t.tau {
                    for j in 0..tk {
                        pp[j * tq + i] = 0.0;
                    }
                    c_rows_skipped += 1;
                    thresh_skips += 1;
                    continue;
                }
            }
            live += 1;
            let row_max = (d.scale_max_slice)(&mut srow[..valid], scale);
            let new_m = m[i].max(row_max);
            // exp(-inf - finite) = 0, so the first tile's rescale is a
            // no-op on the zeroed accumulator without a special case
            let alpha = (m[i] - new_m).exp();
            if alpha != 1.0 {
                (d.scale_slice)(&mut acc[i * hd..(i + 1) * hd], alpha);
            }
            let row_sum = (d.exp_shift_sum)(&mut srow[..valid], new_m);
            for (j, &p) in srow.iter().enumerate().take(valid) {
                pp[j * tq + i] = p;
            }
            for j in valid..tk {
                pp[j * tq + i] = 0.0;
            }
            l[i] = l[i] * alpha + row_sum;
            m[i] = new_m;
        }
        if th.is_some() {
            c_tiles += 1;
            if live == 0 && thresh_skips > 0 {
                // every causally-live row thresholded out: the P tile is
                // all zeros, so the P·V micro-GEMM is pure skipped work.
                // (A tile dead by causality alone still runs it, exactly
                // like the unarmed path.)
                c_tiles_skipped += 1;
                k0 = k1;
                continue;
            }
        }
        // O[tq × hd] += P · V_tile (V rows are already the row-major B
        // operand the micro-kernel wants)
        microkernel_d(
            d,
            &pp,
            tq,
            tq,
            &vh[k0 * hd..k1 * hd],
            hd,
            hd,
            tk,
            &mut acc,
            hd,
            Epilogue::None,
        );
        k0 = k1;
    }
    if let Some(t) = th {
        t.counters.add_prefill(c_tiles, c_tiles_skipped, c_rows, c_rows_skipped);
    }
    // normalize and scatter into the merged (q_rows, heads*hd) output
    for i in 0..tq {
        let inv = 1.0 / l[i];
        // SAFETY: each (head, q-tile) item owns the disjoint output span
        // row (i0+i) × column stripe h*hd..(h+1)*hd, and the caller's
        // parallel_for_weighted blocks until every item finishes.
        let orow = unsafe {
            std::slice::from_raw_parts_mut(
                (out_base as *mut f32).add((i0 + i) * heads * hd + h * hd),
                hd,
            )
        };
        for (o, &a) in orow.iter_mut().zip(&acc[i * hd..(i + 1) * hd]) {
            *o = a * inv;
        }
    }
}

/// Seed causal attention (scalar per-row dots, full softmax per row) —
/// retained as the tiled kernel's oracle and the `blast exp attention`
/// A/B baseline. Same signature and semantics as [`causal_attention`].
pub fn causal_attention_ref(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    seq: usize,
    hd: usize,
) -> Vec<f32> {
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; seq * heads * hd];
    let out_base = out.as_mut_ptr() as usize;
    threadpool::parallel_for(heads, |h| {
        let qh = &q[h * seq * hd..(h + 1) * seq * hd];
        let kh = &k[h * seq * hd..(h + 1) * seq * hd];
        let vh = &v[h * seq * hd..(h + 1) * seq * hd];
        // scratch-arena scores (was a per-head `vec![0.0; seq]` on every
        // closure invocation): every element of row `0..=i` is written
        // before softmax reads it
        let mut scores = scratch::take_uninit(seq);
        for i in 0..seq {
            let qi = &qh[i * hd..(i + 1) * hd];
            for (j, s) in scores.iter_mut().enumerate().take(i + 1) {
                let kj = &kh[j * hd..(j + 1) * hd];
                *s = dot(qi, kj) * scale;
            }
            // the ref kernels stay on the scalar softmax so the A/B
            // baseline keeps measuring the true seed
            softmax_row_scalar(&mut scores[..i + 1]);
            // out[i, h*hd..] = sum_j scores[j] * v[j]
            // SAFETY: each head writes a disjoint column stripe.
            let orow = unsafe {
                std::slice::from_raw_parts_mut(
                    (out_base as *mut f32).add(i * heads * hd + h * hd),
                    hd,
                )
            };
            orow.fill(0.0);
            for (j, &w) in scores.iter().enumerate().take(i + 1) {
                let vj = &vh[j * hd..(j + 1) * hd];
                for d in 0..hd {
                    orow[d] += w * vj[d];
                }
            }
        }
    });
    out
}

/// Seed decode attention over a **flat** KV cache — retained as the paged
/// kernel's oracle and A/B baseline.
///
/// `q`: `(heads, hd)` for the new token. `kcache`/`vcache`:
/// `(heads, max_seq, hd)`; positions `0..=pos` are valid. Returns
/// `(heads*hd,)` merged.
pub fn decode_attention_ref(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    heads: usize,
    max_seq: usize,
    hd: usize,
    pos: usize,
) -> Vec<f32> {
    assert!(pos < max_seq);
    let mut out = vec![0.0f32; heads * hd];
    let out_base = out.as_mut_ptr() as usize;
    threadpool::parallel_for(heads, |h| {
        // SAFETY: each head writes a disjoint `hd`-wide stripe of `out`,
        // and parallel_for blocks until every head is done.
        let orow = unsafe {
            std::slice::from_raw_parts_mut((out_base as *mut f32).add(h * hd), hd)
        };
        decode_head_into(
            &q[h * hd..(h + 1) * hd],
            &kcache[h * max_seq * hd..],
            &vcache[h * max_seq * hd..],
            hd,
            pos,
            orow,
        );
    });
    out
}

/// One head of seed decode attention, single-threaded: softmax(q·Kᵀ)·V
/// over positions `0..=pos` of a flat per-head stripe, written into `out`
/// (length `hd`, overwritten). Oracle for [`decode_head_paged_into`].
pub fn decode_head_into(q: &[f32], kh: &[f32], vh: &[f32], hd: usize, pos: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), hd);
    debug_assert_eq!(out.len(), hd);
    let scale = 1.0 / (hd as f32).sqrt();
    // scratch-arena scores: every element is written below before softmax
    // reads it, and the buffer recycles per pool worker — the decode hot
    // path stays allocation-free after warmup
    let mut scores = scratch::take_uninit(pos + 1);
    for (j, s) in scores.iter_mut().enumerate() {
        *s = dot(q, &kh[j * hd..(j + 1) * hd]) * scale;
    }
    softmax_row_scalar(&mut scores);
    out.fill(0.0);
    for (j, &w) in scores.iter().enumerate() {
        let vj = &vh[j * hd..(j + 1) * hd];
        for d in 0..hd {
            out[d] += w * vj[d];
        }
    }
}

/// One head of decode attention over a **paged** KV cache:
/// softmax(q·Kᵀ)·V over positions `0..=pos`, written into `out` (length
/// `hd`, overwritten).
///
/// `kv_page(pi)` returns the `(K, V)` stripes of page `pi` for this
/// `(layer, head)` — each `page × hd` position-major floats (the layout
/// [`crate::model::kv::KvCache::k_head`] serves; a flat buffer works too,
/// sliced at `pi*page*hd`). Score dots and the weighted-V accumulation run
/// the dispatched `dot`/`axpy` lanes (AVX2/NEON FMA; the scalar arm is the
/// unrolled multi-accumulator [`dot_lanes`]); each lane's summation order
/// depends only on `hd`, never on the page geometry, so **page size never
/// changes the result bits** — only where positions live.
///
/// This is the shared inner body of the engine's sequential *and* batched
/// decode, which schedule `(session, head)` items on the thread pool
/// cost-aware by `pos` — same arithmetic, same summation order, so the
/// two paths stay bit-identical.
pub fn decode_head_paged_into<'a>(
    q: &[f32],
    hd: usize,
    page: usize,
    pos: usize,
    kv_page: impl Fn(usize) -> (&'a [f32], &'a [f32]),
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), hd);
    debug_assert_eq!(out.len(), hd);
    debug_assert!(page > 0);
    let d = simd::dispatch();
    let scale = 1.0 / (hd as f32).sqrt();
    let n = pos + 1;
    let n_pages = n.div_ceil(page);
    let mut scores = scratch::take_uninit(n);
    for pi in 0..n_pages {
        let (kp, _) = kv_page(pi);
        let base = pi * page;
        let cnt = (n - base).min(page);
        for j in 0..cnt {
            scores[base + j] = (d.dot)(q, &kp[j * hd..(j + 1) * hd]) * scale;
        }
    }
    softmax_row(&mut scores);
    out.fill(0.0);
    for pi in 0..n_pages {
        let (_, vp) = kv_page(pi);
        let base = pi * page;
        let cnt = (n - base).min(page);
        for j in 0..cnt {
            let w = scores[base + j];
            (d.axpy)(w, &vp[j * hd..(j + 1) * hd], out);
        }
    }
}

/// [`decode_head_paged_into`] with the BLASST page-skip rule: before
/// touching a page's K stripe, bound its best possible score by
/// Cauchy–Schwarz — `q·kⱼ ≤ ‖q‖·max_j‖kⱼ‖` — using the per-page K
/// norm stamp the KV pool maintains (`k_stamp(pi)`, see
/// [`crate::model::kv::KvCache::k_stamp`]). When even that bound falls
/// more than τ below the running score max `m`, every weight the page
/// could contribute is < e^(−τ) of the final max (`m` only grows while
/// pages stream in order), so the page's score dots, shifted-exps and
/// `w·V` accumulation are skipped whole — the page's KV stripes are
/// never even read.
///
/// Structure deliberately mirrors the exact kernel: surviving pages
/// fill the same score slots with the same dots, the softmax runs once
/// over the whole buffer (skipped slots carry `−inf`, whose shifted exp
/// is exactly `0.0`), and the weighted-V walk visits surviving pages in
/// the same order. **When no page skips, the output is bit-identical to
/// [`decode_head_paged_into`]** — asserted by tests with a huge τ.
///
/// RoPE-rotated keys keep their norms (rotations are isometries), so
/// the stamp taken at write time stays valid for scoring.
#[allow(clippy::too_many_arguments)]
pub fn decode_head_paged_thresh_into<'a>(
    q: &[f32],
    hd: usize,
    page: usize,
    pos: usize,
    kv_page: impl Fn(usize) -> (&'a [f32], &'a [f32]),
    k_stamp: impl Fn(usize) -> f32,
    th: AttnThreshold<'_>,
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), hd);
    debug_assert_eq!(out.len(), hd);
    debug_assert!(page > 0);
    let d = simd::dispatch();
    let scale = 1.0 / (hd as f32).sqrt();
    let n = pos + 1;
    let n_pages = n.div_ceil(page);
    let mut scores = scratch::take_uninit(n);
    // 0.0 = visited, 1.0 = skipped (f32 flags so the scratch arena serves
    // them like every other decode buffer)
    let mut skipped = scratch::take_uninit(n_pages);
    let qnorm = (d.dot)(q, q).sqrt();
    let mut m = f32::NEG_INFINITY; // running max over computed scores
    let mut pages_skipped = 0u64;
    for pi in 0..n_pages {
        let base = pi * page;
        let cnt = (n - base).min(page);
        // the first page can never skip (anything < −inf − τ is false),
        // so `m` is finite from page 1 on and `l > 0` is guaranteed
        if qnorm * k_stamp(pi) * scale < m - th.tau {
            scores[base..base + cnt].fill(f32::NEG_INFINITY);
            skipped[pi] = 1.0;
            pages_skipped += 1;
            continue;
        }
        skipped[pi] = 0.0;
        let (kp, _) = kv_page(pi);
        for j in 0..cnt {
            scores[base + j] = (d.dot)(q, &kp[j * hd..(j + 1) * hd]) * scale;
        }
        m = m.max((d.tile_max)(&scores[base..base + cnt]));
    }
    // one softmax over the whole buffer, exactly like the exact kernel
    // (max/exp ignore the −inf slots: exp(−inf − max) = 0 contributes
    // nothing to the sum)
    softmax_row(&mut scores);
    out.fill(0.0);
    for pi in 0..n_pages {
        if skipped[pi] != 0.0 {
            continue;
        }
        let (_, vp) = kv_page(pi);
        let base = pi * page;
        let cnt = (n - base).min(page);
        for j in 0..cnt {
            let w = scores[base + j];
            (d.axpy)(w, &vp[j * hd..(j + 1) * hd], out);
        }
    }
    th.counters.add_decode(n_pages as u64, pages_skipped);
}

/// Unrolled 8-lane dot product: eight independent accumulators FMA'd over
/// 8-wide chunks (vectorizer-friendly without arch intrinsics), combined
/// with a fixed reduction tree, scalar tail last. The lane split depends
/// only on the vector length (`hd`), never on KV page size.
#[inline(always)]
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        // fixed-size reborrows drop interior bounds checks
        let aa: &[f32; 8] = a[c * 8..c * 8 + 8].try_into().unwrap();
        let bb: &[f32; 8] = b[c * 8..c * 8 + 8].try_into().unwrap();
        for lane in 0..8 {
            acc[lane] += aa[lane] * bb[lane];
        }
    }
    let tree = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    tree + tail
}

#[inline(always)]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive single-threaded oracle (independent of both shipped kernels).
    fn causal_naive(q: &[f32], k: &[f32], v: &[f32], h: usize, s: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; s * h * d];
        for hh in 0..h {
            for i in 0..s {
                let qi = &q[hh * s * d + i * d..hh * s * d + (i + 1) * d];
                let mut sc: Vec<f32> = (0..=i)
                    .map(|j| {
                        dot(qi, &k[hh * s * d + j * d..hh * s * d + (j + 1) * d])
                            / (d as f32).sqrt()
                    })
                    .collect();
                softmax_row(&mut sc);
                for (j, &w) in sc.iter().enumerate() {
                    for dd in 0..d {
                        out[i * h * d + hh * d + dd] += w * v[hh * s * d + j * d + dd];
                    }
                }
            }
        }
        out
    }

    #[test]
    fn seed_ref_matches_naive() {
        let (h, s, d) = (3, 7, 4);
        let mut rng = Rng::new(1);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let got = causal_attention_ref(&q, &k, &v, h, s, d);
        let want = causal_naive(&q, &k, &v, h, s, d);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    /// The tentpole tolerance gate: the tiled streaming-softmax kernel
    /// matches the retained seed oracle within 1e-5 abs, across shapes
    /// that straddle every tile boundary (TQ±1, TK±1, multi-tile, ragged
    /// head dims that exercise the micro-kernel remainder paths).
    #[test]
    fn tiled_matches_seed_oracle_across_tile_boundaries() {
        let shapes: &[(usize, usize, usize)] = &[
            (1, 1, 4),
            (2, 2, 8),
            (2, TQ - 1, 16),
            (2, TQ, 16),
            (2, TQ + 1, 16),
            (1, TK - 1, 12),
            (1, TK, 12),
            (1, TK + 1, 12),
            (2, 100, 20),
            (3, 2 * TK + 5, 8),
        ];
        for &(h, s, d) in shapes {
            let mut rng = Rng::new(0x7157 + (h * 1000 + s * 10 + d) as u64);
            let q = rng.normal_vec(h * s * d, 1.0);
            let k = rng.normal_vec(h * s * d, 1.0);
            let v = rng.normal_vec(h * s * d, 1.0);
            let got = causal_attention(&q, &k, &v, h, s, d);
            let want = causal_attention_ref(&q, &k, &v, h, s, d);
            let mut max_diff = 0.0f32;
            for (a, b) in got.iter().zip(&want) {
                max_diff = max_diff.max((a - b).abs());
            }
            assert!(
                max_diff < 1e-5,
                "tiled vs seed diff {max_diff} at h={h} s={s} d={d}"
            );
        }
    }

    #[test]
    fn decode_ref_matches_last_row_of_causal() {
        let (h, s, d) = (2, 6, 4);
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let full = causal_attention_ref(&q, &k, &v, h, s, d);
        // decode for position s-1 using q's last row per head
        let mut qlast = vec![0.0f32; h * d];
        for hh in 0..h {
            qlast[hh * d..(hh + 1) * d]
                .copy_from_slice(&q[hh * s * d + (s - 1) * d..hh * s * d + s * d]);
        }
        let got = decode_attention_ref(&qlast, &k, &v, h, s, d, s - 1);
        for hh in 0..h {
            for dd in 0..d {
                let want = full[(s - 1) * h * d + hh * d + dd];
                assert!((got[hh * d + dd] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn decode_head_matches_full_decode_bitwise() {
        let (h, s, d) = (3, 5, 4);
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(h * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let full = decode_attention_ref(&q, &k, &v, h, s, d, s - 1);
        for hh in 0..h {
            let mut out = vec![7.0f32; d]; // dirty buffer: must be overwritten
            decode_head_into(
                &q[hh * d..(hh + 1) * d],
                &k[hh * s * d..],
                &v[hh * s * d..],
                d,
                s - 1,
                &mut out,
            );
            assert_eq!(out, full[hh * d..(hh + 1) * d].to_vec(), "head {hh}");
        }
    }

    /// Paged decode vs the seed oracle: within 1e-5 (the lane-split dot
    /// reorders the reduction), at page sizes and positions straddling
    /// every page boundary.
    #[test]
    fn paged_decode_matches_seed_oracle() {
        let (s, d) = (11, 20); // d exercises the 8-lane tail
        let mut rng = Rng::new(5);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(s * d, 1.0);
        let v = rng.normal_vec(s * d, 1.0);
        for page in [1usize, 3, 4, 16] {
            for pos in [0usize, 2, 3, 4, 10] {
                let mut want = vec![0.0f32; d];
                decode_head_into(&q, &k, &v, d, pos, &mut want);
                let mut got = vec![9.0f32; d]; // dirty: must be overwritten
                decode_head_paged_into(
                    &q,
                    d,
                    page,
                    pos,
                    |pi| (&k[pi * page * d..], &v[pi * page * d..]),
                    &mut got,
                );
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "page={page} pos={pos}: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// The tentpole layout guarantee at the kernel level: changing the
    /// page size changes *where* positions live, never the result bits.
    #[test]
    fn paged_decode_bitwise_invariant_across_page_sizes() {
        let (s, d) = (13, 12);
        let mut rng = Rng::new(6);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(s * d, 1.0);
        let v = rng.normal_vec(s * d, 1.0);
        for pos in 0..s {
            // page = s is the "flat" special case
            let mut flat = vec![0.0f32; d];
            decode_head_paged_into(&q, d, s, pos, |pi| (&k[pi * s * d..], &v[pi * s * d..]), &mut flat);
            for page in [1usize, 2, 3, 5, 8] {
                let mut paged = vec![0.0f32; d];
                decode_head_paged_into(
                    &q,
                    d,
                    page,
                    pos,
                    |pi| (&k[pi * page * d..], &v[pi * page * d..]),
                    &mut paged,
                );
                let same = flat.iter().zip(&paged).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "page={page} pos={pos}: bits differ from flat layout");
            }
        }
    }

    #[test]
    fn dot_lanes_matches_scalar_dot() {
        let mut rng = Rng::new(7);
        for n in [0usize, 1, 7, 8, 9, 16, 20, 64, 65] {
            let a = rng.normal_vec(n, 1.0);
            let b = rng.normal_vec(n, 1.0);
            let want = dot(&a, &b);
            let got = dot_lanes(&a, &b);
            assert!((got - want).abs() < 1e-4 * (n as f32).max(1.0), "n={n}");
        }
    }

    /// The prefix-sharing resume guarantee at the kernel level: computing
    /// only the tail rows against the full K/V reproduces the full
    /// prefill's rows **bit for bit**, at offsets straddling every TQ/TK
    /// tile boundary (including offsets that make whole k-tiles fall
    /// beyond a row's causal limit — the masking branch dead at offset 0).
    #[test]
    fn offset_rows_bitwise_match_full_prefill() {
        for &(h, s, d) in &[(2usize, 7usize, 4usize), (2, TQ + 3, 8), (1, TK + 9, 12), (2, 2 * TK + 5, 8)] {
            let mut rng = Rng::new(0x0FF5E7 + (h * 1000 + s * 10 + d) as u64);
            let q = rng.normal_vec(h * s * d, 1.0);
            let k = rng.normal_vec(h * s * d, 1.0);
            let v = rng.normal_vec(h * s * d, 1.0);
            let full = causal_attention(&q, &k, &v, h, s, d);
            for off in [1usize, 2, TQ - 1, TQ, TQ + 1, TK - 1, TK, TK + 1, s - 1] {
                if off >= s {
                    continue;
                }
                let rows = s - off;
                // gather the tail query rows per head: (h, rows, d)
                let mut qt = vec![0.0f32; h * rows * d];
                for hh in 0..h {
                    qt[hh * rows * d..(hh + 1) * rows * d]
                        .copy_from_slice(&q[hh * s * d + off * d..(hh + 1) * s * d]);
                }
                let got = causal_attention_offset(&qt, &k, &v, h, rows, s, d);
                for i in 0..rows {
                    let a = &got[i * h * d..(i + 1) * h * d];
                    let b = &full[(off + i) * h * d..(off + i + 1) * h * d];
                    let same = a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "h={h} s={s} d={d} off={off}: row {i} bits differ");
                }
            }
        }
    }

    #[test]
    fn offset_zero_is_full_prefill() {
        let (h, s, d) = (2, 41, 8);
        let mut rng = Rng::new(0x0FF0);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        let a = causal_attention(&q, &k, &v, h, s, d);
        let b = causal_attention_offset(&q, &k, &v, h, s, s, d);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    /// Inputs engineered so the BLASST skip rule actually fires: a huge
    /// key spike early in the sequence drives the running row max far
    /// above everything later, so low-τ runs skip the later k-tiles.
    /// Returns `(q, k, v)` shaped `(h, s, d)`.
    fn spiky_qkv(h: usize, s: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let q = rng.normal_vec(h * s * d, 1.0);
        let mut k: Vec<f32> = rng.normal_vec(h * s * d, 0.05);
        let v = rng.normal_vec(h * s * d, 1.0);
        // make position 0's key big and query-aligned in every head
        for hh in 0..h {
            for dd in 0..d {
                k[hh * s * d + dd] = 40.0 * q[hh * s * d + (s - 1) * d + dd].signum();
            }
        }
        (q, k, v)
    }

    /// An armed threshold so large the skip condition can never fire
    /// must leave the prefill output **bit-identical** to the exact
    /// kernel — the armed live path runs the same instructions.
    #[test]
    fn huge_tau_prefill_is_bitwise_exact_and_skips_nothing() {
        for &(h, s, d) in &[(2usize, 2 * TK + 5, 8), (1, TK + 9, 12)] {
            let (q, k, v) = spiky_qkv(h, s, d, 0xB1A5);
            let exact = causal_attention(&q, &k, &v, h, s, d);
            let c = AttnCounters::new();
            let th = AttnThreshold { tau: 1e30, counters: &c };
            let got = causal_attention_thresh(&q, &k, &v, h, s, d, Some(th));
            assert!(got.iter().zip(&exact).all(|(a, b)| a.to_bits() == b.to_bits()));
            let st = c.snapshot();
            assert!(st.tiles > 0 && st.rows > 0, "armed path must count visits");
            assert_eq!(st.rows_skipped, 0);
            assert_eq!(st.tiles_skipped, 0);
            // offset resume with huge τ: also bitwise vs the exact rows
            let off = TQ + 1;
            let rows = s - off;
            let mut qt = vec![0.0f32; h * rows * d];
            for hh in 0..h {
                qt[hh * rows * d..(hh + 1) * rows * d]
                    .copy_from_slice(&q[hh * s * d + off * d..(hh + 1) * s * d]);
            }
            let c2 = AttnCounters::new();
            let th2 = AttnThreshold { tau: 1e30, counters: &c2 };
            let got = causal_attention_offset_thresh(&qt, &k, &v, h, rows, s, d, Some(th2));
            for i in 0..rows {
                let a = &got[i * h * d..(i + 1) * h * d];
                let b = &exact[(off + i) * h * d..(off + i + 1) * h * d];
                assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        }
    }

    /// The monotone halves of the knob on spike-engineered inputs:
    /// growing τ never skips *more* rows (the condition only gets
    /// stricter) and the output drift vs exact never grows.
    #[test]
    fn skips_and_drift_are_monotone_in_tau() {
        let (h, s, d) = (2usize, 3 * TK + 7, 8);
        let (q, k, v) = spiky_qkv(h, s, d, 0x7A05);
        let exact = causal_attention(&q, &k, &v, h, s, d);
        let mut last_skips = u64::MAX;
        let mut last_drift = f32::INFINITY;
        let mut fired = false;
        for tau in [0.0f32, 1.0, 3.0, 6.0, 12.0, 1e30] {
            let c = AttnCounters::new();
            let th = AttnThreshold { tau, counters: &c };
            let got = causal_attention_thresh(&q, &k, &v, h, s, d, Some(th));
            let st = c.snapshot();
            let drift = got
                .iter()
                .zip(&exact)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                st.rows_skipped <= last_skips,
                "τ={tau}: skips grew ({} > {last_skips})",
                st.rows_skipped
            );
            // drift may only shrink as τ grows (1e-6 float slack)
            assert!(
                drift <= last_drift + 1e-6,
                "τ={tau}: drift grew ({drift} > {last_drift})"
            );
            assert!(st.rows_skipped <= st.rows && st.tiles_skipped <= st.tiles);
            fired |= st.rows_skipped > 0;
            last_skips = st.rows_skipped;
            last_drift = drift;
        }
        assert!(fired, "the spike inputs must actually trigger skips");
    }

    /// Tight-τ runs on spiky inputs stay close to exact: everything
    /// skipped carries post-softmax mass ≤ count·e^(−τ), so with τ = 12
    /// the output drift is bounded far below the signal scale.
    #[test]
    fn moderate_tau_drift_is_small() {
        let (h, s, d) = (2usize, 2 * TK + 3, 8);
        let (q, k, v) = spiky_qkv(h, s, d, 0xD81F);
        let exact = causal_attention(&q, &k, &v, h, s, d);
        let c = AttnCounters::new();
        let th = AttnThreshold { tau: 12.0, counters: &c };
        let got = causal_attention_thresh(&q, &k, &v, h, s, d, Some(th));
        let drift = got
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(drift < 1e-2, "τ=12 drift {drift} too large");
    }

    /// Paged decode with the norm-stamp bound: huge τ is bitwise the
    /// exact paged kernel; small τ on spiky data skips pages whole and
    /// stays within the mass bound; skip counts are monotone in τ.
    #[test]
    fn thresh_paged_decode_bitwise_at_huge_tau_and_monotone() {
        let (s, d, page) = (24usize, 12usize, 4usize);
        let mut rng = Rng::new(0xDECD);
        let q = rng.normal_vec(d, 1.0);
        let mut k = rng.normal_vec(s * d, 0.05);
        let v = rng.normal_vec(s * d, 1.0);
        for dd in 0..d {
            k[dd] = 30.0 * q[dd].signum(); // page-0 spike
        }
        // true per-page max K norms — what the pool's stamps hold
        let n_pages = s.div_ceil(page);
        let stamps: Vec<f32> = (0..n_pages)
            .map(|pi| {
                (pi * page..((pi + 1) * page).min(s))
                    .map(|j| {
                        k[j * d..(j + 1) * d].iter().map(|x| x * x).sum::<f32>().sqrt()
                    })
                    .fold(0.0f32, f32::max)
            })
            .collect();
        let pos = s - 1;
        let mut exact = vec![0.0f32; d];
        decode_head_paged_into(&q, d, page, pos, |pi| (&k[pi * page * d..], &v[pi * page * d..]), &mut exact);
        let mut last_skips = u64::MAX;
        let mut fired = false;
        for tau in [0.0f32, 2.0, 6.0, 1e30] {
            let c = AttnCounters::new();
            let th = AttnThreshold { tau, counters: &c };
            let mut got = vec![0.0f32; d];
            decode_head_paged_thresh_into(
                &q,
                d,
                page,
                pos,
                |pi| (&k[pi * page * d..], &v[pi * page * d..]),
                |pi| stamps[pi],
                th,
                &mut got,
            );
            let st = c.snapshot();
            assert_eq!(st.pages, n_pages as u64);
            assert!(st.pages_skipped <= last_skips, "τ={tau}: page skips grew");
            if tau == 1e30 {
                assert_eq!(st.pages_skipped, 0);
                assert!(
                    got.iter().zip(&exact).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "huge-τ paged decode must be bit-identical"
                );
            } else {
                let drift = got
                    .iter()
                    .zip(&exact)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(drift < 0.2, "τ={tau} decode drift {drift}");
            }
            fired |= st.pages_skipped > 0;
            last_skips = st.pages_skipped;
        }
        assert!(fired, "spiky page-0 data must skip at least one page at low τ");
    }

    #[test]
    fn first_position_attends_only_to_itself() {
        let (h, s, d) = (1, 3, 2);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(h * s * d, 1.0);
        let k = rng.normal_vec(h * s * d, 1.0);
        let v = rng.normal_vec(h * s * d, 1.0);
        for out in [
            causal_attention(&q, &k, &v, h, s, d),
            causal_attention_ref(&q, &k, &v, h, s, d),
        ] {
            assert!((out[0] - v[0]).abs() < 1e-5);
            assert!((out[1] - v[1]).abs() < 1e-5);
        }
    }
}
