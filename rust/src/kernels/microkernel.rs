//! Register-blocked f32 micro-GEMM — the one inner kernel shared by the
//! dense GEMM, the BSpMM and the fused sparse MLPs.
//!
//! The BLIS/COSMA decomposition: outer code packs operands into panels
//! ([`crate::kernels::pack`]) and tiles the output; this module computes
//!
//! ```text
//! C[rows×cols] += Aᵖ · Bᵖ
//! ```
//!
//! where `Aᵖ` is a *k-major* packed panel (`ap[kk*lda + i]`, so the `rows`
//! values of one depth step are contiguous) and `Bᵖ` is row-major with
//! leading dimension `ldb` (`bp[kk*ldb + j]` — either a packed NR-wide
//! B panel or a raw BCSC block, which is already the right layout).
//!
//! The inner loop keeps a small accumulator array in registers, broadcasts
//! one packed A value per row and FMAs an NR-wide B row chunk — no
//! per-element branches, no strided gathers, C touched exactly once at the
//! end. Unrolled specializations exist for the BCSC block widths 8/16/32
//! (`NR` fixed at compile time so LLVM keeps the accumulators in vector
//! registers); odd shapes fall back to a generic remainder kernel. The
//! register tile is 4×8 / 4×16 (≤ 8 YMM of accumulators) but drops to
//! 2×32 for the widest chunk: 4×32 f32 would consume all 16 YMM registers
//! of an AVX2 file by itself and force per-iteration spills.

/// Rows per register sub-tile for NR ≤ 16 (4×16 f32 = 8 YMM accumulators,
/// leaving room for the A broadcast and B loads).
const RB: usize = 4;

/// Rows per register sub-tile for the 32-wide chunk (2×32 f32 = 8 YMM).
const RB32: usize = 2;

/// Max columns a remainder micro-tile handles at once (matches the widest
/// specialization).
const MAX_NR: usize = 32;

/// `C[rows×cols] += Aᵖ · Bᵖ`.
///
/// * `ap` — k-major packed A panel: element `(kk, i)` at `ap[kk*lda + i]`,
///   `i < rows ≤ lda`, `kk < k`.
/// * `bp` — row-major B: element `(kk, j)` at `bp[kk*ldb + j]`, `j < cols ≤ ldb`.
/// * `c` — row-major output region: element `(i, j)` at `c[i*ldc + j]`;
///   `c.len()` must cover `(rows-1)*ldc + cols`.
#[allow(clippy::too_many_arguments)] // a GEMM kernel ABI is what it is
pub fn microkernel(
    ap: &[f32],
    lda: usize,
    rows: usize,
    bp: &[f32],
    ldb: usize,
    cols: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
) {
    debug_assert!(rows <= lda || k == 0);
    debug_assert!(cols <= ldb || k == 0);
    debug_assert!(k == 0 || ap.len() >= (k - 1) * lda + rows);
    debug_assert!(k == 0 || bp.len() >= (k - 1) * ldb + cols);
    debug_assert!(rows == 0 || c.len() >= (rows - 1) * ldc + cols);
    if rows == 0 || cols == 0 || k == 0 {
        return;
    }
    let mut j0 = 0;
    while j0 < cols {
        let rem = cols - j0;
        let take = if rem >= 32 {
            32
        } else if rem >= 16 {
            16
        } else if rem >= 8 {
            8
        } else {
            rem
        };
        let bp_sub = &bp[j0..];
        let rstep = if take == 32 { RB32 } else { RB };
        let mut i0 = 0;
        while i0 < rows {
            let r = (rows - i0).min(rstep);
            let ap_sub = &ap[i0..];
            let c_sub = &mut c[i0 * ldc + j0..];
            if r == RB32 && take == 32 {
                mk2::<32>(ap_sub, lda, bp_sub, ldb, k, c_sub, ldc);
            } else if r == RB && take == 16 {
                mk4::<16>(ap_sub, lda, bp_sub, ldb, k, c_sub, ldc);
            } else if r == RB && take == 8 {
                mk4::<8>(ap_sub, lda, bp_sub, ldb, k, c_sub, ldc);
            } else {
                mk_small(ap_sub, lda, r, bp_sub, ldb, take, k, c_sub, ldc);
            }
            i0 += r;
        }
        j0 += take;
    }
}

/// 4×NR register tile, NR known at compile time. The `&[f32; NR]` reborrows
/// let LLVM drop all interior bounds checks and vectorize the j-loop.
#[inline(always)]
fn mk4<const NR: usize>(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let mut acc = [[0.0f32; NR]; RB];
    for kk in 0..k {
        let a: &[f32; RB] = ap[kk * lda..kk * lda + RB].try_into().unwrap();
        let b: &[f32; NR] = bp[kk * ldb..kk * ldb + NR].try_into().unwrap();
        for i in 0..RB {
            let ai = a[i];
            for j in 0..NR {
                acc[i][j] += ai * b[j];
            }
        }
    }
    for i in 0..RB {
        let crow: &mut [f32] = &mut c[i * ldc..i * ldc + NR];
        for j in 0..NR {
            crow[j] += acc[i][j];
        }
    }
}

/// 2×NR register tile for the widest chunk (see the module doc on
/// register budgets).
#[inline(always)]
fn mk2<const NR: usize>(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
) {
    let mut acc = [[0.0f32; NR]; RB32];
    for kk in 0..k {
        let a: &[f32; RB32] = ap[kk * lda..kk * lda + RB32].try_into().unwrap();
        let b: &[f32; NR] = bp[kk * ldb..kk * ldb + NR].try_into().unwrap();
        for i in 0..RB32 {
            let ai = a[i];
            for j in 0..NR {
                acc[i][j] += ai * b[j];
            }
        }
    }
    for i in 0..RB32 {
        let crow: &mut [f32] = &mut c[i * ldc..i * ldc + NR];
        for j in 0..NR {
            crow[j] += acc[i][j];
        }
    }
}

/// Remainder tile: `rows ≤ 4`, `cols ≤ 32`, any combination.
#[allow(clippy::too_many_arguments)]
fn mk_small(
    ap: &[f32],
    lda: usize,
    rows: usize,
    bp: &[f32],
    ldb: usize,
    cols: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
) {
    debug_assert!(rows <= RB && cols <= MAX_NR);
    let mut acc = [[0.0f32; MAX_NR]; RB];
    for kk in 0..k {
        let b = &bp[kk * ldb..kk * ldb + cols];
        for (i, accrow) in acc.iter_mut().enumerate().take(rows) {
            let ai = ap[kk * lda + i];
            for (j, &bv) in b.iter().enumerate() {
                accrow[j] += ai * bv;
            }
        }
    }
    for (i, accrow) in acc.iter().enumerate().take(rows) {
        let crow = &mut c[i * ldc..i * ldc + cols];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv += accrow[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;

    /// Oracle: straightforward triple loop over the same packed layouts.
    fn naive(
        ap: &[f32],
        lda: usize,
        rows: usize,
        bp: &[f32],
        ldb: usize,
        cols: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
    ) {
        for i in 0..rows {
            for j in 0..cols {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += ap[kk * lda + i] * bp[kk * ldb + j];
                }
                c[i * ldc + j] += s;
            }
        }
    }

    #[test]
    fn matches_naive_property() {
        prop::check_default("microkernel-vs-naive", |rng| {
            let rows = prop::usize_in(rng, 1, 13);
            let lda = rows + prop::usize_in(rng, 0, 3);
            let cols = prop::usize_in(rng, 1, 70);
            let ldb = cols + prop::usize_in(rng, 0, 5);
            let ldc = cols + prop::usize_in(rng, 0, 5);
            let k = prop::usize_in(rng, 1, 24);
            let ap = prop::normal_vec(rng, k * lda);
            let bp = prop::normal_vec(rng, k * ldb);
            let mut c_fast = prop::normal_vec(rng, (rows - 1) * ldc + cols);
            let mut c_slow = c_fast.clone();
            microkernel(&ap, lda, rows, &bp, ldb, cols, k, &mut c_fast, ldc);
            naive(&ap, lda, rows, &bp, ldb, cols, k, &mut c_slow, ldc);
            for (idx, (a, b)) in c_fast.iter().zip(&c_slow).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-3,
                    "idx {idx}: {a} vs {b} (rows={rows} cols={cols} k={k})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn specialized_widths_exact_tiles() {
        // hit mk4::<8|16|32> head-on: rows multiple of 4, cols = NR
        for &nr in &[8usize, 16, 32] {
            let (rows, k) = (8usize, 16usize);
            let ap: Vec<f32> = (0..k * rows).map(|i| (i % 11) as f32 * 0.25).collect();
            let bp: Vec<f32> = (0..k * nr).map(|i| (i % 7) as f32 * 0.5 - 1.0).collect();
            let mut c_fast = vec![0.0f32; rows * nr];
            let mut c_slow = vec![0.0f32; rows * nr];
            microkernel(&ap, rows, rows, &bp, nr, nr, k, &mut c_fast, nr);
            naive(&ap, rows, rows, &bp, nr, nr, k, &mut c_slow, nr);
            assert_eq!(c_fast, c_slow, "nr={nr}");
        }
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut c = vec![1.0f32; 8];
        microkernel(&[], 4, 0, &[], 8, 8, 0, &mut c, 8);
        microkernel(&[1.0; 4], 4, 1, &[1.0; 8], 8, 0, 1, &mut c, 8);
        microkernel(&[], 4, 1, &[1.0; 8], 8, 8, 0, &mut c, 8);
        assert!(c.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn accumulates_into_existing_c() {
        let (rows, cols, k) = (5usize, 9usize, 3usize);
        let ap: Vec<f32> = (0..k * rows).map(|i| i as f32 * 0.1).collect();
        let bp: Vec<f32> = (0..k * cols).map(|i| 1.0 - i as f32 * 0.05).collect();
        let mut c = vec![2.0f32; rows * cols];
        let mut want = vec![2.0f32; rows * cols];
        microkernel(&ap, rows, rows, &bp, cols, cols, k, &mut c, cols);
        naive(&ap, rows, rows, &bp, cols, cols, k, &mut want, cols);
        assert_eq!(c, want);
    }
}
