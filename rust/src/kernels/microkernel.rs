//! Register-blocked f32 micro-GEMM — the one inner kernel shared by the
//! dense GEMM, the BSpMM and the fused sparse MLPs.
//!
//! The BLIS/COSMA decomposition: outer code packs operands into panels
//! ([`crate::kernels::pack`]) and tiles the output; this module computes
//!
//! ```text
//! C[rows×cols] (+)= Aᵖ · Bᵖ   then   C = epilogue(C)
//! ```
//!
//! where `Aᵖ` is a *k-major* packed panel (`ap[kk*lda + i]`, so the `rows`
//! values of one depth step are contiguous) and `Bᵖ` is row-major with
//! leading dimension `ldb` (`bp[kk*ldb + j]` — either a packed NR-wide
//! B panel or a raw BCSC block, which is already the right layout).
//!
//! Since PR 5 the register tiles are *dispatched*: this module owns the
//! tiling loop and the portable scalar tiles, while
//! [`crate::kernels::simd`] supplies hand-written AVX2+FMA / NEON
//! implementations of the same four slots (`mk4x16`, `mk4x8`, `mk2x32`,
//! tail) behind a function-pointer table resolved once per process. Outer
//! kernels resolve the table once per call ([`microkernel_d`]) so the
//! per-tile dispatch cost is a pointer read.
//!
//! The second PR-5 addition is the fused **epilogue**
//! ([`crate::kernels::simd::Epilogue`]): bias/activation/SwiGLU-gate
//! transforms applied during the C write-back while the accumulator tile
//! is still in registers. A call may carry a non-`None` epilogue only when
//! it performs the *final* accumulation into its C region — see the
//! contract on [`Epilogue`].
//!
//! The scalar tiles keep the exact structure LLVM autovectorizes well
//! (`&[f32; NR]` reborrows, 4×8/4×16/2×32 accumulator arrays ≤ 8 YMM), so
//! the fallback arm costs nothing relative to PR 1–4, and every SIMD arm
//! is parity-tested against it.

use crate::kernels::simd::{self, Epilogue, KernelDispatch};

/// Rows per register sub-tile for NR ≤ 16 (4×16 f32 = 8 YMM accumulators,
/// leaving room for the A broadcast and B loads).
const RB: usize = 4;

/// Rows per register sub-tile for the 32-wide chunk (2×32 f32 = 8 YMM).
const RB32: usize = 2;

/// Max columns a remainder micro-tile handles at once (matches the widest
/// specialization).
const MAX_NR: usize = 32;

/// `C[rows×cols] += Aᵖ · Bᵖ` on the active dispatch table, no epilogue —
/// the drop-in PR 1 entry point.
///
/// * `ap` — k-major packed A panel: element `(kk, i)` at `ap[kk*lda + i]`,
///   `i < rows ≤ lda`, `kk < k`.
/// * `bp` — row-major B: element `(kk, j)` at `bp[kk*ldb + j]`, `j < cols ≤ ldb`.
/// * `c` — row-major output region: element `(i, j)` at `c[i*ldc + j]`;
///   `c.len()` must cover `(rows-1)*ldc + cols`.
#[allow(clippy::too_many_arguments)] // a GEMM kernel ABI is what it is
pub fn microkernel(
    ap: &[f32],
    lda: usize,
    rows: usize,
    bp: &[f32],
    ldb: usize,
    cols: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
) {
    microkernel_d(simd::dispatch(), ap, lda, rows, bp, ldb, cols, k, c, ldc, Epilogue::None);
}

/// [`microkernel`] with an explicit dispatch table and fused epilogue —
/// the entry the outer kernels use (table resolved once per GEMM/BSpMM
/// call, epilogue applied during the final C write-back).
#[allow(clippy::too_many_arguments)]
pub fn microkernel_d(
    d: &KernelDispatch,
    ap: &[f32],
    lda: usize,
    rows: usize,
    bp: &[f32],
    ldb: usize,
    cols: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    // Hard asserts, not debug: the SIMD arms read these operands through
    // raw vector loads, so a short slice must fail loudly in release too
    // (the pre-SIMD scalar code would have hit a bounds check instead).
    assert!(rows <= lda || k == 0);
    assert!(cols <= ldb || k == 0);
    assert!(k == 0 || ap.len() >= (k - 1) * lda + rows);
    assert!(k == 0 || bp.len() >= (k - 1) * ldb + cols);
    assert!(rows == 0 || c.len() >= (rows - 1) * ldc + cols);
    ep.check_operands(rows, cols);
    if rows == 0 || cols == 0 {
        return;
    }
    if k == 0 {
        // Nothing to accumulate and `ap`/`bp` may be empty, so skip the
        // tiling loop entirely (its operand sub-slicing would index past
        // empty slices) — but the epilogue must still reach every element
        // exactly once.
        d.apply_epilogue_region(c, ldc, rows, cols, ep);
        return;
    }
    let mut j0 = 0;
    while j0 < cols {
        let rem = cols - j0;
        let take = if rem >= 32 {
            32
        } else if rem >= 16 {
            16
        } else if rem >= 8 {
            8
        } else {
            rem
        };
        let bp_sub = &bp[j0..];
        let rstep = if take == 32 { RB32 } else { RB };
        let mut i0 = 0;
        while i0 < rows {
            let r = (rows - i0).min(rstep);
            let ap_sub = &ap[i0..];
            let c_sub = &mut c[i0 * ldc + j0..];
            let ep_sub = ep.shift(i0, j0);
            if r == RB32 && take == 32 {
                (d.mk2x32)(ap_sub, lda, bp_sub, ldb, k, c_sub, ldc, ep_sub);
            } else if r == RB && take == 16 {
                (d.mk4x16)(ap_sub, lda, bp_sub, ldb, k, c_sub, ldc, ep_sub);
            } else if r == RB && take == 8 {
                (d.mk4x8)(ap_sub, lda, bp_sub, ldb, k, c_sub, ldc, ep_sub);
            } else {
                (d.mk_tail)(ap_sub, lda, r, bp_sub, ldb, take, k, c_sub, ldc, ep_sub);
            }
            i0 += r;
        }
        j0 += take;
    }
}

// ---------------------------------------------------------------------
// scalar register tiles — the fallback arm of the dispatch table and the
// parity oracles for the SIMD arms
// ---------------------------------------------------------------------

/// Scalar 4×16 tile (dispatch-table slot `mk4x16`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mk4x16_scalar(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    mk4::<16>(ap, lda, bp, ldb, k, c, ldc, ep);
}

/// Scalar 4×8 tile (dispatch-table slot `mk4x8`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mk4x8_scalar(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    mk4::<8>(ap, lda, bp, ldb, k, c, ldc, ep);
}

/// Scalar 2×32 tile (dispatch-table slot `mk2x32`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mk2x32_scalar(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    mk2::<32>(ap, lda, bp, ldb, k, c, ldc, ep);
}

/// 4×NR register tile, NR known at compile time. The `&[f32; NR]` reborrows
/// let LLVM drop all interior bounds checks and vectorize the j-loop.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn mk4<const NR: usize>(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    let mut acc = [[0.0f32; NR]; RB];
    for kk in 0..k {
        let a: &[f32; RB] = ap[kk * lda..kk * lda + RB].try_into().unwrap();
        let b: &[f32; NR] = bp[kk * ldb..kk * ldb + NR].try_into().unwrap();
        for i in 0..RB {
            let ai = a[i];
            for j in 0..NR {
                acc[i][j] += ai * b[j];
            }
        }
    }
    for i in 0..RB {
        let crow: &mut [f32] = &mut c[i * ldc..i * ldc + NR];
        for j in 0..NR {
            crow[j] = ep.apply(crow[j] + acc[i][j], i, j);
        }
    }
}

/// 2×NR register tile for the widest chunk (see the module doc on
/// register budgets).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn mk2<const NR: usize>(
    ap: &[f32],
    lda: usize,
    bp: &[f32],
    ldb: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    let mut acc = [[0.0f32; NR]; RB32];
    for kk in 0..k {
        let a: &[f32; RB32] = ap[kk * lda..kk * lda + RB32].try_into().unwrap();
        let b: &[f32; NR] = bp[kk * ldb..kk * ldb + NR].try_into().unwrap();
        for i in 0..RB32 {
            let ai = a[i];
            for j in 0..NR {
                acc[i][j] += ai * b[j];
            }
        }
    }
    for i in 0..RB32 {
        let crow: &mut [f32] = &mut c[i * ldc..i * ldc + NR];
        for j in 0..NR {
            crow[j] = ep.apply(crow[j] + acc[i][j], i, j);
        }
    }
}

/// Scalar remainder tile: `rows ≤ 4`, `cols ≤ 32`, any combination
/// (dispatch-table slot `mk_tail`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mk_tail_scalar(
    ap: &[f32],
    lda: usize,
    rows: usize,
    bp: &[f32],
    ldb: usize,
    cols: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    ep: Epilogue<'_>,
) {
    debug_assert!(rows <= RB && cols <= MAX_NR);
    let mut acc = [[0.0f32; MAX_NR]; RB];
    for kk in 0..k {
        let b = &bp[kk * ldb..kk * ldb + cols];
        for (i, accrow) in acc.iter_mut().enumerate().take(rows) {
            let ai = ap[kk * lda + i];
            for (j, &bv) in b.iter().enumerate() {
                accrow[j] += ai * bv;
            }
        }
    }
    for (i, accrow) in acc.iter().enumerate().take(rows) {
        let crow = &mut c[i * ldc..i * ldc + cols];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = ep.apply(*cv + accrow[j], i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::simd;
    use crate::prop_assert;
    use crate::testkit::prop;

    /// Oracle: straightforward triple loop over the same packed layouts.
    #[allow(clippy::too_many_arguments)]
    fn naive(
        ap: &[f32],
        lda: usize,
        rows: usize,
        bp: &[f32],
        ldb: usize,
        cols: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
    ) {
        for i in 0..rows {
            for j in 0..cols {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += ap[kk * lda + i] * bp[kk * ldb + j];
                }
                c[i * ldc + j] += s;
            }
        }
    }

    #[test]
    fn matches_naive_property() {
        prop::check_default("microkernel-vs-naive", |rng| {
            let rows = prop::usize_in(rng, 1, 13);
            let lda = rows + prop::usize_in(rng, 0, 3);
            let cols = prop::usize_in(rng, 1, 70);
            let ldb = cols + prop::usize_in(rng, 0, 5);
            let ldc = cols + prop::usize_in(rng, 0, 5);
            let k = prop::usize_in(rng, 1, 24);
            let ap = prop::normal_vec(rng, k * lda);
            let bp = prop::normal_vec(rng, k * ldb);
            let mut c_fast = prop::normal_vec(rng, (rows - 1) * ldc + cols);
            let mut c_slow = c_fast.clone();
            microkernel(&ap, lda, rows, &bp, ldb, cols, k, &mut c_fast, ldc);
            naive(&ap, lda, rows, &bp, ldb, cols, k, &mut c_slow, ldc);
            for (idx, (a, b)) in c_fast.iter().zip(&c_slow).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-3,
                    "idx {idx}: {a} vs {b} (rows={rows} cols={cols} k={k})"
                );
            }
            Ok(())
        });
    }

    /// The full tiling loop with every epilogue variant, on both the
    /// scalar table (exact vs oracle+`Epilogue::apply`) and the native
    /// table (tolerance-gated) — the "forced scalar" arm runs on every
    /// host, not just scalar CI.
    #[test]
    fn epilogue_property_both_arms() {
        for d in [simd::scalar(), simd::native()] {
            prop::check_default("microkernel-epilogue", |rng| {
                let rows = prop::usize_in(rng, 1, 13);
                let lda = rows + prop::usize_in(rng, 0, 2);
                let cols = prop::usize_in(rng, 1, 70);
                let ldb = cols + prop::usize_in(rng, 0, 3);
                let ldc = cols + prop::usize_in(rng, 0, 3);
                let k = prop::usize_in(rng, 0, 16);
                let ap = prop::normal_vec(rng, k.max(1) * lda);
                let bp = prop::normal_vec(rng, k.max(1) * ldb);
                let c0 = prop::normal_vec(rng, (rows - 1) * ldc + cols);
                let bias = prop::normal_vec(rng, cols);
                let ldg = cols + 1;
                let gate = prop::normal_vec(rng, rows * ldg);
                let eps: [simd::Epilogue<'_>; 7] = [
                    simd::Epilogue::None,
                    simd::Epilogue::Bias(&bias),
                    simd::Epilogue::BiasGelu(&bias),
                    simd::Epilogue::BiasSilu(&bias),
                    simd::Epilogue::Gelu,
                    simd::Epilogue::Silu,
                    simd::Epilogue::SiluGate { g: &gate, ldg },
                ];
                for ep in eps {
                    let mut c = c0.clone();
                    microkernel_d(d, &ap, lda, rows, &bp, ldb, cols, k, &mut c, ldc, ep);
                    let mut want = c0.clone();
                    naive(&ap, lda, rows, &bp, ldb, cols, k, &mut want, ldc);
                    for i in 0..rows {
                        for j in 0..cols {
                            let w = ep.apply(want[i * ldc + j], i, j);
                            let g = c[i * ldc + j];
                            prop_assert!(
                                (g - w).abs() <= 1e-4 + 1e-5 * w.abs(),
                                "isa={} ({i},{j}): {g} vs {w} (rows={rows} cols={cols} k={k})",
                                d.isa.name()
                            );
                        }
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn specialized_widths_exact_tiles() {
        // hit the 4x8/4x16/2x32 slots head-on: rows multiple of 4, cols = NR
        for &nr in &[8usize, 16, 32] {
            let (rows, k) = (8usize, 16usize);
            let ap: Vec<f32> = (0..k * rows).map(|i| (i % 11) as f32 * 0.25).collect();
            let bp: Vec<f32> = (0..k * nr).map(|i| (i % 7) as f32 * 0.5 - 1.0).collect();
            let mut c_fast = vec![0.0f32; rows * nr];
            let mut c_slow = vec![0.0f32; rows * nr];
            microkernel_d(
                simd::scalar(),
                &ap,
                rows,
                rows,
                &bp,
                nr,
                nr,
                k,
                &mut c_fast,
                nr,
                simd::Epilogue::None,
            );
            naive(&ap, rows, rows, &bp, nr, nr, k, &mut c_slow, nr);
            assert_eq!(c_fast, c_slow, "nr={nr}");
        }
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut c = vec![1.0f32; 8];
        microkernel(&[], 4, 0, &[], 8, 8, 0, &mut c, 8);
        microkernel(&[1.0; 4], 4, 1, &[1.0; 8], 8, 0, 1, &mut c, 8);
        microkernel(&[], 4, 1, &[1.0; 8], 8, 8, 0, &mut c, 8);
        assert!(c.iter().all(|&v| v == 1.0));
        // k == 0 with a *multi-tile* shape and empty operands: must not
        // slice past the empty ap/bp (regression: the tiling loop used to
        // run and panic on `&bp[32..]`)
        let mut c = vec![2.0f32; 8 * 64];
        microkernel(&[], 8, 8, &[], 64, 64, 0, &mut c, 64);
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn k_zero_still_applies_epilogue() {
        // bias must land even when there is nothing to accumulate
        let bias = [0.5f32; 8];
        let mut c = vec![1.0f32; 8];
        microkernel_d(
            simd::dispatch(),
            &[],
            4,
            1,
            &[],
            8,
            8,
            0,
            &mut c,
            8,
            simd::Epilogue::Bias(&bias),
        );
        for v in &c {
            assert!((v - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn accumulates_into_existing_c() {
        let (rows, cols, k) = (5usize, 9usize, 3usize);
        let ap: Vec<f32> = (0..k * rows).map(|i| i as f32 * 0.1).collect();
        let bp: Vec<f32> = (0..k * cols).map(|i| 1.0 - i as f32 * 0.05).collect();
        let mut c = vec![2.0f32; rows * cols];
        let mut want = vec![2.0f32; rows * cols];
        microkernel(&ap, rows, rows, &bp, cols, cols, k, &mut c, cols);
        naive(&ap, rows, rows, &bp, cols, cols, k, &mut want, cols);
        for (a, b) in c.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
