//! Explicit-SIMD kernel backend with runtime CPU dispatch — the one place
//! in the crate where vector code is *written*, not hoped for.
//!
//! PR 1–4 built every hot path on scalar Rust shaped so LLVM *can*
//! autovectorize (fixed-size reborrows, unrolled lanes). This module makes
//! the vector code deliberate: hand-written micro-kernel register tiles and
//! element-wise lanes for **x86_64 AVX2+FMA** (8-lane `__m256`,
//! `_mm256_fmadd_ps`) and **aarch64 NEON** (4-lane `float32x4_t`,
//! `vfmaq_f32`), selected **once per process** into a [`KernelDispatch`]
//! table of plain function pointers. The scalar implementations survive as
//! the portable fallback arm of the same table *and* as parity oracles for
//! the tests.
//!
//! # The dispatch seam
//!
//! [`dispatch`] resolves the active table: the SIMD arm detected at first
//! use (`is_x86_feature_detected!("avx2")` + `"fma"` on x86_64; NEON is
//! baseline on aarch64), unless the `BLAST_SIMD` environment variable
//! (`off`/`0`/`false`/`scalar`/`no`) or [`set_simd_enabled`]`(false)` (the
//! CLI's `--no-simd`) forces the scalar arm. Consumers resolve the table
//! once per kernel invocation and pass it down (`microkernel_d`,
//! `tile_bspmm_packed`, `causal_tile`), so the per-tile cost of dispatch is
//! zero.
//!
//! # Fused epilogues
//!
//! [`Epilogue`] describes a transform applied to each output element of a
//! micro-kernel call **during the C write-back**, while the accumulator
//! tile is still in registers: bias add, GeLU/SiLU activation, bias +
//! activation, or the SwiGLU gate (`silu(c) * g`). The contract is
//! *exactly-once at final accumulation*: a call may carry an epilogue only
//! if it performs the last accumulation into that C region (the packed
//! GEMM runs full depth per panel; the BSpMM passes the epilogue on the
//! last resident block of each block column). This is what lets
//! `gelu_mlp_sparse` / `fused_mlp_sparse` / the engine's dense MLP drop
//! their separate full-tensor activation passes.
//!
//! # Unsafe-boundary policy
//!
//! Every `unsafe` block of the SIMD backend lives in this file, in the
//! arch-gated `avx2` / `neon` submodules. The function-pointer table is the
//! boundary: the SIMD arms are only reachable through tables installed
//! after feature detection, the wrappers are private, and everything above
//! the seam (`microkernel.rs`, `pack.rs`, `ops.rs`, …) is safe code that
//! works with any arm. Scratch buffers are 64-byte aligned
//! ([`crate::util::scratch`]), but the lanes use unaligned load/store
//! instructions throughout — alignment is a performance guarantee, never a
//! soundness precondition, so ragged tails and caller-supplied slices are
//! always legal.
//!
//! # Numerics
//!
//! The vector `exp` is the classic Cephes polynomial (used by
//! sse_mathfun/SLEEF-style libraries): range-reduce by `log2(e)`, 6-term
//! minimax polynomial, reconstruct with the exponent field. Relative error
//! is ~2 ulp vs `f32::exp`, so SIMD and scalar arms agree to ≤ 1e-6 + 1e-6
//! · |value| on every element-wise lane (the parity property tests pin
//! this); pure-FMA contractions differ from scalar only by rounding of the
//! fused multiply-add. Summation *order* within a lane never depends on
//! input values, so results are deterministic per arm.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use crate::kernels::ops;

/// Instruction set of a dispatch table arm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// x86_64 with AVX2 + FMA (8-lane f32, fused multiply-add).
    Avx2Fma,
    /// aarch64 NEON (4-lane f32, `vfmaq_f32`).
    Neon,
    /// Portable scalar Rust — the fallback arm and the parity oracle.
    Scalar,
}

impl Isa {
    /// Stable string recorded in `BENCH_*.json` metadata (`"avx2+fma"`,
    /// `"neon"`, `"scalar"`), so perf-trajectory numbers are comparable
    /// across machines.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Avx2Fma => "avx2+fma",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        }
    }
}

/// A transform fused into the micro-kernel C write-back.
///
/// Operand slices are relative to the C region of the call that carries the
/// epilogue: `Bias`-family slices hold one value per C *column*;
/// `SiluGate`'s `g` is a row-major matrix congruent with the C region
/// (`g[i*ldg + j]` gates element `(i, j)`). [`Epilogue::shift`] re-bases
/// the operands when a kernel tiles its C region.
///
/// Contract: the epilogue is applied **exactly once** per element, by the
/// call that performs the **final** accumulation into that element — it
/// transforms the fully-accumulated value `C_prev + ΣA·B`, so partial
/// products must never pass through it.
#[derive(Clone, Copy, Debug, Default)]
pub enum Epilogue<'a> {
    /// Plain accumulate (`c += acc`), no transform.
    #[default]
    None,
    /// `c = c + acc + bias[j]`.
    Bias(&'a [f32]),
    /// `c = gelu(c + acc + bias[j])`.
    BiasGelu(&'a [f32]),
    /// `c = silu(c + acc + bias[j])`.
    BiasSilu(&'a [f32]),
    /// `c = gelu(c + acc)` — the GPT-2 MLP hidden activation.
    Gelu,
    /// `c = silu(c + acc)`.
    Silu,
    /// `c = silu(c + acc) * g[i*ldg + j]` — the SwiGLU gate (paper Eq. 1).
    SiluGate {
        /// Gate operand, row-major, congruent with the C region.
        g: &'a [f32],
        /// Leading dimension (elements per row) of `g`.
        ldg: usize,
    },
}

impl<'a> Epilogue<'a> {
    /// Re-base the operands for the sub-tile starting at `(i0, j0)` of the
    /// region this epilogue was built for.
    #[inline]
    pub fn shift(&self, i0: usize, j0: usize) -> Epilogue<'a> {
        match *self {
            Epilogue::None => Epilogue::None,
            Epilogue::Bias(b) => Epilogue::Bias(&b[j0..]),
            Epilogue::BiasGelu(b) => Epilogue::BiasGelu(&b[j0..]),
            Epilogue::BiasSilu(b) => Epilogue::BiasSilu(&b[j0..]),
            Epilogue::Gelu => Epilogue::Gelu,
            Epilogue::Silu => Epilogue::Silu,
            Epilogue::SiluGate { g, ldg } => Epilogue::SiluGate { g: &g[i0 * ldg + j0..], ldg },
        }
    }

    /// True when the transform maps 0 to 0, i.e. skipping it over a
    /// never-accumulated (all-zero) region is exact. The `Bias` family is
    /// not zero-preserving: a BSpMM with a fully-pruned block column must
    /// still apply it there.
    #[inline]
    pub fn zero_preserving(&self) -> bool {
        !matches!(
            self,
            Epilogue::Bias(_) | Epilogue::BiasGelu(_) | Epilogue::BiasSilu(_)
        )
    }

    /// Scalar reference application to the fully-accumulated value `v` at
    /// C coordinates `(i, j)` — the semantics every SIMD arm must match.
    #[inline(always)]
    pub fn apply(&self, v: f32, i: usize, j: usize) -> f32 {
        match *self {
            Epilogue::None => v,
            Epilogue::Bias(b) => v + b[j],
            Epilogue::BiasGelu(b) => ops::gelu(v + b[j]),
            Epilogue::BiasSilu(b) => ops::silu(v + b[j]),
            Epilogue::Gelu => ops::gelu(v),
            Epilogue::Silu => ops::silu(v),
            Epilogue::SiluGate { g, ldg } => ops::silu(v) * g[i * ldg + j],
        }
    }

    /// Minimum operand coverage for a `rows × cols` C region, checked
    /// (hard, not debug — the SIMD arms read the operands through raw
    /// vector loads) at the `microkernel_d` / `apply_epilogue_region`
    /// boundary, once per kernel call, so a short bias/gate slice fails
    /// loudly instead of as an out-of-bounds vector read.
    #[inline]
    pub fn check_operands(&self, rows: usize, cols: usize) {
        match *self {
            Epilogue::Bias(b) | Epilogue::BiasGelu(b) | Epilogue::BiasSilu(b) => {
                assert!(b.len() >= cols, "epilogue bias {} < cols {cols}", b.len());
            }
            Epilogue::SiluGate { g, ldg } => {
                assert!(ldg >= cols, "epilogue gate ldg {ldg} < cols {cols}");
                assert!(
                    rows == 0 || g.len() >= (rows - 1) * ldg + cols,
                    "epilogue gate {} too short for {rows}x{cols} (ldg {ldg})",
                    g.len()
                );
            }
            _ => {}
        }
    }
}

/// Fixed-shape micro-kernel register tile: `C[R×NR] += Aᵖ·Bᵖ`, epilogue on
/// write-back. `ap` is k-major with leading dim `lda`, `bp` row-major with
/// leading dim `ldb`, `c` row-major with leading dim `ldc`; the tile shape
/// (4×16, 4×8 or 2×32) is fixed by the table slot.
pub type MkFn = fn(&[f32], usize, &[f32], usize, usize, &mut [f32], usize, Epilogue<'_>);

/// Remainder micro-kernel: `rows ≤ 4`, `cols ≤ 32`, any combination
/// (`(ap, lda, rows, bp, ldb, cols, k, c, ldc, ep)`).
pub type MkTailFn =
    fn(&[f32], usize, usize, &[f32], usize, usize, usize, &mut [f32], usize, Epilogue<'_>);

/// The per-ISA kernel table. One `static` per arm; every field is a plain
/// function pointer so the table is `Sync` and resolution is a pointer
/// read. Scalar-arm entries are the exact legacy implementations, so
/// forcing scalar reproduces pre-SIMD behavior bit-for-bit.
pub struct KernelDispatch {
    /// Which arm this table is.
    pub isa: Isa,
    /// 4×16 register tile (`C += Aᵖ·Bᵖ`, epilogue fused).
    pub mk4x16: MkFn,
    /// 4×8 register tile.
    pub mk4x8: MkFn,
    /// 2×32 register tile (see `microkernel.rs` on register budgets).
    pub mk2x32: MkFn,
    /// Remainder tile, `rows ≤ 4` × `cols ≤ 32`.
    pub mk_tail: MkTailFn,
    /// Blocked transpose pack: `out[kk*rows + r] = src[r*k + kk]`
    /// (`(src, rows, k, out)` — the contiguous A/X/Kᵀ panel pack).
    pub pack_kt: fn(&[f32], usize, usize, &mut [f32]),
    /// `v[i] = gelu(v[i])` (tanh approximation).
    pub gelu_slice: fn(&mut [f32]),
    /// `v[i] = silu(v[i])`.
    pub silu_slice: fn(&mut [f32]),
    /// `a[i] = silu(a[i]) * g[i]` — the SwiGLU gate lane.
    pub silu_gate_slice: fn(&mut [f32], &[f32]),
    /// `dh[i] *= gelu'(h[i])` — GeLU backward lane.
    pub gelu_bwd_slice: fn(&[f32], &mut [f32]),
    /// SwiGLU backward lane: `(h1, h2, d_act, dh1, dh2)` with
    /// `dh1 = d_act·h2·silu'(h1)`, `dh2 = d_act·silu(h1)`.
    pub swiglu_bwd_slice: fn(&[f32], &[f32], &[f32], &mut [f32], &mut [f32]),
    /// `y[i] += b[i]` — standalone bias lane (cold epilogue regions).
    pub add_bias_slice: fn(&mut [f32], &[f32]),
    /// Max over a row (`-inf` for an empty row) — softmax pass 1.
    pub row_max: fn(&[f32]) -> f32,
    /// Max over an unscaled score-tile row (`-inf` when empty) — the
    /// BLASST skip test. Kept as its own lane so the dynamic-sparsity
    /// threshold check costs exactly one extra reduction per k-tile row
    /// and can be retargeted (e.g. fused into the score epilogue)
    /// without touching the softmax `row_max` contract. Max commutes
    /// with the positive score scale (f32 multiply is monotone), so
    /// thresholding on `scale * tile_max(row)` equals thresholding on
    /// the scaled row's max bit-for-bit.
    pub tile_max: fn(&[f32]) -> f32,
    /// `v[i] *= scale` returning the running max — the attention score
    /// scale+mask-max fusion (`-inf` for an empty row).
    pub scale_max_slice: fn(&mut [f32], f32) -> f32,
    /// `v[i] = exp(v[i] - shift)` returning the sum — softmax pass 2.
    pub exp_shift_sum: fn(&mut [f32], f32) -> f32,
    /// `v[i] *= scale` — softmax normalize / streaming rescale.
    pub scale_slice: fn(&mut [f32], f32),
    /// Plain sum — layernorm mean reduction.
    pub sum_slice: fn(&[f32]) -> f32,
    /// `Σ (v[i] - shift)²` — layernorm variance / rmsnorm mean-square
    /// (`shift = 0`) reduction.
    pub sumsq_shift_slice: fn(&[f32], f32) -> f32,
    /// Dot product — the decode attention score lane.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `y += a * x` — the decode attention value-accumulate lane.
    pub axpy: fn(f32, &[f32], &mut [f32]),
}

impl KernelDispatch {
    /// Apply `ep` to a `rows × cols` row-major region whose accumulation is
    /// already complete — the cold path for C regions no micro-kernel call
    /// finishes (fully-pruned BSpMM block columns, `k == 0` GEMMs).
    pub fn apply_epilogue_region(
        &self,
        c: &mut [f32],
        ldc: usize,
        rows: usize,
        cols: usize,
        ep: Epilogue<'_>,
    ) {
        if rows == 0 || cols == 0 {
            return;
        }
        ep.check_operands(rows, cols);
        debug_assert!(c.len() >= (rows - 1) * ldc + cols);
        for i in 0..rows {
            let row = &mut c[i * ldc..i * ldc + cols];
            match ep {
                Epilogue::None => {}
                Epilogue::Bias(b) => (self.add_bias_slice)(row, &b[..cols]),
                Epilogue::BiasGelu(b) => {
                    (self.add_bias_slice)(row, &b[..cols]);
                    (self.gelu_slice)(row);
                }
                Epilogue::BiasSilu(b) => {
                    (self.add_bias_slice)(row, &b[..cols]);
                    (self.silu_slice)(row);
                }
                Epilogue::Gelu => (self.gelu_slice)(row),
                Epilogue::Silu => (self.silu_slice)(row),
                Epilogue::SiluGate { g, ldg } => {
                    (self.silu_gate_slice)(row, &g[i * ldg..i * ldg + cols])
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// table resolution: detection + overrides
// ---------------------------------------------------------------------

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static ENV_OFF: OnceLock<bool> = OnceLock::new();
static NATIVE: OnceLock<&'static KernelDispatch> = OnceLock::new();

/// Does a `BLAST_SIMD` value disable the SIMD arm? Case-insensitive, so
/// `BLAST_SIMD=OFF` behaves like `off`.
fn env_disables(val: Option<&str>) -> bool {
    matches!(
        val.map(|v| v.to_ascii_lowercase()).as_deref(),
        Some("off" | "0" | "false" | "no" | "scalar")
    )
}

/// Pure resolution rule `(env_off, forced_scalar) → table`; split out so
/// tests can exercise every combination without racing global state.
fn resolve(env_off: bool, forced_scalar: bool) -> &'static KernelDispatch {
    if env_off || forced_scalar {
        scalar()
    } else {
        native()
    }
}

/// The active kernel table: the detected SIMD arm unless `BLAST_SIMD`
/// or [`set_simd_enabled`]`(false)` forces scalar.
#[inline]
pub fn dispatch() -> &'static KernelDispatch {
    let env_off = *ENV_OFF
        .get_or_init(|| env_disables(std::env::var("BLAST_SIMD").ok().as_deref()));
    resolve(env_off, FORCE_SCALAR.load(Ordering::Relaxed))
}

/// The portable scalar table (always available; the parity oracle).
pub fn scalar() -> &'static KernelDispatch {
    &SCALAR_TABLE
}

/// The best table this host supports (detection runs once). Equal to
/// [`scalar`] when the host has no supported SIMD extension.
pub fn native() -> &'static KernelDispatch {
    NATIVE.get_or_init(detect)
}

/// Programmatic override behind the CLI's `--no-simd`: `false` forces the
/// scalar arm for subsequent [`dispatch`] calls. Meant to be set once at
/// process startup, before kernel work begins — flipping it mid-run is
/// safe (all arms are correct) but changes rounding between calls, so
/// bit-reproducibility comparisons must not straddle a flip. Tests that
/// want a specific arm should pass [`scalar`]/[`native`] tables explicitly
/// instead of toggling this.
pub fn set_simd_enabled(on: bool) {
    FORCE_SCALAR.store(!on, Ordering::Relaxed);
}

/// Detect the best arm for this host.
#[allow(unreachable_code)] // the aarch64 arm returns unconditionally
fn detect() -> &'static KernelDispatch {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
            return &AVX2_TABLE;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return &NEON_TABLE;
    }
    &SCALAR_TABLE
}

// ---------------------------------------------------------------------
// scalar arm — the legacy implementations, verbatim semantics
// ---------------------------------------------------------------------

static SCALAR_TABLE: KernelDispatch = KernelDispatch {
    isa: Isa::Scalar,
    mk4x16: crate::kernels::microkernel::mk4x16_scalar,
    mk4x8: crate::kernels::microkernel::mk4x8_scalar,
    mk2x32: crate::kernels::microkernel::mk2x32_scalar,
    mk_tail: crate::kernels::microkernel::mk_tail_scalar,
    pack_kt: crate::kernels::pack::pack_kt_panel_scalar,
    gelu_slice: scalar_arm::gelu_slice,
    silu_slice: scalar_arm::silu_slice,
    silu_gate_slice: scalar_arm::silu_gate_slice,
    gelu_bwd_slice: ops::gelu_bwd_scalar,
    swiglu_bwd_slice: scalar_arm::swiglu_bwd_slice,
    add_bias_slice: scalar_arm::add_bias_slice,
    row_max: scalar_arm::row_max,
    tile_max: scalar_arm::row_max,
    scale_max_slice: scalar_arm::scale_max_slice,
    exp_shift_sum: scalar_arm::exp_shift_sum,
    scale_slice: scalar_arm::scale_slice,
    sum_slice: scalar_arm::sum_slice,
    sumsq_shift_slice: scalar_arm::sumsq_shift_slice,
    dot: crate::kernels::attention::dot_lanes,
    axpy: crate::kernels::gemm::axpy,
};

/// Scalar lane bodies. Loop shapes deliberately mirror the pre-SIMD code
/// (sequential folds, same association order), so the scalar arm is
/// bit-identical to the seed kernels it replaced.
mod scalar_arm {
    use crate::kernels::ops;

    pub fn gelu_slice(v: &mut [f32]) {
        for x in v.iter_mut() {
            *x = ops::gelu(*x);
        }
    }

    pub fn silu_slice(v: &mut [f32]) {
        for x in v.iter_mut() {
            *x = ops::silu(*x);
        }
    }

    pub fn silu_gate_slice(a: &mut [f32], g: &[f32]) {
        debug_assert_eq!(a.len(), g.len());
        for (x, &gg) in a.iter_mut().zip(g) {
            *x = ops::silu(*x) * gg;
        }
    }

    pub fn swiglu_bwd_slice(
        h1: &[f32],
        h2: &[f32],
        d_act: &[f32],
        dh1: &mut [f32],
        dh2: &mut [f32],
    ) {
        debug_assert!(
            h1.len() == h2.len()
                && h1.len() == d_act.len()
                && h1.len() == dh1.len()
                && h1.len() == dh2.len()
        );
        for i in 0..h1.len() {
            dh1[i] = d_act[i] * h2[i] * ops::silu_grad(h1[i]);
            dh2[i] = d_act[i] * ops::silu(h1[i]);
        }
    }

    pub fn add_bias_slice(y: &mut [f32], b: &[f32]) {
        debug_assert_eq!(y.len(), b.len());
        for (v, &bb) in y.iter_mut().zip(b) {
            *v += bb;
        }
    }

    pub fn row_max(v: &[f32]) -> f32 {
        v.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
    }

    pub fn scale_max_slice(v: &mut [f32], scale: f32) -> f32 {
        let mut max = f32::NEG_INFINITY;
        for x in v.iter_mut() {
            *x *= scale;
            max = max.max(*x);
        }
        max
    }

    pub fn exp_shift_sum(v: &mut [f32], shift: f32) -> f32 {
        let mut sum = 0.0f32;
        for x in v.iter_mut() {
            *x = (*x - shift).exp();
            sum += *x;
        }
        sum
    }

    pub fn scale_slice(v: &mut [f32], scale: f32) {
        for x in v.iter_mut() {
            *x *= scale;
        }
    }

    pub fn sum_slice(v: &[f32]) -> f32 {
        v.iter().sum()
    }

    pub fn sumsq_shift_slice(v: &[f32], shift: f32) -> f32 {
        let mut acc = 0.0f32;
        for &x in v {
            let d = x - shift;
            acc += d * d;
        }
        acc
    }
}

// ---------------------------------------------------------------------
// AVX2 + FMA arm (x86_64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2_TABLE: KernelDispatch = KernelDispatch {
    isa: Isa::Avx2Fma,
    mk4x16: avx2::mk4x16,
    mk4x8: avx2::mk4x8,
    mk2x32: avx2::mk2x32,
    mk_tail: avx2::mk_tail,
    pack_kt: avx2::pack_kt,
    gelu_slice: avx2::gelu_slice,
    silu_slice: avx2::silu_slice,
    silu_gate_slice: avx2::silu_gate_slice,
    gelu_bwd_slice: avx2::gelu_bwd_slice,
    swiglu_bwd_slice: avx2::swiglu_bwd_slice,
    add_bias_slice: avx2::add_bias_slice,
    row_max: avx2::row_max,
    tile_max: avx2::row_max,
    scale_max_slice: avx2::scale_max_slice,
    exp_shift_sum: avx2::exp_shift_sum,
    scale_slice: avx2::scale_slice,
    sum_slice: avx2::sum_slice,
    sumsq_shift_slice: avx2::sumsq_shift_slice,
    dot: avx2::dot,
    axpy: avx2::axpy,
};

/// AVX2+FMA lane implementations. Layout per lane: a safe wrapper (the
/// table entry — sound because the table is only installed after
/// `is_x86_feature_detected!`) around a `#[target_feature]` body whose
/// `unsafe` blocks are the crate's only vector-intrinsic code. All memory
/// access is via unaligned load/store, so slice alignment is never a
/// soundness requirement; scalar tails reuse the scalar-arm formulas.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)] // GEMM kernel ABIs are what they are
mod avx2 {
    use super::Epilogue;
    use crate::kernels::ops;
    use std::arch::x86_64::*;

    // ---- helpers ----------------------------------------------------

    /// Horizontal sum of all 8 lanes.
    #[inline(always)]
    unsafe fn hsum(v: __m256) -> f32 {
        unsafe {
            let q = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
            let d = _mm_add_ps(q, _mm_movehl_ps(q, q));
            let s = _mm_add_ss(d, _mm_shuffle_ps::<0b01>(d, d));
            _mm_cvtss_f32(s)
        }
    }

    /// Horizontal max of all 8 lanes.
    #[inline(always)]
    unsafe fn hmax(v: __m256) -> f32 {
        unsafe {
            let q = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
            let d = _mm_max_ps(q, _mm_movehl_ps(q, q));
            let s = _mm_max_ss(d, _mm_shuffle_ps::<0b01>(d, d));
            _mm_cvtss_f32(s)
        }
    }

    /// Vector `exp` — Cephes polynomial (see the module doc on numerics).
    #[inline(always)]
    unsafe fn exp_ps(x: __m256) -> __m256 {
        unsafe {
            let one = _mm256_set1_ps(1.0);
            let x = _mm256_min_ps(x, _mm256_set1_ps(88.0));
            let x = _mm256_max_ps(x, _mm256_set1_ps(-88.0));
            // n = floor(x * log2(e) + 0.5)
            let fx = _mm256_floor_ps(_mm256_fmadd_ps(
                x,
                _mm256_set1_ps(std::f32::consts::LOG2_E),
                _mm256_set1_ps(0.5),
            ));
            // r = x - n*ln(2), split into hi/lo parts for precision
            let r = _mm256_sub_ps(
                _mm256_sub_ps(x, _mm256_mul_ps(fx, _mm256_set1_ps(0.693359375))),
                _mm256_mul_ps(fx, _mm256_set1_ps(-2.121_944_4e-4)),
            );
            let r2 = _mm256_mul_ps(r, r);
            let mut p = _mm256_set1_ps(1.987_569_1e-4);
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.398_2e-3));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(8.333_452e-3));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(4.166_579_6e-2));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.666_666_5e-1));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(5.000_000_3e-1));
            let y = _mm256_fmadd_ps(p, r2, _mm256_add_ps(r, one));
            // * 2^n via the exponent field (n is integral after floor)
            let n = _mm256_cvttps_epi32(fx);
            let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
                n,
                _mm256_set1_epi32(127),
            )));
            _mm256_mul_ps(y, pow2n)
        }
    }

    /// `silu(x) = x / (1 + exp(-x))`.
    #[inline(always)]
    unsafe fn silu_ps(x: __m256) -> __m256 {
        unsafe {
            let one = _mm256_set1_ps(1.0);
            let neg = _mm256_sub_ps(_mm256_setzero_ps(), x);
            _mm256_div_ps(x, _mm256_add_ps(one, exp_ps(neg)))
        }
    }

    /// `sigmoid(x) = 1 / (1 + exp(-x))`.
    #[inline(always)]
    unsafe fn sigmoid_ps(x: __m256) -> __m256 {
        unsafe {
            let one = _mm256_set1_ps(1.0);
            let neg = _mm256_sub_ps(_mm256_setzero_ps(), x);
            _mm256_div_ps(one, _mm256_add_ps(one, exp_ps(neg)))
        }
    }

    /// `tanh(u) = (e^{2u} - 1) / (e^{2u} + 1)` via the clamped `exp_ps`
    /// (the clamp saturates the ratio to ±1 for large |u|).
    #[inline(always)]
    unsafe fn tanh_ps(u: __m256) -> __m256 {
        unsafe {
            let one = _mm256_set1_ps(1.0);
            let e = exp_ps(_mm256_add_ps(u, u));
            _mm256_div_ps(_mm256_sub_ps(e, one), _mm256_add_ps(e, one))
        }
    }

    const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi), matches ops::gelu
    const GELU_A: f32 = 0.044715;

    /// `u(x) = C·(x + A·x³)` — the gelu tanh argument.
    #[inline(always)]
    unsafe fn gelu_u_ps(x: __m256) -> __m256 {
        unsafe {
            let x2 = _mm256_mul_ps(x, x);
            let inner = _mm256_fmadd_ps(_mm256_mul_ps(_mm256_set1_ps(GELU_A), x2), x, x);
            _mm256_mul_ps(_mm256_set1_ps(GELU_C), inner)
        }
    }

    /// `gelu(x) = 0.5·x·(1 + tanh(u)) = x·e^{2u}/(e^{2u}+1)`.
    #[inline(always)]
    unsafe fn gelu_ps(x: __m256) -> __m256 {
        unsafe {
            let one = _mm256_set1_ps(1.0);
            let u = gelu_u_ps(x);
            let e = exp_ps(_mm256_add_ps(u, u));
            _mm256_mul_ps(x, _mm256_div_ps(e, _mm256_add_ps(e, one)))
        }
    }

    /// `gelu'(x) = 0.5(1+t) + 0.5·x·(1−t²)·C·(1+3A·x²)`, `t = tanh(u)`.
    #[inline(always)]
    unsafe fn gelu_grad_ps(x: __m256) -> __m256 {
        unsafe {
            let one = _mm256_set1_ps(1.0);
            let half = _mm256_set1_ps(0.5);
            let t = tanh_ps(gelu_u_ps(x));
            let x2 = _mm256_mul_ps(x, x);
            let du = _mm256_mul_ps(
                _mm256_set1_ps(GELU_C),
                _mm256_fmadd_ps(_mm256_set1_ps(3.0 * GELU_A), x2, one),
            );
            let sech2 = _mm256_sub_ps(one, _mm256_mul_ps(t, t));
            let lhs = _mm256_mul_ps(half, _mm256_add_ps(one, t));
            _mm256_fmadd_ps(_mm256_mul_ps(_mm256_mul_ps(half, x), sech2), du, lhs)
        }
    }

    /// Apply the epilogue to one 8-wide writeback vector at C coordinates
    /// `(i, j..j+8)`. SAFETY: caller guarantees the operand coverage
    /// checked by `Epilogue::check_operands`.
    #[inline(always)]
    unsafe fn apply_ep(v: __m256, i: usize, j: usize, ep: &Epilogue<'_>) -> __m256 {
        unsafe {
            match *ep {
                Epilogue::None => v,
                Epilogue::Bias(b) => _mm256_add_ps(v, _mm256_loadu_ps(b.as_ptr().add(j))),
                Epilogue::BiasGelu(b) => {
                    gelu_ps(_mm256_add_ps(v, _mm256_loadu_ps(b.as_ptr().add(j))))
                }
                Epilogue::BiasSilu(b) => {
                    silu_ps(_mm256_add_ps(v, _mm256_loadu_ps(b.as_ptr().add(j))))
                }
                Epilogue::Gelu => gelu_ps(v),
                Epilogue::Silu => silu_ps(v),
                Epilogue::SiluGate { g, ldg } => _mm256_mul_ps(
                    silu_ps(v),
                    _mm256_loadu_ps(g.as_ptr().add(i * ldg + j)),
                ),
            }
        }
    }

    // ---- micro-kernel register tiles --------------------------------

    pub fn mk4x16(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { mk4x16_tf(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn mk4x16_tf(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe { mk_rxw::<4, 2>(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    pub fn mk4x8(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: as above.
        unsafe { mk4x8_tf(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn mk4x8_tf(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe { mk_rxw::<4, 1>(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    pub fn mk2x32(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: as above.
        unsafe { mk2x32_tf(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn mk2x32_tf(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe { mk_rxw::<2, 4>(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    /// R rows × (W·8) columns register tile: R·W YMM accumulators, one
    /// broadcast per (row, depth step), W row loads per depth step, C
    /// touched exactly once with the epilogue fused into the store.
    /// `inline(always)` without its own `target_feature`: the generic body
    /// is only ever inlined into the concrete `_tf` entries above, so it
    /// codegens with AVX2+FMA enabled (the standard helper pattern —
    /// `target_feature` and `inline(always)` cannot be combined, and
    /// keeping the generic free of the attribute sidesteps the generic-fn
    /// restriction).
    #[inline(always)]
    unsafe fn mk_rxw<const R: usize, const W: usize>(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe {
            debug_assert!(k == 0 || ap.len() >= (k - 1) * lda + R);
            debug_assert!(k == 0 || bp.len() >= (k - 1) * ldb + W * 8);
            debug_assert!(c.len() >= (R - 1) * ldc + W * 8);
            let mut acc = [[_mm256_setzero_ps(); W]; R];
            let a_ptr = ap.as_ptr();
            let b_ptr = bp.as_ptr();
            for kk in 0..k {
                let brow = b_ptr.add(kk * ldb);
                let mut bv = [_mm256_setzero_ps(); W];
                for (w, bvw) in bv.iter_mut().enumerate() {
                    *bvw = _mm256_loadu_ps(brow.add(w * 8));
                }
                let arow = a_ptr.add(kk * lda);
                for (i, acci) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*arow.add(i));
                    for (w, bvw) in bv.iter().enumerate() {
                        acci[w] = _mm256_fmadd_ps(av, *bvw, acci[w]);
                    }
                }
            }
            for (i, acci) in acc.iter().enumerate() {
                let crow = c.as_mut_ptr().add(i * ldc);
                for (w, accw) in acci.iter().enumerate() {
                    let v = _mm256_add_ps(_mm256_loadu_ps(crow.add(w * 8)), *accw);
                    _mm256_storeu_ps(crow.add(w * 8), apply_ep(v, i, w * 8, &ep));
                }
            }
        }
    }

    /// Remainder tile: `rows ≤ 4`, `cols ≤ 32`. Full 8-wide chunks run
    /// vectorized; the last `cols % 8` columns accumulate in scalar lanes
    /// (by construction of the tiling loop this remainder only coexists
    /// with `cols < 8`, so register pressure stays within budget).
    #[allow(clippy::too_many_arguments)]
    pub fn mk_tail(
        ap: &[f32],
        lda: usize,
        rows: usize,
        bp: &[f32],
        ldb: usize,
        cols: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { mk_tail_impl(ap, lda, rows, bp, ldb, cols, k, c, ldc, ep) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn mk_tail_impl(
        ap: &[f32],
        lda: usize,
        rows: usize,
        bp: &[f32],
        ldb: usize,
        cols: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe {
            debug_assert!(rows <= 4 && cols <= 32);
            let chunks = cols / 8;
            let rem = cols - chunks * 8;
            let mut acc = [[_mm256_setzero_ps(); 4]; 4];
            let mut racc = [[0.0f32; 8]; 4];
            let a_ptr = ap.as_ptr();
            let b_ptr = bp.as_ptr();
            for kk in 0..k {
                let brow = b_ptr.add(kk * ldb);
                for i in 0..rows {
                    let a = *a_ptr.add(kk * lda + i);
                    let av = _mm256_set1_ps(a);
                    for ch in 0..chunks {
                        acc[i][ch] =
                            _mm256_fmadd_ps(av, _mm256_loadu_ps(brow.add(ch * 8)), acc[i][ch]);
                    }
                    for j in 0..rem {
                        racc[i][j] += a * *brow.add(chunks * 8 + j);
                    }
                }
            }
            for i in 0..rows {
                let crow = c.as_mut_ptr().add(i * ldc);
                for ch in 0..chunks {
                    let v = _mm256_add_ps(_mm256_loadu_ps(crow.add(ch * 8)), acc[i][ch]);
                    _mm256_storeu_ps(crow.add(ch * 8), apply_ep(v, i, ch * 8, &ep));
                }
                for j in 0..rem {
                    let col = chunks * 8 + j;
                    let v = *crow.add(col) + racc[i][j];
                    *crow.add(col) = ep.apply(v, i, col);
                }
            }
        }
    }

    // ---- pack -------------------------------------------------------

    /// In-register 8×8 transpose: rows `r0..r0+8` × depth `k0..k0+8` of a
    /// row-major source land as 8 contiguous 8-wide stores in the k-major
    /// panel. The unpack/shuffle/permute network is validated by numpy
    /// emulation in `python/tests/simd_check.py`.
    #[inline(always)]
    unsafe fn transpose8x8(src: *const f32, src_stride: usize, dst: *mut f32, dst_stride: usize) {
        unsafe {
            let r0 = _mm256_loadu_ps(src);
            let r1 = _mm256_loadu_ps(src.add(src_stride));
            let r2 = _mm256_loadu_ps(src.add(2 * src_stride));
            let r3 = _mm256_loadu_ps(src.add(3 * src_stride));
            let r4 = _mm256_loadu_ps(src.add(4 * src_stride));
            let r5 = _mm256_loadu_ps(src.add(5 * src_stride));
            let r6 = _mm256_loadu_ps(src.add(6 * src_stride));
            let r7 = _mm256_loadu_ps(src.add(7 * src_stride));
            let t0 = _mm256_unpacklo_ps(r0, r1);
            let t1 = _mm256_unpackhi_ps(r0, r1);
            let t2 = _mm256_unpacklo_ps(r2, r3);
            let t3 = _mm256_unpackhi_ps(r2, r3);
            let t4 = _mm256_unpacklo_ps(r4, r5);
            let t5 = _mm256_unpackhi_ps(r4, r5);
            let t6 = _mm256_unpacklo_ps(r6, r7);
            let t7 = _mm256_unpackhi_ps(r6, r7);
            let s0 = _mm256_shuffle_ps::<0x44>(t0, t2);
            let s1 = _mm256_shuffle_ps::<0xEE>(t0, t2);
            let s2 = _mm256_shuffle_ps::<0x44>(t1, t3);
            let s3 = _mm256_shuffle_ps::<0xEE>(t1, t3);
            let s4 = _mm256_shuffle_ps::<0x44>(t4, t6);
            let s5 = _mm256_shuffle_ps::<0xEE>(t4, t6);
            let s6 = _mm256_shuffle_ps::<0x44>(t5, t7);
            let s7 = _mm256_shuffle_ps::<0xEE>(t5, t7);
            _mm256_storeu_ps(dst, _mm256_permute2f128_ps::<0x20>(s0, s4));
            _mm256_storeu_ps(dst.add(dst_stride), _mm256_permute2f128_ps::<0x20>(s1, s5));
            _mm256_storeu_ps(dst.add(2 * dst_stride), _mm256_permute2f128_ps::<0x20>(s2, s6));
            _mm256_storeu_ps(dst.add(3 * dst_stride), _mm256_permute2f128_ps::<0x20>(s3, s7));
            _mm256_storeu_ps(dst.add(4 * dst_stride), _mm256_permute2f128_ps::<0x31>(s0, s4));
            _mm256_storeu_ps(dst.add(5 * dst_stride), _mm256_permute2f128_ps::<0x31>(s1, s5));
            _mm256_storeu_ps(dst.add(6 * dst_stride), _mm256_permute2f128_ps::<0x31>(s2, s6));
            _mm256_storeu_ps(dst.add(7 * dst_stride), _mm256_permute2f128_ps::<0x31>(s3, s7));
        }
    }

    pub fn pack_kt(src: &[f32], rows: usize, k: usize, out: &mut [f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { pack_kt_impl(src, rows, k, out) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn pack_kt_impl(src: &[f32], rows: usize, k: usize, out: &mut [f32]) {
        unsafe {
            debug_assert!(src.len() >= rows * k);
            debug_assert!(out.len() >= rows * k);
            let sp = src.as_ptr();
            let op = out.as_mut_ptr();
            let mut r0 = 0;
            while r0 + 8 <= rows {
                let mut k0 = 0;
                while k0 + 8 <= k {
                    transpose8x8(sp.add(r0 * k + k0), k, op.add(k0 * rows + r0), rows);
                    k0 += 8;
                }
                for kk in k0..k {
                    for i in 0..8 {
                        *op.add(kk * rows + r0 + i) = *sp.add((r0 + i) * k + kk);
                    }
                }
                r0 += 8;
            }
            for r in r0..rows {
                for kk in 0..k {
                    *op.add(kk * rows + r) = *sp.add(r * k + kk);
                }
            }
        }
    }

    // ---- element-wise / reduction lanes -----------------------------

    pub fn gelu_slice(v: &mut [f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { gelu_slice_impl(v) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn gelu_slice_impl(v: &mut [f32]) {
        unsafe {
            let n = v.len();
            let p = v.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= n {
                _mm256_storeu_ps(p.add(i), gelu_ps(_mm256_loadu_ps(p.add(i))));
                i += 8;
            }
            for j in i..n {
                *p.add(j) = ops::gelu(*p.add(j));
            }
        }
    }

    pub fn silu_slice(v: &mut [f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { silu_slice_impl(v) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn silu_slice_impl(v: &mut [f32]) {
        unsafe {
            let n = v.len();
            let p = v.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= n {
                _mm256_storeu_ps(p.add(i), silu_ps(_mm256_loadu_ps(p.add(i))));
                i += 8;
            }
            for j in i..n {
                *p.add(j) = ops::silu(*p.add(j));
            }
        }
    }

    pub fn silu_gate_slice(a: &mut [f32], g: &[f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { silu_gate_impl(a, g) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn silu_gate_impl(a: &mut [f32], g: &[f32]) {
        unsafe {
            debug_assert_eq!(a.len(), g.len());
            let n = a.len();
            let ap = a.as_mut_ptr();
            let gp = g.as_ptr();
            let mut i = 0;
            while i + 8 <= n {
                let x = _mm256_loadu_ps(ap.add(i));
                let gg = _mm256_loadu_ps(gp.add(i));
                _mm256_storeu_ps(ap.add(i), _mm256_mul_ps(silu_ps(x), gg));
                i += 8;
            }
            for j in i..n {
                *ap.add(j) = ops::silu(*ap.add(j)) * *gp.add(j);
            }
        }
    }

    pub fn gelu_bwd_slice(h: &[f32], dh: &mut [f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { gelu_bwd_impl(h, dh) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn gelu_bwd_impl(h: &[f32], dh: &mut [f32]) {
        unsafe {
            debug_assert_eq!(h.len(), dh.len());
            let n = h.len();
            let hp = h.as_ptr();
            let dp = dh.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= n {
                let x = _mm256_loadu_ps(hp.add(i));
                let d = _mm256_loadu_ps(dp.add(i));
                _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(d, gelu_grad_ps(x)));
                i += 8;
            }
            for j in i..n {
                *dp.add(j) *= ops::gelu_grad(*hp.add(j));
            }
        }
    }

    pub fn swiglu_bwd_slice(
        h1: &[f32],
        h2: &[f32],
        d_act: &[f32],
        dh1: &mut [f32],
        dh2: &mut [f32],
    ) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { swiglu_bwd_impl(h1, h2, d_act, dh1, dh2) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn swiglu_bwd_impl(
        h1: &[f32],
        h2: &[f32],
        d_act: &[f32],
        dh1: &mut [f32],
        dh2: &mut [f32],
    ) {
        unsafe {
            let n = h1.len();
            debug_assert!(h2.len() == n && d_act.len() == n && dh1.len() == n && dh2.len() == n);
            let one = _mm256_set1_ps(1.0);
            let mut i = 0;
            while i + 8 <= n {
                let x = _mm256_loadu_ps(h1.as_ptr().add(i));
                let g = _mm256_loadu_ps(h2.as_ptr().add(i));
                let d = _mm256_loadu_ps(d_act.as_ptr().add(i));
                let s = sigmoid_ps(x);
                let sil = _mm256_mul_ps(x, s);
                // silu'(x) = s · (1 + x·(1−s))
                let grad = _mm256_mul_ps(s, _mm256_fmadd_ps(x, _mm256_sub_ps(one, s), one));
                _mm256_storeu_ps(dh1.as_mut_ptr().add(i), _mm256_mul_ps(_mm256_mul_ps(d, g), grad));
                _mm256_storeu_ps(dh2.as_mut_ptr().add(i), _mm256_mul_ps(d, sil));
                i += 8;
            }
            for j in i..n {
                dh1[j] = d_act[j] * h2[j] * ops::silu_grad(h1[j]);
                dh2[j] = d_act[j] * ops::silu(h1[j]);
            }
        }
    }

    pub fn add_bias_slice(y: &mut [f32], b: &[f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { add_bias_impl(y, b) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn add_bias_impl(y: &mut [f32], b: &[f32]) {
        unsafe {
            debug_assert_eq!(y.len(), b.len());
            let n = y.len();
            let mut i = 0;
            while i + 8 <= n {
                let v = _mm256_add_ps(
                    _mm256_loadu_ps(y.as_ptr().add(i)),
                    _mm256_loadu_ps(b.as_ptr().add(i)),
                );
                _mm256_storeu_ps(y.as_mut_ptr().add(i), v);
                i += 8;
            }
            for j in i..n {
                y[j] += b[j];
            }
        }
    }

    pub fn row_max(v: &[f32]) -> f32 {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { row_max_impl(v) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn row_max_impl(v: &[f32]) -> f32 {
        unsafe {
            let n = v.len();
            let mut best = f32::NEG_INFINITY;
            let mut i = 0;
            if n >= 8 {
                let mut m = _mm256_loadu_ps(v.as_ptr());
                i = 8;
                while i + 8 <= n {
                    m = _mm256_max_ps(m, _mm256_loadu_ps(v.as_ptr().add(i)));
                    i += 8;
                }
                best = hmax(m);
            }
            for &x in &v[i..] {
                best = best.max(x);
            }
            best
        }
    }

    pub fn scale_max_slice(v: &mut [f32], scale: f32) -> f32 {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { scale_max_impl(v, scale) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scale_max_impl(v: &mut [f32], scale: f32) -> f32 {
        unsafe {
            let n = v.len();
            let sv = _mm256_set1_ps(scale);
            let p = v.as_mut_ptr();
            let mut best = f32::NEG_INFINITY;
            let mut i = 0;
            if n >= 8 {
                let first = _mm256_mul_ps(_mm256_loadu_ps(p), sv);
                _mm256_storeu_ps(p, first);
                let mut m = first;
                i = 8;
                while i + 8 <= n {
                    let x = _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), sv);
                    _mm256_storeu_ps(p.add(i), x);
                    m = _mm256_max_ps(m, x);
                    i += 8;
                }
                best = hmax(m);
            }
            for j in i..n {
                let x = *p.add(j) * scale;
                *p.add(j) = x;
                best = best.max(x);
            }
            best
        }
    }

    pub fn exp_shift_sum(v: &mut [f32], shift: f32) -> f32 {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { exp_shift_sum_impl(v, shift) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp_shift_sum_impl(v: &mut [f32], shift: f32) -> f32 {
        unsafe {
            let n = v.len();
            let sh = _mm256_set1_ps(shift);
            let p = v.as_mut_ptr();
            let mut accv = _mm256_setzero_ps();
            let mut i = 0;
            while i + 8 <= n {
                let e = exp_ps(_mm256_sub_ps(_mm256_loadu_ps(p.add(i)), sh));
                _mm256_storeu_ps(p.add(i), e);
                accv = _mm256_add_ps(accv, e);
                i += 8;
            }
            let mut sum = hsum(accv);
            for j in i..n {
                let e = (*p.add(j) - shift).exp();
                *p.add(j) = e;
                sum += e;
            }
            sum
        }
    }

    pub fn scale_slice(v: &mut [f32], scale: f32) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { scale_slice_impl(v, scale) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scale_slice_impl(v: &mut [f32], scale: f32) {
        unsafe {
            let n = v.len();
            let sv = _mm256_set1_ps(scale);
            let p = v.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= n {
                _mm256_storeu_ps(p.add(i), _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), sv));
                i += 8;
            }
            for j in i..n {
                *p.add(j) *= scale;
            }
        }
    }

    pub fn sum_slice(v: &[f32]) -> f32 {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { sum_slice_impl(v) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn sum_slice_impl(v: &[f32]) -> f32 {
        unsafe {
            let n = v.len();
            let mut accv = _mm256_setzero_ps();
            let mut i = 0;
            while i + 8 <= n {
                accv = _mm256_add_ps(accv, _mm256_loadu_ps(v.as_ptr().add(i)));
                i += 8;
            }
            let mut sum = hsum(accv);
            for &x in &v[i..] {
                sum += x;
            }
            sum
        }
    }

    pub fn sumsq_shift_slice(v: &[f32], shift: f32) -> f32 {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { sumsq_shift_impl(v, shift) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn sumsq_shift_impl(v: &[f32], shift: f32) -> f32 {
        unsafe {
            let n = v.len();
            let sh = _mm256_set1_ps(shift);
            let mut accv = _mm256_setzero_ps();
            let mut i = 0;
            while i + 8 <= n {
                let d = _mm256_sub_ps(_mm256_loadu_ps(v.as_ptr().add(i)), sh);
                accv = _mm256_fmadd_ps(d, d, accv);
                i += 8;
            }
            let mut acc = hsum(accv);
            for &x in &v[i..] {
                let d = x - shift;
                acc += d * d;
            }
            acc
        }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { dot_impl(a, b) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        unsafe {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut i = 0;
            while i + 16 <= n {
                acc0 =
                    _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
                acc1 = _mm256_fmadd_ps(
                    _mm256_loadu_ps(ap.add(i + 8)),
                    _mm256_loadu_ps(bp.add(i + 8)),
                    acc1,
                );
                i += 16;
            }
            if i + 8 <= n {
                acc0 =
                    _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
                i += 8;
            }
            let mut sum = hsum(_mm256_add_ps(acc0, acc1));
            for j in i..n {
                sum += *ap.add(j) * *bp.add(j);
            }
            sum
        }
    }

    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        // SAFETY: reachable only through the detected AVX2 table.
        unsafe { axpy_impl(a, x, y) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_impl(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe {
            debug_assert_eq!(x.len(), y.len());
            let n = x.len();
            let av = _mm256_set1_ps(a);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= n {
                let v = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
                _mm256_storeu_ps(yp.add(i), v);
                i += 8;
            }
            for j in i..n {
                *yp.add(j) += a * *xp.add(j);
            }
        }
    }
}

// ---------------------------------------------------------------------
// NEON arm (aarch64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON_TABLE: KernelDispatch = KernelDispatch {
    isa: Isa::Neon,
    mk4x16: neon::mk4x16,
    mk4x8: neon::mk4x8,
    mk2x32: neon::mk2x32,
    mk_tail: neon::mk_tail,
    pack_kt: neon::pack_kt,
    gelu_slice: neon::gelu_slice,
    silu_slice: neon::silu_slice,
    silu_gate_slice: neon::silu_gate_slice,
    gelu_bwd_slice: neon::gelu_bwd_slice,
    swiglu_bwd_slice: neon::swiglu_bwd_slice,
    add_bias_slice: neon::add_bias_slice,
    row_max: neon::row_max,
    tile_max: neon::row_max,
    scale_max_slice: neon::scale_max_slice,
    exp_shift_sum: neon::exp_shift_sum,
    scale_slice: neon::scale_slice,
    sum_slice: neon::sum_slice,
    sumsq_shift_slice: neon::sumsq_shift_slice,
    dot: neon::dot,
    axpy: neon::axpy,
};

/// aarch64 NEON lane implementations — the 4-lane mirror of the AVX2 arm
/// (`vfmaq_f32` fused multiply-add, `vaddvq`/`vmaxvq` horizontal
/// reductions, `vtrn1q/vtrn2q` 4×4 transpose network). Same structure:
/// safe table-entry wrappers around `#[target_feature(enable = "neon")]`
/// bodies; NEON is baseline on aarch64 so the table is unconditionally
/// sound there.
#[cfg(target_arch = "aarch64")]
#[allow(clippy::too_many_arguments)] // GEMM kernel ABIs are what they are
mod neon {
    use super::Epilogue;
    use crate::kernels::ops;
    use std::arch::aarch64::*;

    /// Vector `exp` — same Cephes polynomial as the AVX2 arm.
    /// `vfmaq_f32(c, a, b) = c + a·b` (accumulator first).
    #[inline(always)]
    unsafe fn exp_ps(x: float32x4_t) -> float32x4_t {
        unsafe {
            let one = vdupq_n_f32(1.0);
            let x = vminq_f32(x, vdupq_n_f32(88.0));
            let x = vmaxq_f32(x, vdupq_n_f32(-88.0));
            let fx = vrndmq_f32(vfmaq_f32(
                vdupq_n_f32(0.5),
                x,
                vdupq_n_f32(std::f32::consts::LOG2_E),
            ));
            let r = vsubq_f32(
                vsubq_f32(x, vmulq_f32(fx, vdupq_n_f32(0.693359375))),
                vmulq_f32(fx, vdupq_n_f32(-2.121_944_4e-4)),
            );
            let r2 = vmulq_f32(r, r);
            let mut p = vdupq_n_f32(1.987_569_1e-4);
            p = vfmaq_f32(vdupq_n_f32(1.398_2e-3), p, r);
            p = vfmaq_f32(vdupq_n_f32(8.333_452e-3), p, r);
            p = vfmaq_f32(vdupq_n_f32(4.166_579_6e-2), p, r);
            p = vfmaq_f32(vdupq_n_f32(1.666_666_5e-1), p, r);
            p = vfmaq_f32(vdupq_n_f32(5.000_000_3e-1), p, r);
            let y = vfmaq_f32(vaddq_f32(r, one), p, r2);
            let n = vcvtq_s32_f32(fx); // truncation is exact: fx is integral
            let pow2n = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(n, vdupq_n_s32(127))));
            vmulq_f32(y, pow2n)
        }
    }

    #[inline(always)]
    unsafe fn silu_ps(x: float32x4_t) -> float32x4_t {
        unsafe {
            let one = vdupq_n_f32(1.0);
            vdivq_f32(x, vaddq_f32(one, exp_ps(vnegq_f32(x))))
        }
    }

    #[inline(always)]
    unsafe fn sigmoid_ps(x: float32x4_t) -> float32x4_t {
        unsafe {
            let one = vdupq_n_f32(1.0);
            vdivq_f32(one, vaddq_f32(one, exp_ps(vnegq_f32(x))))
        }
    }

    #[inline(always)]
    unsafe fn tanh_ps(u: float32x4_t) -> float32x4_t {
        unsafe {
            let one = vdupq_n_f32(1.0);
            let e = exp_ps(vaddq_f32(u, u));
            vdivq_f32(vsubq_f32(e, one), vaddq_f32(e, one))
        }
    }

    const GELU_C: f32 = 0.797_884_6;
    const GELU_A: f32 = 0.044715;

    #[inline(always)]
    unsafe fn gelu_u_ps(x: float32x4_t) -> float32x4_t {
        unsafe {
            let x2 = vmulq_f32(x, x);
            let inner = vfmaq_f32(x, vmulq_f32(vdupq_n_f32(GELU_A), x2), x);
            vmulq_f32(vdupq_n_f32(GELU_C), inner)
        }
    }

    #[inline(always)]
    unsafe fn gelu_ps(x: float32x4_t) -> float32x4_t {
        unsafe {
            let one = vdupq_n_f32(1.0);
            let u = gelu_u_ps(x);
            let e = exp_ps(vaddq_f32(u, u));
            vmulq_f32(x, vdivq_f32(e, vaddq_f32(e, one)))
        }
    }

    #[inline(always)]
    unsafe fn gelu_grad_ps(x: float32x4_t) -> float32x4_t {
        unsafe {
            let one = vdupq_n_f32(1.0);
            let half = vdupq_n_f32(0.5);
            let t = tanh_ps(gelu_u_ps(x));
            let x2 = vmulq_f32(x, x);
            let du = vmulq_f32(
                vdupq_n_f32(GELU_C),
                vfmaq_f32(one, vdupq_n_f32(3.0 * GELU_A), x2),
            );
            let sech2 = vsubq_f32(one, vmulq_f32(t, t));
            let lhs = vmulq_f32(half, vaddq_f32(one, t));
            vfmaq_f32(lhs, vmulq_f32(vmulq_f32(half, x), sech2), du)
        }
    }

    /// Apply the epilogue to one 4-wide writeback vector at `(i, j..j+4)`.
    #[inline(always)]
    unsafe fn apply_ep(v: float32x4_t, i: usize, j: usize, ep: &Epilogue<'_>) -> float32x4_t {
        unsafe {
            match *ep {
                Epilogue::None => v,
                Epilogue::Bias(b) => vaddq_f32(v, vld1q_f32(b.as_ptr().add(j))),
                Epilogue::BiasGelu(b) => gelu_ps(vaddq_f32(v, vld1q_f32(b.as_ptr().add(j)))),
                Epilogue::BiasSilu(b) => silu_ps(vaddq_f32(v, vld1q_f32(b.as_ptr().add(j)))),
                Epilogue::Gelu => gelu_ps(v),
                Epilogue::Silu => silu_ps(v),
                Epilogue::SiluGate { g, ldg } => {
                    vmulq_f32(silu_ps(v), vld1q_f32(g.as_ptr().add(i * ldg + j)))
                }
            }
        }
    }

    // ---- micro-kernel register tiles --------------------------------

    pub fn mk4x16(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { mk4x16_tf(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn mk4x16_tf(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe { mk_rxw::<4, 4>(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    pub fn mk4x8(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: as above.
        unsafe { mk4x8_tf(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn mk4x8_tf(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe { mk_rxw::<4, 2>(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    pub fn mk2x32(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: as above.
        unsafe { mk2x32_tf(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn mk2x32_tf(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe { mk_rxw::<2, 8>(ap, lda, bp, ldb, k, c, ldc, ep) }
    }

    /// R rows × (W·4) columns register tile (R·W of the 32 q-registers as
    /// accumulators). Generic helper inlined into the concrete `_tf`
    /// entries (see the AVX2 twin for the pattern rationale).
    #[inline(always)]
    unsafe fn mk_rxw<const R: usize, const W: usize>(
        ap: &[f32],
        lda: usize,
        bp: &[f32],
        ldb: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe {
            debug_assert!(k == 0 || ap.len() >= (k - 1) * lda + R);
            debug_assert!(k == 0 || bp.len() >= (k - 1) * ldb + W * 4);
            debug_assert!(c.len() >= (R - 1) * ldc + W * 4);
            let mut acc = [[vdupq_n_f32(0.0); W]; R];
            let a_ptr = ap.as_ptr();
            let b_ptr = bp.as_ptr();
            for kk in 0..k {
                let brow = b_ptr.add(kk * ldb);
                let mut bv = [vdupq_n_f32(0.0); W];
                for (w, bvw) in bv.iter_mut().enumerate() {
                    *bvw = vld1q_f32(brow.add(w * 4));
                }
                let arow = a_ptr.add(kk * lda);
                for (i, acci) in acc.iter_mut().enumerate() {
                    let av = vdupq_n_f32(*arow.add(i));
                    for (w, bvw) in bv.iter().enumerate() {
                        acci[w] = vfmaq_f32(acci[w], av, *bvw);
                    }
                }
            }
            for (i, acci) in acc.iter().enumerate() {
                let crow = c.as_mut_ptr().add(i * ldc);
                for (w, accw) in acci.iter().enumerate() {
                    let v = vaddq_f32(vld1q_f32(crow.add(w * 4)), *accw);
                    vst1q_f32(crow.add(w * 4), apply_ep(v, i, w * 4, &ep));
                }
            }
        }
    }

    /// Remainder tile: `rows ≤ 4`, `cols ≤ 32`; 4-wide chunks + scalar
    /// remainder lanes.
    pub fn mk_tail(
        ap: &[f32],
        lda: usize,
        rows: usize,
        bp: &[f32],
        ldb: usize,
        cols: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { mk_tail_impl(ap, lda, rows, bp, ldb, cols, k, c, ldc, ep) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn mk_tail_impl(
        ap: &[f32],
        lda: usize,
        rows: usize,
        bp: &[f32],
        ldb: usize,
        cols: usize,
        k: usize,
        c: &mut [f32],
        ldc: usize,
        ep: Epilogue<'_>,
    ) {
        unsafe {
            debug_assert!(rows <= 4 && cols <= 32);
            let chunks = cols / 4;
            let rem = cols - chunks * 4;
            let mut acc = [[vdupq_n_f32(0.0); 8]; 4];
            let mut racc = [[0.0f32; 4]; 4];
            let a_ptr = ap.as_ptr();
            let b_ptr = bp.as_ptr();
            for kk in 0..k {
                let brow = b_ptr.add(kk * ldb);
                for i in 0..rows {
                    let a = *a_ptr.add(kk * lda + i);
                    let av = vdupq_n_f32(a);
                    for ch in 0..chunks {
                        acc[i][ch] = vfmaq_f32(acc[i][ch], av, vld1q_f32(brow.add(ch * 4)));
                    }
                    for j in 0..rem {
                        racc[i][j] += a * *brow.add(chunks * 4 + j);
                    }
                }
            }
            for i in 0..rows {
                let crow = c.as_mut_ptr().add(i * ldc);
                for ch in 0..chunks {
                    let v = vaddq_f32(vld1q_f32(crow.add(ch * 4)), acc[i][ch]);
                    vst1q_f32(crow.add(ch * 4), apply_ep(v, i, ch * 4, &ep));
                }
                for j in 0..rem {
                    let col = chunks * 4 + j;
                    let v = *crow.add(col) + racc[i][j];
                    *crow.add(col) = ep.apply(v, i, col);
                }
            }
        }
    }

    // ---- pack -------------------------------------------------------

    /// 4×4 in-register transpose via the trn1/trn2 f32→f64 network
    /// (validated by numpy emulation in `python/tests/simd_check.py`).
    #[inline(always)]
    unsafe fn transpose4x4(src: *const f32, src_stride: usize, dst: *mut f32, dst_stride: usize) {
        unsafe {
            let r0 = vld1q_f32(src);
            let r1 = vld1q_f32(src.add(src_stride));
            let r2 = vld1q_f32(src.add(2 * src_stride));
            let r3 = vld1q_f32(src.add(3 * src_stride));
            let t0 = vtrn1q_f32(r0, r1);
            let t1 = vtrn2q_f32(r0, r1);
            let t2 = vtrn1q_f32(r2, r3);
            let t3 = vtrn2q_f32(r2, r3);
            let o0 = vreinterpretq_f32_f64(vtrn1q_f64(
                vreinterpretq_f64_f32(t0),
                vreinterpretq_f64_f32(t2),
            ));
            let o1 = vreinterpretq_f32_f64(vtrn1q_f64(
                vreinterpretq_f64_f32(t1),
                vreinterpretq_f64_f32(t3),
            ));
            let o2 = vreinterpretq_f32_f64(vtrn2q_f64(
                vreinterpretq_f64_f32(t0),
                vreinterpretq_f64_f32(t2),
            ));
            let o3 = vreinterpretq_f32_f64(vtrn2q_f64(
                vreinterpretq_f64_f32(t1),
                vreinterpretq_f64_f32(t3),
            ));
            vst1q_f32(dst, o0);
            vst1q_f32(dst.add(dst_stride), o1);
            vst1q_f32(dst.add(2 * dst_stride), o2);
            vst1q_f32(dst.add(3 * dst_stride), o3);
        }
    }

    pub fn pack_kt(src: &[f32], rows: usize, k: usize, out: &mut [f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { pack_kt_impl(src, rows, k, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn pack_kt_impl(src: &[f32], rows: usize, k: usize, out: &mut [f32]) {
        unsafe {
            debug_assert!(src.len() >= rows * k);
            debug_assert!(out.len() >= rows * k);
            let sp = src.as_ptr();
            let op = out.as_mut_ptr();
            let mut r0 = 0;
            while r0 + 4 <= rows {
                let mut k0 = 0;
                while k0 + 4 <= k {
                    transpose4x4(sp.add(r0 * k + k0), k, op.add(k0 * rows + r0), rows);
                    k0 += 4;
                }
                for kk in k0..k {
                    for i in 0..4 {
                        *op.add(kk * rows + r0 + i) = *sp.add((r0 + i) * k + kk);
                    }
                }
                r0 += 4;
            }
            for r in r0..rows {
                for kk in 0..k {
                    *op.add(kk * rows + r) = *sp.add(r * k + kk);
                }
            }
        }
    }

    // ---- element-wise / reduction lanes -----------------------------

    pub fn gelu_slice(v: &mut [f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { gelu_slice_impl(v) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn gelu_slice_impl(v: &mut [f32]) {
        unsafe {
            let n = v.len();
            let p = v.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                vst1q_f32(p.add(i), gelu_ps(vld1q_f32(p.add(i))));
                i += 4;
            }
            for j in i..n {
                *p.add(j) = ops::gelu(*p.add(j));
            }
        }
    }

    pub fn silu_slice(v: &mut [f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { silu_slice_impl(v) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn silu_slice_impl(v: &mut [f32]) {
        unsafe {
            let n = v.len();
            let p = v.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                vst1q_f32(p.add(i), silu_ps(vld1q_f32(p.add(i))));
                i += 4;
            }
            for j in i..n {
                *p.add(j) = ops::silu(*p.add(j));
            }
        }
    }

    pub fn silu_gate_slice(a: &mut [f32], g: &[f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { silu_gate_impl(a, g) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn silu_gate_impl(a: &mut [f32], g: &[f32]) {
        unsafe {
            debug_assert_eq!(a.len(), g.len());
            let n = a.len();
            let ap = a.as_mut_ptr();
            let gp = g.as_ptr();
            let mut i = 0;
            while i + 4 <= n {
                let x = vld1q_f32(ap.add(i));
                vst1q_f32(ap.add(i), vmulq_f32(silu_ps(x), vld1q_f32(gp.add(i))));
                i += 4;
            }
            for j in i..n {
                *ap.add(j) = ops::silu(*ap.add(j)) * *gp.add(j);
            }
        }
    }

    pub fn gelu_bwd_slice(h: &[f32], dh: &mut [f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { gelu_bwd_impl(h, dh) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn gelu_bwd_impl(h: &[f32], dh: &mut [f32]) {
        unsafe {
            debug_assert_eq!(h.len(), dh.len());
            let n = h.len();
            let mut i = 0;
            while i + 4 <= n {
                let x = vld1q_f32(h.as_ptr().add(i));
                let d = vld1q_f32(dh.as_ptr().add(i));
                vst1q_f32(dh.as_mut_ptr().add(i), vmulq_f32(d, gelu_grad_ps(x)));
                i += 4;
            }
            for j in i..n {
                dh[j] *= ops::gelu_grad(h[j]);
            }
        }
    }

    pub fn swiglu_bwd_slice(
        h1: &[f32],
        h2: &[f32],
        d_act: &[f32],
        dh1: &mut [f32],
        dh2: &mut [f32],
    ) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { swiglu_bwd_impl(h1, h2, d_act, dh1, dh2) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn swiglu_bwd_impl(
        h1: &[f32],
        h2: &[f32],
        d_act: &[f32],
        dh1: &mut [f32],
        dh2: &mut [f32],
    ) {
        unsafe {
            let n = h1.len();
            debug_assert!(h2.len() == n && d_act.len() == n && dh1.len() == n && dh2.len() == n);
            let one = vdupq_n_f32(1.0);
            let mut i = 0;
            while i + 4 <= n {
                let x = vld1q_f32(h1.as_ptr().add(i));
                let g = vld1q_f32(h2.as_ptr().add(i));
                let d = vld1q_f32(d_act.as_ptr().add(i));
                let s = sigmoid_ps(x);
                let sil = vmulq_f32(x, s);
                let grad = vmulq_f32(s, vfmaq_f32(one, x, vsubq_f32(one, s)));
                vst1q_f32(dh1.as_mut_ptr().add(i), vmulq_f32(vmulq_f32(d, g), grad));
                vst1q_f32(dh2.as_mut_ptr().add(i), vmulq_f32(d, sil));
                i += 4;
            }
            for j in i..n {
                dh1[j] = d_act[j] * h2[j] * ops::silu_grad(h1[j]);
                dh2[j] = d_act[j] * ops::silu(h1[j]);
            }
        }
    }

    pub fn add_bias_slice(y: &mut [f32], b: &[f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { add_bias_impl(y, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn add_bias_impl(y: &mut [f32], b: &[f32]) {
        unsafe {
            debug_assert_eq!(y.len(), b.len());
            let n = y.len();
            let mut i = 0;
            while i + 4 <= n {
                let v = vaddq_f32(vld1q_f32(y.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
                vst1q_f32(y.as_mut_ptr().add(i), v);
                i += 4;
            }
            for j in i..n {
                y[j] += b[j];
            }
        }
    }

    pub fn row_max(v: &[f32]) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { row_max_impl(v) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn row_max_impl(v: &[f32]) -> f32 {
        unsafe {
            let n = v.len();
            let mut best = f32::NEG_INFINITY;
            let mut i = 0;
            if n >= 4 {
                let mut m = vld1q_f32(v.as_ptr());
                i = 4;
                while i + 4 <= n {
                    m = vmaxq_f32(m, vld1q_f32(v.as_ptr().add(i)));
                    i += 4;
                }
                best = vmaxvq_f32(m);
            }
            for &x in &v[i..] {
                best = best.max(x);
            }
            best
        }
    }

    pub fn scale_max_slice(v: &mut [f32], scale: f32) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { scale_max_impl(v, scale) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn scale_max_impl(v: &mut [f32], scale: f32) -> f32 {
        unsafe {
            let n = v.len();
            let sv = vdupq_n_f32(scale);
            let p = v.as_mut_ptr();
            let mut best = f32::NEG_INFINITY;
            let mut i = 0;
            if n >= 4 {
                let first = vmulq_f32(vld1q_f32(p), sv);
                vst1q_f32(p, first);
                let mut m = first;
                i = 4;
                while i + 4 <= n {
                    let x = vmulq_f32(vld1q_f32(p.add(i)), sv);
                    vst1q_f32(p.add(i), x);
                    m = vmaxq_f32(m, x);
                    i += 4;
                }
                best = vmaxvq_f32(m);
            }
            for j in i..n {
                let x = *p.add(j) * scale;
                *p.add(j) = x;
                best = best.max(x);
            }
            best
        }
    }

    pub fn exp_shift_sum(v: &mut [f32], shift: f32) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { exp_shift_sum_impl(v, shift) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn exp_shift_sum_impl(v: &mut [f32], shift: f32) -> f32 {
        unsafe {
            let n = v.len();
            let sh = vdupq_n_f32(shift);
            let p = v.as_mut_ptr();
            let mut accv = vdupq_n_f32(0.0);
            let mut i = 0;
            while i + 4 <= n {
                let e = exp_ps(vsubq_f32(vld1q_f32(p.add(i)), sh));
                vst1q_f32(p.add(i), e);
                accv = vaddq_f32(accv, e);
                i += 4;
            }
            let mut sum = vaddvq_f32(accv);
            for j in i..n {
                let e = (*p.add(j) - shift).exp();
                *p.add(j) = e;
                sum += e;
            }
            sum
        }
    }

    pub fn scale_slice(v: &mut [f32], scale: f32) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { scale_slice_impl(v, scale) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn scale_slice_impl(v: &mut [f32], scale: f32) {
        unsafe {
            let n = v.len();
            let sv = vdupq_n_f32(scale);
            let p = v.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                vst1q_f32(p.add(i), vmulq_f32(vld1q_f32(p.add(i)), sv));
                i += 4;
            }
            for j in i..n {
                *p.add(j) *= scale;
            }
        }
    }

    pub fn sum_slice(v: &[f32]) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { sum_slice_impl(v) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn sum_slice_impl(v: &[f32]) -> f32 {
        unsafe {
            let n = v.len();
            let mut accv = vdupq_n_f32(0.0);
            let mut i = 0;
            while i + 4 <= n {
                accv = vaddq_f32(accv, vld1q_f32(v.as_ptr().add(i)));
                i += 4;
            }
            let mut sum = vaddvq_f32(accv);
            for &x in &v[i..] {
                sum += x;
            }
            sum
        }
    }

    pub fn sumsq_shift_slice(v: &[f32], shift: f32) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { sumsq_shift_impl(v, shift) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn sumsq_shift_impl(v: &[f32], shift: f32) -> f32 {
        unsafe {
            let n = v.len();
            let sh = vdupq_n_f32(shift);
            let mut accv = vdupq_n_f32(0.0);
            let mut i = 0;
            while i + 4 <= n {
                let d = vsubq_f32(vld1q_f32(v.as_ptr().add(i)), sh);
                accv = vfmaq_f32(accv, d, d);
                i += 4;
            }
            let mut acc = vaddvq_f32(accv);
            for &x in &v[i..] {
                let d = x - shift;
                acc += d * d;
            }
            acc
        }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { dot_impl(a, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        unsafe {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut i = 0;
            while i + 8 <= n {
                acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
                acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
                i += 8;
            }
            if i + 4 <= n {
                acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
                i += 4;
            }
            let mut sum = vaddvq_f32(vaddq_f32(acc0, acc1));
            for j in i..n {
                sum += *ap.add(j) * *bp.add(j);
            }
            sum
        }
    }

    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { axpy_impl(a, x, y) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_impl(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe {
            debug_assert_eq!(x.len(), y.len());
            let n = x.len();
            let av = vdupq_n_f32(a);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                let v = vfmaq_f32(vld1q_f32(yp.add(i)), av, vld1q_f32(xp.add(i)));
                vst1q_f32(yp.add(i), v);
                i += 4;
            }
            for j in i..n {
                *yp.add(j) += a * *xp.add(j);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;

    /// The arms testable on this host: scalar always; the native table too
    /// when it differs (i.e. on an AVX2 or NEON machine). On a scalar-only
    /// host SIMD-vs-scalar parity degenerates to bitwise self-agreement,
    /// and the CI `BLAST_SIMD=off` lane covers the scalar arm everywhere.
    fn tables() -> Vec<&'static KernelDispatch> {
        let n = native();
        if std::ptr::eq(n, scalar()) {
            vec![scalar()]
        } else {
            vec![scalar(), n]
        }
    }

    /// Mixed abs+rel gate for exp-based lanes (see module doc: the vector
    /// exp is ~2 ulp off `f32::exp`).
    fn close(got: f32, want: f32, tol: f32) -> bool {
        (got - want).abs() <= tol + tol * want.abs()
    }

    #[test]
    fn resolution_rules_and_names() {
        assert!(std::ptr::eq(resolve(true, false), scalar()));
        assert!(std::ptr::eq(resolve(false, true), scalar()));
        assert!(std::ptr::eq(resolve(true, true), scalar()));
        assert!(std::ptr::eq(resolve(false, false), native()));
        for off in ["off", "0", "false", "no", "scalar", "OFF", "False", "SCALAR"] {
            assert!(env_disables(Some(off)), "{off}");
        }
        assert!(!env_disables(None));
        assert!(!env_disables(Some("on")));
        assert!(!env_disables(Some("1")));
        assert_eq!(Isa::Avx2Fma.name(), "avx2+fma");
        assert_eq!(Isa::Neon.name(), "neon");
        assert_eq!(Isa::Scalar.name(), "scalar");
        // the dispatch entry point returns one of the two tables
        let d = dispatch();
        assert!(std::ptr::eq(d, scalar()) || std::ptr::eq(d, native()));
    }

    #[test]
    fn elementwise_lane_parity() {
        for d in tables() {
            prop::check_default("simd-elementwise-parity", |rng| {
                let n = prop::usize_in(rng, 0, 67); // ragged: tails of every width
                let x = prop::normal_vec(rng, n);
                let scalar_is = d.isa == Isa::Scalar;
                let tol = if scalar_is { 0.0 } else { 1e-6 };

                let mut v = x.clone();
                (d.gelu_slice)(&mut v);
                for i in 0..n {
                    let want = ops::gelu(x[i]);
                    prop_assert!(close(v[i], want, tol), "gelu[{i}] {} vs {want}", v[i]);
                }
                let mut v = x.clone();
                (d.silu_slice)(&mut v);
                for i in 0..n {
                    let want = ops::silu(x[i]);
                    prop_assert!(close(v[i], want, tol), "silu[{i}] {} vs {want}", v[i]);
                }
                let g = prop::normal_vec(rng, n);
                let mut v = x.clone();
                (d.silu_gate_slice)(&mut v, &g);
                for i in 0..n {
                    let want = ops::silu(x[i]) * g[i];
                    prop_assert!(close(v[i], want, tol), "silu_gate[{i}]");
                }
                let mut dh = g.clone();
                (d.gelu_bwd_slice)(&x, &mut dh);
                for i in 0..n {
                    let want = g[i] * ops::gelu_grad(x[i]);
                    prop_assert!(close(dh[i], want, 2.0 * tol), "gelu_bwd[{i}]");
                }
                let h2 = prop::normal_vec(rng, n);
                let da = prop::normal_vec(rng, n);
                let mut dh1 = vec![0.0f32; n];
                let mut dh2 = vec![0.0f32; n];
                (d.swiglu_bwd_slice)(&x, &h2, &da, &mut dh1, &mut dh2);
                for i in 0..n {
                    let w1 = da[i] * h2[i] * ops::silu_grad(x[i]);
                    let w2 = da[i] * ops::silu(x[i]);
                    prop_assert!(close(dh1[i], w1, 2.0 * tol), "swiglu dh1[{i}]");
                    prop_assert!(close(dh2[i], w2, 2.0 * tol), "swiglu dh2[{i}]");
                }
                let mut y = x.clone();
                (d.add_bias_slice)(&mut y, &g);
                for i in 0..n {
                    prop_assert!(y[i] == x[i] + g[i], "add_bias[{i}]");
                }
                Ok(())
            });
        }
    }

    #[test]
    fn reduction_lane_parity() {
        for d in tables() {
            prop::check_default("simd-reduction-parity", |rng| {
                let n = prop::usize_in(rng, 0, 67);
                let x = prop::normal_vec(rng, n);
                // max is order-invariant: exact across arms
                let want_max = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                prop_assert!((d.row_max)(&x) == want_max, "row_max");
                prop_assert!((d.tile_max)(&x) == want_max, "tile_max");
                let mut v = x.clone();
                let m = (d.scale_max_slice)(&mut v, 0.37);
                let mut want_m = f32::NEG_INFINITY;
                for i in 0..n {
                    let s = x[i] * 0.37;
                    prop_assert!(v[i] == s, "scale_max elem [{i}]");
                    want_m = want_m.max(s);
                }
                prop_assert!(m == want_m, "scale_max max {m} vs {want_m}");
                let mut v = x.clone();
                (d.scale_slice)(&mut v, -1.25);
                for i in 0..n {
                    prop_assert!(v[i] == x[i] * -1.25, "scale[{i}]");
                }
                // sums: gate against an f64 reference (association differs
                // across arms by design)
                let sum64: f64 = x.iter().map(|&v| v as f64).sum();
                let got = (d.sum_slice)(&x);
                prop_assert!(
                    (got as f64 - sum64).abs() < 1e-4,
                    "sum {got} vs {sum64}"
                );
                let shift = 0.3f32;
                let ssq64: f64 = x.iter().map(|&v| (v as f64 - shift as f64).powi(2)).sum();
                let got = (d.sumsq_shift_slice)(&x, shift);
                prop_assert!(
                    (got as f64 - ssq64).abs() < 1e-3,
                    "sumsq {got} vs {ssq64}"
                );
                // exp_shift_sum: elementwise + sum
                let mut v = x.clone();
                let shift = (d.row_max)(&x);
                let s = (d.exp_shift_sum)(&mut v, shift);
                let mut want_s = 0.0f64;
                for i in 0..n {
                    let want = ((x[i] - shift) as f64).exp();
                    want_s += want;
                    prop_assert!(
                        (v[i] as f64 - want).abs() < 2e-6,
                        "exp[{i}] {} vs {want}",
                        v[i]
                    );
                }
                prop_assert!((s as f64 - want_s).abs() < 1e-4 * want_s.max(1.0), "exp sum");
                // dot / axpy
                let y = prop::normal_vec(rng, n);
                let dot64: f64 = x.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
                let got = (d.dot)(&x, &y);
                prop_assert!(
                    (got as f64 - dot64).abs() < 1e-4 * (1.0 + dot64.abs()),
                    "dot {got} vs {dot64}"
                );
                let mut acc = y.clone();
                (d.axpy)(0.73, &x, &mut acc);
                for i in 0..n {
                    let want = y[i] as f64 + 0.73f64 * x[i] as f64;
                    prop_assert!((acc[i] as f64 - want).abs() < 1e-6, "axpy[{i}]");
                }
                Ok(())
            });
        }
    }

    #[test]
    fn empty_slices_are_safe() {
        for d in tables() {
            assert_eq!((d.row_max)(&[]), f32::NEG_INFINITY);
            assert_eq!((d.tile_max)(&[]), f32::NEG_INFINITY);
            assert_eq!((d.scale_max_slice)(&mut [], 2.0), f32::NEG_INFINITY);
            assert_eq!((d.sum_slice)(&[]), 0.0);
            assert_eq!((d.sumsq_shift_slice)(&[], 1.0), 0.0);
            assert_eq!((d.dot)(&[], &[]), 0.0);
            assert_eq!((d.exp_shift_sum)(&mut [], 0.0), 0.0);
            (d.gelu_slice)(&mut []);
            (d.axpy)(1.0, &[], &mut []);
        }
    }

    #[test]
    fn pack_kt_lane_is_exact_transpose() {
        for d in tables() {
            // crosses the 8x8 / 4x4 blocked bodies and every remainder
            for rows in [1usize, 3, 4, 5, 7, 8, 9, 12, 16, 17] {
                for k in [1usize, 2, 4, 7, 8, 9, 16, 19] {
                    let src: Vec<f32> = (0..rows * k).map(|i| i as f32 * 0.5 - 3.0).collect();
                    let mut out = vec![-1.0f32; rows * k];
                    (d.pack_kt)(&src, rows, k, &mut out);
                    for r in 0..rows {
                        for kk in 0..k {
                            assert_eq!(
                                out[kk * rows + r],
                                src[r * k + kk],
                                "isa={} rows={rows} k={k} ({r},{kk})",
                                d.isa.name()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Exp accuracy across the useful range: the vector exp must track
    /// `f64::exp` to ~1e-6 relative (scalar arm trivially does).
    #[test]
    fn exp_lane_accuracy_over_range() {
        for d in tables() {
            let mut v: Vec<f32> = (-870..=80).map(|i| i as f32 * 0.1).collect();
            let orig = v.clone();
            let _ = (d.exp_shift_sum)(&mut v, 0.0);
            for (i, &x) in orig.iter().enumerate() {
                let want = (x as f64).exp();
                let got = v[i] as f64;
                assert!(
                    (got - want).abs() <= 2e-6 * want.max(1e-30),
                    "isa={} exp({x}) = {got} vs {want}",
                    d.isa.name()
                );
            }
        }
    }

    /// The micro-kernel register tiles against a sequential f32 oracle —
    /// the scalar arm must match it bitwise (identical association order),
    /// the SIMD arms within FMA-rounding tolerance — for every epilogue
    /// variant, on ~50+ random shapes per slot.
    #[test]
    fn mk_lane_parity_with_epilogues() {
        for d in tables() {
            prop::check_default("simd-mk-parity", |rng| {
                // slot: (rows, cols, fn)
                let slot = prop::usize_in(rng, 0, 3);
                let (rows, cols) = [(4, 16), (4, 8), (2, 32), (0, 0)][slot];
                let (rows, cols) = if slot == 3 {
                    (prop::usize_in(rng, 1, 4), prop::usize_in(rng, 1, 32))
                } else {
                    (rows, cols)
                };
                let k = prop::usize_in(rng, 0, 24);
                let lda = rows + prop::usize_in(rng, 0, 3);
                let ldb = cols + prop::usize_in(rng, 0, 5);
                let ldc = cols + prop::usize_in(rng, 0, 5);
                let ap = prop::normal_vec(rng, k.max(1) * lda);
                let bp = prop::normal_vec(rng, k.max(1) * ldb);
                let c0 = prop::normal_vec(rng, (rows - 1) * ldc + cols);
                let bias = prop::normal_vec(rng, cols);
                let ldg = cols + 2;
                let gate = prop::normal_vec(rng, rows * ldg);
                let eps: [Epilogue<'_>; 7] = [
                    Epilogue::None,
                    Epilogue::Bias(&bias),
                    Epilogue::BiasGelu(&bias),
                    Epilogue::BiasSilu(&bias),
                    Epilogue::Gelu,
                    Epilogue::Silu,
                    Epilogue::SiluGate { g: &gate, ldg },
                ];
                for (ei, ep) in eps.iter().enumerate() {
                    let mut c = c0.clone();
                    match slot {
                        0 => (d.mk4x16)(&ap, lda, &bp, ldb, k, &mut c, ldc, *ep),
                        1 => (d.mk4x8)(&ap, lda, &bp, ldb, k, &mut c, ldc, *ep),
                        2 => (d.mk2x32)(&ap, lda, &bp, ldb, k, &mut c, ldc, *ep),
                        _ => (d.mk_tail)(&ap, lda, rows, &bp, ldb, cols, k, &mut c, ldc, *ep),
                    }
                    for i in 0..rows {
                        for j in 0..cols {
                            // sequential-order f32 oracle + scalar epilogue
                            let mut s = c0[i * ldc + j];
                            for kk in 0..k {
                                s += ap[kk * lda + i] * bp[kk * ldb + j];
                            }
                            let want = ep.apply(s, i, j);
                            let got = c[i * ldc + j];
                            let ok = if d.isa == Isa::Scalar {
                                got == want || (got.is_nan() && want.is_nan())
                            } else {
                                // FMA keeps one rounding per step the scalar
                                // oracle doesn't; bound the drift over k steps
                                (got - want).abs() <= 1e-4 + 1e-5 * want.abs()
                            };
                            prop_assert!(
                                ok,
                                "isa={} slot={slot} ep={ei} ({i},{j}): {got} vs {want} \
                                 (rows={rows} cols={cols} k={k})",
                                d.isa.name()
                            );
                        }
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn epilogue_shift_rebases_operands() {
        let bias: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let ldg = 16;
        let gate: Vec<f32> = (0..8 * ldg).map(|i| i as f32 * 0.25).collect();
        let ep = Epilogue::Bias(&bias);
        assert_eq!(ep.shift(2, 5).apply(1.0, 0, 0), 1.0 + bias[5]);
        let ep = Epilogue::SiluGate { g: &gate, ldg };
        let direct = ep.apply(0.7, 3, 4);
        let shifted = ep.shift(1, 2).apply(0.7, 2, 2);
        assert_eq!(direct, shifted);
        assert!(matches!(Epilogue::Gelu.shift(5, 9), Epilogue::Gelu));
    }

    #[test]
    fn epilogue_zero_preserving_classification() {
        let b = [1.0f32; 4];
        let g = [1.0f32; 8];
        assert!(Epilogue::None.zero_preserving());
        assert!(Epilogue::Gelu.zero_preserving());
        assert!(Epilogue::Silu.zero_preserving());
        assert!(Epilogue::SiluGate { g: &g, ldg: 4 }.zero_preserving());
        assert!(!Epilogue::Bias(&b).zero_preserving());
        assert!(!Epilogue::BiasGelu(&b).zero_preserving());
        assert!(!Epilogue::BiasSilu(&b).zero_preserving());
        // the zero-preserving ones really do map 0 -> 0
        for ep in [
            Epilogue::None,
            Epilogue::Gelu,
            Epilogue::Silu,
            Epilogue::SiluGate { g: &g, ldg: 4 },
        ] {
            assert_eq!(ep.apply(0.0, 1, 2), 0.0);
        }
    }

    #[test]
    fn apply_epilogue_region_matches_scalar_apply() {
        for d in tables() {
            let (rows, cols, ldc) = (3usize, 11usize, 13usize);
            let base: Vec<f32> = (0..rows * ldc).map(|i| (i as f32 * 0.37).sin()).collect();
            let bias: Vec<f32> = (0..cols).map(|i| i as f32 * 0.1 - 0.5).collect();
            let ldg = cols + 3;
            let gate: Vec<f32> = (0..rows * ldg).map(|i| (i as f32 * 0.21).cos()).collect();
            let eps: [Epilogue<'_>; 7] = [
                Epilogue::None,
                Epilogue::Bias(&bias),
                Epilogue::BiasGelu(&bias),
                Epilogue::BiasSilu(&bias),
                Epilogue::Gelu,
                Epilogue::Silu,
                Epilogue::SiluGate { g: &gate, ldg },
            ];
            for ep in eps {
                let mut c = base.clone();
                d.apply_epilogue_region(&mut c, ldc, rows, cols, ep);
                for i in 0..rows {
                    for j in 0..cols {
                        let want = ep.apply(base[i * ldc + j], i, j);
                        let tol = if d.isa == Isa::Scalar { 0.0 } else { 1e-6 };
                        assert!(
                            close(c[i * ldc + j], want, tol),
                            "isa={} ({i},{j})",
                            d.isa.name()
                        );
                    }
                    // outside cols untouched
                    for j in cols..ldc.min(cols + 2) {
                        assert_eq!(c[i * ldc + j], base[i * ldc + j]);
                    }
                }
            }
        }
    }
}
