//! Native CPU kernel stack — the measured reproduction substrate.
//!
//! The paper's wall-clock claims (Figs. 4–6) are *kernel* claims: a BCSC
//! block-sparse matmul that beats the best dense baseline once sparsity
//! crosses ~50%, a fused sparse MLP, and the end-to-end inference speedup
//! they produce. On this testbed the compute device is the CPU, so the
//! whole kernel stack is implemented here and benchmarked directly:
//!
//! * [`gemm`] — cache-blocked, multithreaded dense GEMM: the
//!   cuBLAS/CUTLASS stand-in and the denominator of every speedup.
//! * [`bspmm`] — the paper's kernel: stream surviving BCSC blocks, run a
//!   dense micro-GEMM per block, fuse the epilogue.
//! * [`csr_spmm`] — the unstructured-sparsity baseline (cuSPARSE role).
//! * [`ops`] — softmax/norms/activations/rope for the native engine.
//! * [`attention`] — dense causal attention + KV-cache decode.

pub mod attention;
pub mod bspmm;
pub mod csr_spmm;
pub mod gemm;
pub mod ops;

pub use bspmm::{bspmm, fused_mlp_sparse, FusedMlpWeights};
pub use csr_spmm::csr_spmm;
pub use gemm::{gemm, gemm_into};
