//! Native CPU kernel stack — the measured reproduction substrate.
//!
//! The paper's wall-clock claims (Figs. 4–6) are *kernel* claims: a BCSC
//! block-sparse matmul that beats the best dense baseline once sparsity
//! crosses ~50%, a fused sparse MLP, and the end-to-end inference speedup
//! they produce. On this testbed the compute device is the CPU, so the
//! whole kernel stack is implemented here and benchmarked directly.
//!
//! Since PR 1 every contraction funnels into one packed register-blocked
//! micro-kernel (BLIS/COSMA architecture):
//!
//! * [`microkernel`] — the shared inner kernel: 4×NR register-tiled
//!   `C += Aᵖ·Bᵖ` over k-major packed panels, unrolled for NR ∈ {8, 16, 32}
//!   (the BCSC block widths) with a generic remainder path.
//! * [`pack`] — operand packing: k-major A/X row-tile panels (packed once,
//!   streamed by every block / B panel) and [`pack::PackedB`], the NR-wide
//!   zero-padded B panels that weight matrices are packed into once at
//!   model load.
//! * [`gemm`] — cache-blocked, multithreaded dense GEMM on the packed
//!   engine: the cuBLAS/CUTLASS stand-in and the denominator of every
//!   speedup. The seed scalar kernel survives as `gemm_into_ref`, the
//!   baseline of the `BENCH_kernels.json` A/B harness.
//! * [`bspmm`] — the paper's kernel: stream surviving BCSC blocks through
//!   the micro-kernel against the packed X tile, schedule block columns
//!   cost-aware (weighted by surviving blocks), fuse the MLP epilogues on
//!   thread-local scratch tiles.
//! * [`csr_spmm`] — the unstructured-sparsity baseline (cuSPARSE role).
//! * [`ops`] — softmax/norms/activations/rope for the native engine.
//! * [`attention`] — dense attention as position-blocked kernels: tiled
//!   streaming-softmax prefill (two packed micro-GEMMs per q-tile ×
//!   k-tile pair) and paged-KV decode with unrolled dot lanes; the seed
//!   scalar kernels survive as `*_ref` oracles for the
//!   `BENCH_attention.json` A/B harness.

//! * [`simd`] — the explicit-SIMD backend: a per-process
//!   [`simd::KernelDispatch`] table (AVX2+FMA / NEON / scalar) supplying
//!   the micro-kernel register tiles, pack transposes and hot element-wise
//!   lanes, plus the fused [`simd::Epilogue`] applied during micro-kernel
//!   write-back. `BLAST_SIMD=off` (or `--no-simd`) forces the scalar arm.

pub mod attention;
pub mod bspmm;
pub mod csr_spmm;
pub mod gemm;
pub mod microkernel;
pub mod ops;
pub mod pack;
pub mod simd;

pub use bspmm::{bspmm, fused_mlp_sparse, FusedMlpWeights};
pub use csr_spmm::csr_spmm;
pub use gemm::{gemm, gemm_into};
// The single source of truth for the activation scalars (PR 5 deduped the
// `bspmm.rs` copies): route all callers through these.
pub use ops::{gelu, silu};
pub use pack::PackedB;
