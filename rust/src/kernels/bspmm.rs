//! BLaST BSpMM — the paper's kernel (§3.3), CPU edition.
//!
//! `Y = X @ W` with `W` in BCSC. The structure mirrors Listing 2 of the
//! paper: for each output block column, stream the surviving blocks,
//! resolve the dynamic `X` panel via the block-row index (the "pointer
//! algebra on blk_col_ptr"), and run a dense micro-GEMM per block. Pruned
//! blocks cost *nothing* — no FLOPs, no loads — which is where the
//! `1/(1-s)`-shaped speedup over [`gemm`] comes from.
//!
//! `blk_M` (the paper's dense-operand tile height) maps to the `MR` row
//! tile here: the loaded `W` block is reused for `MR` rows of `X`.
//!
//! [`fused_mlp_sparse`] extends the kernel over the whole Llama-style MLP
//! (paper §3.3.3): per row tile the gated hidden state is produced and
//! consumed in cache — the memory-bound nonlinearity rides along the
//! compute-bound contractions instead of round-tripping through memory.

use crate::kernels::gemm::axpy;
use crate::sparse::Bcsc;
use crate::tensor::Tensor;
use crate::util::threadpool;

/// Rows of X/Y per task (the paper's blk_M role).
const MR: usize = 8;

/// `Y = X @ W_bcsc`; allocates the output.
pub fn bspmm(x: &Tensor, w: &Bcsc) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let (wk, n) = w.shape();
    assert_eq!(k, wk, "bspmm inner dims {k} vs {wk}");
    let mut y = Tensor::zeros(&[m, n]);
    bspmm_into(x.data(), w, y.data_mut(), m);
    y
}

/// `Y += X @ W_bcsc` over raw slices.
pub fn bspmm_into(x: &[f32], w: &Bcsc, y: &mut [f32], m: usize) {
    let (k, n) = w.shape();
    assert_eq!(x.len(), m * k);
    assert_eq!(y.len(), m * n);
    if m == 0 || w.nnzb() == 0 {
        return;
    }
    let b = w.block;
    let n_row_tiles = m.div_ceil(MR);
    // task grid: row tiles × block columns; output regions are disjoint
    let tasks = n_row_tiles * w.cb;
    let y_base = y.as_mut_ptr() as usize;
    threadpool::parallel_for(tasks, |t| {
        let it = t / w.cb;
        let bc = t % w.cb;
        let i0 = it * MR;
        let i1 = (i0 + MR).min(m);
        let lo = w.col_ptr[bc];
        let hi = w.col_ptr[bc + 1];
        if lo == hi {
            return;
        }
        // SAFETY: (row tile, block column) regions of Y are disjoint and
        // parallel_for blocks until completion.
        let y_ptr = y_base as *mut f32;
        for idx in lo..hi {
            let br = w.row_idx[idx];
            let blk = w.block_vals(idx);
            for i in i0..i1 {
                let xrow = &x[i * k + br * b..i * k + br * b + b];
                let yrow = unsafe {
                    std::slice::from_raw_parts_mut(y_ptr.add(i * n + bc * b), b)
                };
                // micro-GEMM row: y[b] += sum_kk x[kk] * blk[kk, :]
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv != 0.0 {
                        axpy(xv, &blk[kk * b..kk * b + b], yrow);
                    }
                }
            }
        }
    });
}

/// The three masked matrices of one Llama-style MLP block.
pub struct FusedMlpWeights<'a> {
    pub w1: &'a Bcsc, // (e, f) gate
    pub w2: &'a Bcsc, // (e, f) up
    pub w3: &'a Bcsc, // (f, e) down
}

#[inline(always)]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Fused sparse MLP: `Y = (SiLU(X W1) ⊙ (X W2)) W3` (paper Eq. 1).
///
/// Per `MR`-row tile the two gate contractions, the SiLU epilogue and the
/// down-projection all happen on cache-resident tile buffers.
pub fn fused_mlp_sparse(x: &Tensor, w: &FusedMlpWeights) -> Tensor {
    let (m, e) = (x.rows(), x.cols());
    let (e1, f) = w.w1.shape();
    let (f2, e2) = w.w3.shape();
    assert_eq!(e, e1);
    assert_eq!(w.w2.shape(), (e, f));
    assert_eq!((f2, e2), (f, e));
    let mut y = Tensor::zeros(&[m, e]);
    let n_tiles = m.div_ceil(MR);
    let y_base = y.data_mut().as_mut_ptr() as usize;
    let xd = x.data();
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        let mr = i1 - i0;
        // tile-local hidden buffers (thread stack): mr×f each
        let mut h1 = vec![0.0f32; mr * f];
        let mut h2 = vec![0.0f32; mr * f];
        let xt = &xd[i0 * e..i1 * e];
        tile_bspmm(xt, w.w1, &mut h1, mr);
        tile_bspmm(xt, w.w2, &mut h2, mr);
        // fused epilogue: h1 <- silu(h1) * h2, in cache
        for (a, &b) in h1.iter_mut().zip(h2.iter()) {
            *a = silu(*a) * b;
        }
        // down-projection into the tile's Y rows
        // SAFETY: tiles own disjoint Y row ranges.
        let yt = unsafe {
            std::slice::from_raw_parts_mut((y_base as *mut f32).add(i0 * e), mr * e)
        };
        tile_bspmm(&h1, w.w3, yt, mr);
    });
    y
}

/// GELU MLP variant (GPT-2/ViT): `Y = GELU(X W1) W3`.
pub fn gelu_mlp_sparse(x: &Tensor, w1: &Bcsc, w3: &Bcsc) -> Tensor {
    let (m, e) = (x.rows(), x.cols());
    let (_, f) = w1.shape();
    let mut y = Tensor::zeros(&[m, e]);
    let n_tiles = m.div_ceil(MR);
    let y_base = y.data_mut().as_mut_ptr() as usize;
    let xd = x.data();
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        let mr = i1 - i0;
        let mut h = vec![0.0f32; mr * f];
        tile_bspmm(&xd[i0 * e..i1 * e], w1, &mut h, mr);
        for a in h.iter_mut() {
            *a = crate::kernels::ops::gelu(*a);
        }
        let yt = unsafe {
            std::slice::from_raw_parts_mut((y_base as *mut f32).add(i0 * e), mr * e)
        };
        tile_bspmm(&h, w3, yt, mr);
    });
    y
}

/// Single-threaded BSpMM over one row tile (used inside fused kernels).
#[inline]
fn tile_bspmm(x: &[f32], w: &Bcsc, y: &mut [f32], mr: usize) {
    let (k, n) = w.shape();
    debug_assert_eq!(x.len(), mr * k);
    debug_assert_eq!(y.len(), mr * n);
    let b = w.block;
    for bc in 0..w.cb {
        for idx in w.col_ptr[bc]..w.col_ptr[bc + 1] {
            let br = w.row_idx[idx];
            let blk = w.block_vals(idx);
            for i in 0..mr {
                let xrow = &x[i * k + br * b..i * k + br * b + b];
                let yrow = &mut y[i * n + bc * b..i * n + bc * b + b];
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv != 0.0 {
                        axpy(xv, &blk[kk * b..kk * b + b], yrow);
                    }
                }
            }
        }
    }
}

/// FLOPs actually executed by a BSpMM (only surviving blocks).
pub fn bspmm_flops(m: usize, w: &Bcsc) -> f64 {
    2.0 * m as f64 * (w.nnzb() * w.block * w.block) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::gemm_naive;
    use crate::sparse::BlockMask;
    use crate::testkit::prop;
    use crate::prop_assert;
    use crate::util::rng::Rng;

    fn masked_dense(w: &Tensor, mask: &BlockMask, b: usize) -> Tensor {
        let mut out = w.clone();
        mask.apply_to(out.data_mut(), b);
        out
    }

    #[test]
    fn matches_masked_gemm_property() {
        prop::check_default("bspmm-vs-masked-gemm", |rng| {
            let b = *prop::pick(rng, &[4, 8, 16]);
            let rb = prop::usize_in(rng, 1, 6);
            let cb = prop::usize_in(rng, 1, 6);
            let m = prop::usize_in(rng, 1, 20);
            let x = Tensor::randn(&[m, rb * b], 1.0, rng);
            let w = Tensor::randn(&[rb * b, cb * b], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let sp = Bcsc::from_dense(&w, &mask, b);
            let got = bspmm(&x, &sp);
            let want = gemm_naive(&x, &masked_dense(&w, &mask, b));
            let diff = got.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} (b={b} rb={rb} cb={cb} m={m})");
            Ok(())
        });
    }

    #[test]
    fn dense_mask_equals_gemm() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[10, 32], 1.0, &mut rng);
        let w = Tensor::randn(&[32, 48], 1.0, &mut rng);
        let sp = Bcsc::from_dense(&w, &BlockMask::ones(2, 3), 16);
        assert!(bspmm(&x, &sp).allclose(&gemm_naive(&x, &w), 1e-3));
    }

    #[test]
    fn fused_mlp_matches_unfused() {
        prop::check_default("fused-mlp-vs-unfused", |rng| {
            let b = 8;
            let e = 2 * b;
            let f = 4 * b;
            let m = prop::usize_in(rng, 1, 20);
            let x = Tensor::randn(&[m, e], 1.0, rng);
            let w1d = Tensor::randn(&[e, f], 0.3, rng);
            let w2d = Tensor::randn(&[e, f], 0.3, rng);
            let w3d = Tensor::randn(&[f, e], 0.3, rng);
            let m1 = BlockMask::random(e / b, f / b, rng.f64(), rng);
            let m2 = BlockMask::random(e / b, f / b, rng.f64(), rng);
            let m3 = BlockMask::random(f / b, e / b, rng.f64(), rng);
            let w1 = Bcsc::from_dense(&w1d, &m1, b);
            let w2 = Bcsc::from_dense(&w2d, &m2, b);
            let w3 = Bcsc::from_dense(&w3d, &m3, b);
            let got = fused_mlp_sparse(&x, &FusedMlpWeights { w1: &w1, w2: &w2, w3: &w3 });
            // unfused oracle
            let h1 = gemm_naive(&x, &masked_dense(&w1d, &m1, b)).map(silu);
            let h2 = gemm_naive(&x, &masked_dense(&w2d, &m2, b));
            let mut h = h1.clone();
            for (a, &bb) in h.data_mut().iter_mut().zip(h2.data()) {
                *a *= bb;
            }
            let want = gemm_naive(&h, &masked_dense(&w3d, &m3, b));
            let diff = got.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} (m={m})");
            Ok(())
        });
    }

    #[test]
    fn gelu_mlp_matches_unfused() {
        let mut rng = Rng::new(5);
        let (b, e, f, m) = (8, 16, 32, 9);
        let x = Tensor::randn(&[m, e], 1.0, &mut rng);
        let w1d = Tensor::randn(&[e, f], 0.3, &mut rng);
        let w3d = Tensor::randn(&[f, e], 0.3, &mut rng);
        let m1 = BlockMask::random(e / b, f / b, 0.4, &mut rng);
        let m3 = BlockMask::random(f / b, e / b, 0.4, &mut rng);
        let got = gelu_mlp_sparse(
            &x,
            &Bcsc::from_dense(&w1d, &m1, b),
            &Bcsc::from_dense(&w3d, &m3, b),
        );
        let h = gemm_naive(&x, &masked_dense(&w1d, &m1, b)).map(crate::kernels::ops::gelu);
        let want = gemm_naive(&h, &masked_dense(&w3d, &m3, b));
        assert!(got.allclose(&want, 1e-3), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn flop_accounting() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let mask = BlockMask::random(4, 4, 0.5, &mut rng);
        let sp = Bcsc::from_dense(&w, &mask, 16);
        assert_eq!(bspmm_flops(10, &sp), 2.0 * 10.0 * (8 * 16 * 16) as f64);
    }
}
