//! BLaST BSpMM — the paper's kernel (§3.3), CPU edition, on the packed
//! register-blocked micro-kernel engine.
//!
//! `Y = X @ W` with `W` in BCSC. The structure mirrors Listing 2 of the
//! paper — for each output block column, stream the surviving blocks and
//! resolve the dynamic `X` panel via the block-row index — but the inner
//! product is no longer a scalar axpy over strided gathers:
//!
//! 1. every `MR`-row tile of `X` is transposed **once** into a k-major
//!    panel ([`crate::kernels::pack::pack_a_panel`]); a surviving block at
//!    block-row `br` then reads its `b`-deep sub-panel contiguously
//!    instead of gathering stride-`k` per element;
//! 2. each `(row tile, block column)` item accumulates into a contiguous
//!    `mr×b` C tile via [`crate::kernels::microkernel::microkernel`]
//!    (register-tiled accumulators, unrolled for b ∈ {8, 16, 32}) and
//!    writes `Y` back once;
//! 3. items are scheduled **cost-aware** — weighted by surviving blocks
//!    per block column ([`crate::util::threadpool::parallel_for_weighted`])
//!    — so high-sparsity masks with a few dense columns don't serialize
//!    behind uniform index chunking.
//!
//! Pruned blocks still cost *nothing* — no FLOPs, no loads — which is
//! where the `1/(1-s)`-shaped speedup over [`crate::kernels::gemm::gemm`]
//! comes from. `blk_M` (the paper's dense-operand tile height) maps to the
//! `MR` row tile here.
//!
//! [`fused_mlp_sparse`] extends the kernel over the whole Llama-style MLP
//! (paper §3.3.3): per row tile the gated hidden state is produced and
//! consumed in cache, with every tile buffer (packed X panel, h1, h2,
//! packed h panel) drawn from the thread-local scratch arena
//! ([`crate::util::scratch`]) — zero heap traffic after warmup, where the
//! seed kernel paid two `vec![0.0; mr*f]` allocations per tile per call.
//!
//! The seed scalar kernel is retained as [`bspmm_into_ref`]: it is the
//! baseline the `BENCH_kernels.json` A/B harness measures against and a
//! second correctness oracle.

use crate::kernels::gemm::axpy;
use crate::kernels::microkernel::microkernel_d;
use crate::kernels::pack::pack_a_panel;
use crate::kernels::simd::{self, Epilogue, KernelDispatch};
use crate::sparse::{Bcsc, BlockMask};
use crate::tensor::Tensor;
use crate::util::{scratch, threadpool};

/// Rows of X/Y per tile (the paper's blk_M role). Taller than the seed's 8:
/// each loaded `W` block is now reused across 16 packed rows.
const MR: usize = 16;

/// Rows per task of the reference kernel (seed value).
const REF_MR: usize = 8;

/// `Y = X @ W_bcsc`; allocates the output.
pub fn bspmm(x: &Tensor, w: &Bcsc) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let (wk, n) = w.shape();
    assert_eq!(k, wk, "bspmm inner dims {k} vs {wk}");
    let mut y = Tensor::zeros(&[m, n]);
    bspmm_into(x.data(), w, y.data_mut(), m);
    y
}

/// `Y += X @ W_bcsc` over raw slices — packed micro-kernel path.
pub fn bspmm_into(x: &[f32], w: &Bcsc, y: &mut [f32], m: usize) {
    let (k, n) = w.shape();
    assert_eq!(x.len(), m * k);
    assert_eq!(y.len(), m * n);
    if m == 0 || w.nnzb() == 0 {
        return;
    }
    let b = w.block;
    let n_row_tiles = m.div_ceil(MR);
    // Phase 1: transpose every X row tile to k-major, once. Tile t lives at
    // xp[t*MR*k ..] with leading dimension = that tile's row count.
    let mut xp = scratch::take_uninit(m * k);
    threadpool::parallel_chunks_mut(&mut xp, MR * k, |t, chunk| {
        let i0 = t * MR;
        let mr = chunk.len() / k;
        pack_a_panel(&x[i0 * k..(i0 + mr) * k], k, mr, k, chunk);
    });
    // Phase 2: (row tile × block column) items, weighted by surviving
    // blocks per column so pruned columns ride along for free and dense
    // columns spread across workers. Weights come straight from col_ptr —
    // no per-call weight vector on the hot path.
    let cb = w.cb;
    let y_base = y.as_mut_ptr() as usize;
    let xp_ref: &[f32] = &xp;
    let n_items = n_row_tiles * cb;
    let weight = |t: usize| w.col_ptr[t % cb + 1] - w.col_ptr[t % cb];
    let d = simd::dispatch();
    threadpool::parallel_for_weighted(n_items, weight, |t| {
        let it = t / cb;
        let bc = t % cb;
        let lo = w.col_ptr[bc];
        let hi = w.col_ptr[bc + 1];
        if lo == hi {
            return;
        }
        let i0 = it * MR;
        let i1 = (i0 + MR).min(m);
        let mr = i1 - i0;
        let xt = &xp_ref[i0 * k..i0 * k + mr * k];
        // contiguous mr×b C-tile accumulator, written back to Y once
        let mut yt = scratch::take_zeroed(mr * b);
        for idx in lo..hi {
            let br = w.row_idx[idx];
            microkernel_d(
                d,
                &xt[br * b * mr..],
                mr,
                mr,
                w.block_vals(idx),
                b,
                b,
                b,
                &mut yt,
                b,
                Epilogue::None,
            );
        }
        // SAFETY: each (row tile, block column) item owns the disjoint
        // spans y[i0+i, bc*b .. bc*b+b]; the per-row slices of length b
        // never overlap across items and parallel_for blocks until done.
        let y_ptr = y_base as *mut f32;
        for i in 0..mr {
            let dst = unsafe {
                std::slice::from_raw_parts_mut(y_ptr.add((i0 + i) * n + bc * b), b)
            };
            for (d, s) in dst.iter_mut().zip(&yt[i * b..(i + 1) * b]) {
                *d += *s;
            }
        }
    });
}

/// The seed kernel: per-row scalar axpy over strided X gathers, uniform
/// (row tile × block column) task grid. Kept as the A/B baseline for
/// `BENCH_kernels.json` and as a second oracle.
pub fn bspmm_into_ref(x: &[f32], w: &Bcsc, y: &mut [f32], m: usize) {
    let (k, n) = w.shape();
    assert_eq!(x.len(), m * k);
    assert_eq!(y.len(), m * n);
    if m == 0 || w.nnzb() == 0 {
        return;
    }
    let b = w.block;
    let n_row_tiles = m.div_ceil(REF_MR);
    let tasks = n_row_tiles * w.cb;
    let y_base = y.as_mut_ptr() as usize;
    threadpool::parallel_for(tasks, |t| {
        let it = t / w.cb;
        let bc = t % w.cb;
        let i0 = it * REF_MR;
        let i1 = (i0 + REF_MR).min(m);
        let lo = w.col_ptr[bc];
        let hi = w.col_ptr[bc + 1];
        if lo == hi {
            return;
        }
        // SAFETY: (row tile, block column) regions of Y are disjoint and
        // parallel_for blocks until completion.
        let y_ptr = y_base as *mut f32;
        for idx in lo..hi {
            let br = w.row_idx[idx];
            let blk = w.block_vals(idx);
            for i in i0..i1 {
                let xrow = &x[i * k + br * b..i * k + br * b + b];
                let yrow = unsafe {
                    std::slice::from_raw_parts_mut(y_ptr.add(i * n + bc * b), b)
                };
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv != 0.0 {
                        axpy(xv, &blk[kk * b..kk * b + b], yrow);
                    }
                }
            }
        }
    });
}

/// Block-masked weight-gradient accumulator: `dW += Xᵀ · dY` restricted to
/// the **resident** blocks of `mask` — the backward half of the paper's
/// sparsity win. Pruned blocks cost nothing (no FLOPs, no loads, no
/// writes), so the `1/(1-s)` speedup of the forward BSpMM carries over to
/// `dW`; and because `W_eff = W ⊙ expand(M)`, the true gradient *is* zero
/// outside resident blocks, so skipping them is exact, not approximate.
///
/// `x` is `(m × k)`, `dy` is `(m × n)` row-major; `dw` is the dense
/// `(k × n)` gradient — only resident blocks are touched, everything else
/// keeps its incoming value (zeros from the caller give exactly-masked
/// gradients, the `G_i` the prune-and-grow controller consumes).
///
/// Layout: one depth-`m` k-major panel per block-row of `Xᵀ`
/// (`xp[br][d*b + r] = x[d*k + br*b + r]` — contiguous per depth step) and
/// one per block-column of `dY`, each packed once; every resident block
/// then runs a single `b×b` micro-kernel over the full depth `m` and
/// writes its `dW` tile back once. Resident blocks all cost the same
/// (`2·m·b²` FLOPs), so a plain index grab over the resident list
/// load-balances.
pub fn bspmm_dw_masked_into(
    x: &[f32],
    dy: &[f32],
    mask: &BlockMask,
    block: usize,
    dw: &mut [f32],
    m: usize,
) {
    let b = block;
    let (k, n) = (mask.rb * b, mask.cb * b);
    assert_eq!(x.len(), m * k, "bspmm_dw: x {} != {m}x{k}", x.len());
    assert_eq!(dy.len(), m * n, "bspmm_dw: dy {} != {m}x{n}", dy.len());
    assert_eq!(dw.len(), k * n, "bspmm_dw: dw {} != {k}x{n}", dw.len());
    if m == 0 || mask.nnzb() == 0 {
        return;
    }
    // Phase 1: pack Xᵀ block-row panels and dY block-column panels, m-deep.
    let mut xp = scratch::take_uninit(m * k);
    threadpool::parallel_chunks_mut(&mut xp, m * b, |br, chunk| {
        for d in 0..m {
            chunk[d * b..(d + 1) * b].copy_from_slice(&x[d * k + br * b..d * k + (br + 1) * b]);
        }
    });
    let mut dyp = scratch::take_uninit(m * n);
    threadpool::parallel_chunks_mut(&mut dyp, m * b, |bc, chunk| {
        for d in 0..m {
            chunk[d * b..(d + 1) * b].copy_from_slice(&dy[d * n + bc * b..d * n + (bc + 1) * b]);
        }
    });
    // Phase 2: one b×b micro-kernel per resident block, depth m.
    let resident: Vec<(usize, usize)> = (0..mask.rb)
        .flat_map(|br| (0..mask.cb).map(move |bc| (br, bc)))
        .filter(|&(br, bc)| mask.get(br, bc))
        .collect();
    let dw_base = dw.as_mut_ptr() as usize;
    let xp_ref: &[f32] = &xp;
    let dyp_ref: &[f32] = &dyp;
    let d = simd::dispatch();
    threadpool::parallel_for(resident.len(), |t| {
        let (br, bc) = resident[t];
        let mut tile = scratch::take_zeroed(b * b);
        microkernel_d(
            d,
            &xp_ref[br * m * b..(br + 1) * m * b],
            b,
            b,
            &dyp_ref[bc * m * b..(bc + 1) * m * b],
            b,
            b,
            m,
            &mut tile,
            b,
            Epilogue::None,
        );
        // SAFETY: each resident block owns the disjoint dW span
        // dw[br*b+i, bc*b..bc*b+b]; parallel_for blocks until done.
        let dw_ptr = dw_base as *mut f32;
        for i in 0..b {
            let dst = unsafe {
                std::slice::from_raw_parts_mut(dw_ptr.add((br * b + i) * n + bc * b), b)
            };
            for (d, s) in dst.iter_mut().zip(&tile[i * b..(i + 1) * b]) {
                *d += *s;
            }
        }
    });
}

/// The three masked matrices of one Llama-style MLP block.
pub struct FusedMlpWeights<'a> {
    pub w1: &'a Bcsc, // (e, f) gate
    pub w2: &'a Bcsc, // (e, f) up
    pub w3: &'a Bcsc, // (f, e) down
}

/// Fused sparse MLP: `Y = (SiLU(X W1) ⊙ (X W2)) W3` (paper Eq. 1).
///
/// Per `MR`-row tile: the X panel is packed once and shared by both gate
/// contractions, and the SwiGLU epilogue (`silu(h1) ⊙ h2`) is fused into
/// the **W1 contraction's write-back** — the up-projection `h2` runs
/// first, then the gate contraction carries
/// [`Epilogue::SiluGate`], so the hidden tile is activated in registers as
/// its last block lands and the old separate `mr×f` elementwise pass is
/// gone. The down-projection consumes the repacked hidden panel — all four
/// tile buffers come from the thread-local scratch arena, so the hot path
/// is allocation-free after warmup.
pub fn fused_mlp_sparse(x: &Tensor, w: &FusedMlpWeights) -> Tensor {
    let (m, e) = (x.rows(), x.cols());
    let (e1, f) = w.w1.shape();
    let (f2, e2) = w.w3.shape();
    assert_eq!(e, e1);
    assert_eq!(w.w2.shape(), (e, f));
    assert_eq!((f2, e2), (f, e));
    let mut y = Tensor::zeros(&[m, e]);
    let n_tiles = m.div_ceil(MR);
    let y_base = y.data_mut().as_mut_ptr() as usize;
    let xd = x.data();
    let d = simd::dispatch();
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        let mr = i1 - i0;
        // pack the X tile once; both gate contractions stream it
        let mut xp = scratch::take_uninit(mr * e);
        pack_a_panel(&xd[i0 * e..i1 * e], e, mr, e, &mut xp);
        let mut h1 = scratch::take_zeroed(mr * f);
        let mut h2 = scratch::take_zeroed(mr * f);
        tile_bspmm_packed(d, &xp, mr, w.w2, &mut h2, Epilogue::None);
        // gate contraction with the SwiGLU epilogue fused into write-back
        tile_bspmm_packed(d, &xp, mr, w.w1, &mut h1, Epilogue::SiluGate { g: &h2, ldg: f });
        // down-projection into the tile's Y rows
        let mut hp = scratch::take_uninit(mr * f);
        pack_a_panel(&h1, f, mr, f, &mut hp);
        // SAFETY: tiles own disjoint Y row ranges.
        let yt = unsafe {
            std::slice::from_raw_parts_mut((y_base as *mut f32).add(i0 * e), mr * e)
        };
        tile_bspmm_packed(d, &hp, mr, w.w3, yt, Epilogue::None);
    });
    y
}

/// GELU MLP variant (GPT-2/ViT): `Y = GELU(X W1) W3`. The GeLU is fused
/// into the up-projection's write-back ([`Epilogue::Gelu`]) — no separate
/// pass over the hidden tile.
pub fn gelu_mlp_sparse(x: &Tensor, w1: &Bcsc, w3: &Bcsc) -> Tensor {
    let (m, e) = (x.rows(), x.cols());
    let (e1, f) = w1.shape();
    assert_eq!(e, e1, "gelu_mlp_sparse: x cols {e} vs w1 rows {e1}");
    assert_eq!(
        w3.shape(),
        (f, e),
        "gelu_mlp_sparse: w3 shape {:?} vs expected ({f}, {e})",
        w3.shape()
    );
    let mut y = Tensor::zeros(&[m, e]);
    let n_tiles = m.div_ceil(MR);
    let y_base = y.data_mut().as_mut_ptr() as usize;
    let xd = x.data();
    let d = simd::dispatch();
    threadpool::parallel_for(n_tiles, |t| {
        let i0 = t * MR;
        let i1 = (i0 + MR).min(m);
        let mr = i1 - i0;
        let mut xp = scratch::take_uninit(mr * e);
        pack_a_panel(&xd[i0 * e..i1 * e], e, mr, e, &mut xp);
        let mut h = scratch::take_zeroed(mr * f);
        tile_bspmm_packed(d, &xp, mr, w1, &mut h, Epilogue::Gelu);
        let mut hp = scratch::take_uninit(mr * f);
        pack_a_panel(&h, f, mr, f, &mut hp);
        // SAFETY: tiles own disjoint Y row ranges.
        let yt = unsafe {
            std::slice::from_raw_parts_mut((y_base as *mut f32).add(i0 * e), mr * e)
        };
        tile_bspmm_packed(d, &hp, mr, w3, yt, Epilogue::None);
    });
    y
}

/// Single-threaded BSpMM over one packed row tile (the fused-MLP inner
/// contraction). `xp` is k-major with leading dimension `mr`; `y` is
/// row-major `mr × n`; `ep` operands are relative to the full `mr × n`
/// tile.
///
/// Epilogue placement is the kernel's half of the exactly-once contract: a
/// block column's C stripe is complete after its **last resident block**,
/// so only that micro-kernel call carries the (column-shifted) epilogue.
/// Fully-pruned columns never run a micro-kernel, so a
/// non-zero-preserving epilogue (bias) is applied to their zero stripe
/// explicitly; zero-preserving ones (`gelu(0)=silu(0)=0`) are skipped —
/// pruned blocks still cost nothing.
#[inline]
fn tile_bspmm_packed(
    d: &KernelDispatch,
    xp: &[f32],
    mr: usize,
    w: &Bcsc,
    y: &mut [f32],
    ep: Epilogue<'_>,
) {
    let (k, n) = w.shape();
    debug_assert_eq!(xp.len(), mr * k);
    debug_assert_eq!(y.len(), mr * n);
    let b = w.block;
    for bc in 0..w.cb {
        let lo = w.col_ptr[bc];
        let hi = w.col_ptr[bc + 1];
        if lo == hi {
            if !ep.zero_preserving() {
                d.apply_epilogue_region(&mut y[bc * b..], n, mr, b, ep.shift(0, bc * b));
            }
            continue;
        }
        for idx in lo..hi {
            let br = w.row_idx[idx];
            let ep_call = if idx + 1 == hi {
                ep.shift(0, bc * b)
            } else {
                Epilogue::None
            };
            microkernel_d(
                d,
                &xp[br * b * mr..],
                mr,
                mr,
                w.block_vals(idx),
                b,
                b,
                b,
                &mut y[bc * b..],
                n,
                ep_call,
            );
        }
    }
}

/// FLOPs actually executed by a BSpMM (only surviving blocks).
pub fn bspmm_flops(m: usize, w: &Bcsc) -> f64 {
    2.0 * m as f64 * (w.nnzb() * w.block * w.block) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::gemm_naive;
    use crate::kernels::ops::silu;
    use crate::prop_assert;
    use crate::sparse::BlockMask;
    use crate::testkit::prop;
    use crate::util::rng::Rng;

    fn masked_dense(w: &Tensor, mask: &BlockMask, b: usize) -> Tensor {
        let mut out = w.clone();
        mask.apply_to(out.data_mut(), b);
        out
    }

    #[test]
    fn matches_masked_gemm_property() {
        prop::check_default("bspmm-vs-masked-gemm", |rng| {
            let b = *prop::pick(rng, &[4, 8, 16]);
            let rb = prop::usize_in(rng, 1, 6);
            let cb = prop::usize_in(rng, 1, 6);
            let m = prop::usize_in(rng, 1, 20);
            let x = Tensor::randn(&[m, rb * b], 1.0, rng);
            let w = Tensor::randn(&[rb * b, cb * b], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let sp = Bcsc::from_dense(&w, &mask, b);
            let got = bspmm(&x, &sp);
            let want = gemm_naive(&x, &masked_dense(&w, &mask, b));
            let diff = got.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} (b={b} rb={rb} cb={cb} m={m})");
            Ok(())
        });
    }

    #[test]
    fn ref_and_packed_kernels_agree_property() {
        prop::check_default("bspmm-ref-vs-packed", |rng| {
            // wide blocks force the 32-column chunking; m crosses MR
            let b = *prop::pick(rng, &[8, 32, 64]);
            let rb = prop::usize_in(rng, 1, 3);
            let cb = prop::usize_in(rng, 1, 3);
            let m = *prop::pick(rng, &[1, 7, MR, MR + 3, 2 * MR + 5]);
            let x = Tensor::randn(&[m, rb * b], 1.0, rng);
            let w = Tensor::randn(&[rb * b, cb * b], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let sp = Bcsc::from_dense(&w, &mask, b);
            let mut y_new = Tensor::zeros(&[m, cb * b]);
            bspmm_into(x.data(), &sp, y_new.data_mut(), m);
            let mut y_ref = Tensor::zeros(&[m, cb * b]);
            bspmm_into_ref(x.data(), &sp, y_ref.data_mut(), m);
            let diff = y_new.max_abs_diff(&y_ref);
            prop_assert!(diff < 1e-3, "diff {diff} (b={b} rb={rb} cb={cb} m={m})");
            Ok(())
        });
    }

    #[test]
    fn dense_mask_equals_gemm() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[10, 32], 1.0, &mut rng);
        let w = Tensor::randn(&[32, 48], 1.0, &mut rng);
        let sp = Bcsc::from_dense(&w, &BlockMask::ones(2, 3), 16);
        assert!(bspmm(&x, &sp).allclose(&gemm_naive(&x, &w), 1e-3));
    }

    #[test]
    fn zero_rows_and_fully_pruned_masks() {
        let mut rng = Rng::new(2);
        // m == 0: all kernels must accept empty X/Y without touching them
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let sp = Bcsc::from_dense(&w, &BlockMask::ones(2, 2), 8);
        bspmm_into(&[], &sp, &mut [], 0);
        bspmm_into_ref(&[], &sp, &mut [], 0);
        let x0 = Tensor::zeros(&[0, 16]);
        assert_eq!(bspmm(&x0, &sp).shape(), &[0, 16]);
        // fully-pruned W: output must be exactly zero, no block touched
        let pruned = Bcsc::from_dense(&w, &BlockMask::zeros(2, 2), 8);
        let x = Tensor::randn(&[9, 16], 1.0, &mut rng);
        let y = bspmm(&x, &pruned);
        assert!(y.allclose(&Tensor::zeros(&[9, 16]), 0.0));
    }

    #[test]
    fn fused_mlp_matches_unfused() {
        prop::check_default("fused-mlp-vs-unfused", |rng| {
            let b = 8;
            let e = 2 * b;
            let f = 4 * b;
            let m = prop::usize_in(rng, 1, 20);
            let x = Tensor::randn(&[m, e], 1.0, rng);
            let w1d = Tensor::randn(&[e, f], 0.3, rng);
            let w2d = Tensor::randn(&[e, f], 0.3, rng);
            let w3d = Tensor::randn(&[f, e], 0.3, rng);
            let m1 = BlockMask::random(e / b, f / b, rng.f64(), rng);
            let m2 = BlockMask::random(e / b, f / b, rng.f64(), rng);
            let m3 = BlockMask::random(f / b, e / b, rng.f64(), rng);
            let w1 = Bcsc::from_dense(&w1d, &m1, b);
            let w2 = Bcsc::from_dense(&w2d, &m2, b);
            let w3 = Bcsc::from_dense(&w3d, &m3, b);
            let got = fused_mlp_sparse(&x, &FusedMlpWeights { w1: &w1, w2: &w2, w3: &w3 });
            // unfused oracle
            let h1 = gemm_naive(&x, &masked_dense(&w1d, &m1, b)).map(silu);
            let h2 = gemm_naive(&x, &masked_dense(&w2d, &m2, b));
            let mut h = h1.clone();
            for (a, &bb) in h.data_mut().iter_mut().zip(h2.data()) {
                *a *= bb;
            }
            let want = gemm_naive(&h, &masked_dense(&w3d, &m3, b));
            let diff = got.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} (m={m})");
            Ok(())
        });
    }

    #[test]
    fn fused_mlp_edge_rows() {
        // m == 0, m < MR, m == MR, m just past a tile boundary — both
        // fused variants, against the unfused oracle
        let mut rng = Rng::new(3);
        let (b, e, f) = (8, 16, 32);
        let w1d = Tensor::randn(&[e, f], 0.3, &mut rng);
        let w2d = Tensor::randn(&[e, f], 0.3, &mut rng);
        let w3d = Tensor::randn(&[f, e], 0.3, &mut rng);
        let m1 = BlockMask::random(e / b, f / b, 0.4, &mut rng);
        let m2 = BlockMask::random(e / b, f / b, 0.4, &mut rng);
        let m3 = BlockMask::random(f / b, e / b, 0.4, &mut rng);
        let w1 = Bcsc::from_dense(&w1d, &m1, b);
        let w2 = Bcsc::from_dense(&w2d, &m2, b);
        let w3 = Bcsc::from_dense(&w3d, &m3, b);
        for m in [0usize, 1, MR - 1, MR, MR + 1, 2 * MR + 5] {
            let x = Tensor::randn(&[m, e], 1.0, &mut rng);
            let got = fused_mlp_sparse(&x, &FusedMlpWeights { w1: &w1, w2: &w2, w3: &w3 });
            assert_eq!(got.shape(), &[m, e], "swiglu m={m}");
            let h1 = gemm_naive(&x, &masked_dense(&w1d, &m1, b)).map(silu);
            let h2 = gemm_naive(&x, &masked_dense(&w2d, &m2, b));
            let mut h = h1.clone();
            for (a, &bb) in h.data_mut().iter_mut().zip(h2.data()) {
                *a *= bb;
            }
            let want = gemm_naive(&h, &masked_dense(&w3d, &m3, b));
            assert!(
                got.allclose(&want, 1e-3),
                "swiglu m={m} diff {}",
                got.max_abs_diff(&want)
            );
            let got = gelu_mlp_sparse(&x, &w1, &w3);
            assert_eq!(got.shape(), &[m, e], "gelu m={m}");
            let hg = gemm_naive(&x, &masked_dense(&w1d, &m1, b))
                .map(crate::kernels::ops::gelu);
            let want = gemm_naive(&hg, &masked_dense(&w3d, &m3, b));
            assert!(
                got.allclose(&want, 1e-3),
                "gelu m={m} diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn fused_mlp_fully_pruned_is_zero() {
        let mut rng = Rng::new(4);
        let (b, e, f, m) = (8, 16, 32, 11);
        let x = Tensor::randn(&[m, e], 1.0, &mut rng);
        let w1 = Bcsc::from_dense(&Tensor::randn(&[e, f], 0.3, &mut rng), &BlockMask::zeros(2, 4), b);
        let w2 = Bcsc::from_dense(&Tensor::randn(&[e, f], 0.3, &mut rng), &BlockMask::zeros(2, 4), b);
        let w3 = Bcsc::from_dense(&Tensor::randn(&[f, e], 0.3, &mut rng), &BlockMask::zeros(4, 2), b);
        let got = fused_mlp_sparse(&x, &FusedMlpWeights { w1: &w1, w2: &w2, w3: &w3 });
        assert!(got.allclose(&Tensor::zeros(&[m, e]), 0.0));
        let got = gelu_mlp_sparse(&x, &w1, &w3);
        assert!(got.allclose(&Tensor::zeros(&[m, e]), 0.0));
    }

    #[test]
    fn gelu_mlp_matches_unfused() {
        let mut rng = Rng::new(5);
        let (b, e, f, m) = (8, 16, 32, 9);
        let x = Tensor::randn(&[m, e], 1.0, &mut rng);
        let w1d = Tensor::randn(&[e, f], 0.3, &mut rng);
        let w3d = Tensor::randn(&[f, e], 0.3, &mut rng);
        let m1 = BlockMask::random(e / b, f / b, 0.4, &mut rng);
        let m3 = BlockMask::random(f / b, e / b, 0.4, &mut rng);
        let got = gelu_mlp_sparse(
            &x,
            &Bcsc::from_dense(&w1d, &m1, b),
            &Bcsc::from_dense(&w3d, &m3, b),
        );
        let h = gemm_naive(&x, &masked_dense(&w1d, &m1, b)).map(crate::kernels::ops::gelu);
        let want = gemm_naive(&h, &masked_dense(&w3d, &m3, b));
        assert!(got.allclose(&want, 1e-3), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    #[should_panic(expected = "gelu_mlp_sparse: x cols")]
    fn gelu_mlp_rejects_mismatched_w1_rows() {
        let mut rng = Rng::new(6);
        let b = 8;
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng); // e = 16
        let w1 = Bcsc::from_dense(&Tensor::randn(&[24, 32], 0.3, &mut rng), &BlockMask::ones(3, 4), b);
        let w3 = Bcsc::from_dense(&Tensor::randn(&[32, 16], 0.3, &mut rng), &BlockMask::ones(4, 2), b);
        let _ = gelu_mlp_sparse(&x, &w1, &w3);
    }

    #[test]
    #[should_panic(expected = "gelu_mlp_sparse: w3 shape")]
    fn gelu_mlp_rejects_mismatched_w3_shape() {
        let mut rng = Rng::new(7);
        let b = 8;
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let w1 = Bcsc::from_dense(&Tensor::randn(&[16, 32], 0.3, &mut rng), &BlockMask::ones(2, 4), b);
        // wrong: (f, e) should be (32, 16)
        let w3 = Bcsc::from_dense(&Tensor::randn(&[24, 16], 0.3, &mut rng), &BlockMask::ones(3, 2), b);
        let _ = gelu_mlp_sparse(&x, &w1, &w3);
    }

    #[test]
    fn dw_masked_matches_masked_dense_oracle() {
        prop::check_default("bspmm-dw-vs-masked-gemm", |rng| {
            let b = *prop::pick(rng, &[4, 8, 16, 32]);
            let rb = prop::usize_in(rng, 1, 4);
            let cb = prop::usize_in(rng, 1, 4);
            let m = prop::usize_in(rng, 1, 24);
            let x = Tensor::randn(&[m, rb * b], 1.0, rng);
            let dy = Tensor::randn(&[m, cb * b], 1.0, rng);
            let mask = BlockMask::random(rb, cb, rng.f64(), rng);
            let mut dw = Tensor::zeros(&[rb * b, cb * b]);
            bspmm_dw_masked_into(x.data(), dy.data(), &mask, b, dw.data_mut(), m);
            // oracle: dense Xᵀ·dY with the mask applied afterwards
            let mut want = gemm_naive(&x.transpose2(), &dy);
            mask.apply_to(want.data_mut(), b);
            let diff = dw.max_abs_diff(&want);
            prop_assert!(diff < 1e-3, "diff {diff} (b={b} rb={rb} cb={cb} m={m})");
            // the acceptance-gate invariant: *exactly* zero outside residents
            for br in 0..rb {
                for bc in 0..cb {
                    if !mask.get(br, bc) {
                        for i in 0..b {
                            for j in 0..b {
                                prop_assert!(
                                    dw.at2(br * b + i, bc * b + j) == 0.0,
                                    "nonzero outside resident block ({br},{bc})"
                                );
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dw_masked_accumulates_and_handles_edges() {
        let mut rng = Rng::new(8);
        let (b, m) = (8, 11);
        let x = Tensor::randn(&[m, 2 * b], 1.0, &mut rng);
        let dy = Tensor::randn(&[m, 3 * b], 1.0, &mut rng);
        let mask = BlockMask::random(2, 3, 0.4, &mut rng);
        // accumulation: pre-filled resident entries gain the product
        let mut dw = Tensor::full(&[2 * b, 3 * b], 1.0);
        bspmm_dw_masked_into(x.data(), dy.data(), &mask, b, dw.data_mut(), m);
        let mut prod = gemm_naive(&x.transpose2(), &dy);
        mask.apply_to(prod.data_mut(), b);
        for r in 0..2 * b {
            for c in 0..3 * b {
                let want = 1.0 + prod.at2(r, c);
                assert!((dw.at2(r, c) - want).abs() < 1e-3, "({r},{c})");
            }
        }
        // m == 0 and fully-pruned masks are no-ops
        let mut dw0 = Tensor::zeros(&[2 * b, 3 * b]);
        bspmm_dw_masked_into(&[], &[], &mask, b, dw0.data_mut(), 0);
        assert!(dw0.allclose(&Tensor::zeros(&[2 * b, 3 * b]), 0.0));
        bspmm_dw_masked_into(
            x.data(),
            dy.data(),
            &BlockMask::zeros(2, 3),
            b,
            dw0.data_mut(),
            m,
        );
        assert!(dw0.allclose(&Tensor::zeros(&[2 * b, 3 * b]), 0.0));
    }

    #[test]
    fn flop_accounting() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let mask = BlockMask::random(4, 4, 0.5, &mut rng);
        let sp = Bcsc::from_dense(&w, &mask, 16);
        assert_eq!(bspmm_flops(10, &sp), 2.0 * 10.0 * (8 * 16 * 16) as f64);
    }
}
