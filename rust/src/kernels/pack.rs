//! Operand packing for the register-blocked micro-kernel.
//!
//! Two layouts feed [`crate::kernels::microkernel::microkernel`]:
//!
//! * **A/X panels** ([`pack_a_panel`]) — a row tile of the dense operand
//!   transposed to *k-major* order (`out[kk*rows + i]`), so the micro-kernel
//!   broadcasts `rows` contiguous values per depth step instead of
//!   gathering one value per row at stride `k`. For BSpMM the packed X
//!   tile is built **once per row tile** and every surviving block reads
//!   its `b`-deep sub-panel at `out[br*b*rows ..]` — the stride-`k`
//!   gather that the seed kernel repeated per block disappears.
//!
//! * **B panels** ([`PackedB`]) — the right operand split into `NR`-wide
//!   column panels, each stored k-major (`panel[kk*NR + j]`) and
//!   zero-padded to `NR`, so the micro-kernel streams one contiguous
//!   cache line run per depth step. Weight matrices are packed once at
//!   engine build time and reused by every prefill/decode call.

use crate::kernels::simd;
use crate::util::threadpool;

/// Column width of one packed B panel (matches the 16-wide micro-kernel
/// specialization: 2 AVX2 / 1 AVX-512 register per row chunk).
pub const NR: usize = 16;

/// Transpose `rows × k` (row-major, leading dim `lda`) into a k-major
/// panel: `out[kk*rows + i] = a[i*lda + kk]`. `out.len()` must be ≥
/// `rows * k`.
///
/// The hot case (`lda == k`, i.e. a contiguous tile — every GEMM/BSpMM row
/// tile and the fused-MLP hidden repack) routes through the dispatched
/// [`pack_kt_panel`]; the strided general case stays scalar.
pub fn pack_a_panel(a: &[f32], lda: usize, rows: usize, k: usize, out: &mut [f32]) {
    debug_assert!(rows == 0 || a.len() >= (rows - 1) * lda + k);
    debug_assert!(out.len() >= rows * k);
    if lda == k {
        pack_kt_panel(&a[..rows * k], rows, k, out);
        return;
    }
    for i in 0..rows {
        let row = &a[i * lda..i * lda + k];
        for (kk, &v) in row.iter().enumerate() {
            out[kk * rows + i] = v;
        }
    }
}

/// Transpose a **contiguous** `rows × k` tile (leading dim == `k`) into a
/// k-major panel: `out[kk*rows + r] = src[r*k + kk]`.
///
/// Same result as [`pack_a_panel`] with `lda == k` — the layout the tiled
/// attention kernel uses for its Q, Kᵀ and P tiles (`rows` = tile
/// positions, `k` = `hd` or `tk`), where tiles are always contiguous
/// slices of a head's `(seq, hd)` block. Dispatched: the AVX2/NEON arms
/// run in-register 8×8 / 4×4 transpose networks; the scalar arm is the
/// PR-3 four-row blocked copy below. Packing is a pure permutation, so
/// every arm is bit-identical.
pub fn pack_kt_panel(src: &[f32], rows: usize, k: usize, out: &mut [f32]) {
    (simd::dispatch().pack_kt)(src, rows, k, out);
}

/// Scalar arm of [`pack_kt_panel`]: blocked four rows at a time so each
/// depth step writes four consecutive outputs from four streamed source
/// rows.
pub(crate) fn pack_kt_panel_scalar(src: &[f32], rows: usize, k: usize, out: &mut [f32]) {
    debug_assert!(src.len() >= rows * k);
    debug_assert!(out.len() >= rows * k);
    let mut r0 = 0;
    while r0 + 4 <= rows {
        let s0 = &src[r0 * k..(r0 + 1) * k];
        let s1 = &src[(r0 + 1) * k..(r0 + 2) * k];
        let s2 = &src[(r0 + 2) * k..(r0 + 3) * k];
        let s3 = &src[(r0 + 3) * k..(r0 + 4) * k];
        for kk in 0..k {
            let o = &mut out[kk * rows + r0..kk * rows + r0 + 4];
            o[0] = s0[kk];
            o[1] = s1[kk];
            o[2] = s2[kk];
            o[3] = s3[kk];
        }
        r0 += 4;
    }
    for r in r0..rows {
        let row = &src[r * k..(r + 1) * k];
        for (kk, &v) in row.iter().enumerate() {
            out[kk * rows + r] = v;
        }
    }
}

/// A `k × n` matrix packed into `NR`-wide, zero-padded, k-major column
/// panels, ready for repeated multiplication (weights, notably).
#[derive(Clone, Debug)]
pub struct PackedB {
    /// Rows of the logical matrix (the GEMM depth).
    pub k: usize,
    /// Columns of the logical matrix.
    pub n: usize,
    /// Panel width (always [`NR`]; stored for self-description).
    pub nr: usize,
    /// `panels() * k * nr` values; panel `p` at `data[p*k*nr ..]`.
    data: Vec<f32>,
}

impl PackedB {
    /// Pack a row-major `k × n` matrix. Parallelized over panels (packing
    /// a large weight matrix is itself a bandwidth-bound sweep).
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        assert_eq!(b.len(), k * n, "PackedB::pack: {} != {k}x{n}", b.len());
        let panels = n.div_ceil(NR);
        let mut data = vec![0.0f32; panels * k * NR];
        if n > 0 && k > 0 {
            threadpool::parallel_chunks_mut(&mut data, k * NR, |p, chunk| {
                let j0 = p * NR;
                let cols = (n - j0).min(NR);
                for kk in 0..k {
                    let src = &b[kk * n + j0..kk * n + j0 + cols];
                    chunk[kk * NR..kk * NR + cols].copy_from_slice(src);
                }
            });
        }
        PackedB { k, n, nr: NR, data }
    }

    /// Pack the **transpose** of a row-major `n × k` matrix without
    /// materializing it: the panels describe the logical `k × n` matrix
    /// `Bᵀ`, so `gemm_packed_into(A, ·)` computes `A · Bᵀ` — the
    /// backward-pass data-gradient GEMM (`dX = dY · Wᵀ` with `W` stored
    /// un-transposed). Blocked four source rows at a time (the
    /// [`pack_kt_panel`] scheme): each depth step writes four consecutive
    /// panel entries from four streamed rows of `b`.
    pub fn pack_transposed(b: &[f32], n: usize, k: usize) -> PackedB {
        assert_eq!(b.len(), n * k, "pack_transposed: {} != {n}x{k}", b.len());
        let panels = n.div_ceil(NR);
        let mut data = vec![0.0f32; panels * k * NR];
        if n > 0 && k > 0 {
            threadpool::parallel_chunks_mut(&mut data, k * NR, |p, chunk| {
                let j0 = p * NR;
                let cols = (n - j0).min(NR);
                let mut j = 0;
                while j + 4 <= cols {
                    let s0 = &b[(j0 + j) * k..(j0 + j + 1) * k];
                    let s1 = &b[(j0 + j + 1) * k..(j0 + j + 2) * k];
                    let s2 = &b[(j0 + j + 2) * k..(j0 + j + 3) * k];
                    let s3 = &b[(j0 + j + 3) * k..(j0 + j + 4) * k];
                    for kk in 0..k {
                        let o = &mut chunk[kk * NR + j..kk * NR + j + 4];
                        o[0] = s0[kk];
                        o[1] = s1[kk];
                        o[2] = s2[kk];
                        o[3] = s3[kk];
                    }
                    j += 4;
                }
                for jj in j..cols {
                    let s = &b[(j0 + jj) * k..(j0 + jj + 1) * k];
                    for kk in 0..k {
                        chunk[kk * NR + jj] = s[kk];
                    }
                }
            });
        }
        PackedB { k, n, nr: NR, data }
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Packed payload of panel `p` (`k * nr` values, zero-padded).
    #[inline]
    pub fn panel(&self, p: usize) -> &[f32] {
        let sz = self.k * self.nr;
        &self.data[p * sz..(p + 1) * sz]
    }

    /// Valid (unpadded) columns of panel `p`.
    #[inline]
    pub fn panel_cols(&self, p: usize) -> usize {
        (self.n - p * self.nr).min(self.nr)
    }

    /// Resident bytes of the packed representation (incl. padding).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;
    use crate::tensor::Tensor;

    #[test]
    fn a_panel_is_exact_transpose() {
        let lda = 7;
        let (rows, k) = (3usize, 5usize);
        let a: Vec<f32> = (0..rows * lda).map(|i| i as f32).collect();
        let mut out = vec![-1.0f32; rows * k];
        pack_a_panel(&a, lda, rows, k, &mut out);
        for i in 0..rows {
            for kk in 0..k {
                assert_eq!(out[kk * rows + i], a[i * lda + kk], "({i},{kk})");
            }
        }
    }

    #[test]
    fn kt_panel_matches_a_panel_contiguous() {
        // covers the 4-row blocked body and the remainder rows
        for rows in [1usize, 3, 4, 5, 8, 11] {
            for k in [1usize, 2, 7, 16] {
                let src: Vec<f32> = (0..rows * k).map(|i| i as f32 * 0.5 - 3.0).collect();
                let mut a = vec![-1.0f32; rows * k];
                let mut b = vec![-2.0f32; rows * k];
                pack_a_panel(&src, k, rows, k, &mut a);
                pack_kt_panel(&src, rows, k, &mut b);
                assert_eq!(a, b, "rows={rows} k={k}");
            }
        }
    }

    #[test]
    fn packed_b_roundtrip_property() {
        prop::check_default("packedb-roundtrip", |rng| {
            let k = prop::usize_in(rng, 1, 20);
            let n = prop::usize_in(rng, 1, 3 * NR + 5);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let p = PackedB::pack(b.data(), k, n);
            prop_assert!(p.panels() == n.div_ceil(NR), "panel count");
            for pi in 0..p.panels() {
                let cols = p.panel_cols(pi);
                let panel = p.panel(pi);
                for kk in 0..k {
                    for j in 0..NR {
                        let want = if j < cols { b.at2(kk, pi * NR + j) } else { 0.0 };
                        prop_assert!(
                            panel[kk * NR + j] == want,
                            "panel {pi} ({kk},{j}): {} vs {want}",
                            panel[kk * NR + j]
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pack_transposed_matches_pack_of_explicit_transpose() {
        prop::check_default("packedb-transposed", |rng| {
            // n crosses the 4-row blocked body, the remainder and panel tails
            let n = prop::usize_in(rng, 1, 2 * NR + 7);
            let k = prop::usize_in(rng, 1, 24);
            let b = Tensor::randn(&[n, k], 1.0, rng);
            let via_t = PackedB::pack(b.transpose2().data(), k, n);
            let direct = PackedB::pack_transposed(b.data(), n, k);
            prop_assert!(direct.k == k && direct.n == n, "logical shape");
            prop_assert!(direct.panels() == via_t.panels(), "panel count");
            for p in 0..direct.panels() {
                prop_assert!(
                    direct.panel(p) == via_t.panel(p),
                    "panel {p} differs (n={n} k={k})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn zero_sized_matrices() {
        let p = PackedB::pack(&[], 0, 0);
        assert_eq!(p.panels(), 0);
        assert_eq!(p.bytes(), 0);
        let p = PackedB::pack(&[], 4, 0);
        assert_eq!(p.panels(), 0);
        assert_eq!(p.bytes(), 0);
    }
}
