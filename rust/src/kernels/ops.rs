//! Element-wise and row-wise operators for the native engine: activations,
//! softmax, RMSNorm/LayerNorm, RoPE. The fused variants live next to the
//! contractions in [`super::bspmm`]; these standalone forms serve the
//! attention path and the unfused baselines in the ablation benches.

#[inline(always)]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline(always)]
pub fn gelu(x: f32) -> f32 {
    // tanh approximation — matches jax.nn.gelu(approximate=True) / ref.py
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place softmax over a row.
pub fn softmax_row(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm: `x * rsqrt(mean(x²) + eps) * g`, out-of-place.
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32], eps: f32) {
    debug_assert_eq!(x.len(), g.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * r * g[i];
    }
}

/// LayerNorm (no bias, matching the L2 model): `(x-μ)/σ * g`.
pub fn layernorm(x: &[f32], g: &[f32], out: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let mu = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let r = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * r * g[i];
    }
}

/// Rotary position embedding applied in place to one head vector
/// (split-half convention, matching `model._rope`).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let a = x[i];
        let b = x[i + half];
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut row = vec![1.0, 2.0, 3.0, 4.0];
        softmax_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[3] > row[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut row = vec![1000.0, 1000.0];
        softmax_row(&mut row);
        assert!((row[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0, 4.0];
        let g = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &g, &mut out, 0.0);
        // rms = sqrt(12.5); 3/rms ≈ 0.8485
        assert!((out[0] - 3.0 / 12.5f32.sqrt()).abs() < 1e-5);
    }

    #[test]
    fn layernorm_zero_mean() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        layernorm(&x, &g, &mut out, 0.0);
        let mu: f32 = out.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
    }

    #[test]
    fn rope_pos_zero_is_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        rope_inplace(&mut x, 0, 10000.0);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![1.0, -2.0, 0.5, 3.0];
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        rope_inplace(&mut x, 17, 10000.0);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }

    #[test]
    fn activations_reference_points() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }
}
