//! Element-wise and row-wise operators for the native engine: activations,
//! softmax, RMSNorm/LayerNorm, RoPE — forward *and* backward. The fused
//! variants live next to the contractions in [`super::bspmm`]; these
//! standalone forms serve the attention path, the unfused baselines in the
//! ablation benches, and the native training backend
//! ([`crate::train::native`]), whose backward pass chains the `*_bwd`
//! operators here between the packed backward GEMMs.
//!
//! Since PR 5 the slice-level operators route through the
//! [`crate::kernels::simd`] dispatch table: softmax's max/exp/sum passes,
//! the norm reductions and the activation forward/backward lanes all run
//! on the detected AVX2/NEON arm (vector `exp` included) and fall back to
//! the scalar arm under `BLAST_SIMD=off`. The per-element scalar functions
//! ([`gelu`], [`silu`], [`gelu_grad`], [`silu_grad`]) remain the single
//! source of truth for the math and the parity oracles for every arm —
//! `bspmm.rs`'s former private copies were deduplicated into these
//! (re-exported from [`crate::kernels`]).

use crate::kernels::simd;

#[inline(always)]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline(always)]
pub fn gelu(x: f32) -> f32 {
    // tanh approximation — matches jax.nn.gelu(approximate=True) / ref.py
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Derivative of [`silu`]: `σ(x) · (1 + x · (1 − σ(x)))`.
#[inline(always)]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// Derivative of the tanh-approximated [`gelu`].
#[inline(always)]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    const A: f32 = 0.044715;
    let t = (C * (x + A * x * x * x)).tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// Fused GeLU backward over a hidden tile: `dh[i] *= gelu'(h[i])` — the
/// epilogue of the MLP backward chain (`dh = dAct ∘ gelu'(h)`), applied in
/// place on the cache-resident gradient tile. Dispatched.
pub fn gelu_bwd_inplace(h: &[f32], dh: &mut [f32]) {
    debug_assert_eq!(h.len(), dh.len());
    (simd::dispatch().gelu_bwd_slice)(h, dh);
}

/// Scalar arm of [`gelu_bwd_inplace`] (dispatch-table slot
/// `gelu_bwd_slice`; also the parity oracle).
pub(crate) fn gelu_bwd_scalar(h: &[f32], dh: &mut [f32]) {
    debug_assert_eq!(h.len(), dh.len());
    for (d, &x) in dh.iter_mut().zip(h.iter()) {
        *d *= gelu_grad(x);
    }
}

/// `v[i] = gelu(v[i])` over a slice — dispatched (vector `exp` on SIMD
/// arms). The unfused-MLP baselines and the native trainer use this.
pub fn gelu_slice(v: &mut [f32]) {
    (simd::dispatch().gelu_slice)(v);
}

/// `v[i] = silu(v[i])` over a slice — dispatched.
pub fn silu_slice(v: &mut [f32]) {
    (simd::dispatch().silu_slice)(v);
}

/// SwiGLU gate over a slice: `a[i] = silu(a[i]) * g[i]` — dispatched.
pub fn silu_gate_slice(a: &mut [f32], g: &[f32]) {
    debug_assert_eq!(a.len(), g.len());
    (simd::dispatch().silu_gate_slice)(a, g);
}

/// SwiGLU backward over a hidden tile — dispatched:
/// `dh1 = d_act ∘ h2 ∘ silu'(h1)`, `dh2 = d_act ∘ silu(h1)`.
pub fn swiglu_bwd_slice(h1: &[f32], h2: &[f32], d_act: &[f32], dh1: &mut [f32], dh2: &mut [f32]) {
    debug_assert!(h1.len() == h2.len() && h1.len() == d_act.len());
    debug_assert!(h1.len() == dh1.len() && h1.len() == dh2.len());
    (simd::dispatch().swiglu_bwd_slice)(h1, h2, d_act, dh1, dh2);
}

/// In-place softmax over a row — dispatched three-pass kernel (row max,
/// shifted exp + sum, normalize), each pass on the active SIMD arm.
pub fn softmax_row(row: &mut [f32]) {
    let d = simd::dispatch();
    let max = (d.row_max)(row);
    let sum = (d.exp_shift_sum)(row, max);
    (d.scale_slice)(row, 1.0 / sum);
}

/// Scalar reference softmax (the pre-dispatch implementation, fused
/// single pass) — kept as the oracle the dispatched kernel is tested
/// against.
pub fn softmax_row_scalar(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm: `x * rsqrt(mean(x²) + eps) * g`, out-of-place. The
/// mean-square reduction is dispatched; the normalize loop stays scalar
/// (three-stream bandwidth-bound).
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32], eps: f32) {
    debug_assert_eq!(x.len(), g.len());
    let ms = (simd::dispatch().sumsq_shift_slice)(x, 0.0) / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * r * g[i];
    }
}

/// LayerNorm (no bias, matching the L2 model): `(x-μ)/σ * g`. Both
/// reductions (mean, shifted sum of squares) are dispatched.
pub fn layernorm(x: &[f32], g: &[f32], out: &mut [f32], eps: f32) {
    let d = simd::dispatch();
    let n = x.len() as f32;
    let mu = (d.sum_slice)(x) / n;
    let var = (d.sumsq_shift_slice)(x, mu) / n;
    let r = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * r * g[i];
    }
}

/// Rotary position embedding applied in place to one head vector
/// (split-half convention, matching `model._rope`).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let a = x[i];
        let b = x[i + half];
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// Transpose (inverse) rotation of [`rope_inplace`] — backprop through
/// RoPE. The forward is an orthogonal per-pair rotation, so the Jacobian
/// transpose is the rotation by `-angle`; applying this to the gradient of
/// a post-RoPE vector yields the gradient of the pre-RoPE vector.
pub fn rope_bwd_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let a = x[i];
        let b = x[i + half];
        x[i] = a * cos + b * sin;
        x[i + half] = -a * sin + b * cos;
    }
}

/// LayerNorm backward for one row. Forward: `y = (x − μ)/σ · g` (see
/// [`layernorm`]). Given `dy`, **accumulates** `dL/dx` into `dx` and
/// `dL/dg` into `dg` (callers zero the buffers once per pass and sum over
/// rows for the gain gradient).
pub fn layernorm_bwd(x: &[f32], g: &[f32], dy: &[f32], dx: &mut [f32], dg: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let mu = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let r = 1.0 / (var + eps).sqrt();
    // dyh = dy ∘ g; dx = r · (dyh − mean(dyh) − x̂ · mean(dyh ∘ x̂))
    let mut mean_dyh = 0.0f32;
    let mut mean_dyh_xhat = 0.0f32;
    for i in 0..x.len() {
        let xhat = (x[i] - mu) * r;
        let dyh = dy[i] * g[i];
        mean_dyh += dyh;
        mean_dyh_xhat += dyh * xhat;
        dg[i] += dy[i] * xhat;
    }
    mean_dyh /= n;
    mean_dyh_xhat /= n;
    for i in 0..x.len() {
        let xhat = (x[i] - mu) * r;
        dx[i] += r * (dy[i] * g[i] - mean_dyh - xhat * mean_dyh_xhat);
    }
}

/// RMSNorm backward for one row. Forward: `y = x · rsqrt(mean(x²)+eps) · g`
/// (see [`rmsnorm`]). Accumulates `dL/dx` into `dx` and `dL/dg` into `dg`.
pub fn rmsnorm_bwd(x: &[f32], g: &[f32], dy: &[f32], dx: &mut [f32], dg: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let ms = x.iter().map(|v| v * v).sum::<f32>() / n;
    let r = 1.0 / (ms + eps).sqrt();
    // dx_j = r·dy_j·g_j − (r³/n · Σ_i dy_i g_i x_i) · x_j
    let mut dot = 0.0f32;
    for i in 0..x.len() {
        dot += dy[i] * g[i] * x[i];
        dg[i] += dy[i] * x[i] * r;
    }
    let c = r * r * r / n * dot;
    for i in 0..x.len() {
        dx[i] += r * dy[i] * g[i] - c * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut row = vec![1.0, 2.0, 3.0, 4.0];
        softmax_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[3] > row[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut row = vec![1000.0, 1000.0];
        softmax_row(&mut row);
        assert!((row[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0, 4.0];
        let g = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &g, &mut out, 0.0);
        // rms = sqrt(12.5); 3/rms ≈ 0.8485
        assert!((out[0] - 3.0 / 12.5f32.sqrt()).abs() < 1e-5);
    }

    #[test]
    fn layernorm_zero_mean() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        layernorm(&x, &g, &mut out, 0.0);
        let mu: f32 = out.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
    }

    #[test]
    fn rope_pos_zero_is_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        rope_inplace(&mut x, 0, 10000.0);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![1.0, -2.0, 0.5, 3.0];
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        rope_inplace(&mut x, 17, 10000.0);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }

    #[test]
    fn activations_reference_points() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn activation_grads_match_finite_differences() {
        let eps = 1e-3f32;
        for i in -20..=20 {
            let x = i as f32 * 0.25;
            let fd_g = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!(
                (fd_g - gelu_grad(x)).abs() < 1e-3,
                "gelu'({x}): fd {fd_g} vs {}",
                gelu_grad(x)
            );
            let fd_s = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!(
                (fd_s - silu_grad(x)).abs() < 1e-3,
                "silu'({x}): fd {fd_s} vs {}",
                silu_grad(x)
            );
        }
    }

    #[test]
    fn gelu_bwd_inplace_applies_derivative() {
        let h = vec![-2.0f32, -0.5, 0.0, 0.7, 3.0];
        let mut dh = vec![1.0f32; 5];
        gelu_bwd_inplace(&h, &mut dh);
        for (i, &x) in h.iter().enumerate() {
            assert!((dh[i] - gelu_grad(x)).abs() < 1e-7);
        }
    }

    #[test]
    fn dispatched_softmax_matches_scalar_oracle() {
        for n in [1usize, 2, 7, 8, 9, 31, 64, 65] {
            let mut a: Vec<f32> = (0..n).map(|i| ((i * 37 % 11) as f32 - 5.0) * 0.7).collect();
            let mut b = a.clone();
            softmax_row(&mut a);
            softmax_row_scalar(&mut b);
            let mut sum = 0.0f32;
            for i in 0..n {
                assert!(
                    (a[i] - b[i]).abs() < 2e-6,
                    "n={n} [{i}]: {} vs {}",
                    a[i],
                    b[i]
                );
                sum += a[i];
            }
            assert!((sum - 1.0).abs() < 1e-5, "n={n} sum {sum}");
        }
    }

    #[test]
    fn slice_helpers_match_scalar_formulas() {
        let n = 21; // exercises vector body + tail on any arm
        let x: Vec<f32> = (0..n).map(|i| (i as f32 - 10.0) * 0.4).collect();
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut v = x.clone();
        gelu_slice(&mut v);
        for i in 0..n {
            assert!((v[i] - gelu(x[i])).abs() < 2e-6, "gelu[{i}]");
        }
        let mut v = x.clone();
        silu_slice(&mut v);
        for i in 0..n {
            assert!((v[i] - silu(x[i])).abs() < 2e-6, "silu[{i}]");
        }
        let mut v = x.clone();
        silu_gate_slice(&mut v, &g);
        for i in 0..n {
            assert!((v[i] - silu(x[i]) * g[i]).abs() < 2e-6, "silu_gate[{i}]");
        }
        let da: Vec<f32> = (0..n).map(|i| 0.5 - (i % 5) as f32 * 0.2).collect();
        let mut dh1 = vec![0.0f32; n];
        let mut dh2 = vec![0.0f32; n];
        swiglu_bwd_slice(&x, &g, &da, &mut dh1, &mut dh2);
        for i in 0..n {
            let w1 = da[i] * g[i] * silu_grad(x[i]);
            let w2 = da[i] * silu(x[i]);
            assert!((dh1[i] - w1).abs() < 2e-6, "swiglu dh1[{i}]");
            assert!((dh2[i] - w2).abs() < 2e-6, "swiglu dh2[{i}]");
        }
    }

    #[test]
    fn rope_bwd_is_inverse_rotation() {
        let orig = vec![1.0f32, -2.0, 0.5, 3.0, -0.25, 1.5];
        let mut x = orig.clone();
        rope_inplace(&mut x, 23, 10000.0);
        rope_bwd_inplace(&mut x, 23, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    /// Numeric check of both norm backward rules: perturb each input
    /// coordinate and compare `⟨dy, Δy⟩/ε` against the analytic `dx`.
    #[test]
    fn norm_backward_matches_finite_differences() {
        let x = vec![0.3f32, -1.2, 2.0, 0.05, -0.7, 1.4];
        let g = vec![1.1f32, 0.9, -0.5, 1.3, 0.2, 1.0];
        let dy = vec![0.25f32, -1.0, 0.5, 0.8, -0.3, 0.1];
        let n = x.len();
        let eps = 1e-3f32;
        for kind in [0, 1] {
            let fwd = |xx: &[f32], out: &mut [f32]| {
                if kind == 0 {
                    layernorm(xx, &g, out, 1e-5)
                } else {
                    rmsnorm(xx, &g, out, 1e-5)
                }
            };
            let mut dx = vec![0.0f32; n];
            let mut dg = vec![0.0f32; n];
            if kind == 0 {
                layernorm_bwd(&x, &g, &dy, &mut dx, &mut dg, 1e-5);
            } else {
                rmsnorm_bwd(&x, &g, &dy, &mut dx, &mut dg, 1e-5);
            }
            let mut yp = vec![0.0f32; n];
            let mut ym = vec![0.0f32; n];
            for j in 0..n {
                let mut xp = x.clone();
                xp[j] += eps;
                let mut xm = x.clone();
                xm[j] -= eps;
                fwd(&xp, &mut yp);
                fwd(&xm, &mut ym);
                let fd: f32 = yp
                    .iter()
                    .zip(&ym)
                    .zip(&dy)
                    .map(|((a, b), d)| d * (a - b) / (2.0 * eps))
                    .sum();
                assert!(
                    (fd - dx[j]).abs() < 2e-3,
                    "kind {kind} dx[{j}]: fd {fd} vs {}",
                    dx[j]
                );
            }
            // gain gradient: dg[j] = dy[j] * normalized(x)[j]
            let mut y1 = vec![0.0f32; n];
            fwd(&x, &mut y1);
            for j in 0..n {
                let want = dy[j] * y1[j] / g[j];
                assert!((dg[j] - want).abs() < 1e-4, "kind {kind} dg[{j}]");
            }
        }
    }
}
