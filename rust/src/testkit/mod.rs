//! Test & benchmark harnesses (criterion / proptest stand-ins for the
//! offline environment). Used by `benches/*` and by property tests across
//! the crate.

pub mod bench;
pub mod prop;
