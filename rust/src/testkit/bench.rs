//! Benchmark harness (criterion stand-in).
//!
//! Methodology follows Hoefler & Belli ("Scientific benchmarking of parallel
//! computing systems"): warmup until steady state, fixed repetition count,
//! report median + MAD (robust), never a bare mean. Each paper-figure bench
//! builds a [`Table`] whose rows mirror the figure's series so
//! `cargo bench` output can be diffed against the paper directly.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Result of one measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub reps: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// FLOP/s given the per-iteration flop count.
    pub fn flops(&self, flop: f64) -> f64 {
        flop / self.secs()
    }
}

/// Time `f` with automatic batching so the measured quantum is ≥ ~1ms.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_cfg(name, Duration::from_millis(300), 7, &mut f)
}

/// Quick variant for cheap smoke benches.
pub fn bench_quick<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_cfg(name, Duration::from_millis(60), 5, &mut f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    budget: Duration,
    reps: usize,
    f: &mut F,
) -> Measurement {
    // 1. warmup + calibration: find iters/rep so one rep is >= 1ms
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (Duration::from_millis(1).as_nanos() / one.as_nanos()).max(1) as usize;
    // cap total time at budget
    let per_rep = one * iters as u32;
    let max_reps = ((budget.as_nanos() / per_rep.as_nanos().max(1)) as usize).max(3);
    let reps = reps.min(max_reps).max(3);

    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    Measurement {
        name: name.to_string(),
        median_ns: stats::median(&samples),
        mad_ns: stats::mad(&samples),
        reps,
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Paper-style results table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Format seconds human-readably.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.2}s")
    }
}

/// Format FLOP/s human-readably.
pub fn fmt_flops(f: f64) -> String {
    if f >= 1e12 {
        format!("{:.2} TFLOP/s", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2} GFLOP/s", f / 1e9)
    } else {
        format!("{:.2} MFLOP/s", f / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let m = bench_quick("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(m.median_ns > 0.0);
        assert!(m.reps >= 3);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("us"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_flops(3e12).contains("TFLOP"));
    }
}
