//! Benchmark harness (criterion stand-in).
//!
//! Methodology follows Hoefler & Belli ("Scientific benchmarking of parallel
//! computing systems"): warmup until steady state, fixed repetition count,
//! report median + MAD (robust), never a bare mean. Each paper-figure bench
//! builds a [`Table`] whose rows mirror the figure's series so
//! `cargo bench` output can be diffed against the paper directly.
//!
//! [`JsonReport`] adds a machine-readable sink: benches append structured
//! rows and write a `BENCH_<name>.json` file, so perf trajectories can be
//! tracked across PRs (the kernel A/B harness writes `BENCH_kernels.json`).

use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Result of one measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub reps: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// FLOP/s given the per-iteration flop count.
    pub fn flops(&self, flop: f64) -> f64 {
        flop / self.secs()
    }

    /// Structured form for [`JsonReport`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("median_ns", Json::num(self.median_ns)),
            ("mad_ns", Json::num(self.mad_ns)),
            ("reps", Json::num(self.reps as f64)),
        ])
    }
}

/// Time `f` with automatic batching so the measured quantum is ≥ ~1ms.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_cfg(name, Duration::from_millis(300), 7, &mut f)
}

/// Quick variant for cheap smoke benches.
pub fn bench_quick<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_cfg(name, Duration::from_millis(60), 5, &mut f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    budget: Duration,
    reps: usize,
    f: &mut F,
) -> Measurement {
    // 1. warmup + calibration: find iters/rep so one rep is >= 1ms
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (Duration::from_millis(1).as_nanos() / one.as_nanos()).max(1) as usize;
    // cap total time at budget
    let per_rep = one * iters as u32;
    let max_reps = ((budget.as_nanos() / per_rep.as_nanos().max(1)) as usize).max(3);
    let reps = reps.min(max_reps).max(3);

    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    Measurement {
        name: name.to_string(),
        median_ns: stats::median(&samples),
        mad_ns: stats::mad(&samples),
        reps,
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Paper-style results table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Machine-readable bench report: structured rows + free-form metadata,
/// serialized with the in-tree JSON writer to a `BENCH_<name>.json` file.
pub struct JsonReport {
    name: String,
    meta: Vec<(String, Json)>,
    results: Vec<Json>,
}

impl JsonReport {
    pub fn new(name: &str) -> JsonReport {
        JsonReport {
            name: name.to_string(),
            meta: Vec::new(),
            results: Vec::new(),
        }
    }

    /// Attach a top-level metadata field (host info, config, git rev …).
    pub fn meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    /// Append one structured result row.
    pub fn push(&mut self, row: Json) {
        self.results.push(row);
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("bench", Json::str(&self.name))];
        for (k, v) in &self.meta {
            fields.push((k.as_str(), v.clone()));
        }
        fields.push(("results", Json::Arr(self.results.clone())));
        Json::obj(fields)
    }

    /// Write the report; errors surface to the caller (bench drivers treat
    /// an unwritable report as a failure, not a silent skip).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// Format seconds human-readably.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.2}s")
    }
}

/// Format FLOP/s human-readably.
pub fn fmt_flops(f: f64) -> String {
    if f >= 1e12 {
        format!("{:.2} TFLOP/s", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2} GFLOP/s", f / 1e9)
    } else {
        format!("{:.2} MFLOP/s", f / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let m = bench_quick("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(m.median_ns > 0.0);
        assert!(m.reps >= 3);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("us"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_flops(3e12).contains("TFLOP"));
    }

    #[test]
    fn json_report_roundtrips_through_parser() {
        let mut r = JsonReport::new("kernels");
        r.meta("threads", Json::num(8.0));
        r.push(Json::obj(vec![
            ("kernel", Json::str("gemm")),
            ("speedup", Json::num(1.75)),
        ]));
        assert_eq!(r.len(), 1);
        assert!(!r.is_empty());
        let parsed = Json::parse(&r.to_json().dump()).unwrap();
        assert_eq!(parsed.req("bench").as_str(), Some("kernels"));
        assert_eq!(parsed.req("threads").as_f64(), Some(8.0));
        let rows = parsed.req("results").as_arr().unwrap();
        assert_eq!(rows[0].req("speedup").as_f64(), Some(1.75));

        let path = std::env::temp_dir().join("blast_bench_report_test.json");
        r.write(&path).unwrap();
        let txt = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&txt).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn measurement_to_json_fields() {
        let m = bench_quick("spin2", || {
            black_box(1 + 1);
        });
        let j = m.to_json();
        assert_eq!(j.req("name").as_str(), Some("spin2"));
        assert!(j.req("median_ns").as_f64().unwrap() >= 0.0);
    }
}
