//! Property-testing harness (proptest stand-in).
//!
//! Seeded random-case generation with failure reproduction: on failure the
//! harness re-runs the generator deterministically to shrink scalar inputs
//! (halving toward the minimum) and reports the failing seed so the case
//! can be pinned as a regression test.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed can be overridden for reproduction via BLAST_PROP_SEED.
        let seed = std::env::var("BLAST_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xB1A5_7000);
        Config { cases: 64, seed }
    }
}

/// Run `prop(rng)` for `cfg.cases` independent seeds; panic with the failing
/// case number + seed on the first failure.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cfg: Config, prop: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{} (seed {case_seed:#x}, \
                 rerun with BLAST_PROP_SEED={}): {msg}",
                cfg.cases, cfg.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Shorthand with the default config.
pub fn check_default<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, prop: F) {
    check(name, Config::default(), prop);
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Generators -----------------------------------------------------------

/// Uniform usize in [lo, hi] (inclusive).
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Pick one element of a slice.
pub fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len())]
}

/// Random f32 vec with standard-normal entries.
pub fn normal_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    rng.normal_vec(n, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("add-commutes", |rng| {
            let a = rng.f32();
            let b = rng.f32();
            prop_assert!((a + b - (b + a)).abs() < 1e-9, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check(
            "always-fails",
            Config { cases: 3, seed: 1 },
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn generators_in_range() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            let x = usize_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&x));
        }
        let v = [1, 2, 3];
        assert!(v.contains(pick(&mut rng, &v)));
    }
}
