//! Threaded serving front-end: a scheduler thread drives the continuous
//! batcher over engine sessions; clients submit requests through a bounded
//! channel and receive completions on another.
//!
//! Each active session owns a KV cache; the shared block-sparse weights
//! live in one `Arc<Engine>`. Decode rounds touch every active session
//! once (continuous batching), so short requests retire early and free
//! their slot for waiting requests — the Orca/vLLM scheduling shape, with
//! the paper's sparse MLP on the hot path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::router::{Batcher, BatcherConfig, Request};
use crate::model::engine::{Engine, KvCache};

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queue_secs: f64,
    pub ttft_secs: f64,
    pub e2e_secs: f64,
    pub error: Option<String>,
}

struct Timing {
    submitted: Instant,
    admitted: Option<Instant>,
    first_token: Option<Instant>,
}

pub struct Coordinator {
    tx: SyncSender<Request>,
    completions: Receiver<Completion>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServeMetrics>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the scheduler over an engine.
    pub fn start(engine: Arc<Engine>, cfg: BatcherConfig) -> Coordinator {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.max_queue);
        let (ctx, crx) = mpsc::channel::<Completion>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServeMetrics::new()));
        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            scheduler_loop(engine, cfg, rx, ctx, stop2, metrics2);
        });
        Coordinator {
            tx,
            completions: crx,
            stop,
            metrics,
            worker: Some(worker),
        }
    }

    /// Submit a request; `Err` = queue full (backpressure) or shut down.
    pub fn submit(&self, req: Request) -> Result<()> {
        match self.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => anyhow::bail!("queue full, rejected request {}", r.id),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("coordinator stopped"),
        }
    }

    /// Block for the next completion.
    pub fn next_completion(&self, timeout: Duration) -> Option<Completion> {
        self.completions.recv_timeout(timeout).ok()
    }

    pub fn metrics_summary(&self) -> String {
        self.metrics.lock().unwrap().summary()
    }

    pub fn throughput(&self) -> f64 {
        self.metrics.lock().unwrap().throughput()
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

fn scheduler_loop(
    engine: Arc<Engine>,
    cfg: BatcherConfig,
    rx: Receiver<Request>,
    ctx: Sender<Completion>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServeMetrics>>,
) {
    let mut batcher = Batcher::new(cfg);
    let mut caches: HashMap<u64, KvCache> = HashMap::new();
    let mut timing: HashMap<u64, Timing> = HashMap::new();
    while !stop.load(Ordering::Relaxed) {
        // drain the submission channel into the waiting queue
        loop {
            match rx.recv_timeout(if batcher.idle() {
                Duration::from_millis(20)
            } else {
                Duration::ZERO
            }) {
                Ok(req) => {
                    timing.insert(
                        req.id,
                        Timing {
                            submitted: Instant::now(),
                            admitted: None,
                            first_token: None,
                        },
                    );
                    if !batcher.enqueue(req) {
                        // bounded-queue overflow (should not happen: the
                        // channel is the same size) — report as error
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if batcher.idle() {
                        return;
                    }
                    break;
                }
            }
        }

        if batcher.idle() {
            continue;
        }

        // admit + prefill new sessions
        for idx in batcher.admit() {
            let s = &mut batcher.active_mut()[idx];
            let id = s.req.id;
            if let Some(t) = timing.get_mut(&id) {
                t.admitted = Some(Instant::now());
            }
            let mut cache = engine.new_cache();
            match engine.prefill(&s.req.prompt, &mut cache) {
                Ok(logits) => {
                    let tok = Engine::argmax(&logits);
                    s.output.push(tok);
                    s.prefilled = true;
                    if let Some(t) = timing.get_mut(&id) {
                        t.first_token = Some(Instant::now());
                    }
                    caches.insert(id, cache);
                }
                Err(e) => {
                    ctx.send(Completion {
                        id,
                        tokens: vec![],
                        queue_secs: 0.0,
                        ttft_secs: 0.0,
                        e2e_secs: 0.0,
                        error: Some(e.to_string()),
                    })
                    .ok();
                    s.output = vec![0; s.req.max_new]; // force retirement
                    s.prefilled = true;
                }
            }
        }

        // one continuous-batching decode round
        for s in batcher.active_mut() {
            if !s.prefilled || s.finished() {
                continue;
            }
            let id = s.req.id;
            let cache = caches.get_mut(&id).unwrap();
            let last = *s.output.last().unwrap();
            match engine.decode(last, cache) {
                Ok(logits) => s.output.push(Engine::argmax(&logits)),
                Err(_) => {
                    // KV exhausted → finish what we have
                    s.req.max_new = s.output.len();
                }
            }
        }

        // retire finished sessions
        for s in batcher.end_round() {
            let id = s.req.id;
            caches.remove(&id);
            let t = timing.remove(&id);
            let now = Instant::now();
            let (queue_secs, ttft_secs, e2e_secs) = match &t {
                Some(t) => (
                    t.admitted
                        .map(|a| (a - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    t.first_token
                        .map(|f| (f - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    (now - t.submitted).as_secs_f64(),
                ),
                None => (0.0, 0.0, 0.0),
            };
            metrics.lock().unwrap().record_request(
                queue_secs,
                ttft_secs,
                e2e_secs,
                s.req.prompt.len(),
                s.output.len(),
            );
            ctx.send(Completion {
                id,
                tokens: s.output,
                queue_secs,
                ttft_secs,
                e2e_secs,
                error: None,
            })
            .ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelKind, NativeConfig};
    use crate::model::engine::MlpMode;
    use crate::model::params::ParamStore;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn tiny_engine() -> Arc<Engine> {
        let cfg = NativeConfig {
            name: "t".into(),
            kind: ModelKind::Llama,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 1,
            heads: 2,
            max_seq: 32,
            block: 8,
        };
        let mut rng = Rng::new(1);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        Arc::new(Engine::new(cfg, &s, &BTreeMap::new(), MlpMode::Sparse).unwrap())
    }

    #[test]
    fn serves_batch_of_requests_end_to_end() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 16,
            },
        );
        let n = 8;
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 5,
                    eos: None,
                })
                .unwrap();
        }
        let mut done = Vec::new();
        for _ in 0..n {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .expect("completion");
            assert!(c.error.is_none(), "{:?}", c.error);
            assert_eq!(c.tokens.len(), 5);
            assert!(c.e2e_secs >= c.ttft_secs);
            done.push(c.id);
        }
        done.sort_unstable();
        assert_eq!(done, (0..n).collect::<Vec<_>>());
        coord.stop();
    }

    #[test]
    fn identical_prompts_get_identical_outputs() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        for i in 0..2 {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![4, 4, 4],
                    max_new: 6,
                    eos: None,
                })
                .unwrap();
        }
        let a = coord.next_completion(Duration::from_secs(30)).unwrap();
        let b = coord.next_completion(Duration::from_secs(30)).unwrap();
        assert_eq!(a.tokens, b.tokens, "greedy decode must be deterministic");
        coord.stop();
    }

    #[test]
    fn overlong_prompt_reports_error() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1; 100],
                max_new: 4,
                eos: None,
            })
            .unwrap();
        let c = coord.next_completion(Duration::from_secs(30)).unwrap();
        assert!(c.error.is_some());
        coord.stop();
    }
}
